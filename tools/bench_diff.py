"""Compare a fresh ``BENCH_sweeps.json`` against the committed baseline.

The benchmark artifact accumulates one record per sweep (spec + per-cell
mean/std + wall time + backend).  CI regenerates it every run; this tool
makes that regeneration a *gate* instead of a log: records are matched on
``(kind, canonical spec hash, backend)`` and a matched pair fails the diff
when

- its sweep wall time regressed by more than ``--max-time-ratio`` (default
  1.30, i.e. >30%) — only when the baseline wall is above ``--min-wall``
  (default 0.5s; sub-second smoke cells are timer noise, not signal); or
- any per-cell metric *mean* drifted beyond ``--rtol``/``--atol`` for
  ``kind == "sweep"`` records (sweeps are seeded and deterministic per
  backend, so drift means the simulator's outputs changed, not the machine).
  A drift failure prints a per-cell regression table (policy, metric, cell
  index, baseline vs new mean, relative error) covering *every*
  out-of-tolerance cell, so one CI run shows the full shape of a
  regression instead of its first symptom.

Streaming-lane records participate like any other: a streaming sweep's
``stream`` config (slot-pool size, window fractions) is part of the spec
hash, so ladders at different ``n_slots``/windows are distinct lanes, and
record labels carry ``slots=``/``w=[...]`` so reports are tellable apart.

Spec hashing is canonical: falsy entries are dropped before hashing so a
baseline written before a spec field existed (e.g. ``fused`` or
``telemetry``) still matches a new record carrying the field at its
default.  Provenance stamps (``schema_version`` at the artifact top level,
a per-record ``provenance`` dict with git SHA / jax versions / UTC
timestamp — schema v2) are ignored for matching, so pre-v2 baselines
without them parse and gate exactly as before; a schema-version mismatch
between the two files is surfaced as a note.  Baseline records with no
counterpart are reported as lost coverage (warning, not failure — sections
come and go); new records with no baseline are simply new.

``python -m tools.bench_diff BASELINE NEW [--max-time-ratio 1.3]
[--min-wall 0.5] [--rtol 1e-6] [--atol 1e-12]`` — exit 1 on failure.
"""

from __future__ import annotations

import hashlib
import json
import sys


def spec_key(rec: dict) -> str:
    """``(kind, spec-hash, backend)`` identity of a benchmark record.

    The spec dict is canonicalized by dropping falsy values (None/False/0/
    empty) so field additions with falsy defaults don't orphan old
    baselines, then hashed over sorted keys.
    """
    spec = rec.get("spec", {})
    canon = {k: v for k, v in sorted(spec.items()) if v}
    blob = json.dumps(canon, sort_keys=True)
    h = hashlib.sha256(blob.encode()).hexdigest()[:16]
    return f"{rec.get('kind', 'bench')}:{h}:{rec.get('backend', '?')}"


def _label(rec: dict) -> str:
    spec = rec.get("spec", {})
    bits = [rec.get("kind", "bench")]
    if rec.get("lane"):
        bits.append(f"lane={rec['lane']}")
    if "scenario" in spec:
        bits.append(str(spec.get("scenario")))
    if spec.get("n_jobs"):
        bits.append(f"M={spec['n_jobs']}")
    if spec.get("n_chips"):
        bits.append(f"chips={spec['n_chips']}")
    if spec.get("fused"):
        bits.append("fused")
    if spec.get("arm"):
        bits.append(str(spec["arm"]))
    if spec.get("classes"):
        bits.append(f"K={len(spec['classes'])}")
    if spec.get("stream"):  # streaming sweep: label carries the slot/window
        skw = dict(spec["stream"])  # config so lanes are tellable apart
        bits.append(f"slots={skw.get('n_slots')}")
        if "warmup_frac" in skw or "end_frac" in skw:
            bits.append(
                f"w=[{skw.get('warmup_frac', 0.1)},{skw.get('end_frac', 0.9)}]"
            )
    if spec.get("n_slots"):  # dict-spec streaming rows (horizon scaling)
        bits.append(f"slots={spec['n_slots']}")
    bits.append(rec.get("backend", "?"))
    return " ".join(bits)


def _index(records: list[dict]) -> dict[str, dict]:
    out: dict[str, dict] = {}
    for rec in records:
        out[spec_key(rec)] = rec  # same-key reruns: last one wins
    return out


def _metric_drifts(base: dict, new: dict, rtol: float, atol: float):
    """Every drifting cell between two matched ``kind=="sweep"`` records.

    Returns ``(policy, metric, cell, base_mean, new_mean)`` rows — one per
    out-of-tolerance cell, not just the first, so the failure report is a
    complete regression table.  ``cell is None`` flags a shape/coverage
    change (missing policy/metric or a cell-count mismatch)."""
    drifts = []
    for policy, by_metric in (base.get("cells") or {}).items():
        new_by_metric = (new.get("cells") or {}).get(policy)
        if new_by_metric is None:
            drifts.append((policy, "<missing policy>", None, None, None))
            continue
        for metric, stats in by_metric.items():
            new_stats = new_by_metric.get(metric)
            if new_stats is None:
                drifts.append((policy, metric, None, None, None))
                continue
            b, n = _flat(stats["mean"]), _flat(new_stats["mean"])
            if len(b) != len(n):
                drifts.append((policy, metric, None, None, None))
                continue
            for i, (bv, nv) in enumerate(zip(b, n, strict=True)):
                if abs(nv - bv) > atol + rtol * abs(bv):
                    drifts.append((policy, metric, i, bv, nv))
    return drifts


def _drift_table(drifts) -> list[str]:
    """Aligned per-cell rows for a drift failure report."""
    rows = [f"{'policy':<10s} {'metric':<22s} {'cell':>4s} "
            f"{'baseline':>14s} {'new':>14s} {'rel-err':>9s}"]
    for policy, metric, i, bv, nv in drifts:
        if i is None:
            rows.append(f"{policy:<10s} {metric:<22s} {'-':>4s} "
                        "shape/coverage changed")
        else:
            rel = abs(nv - bv) / max(abs(bv), 1e-300)
            rows.append(f"{policy:<10s} {metric:<22s} {i:4d} "
                        f"{bv:14.6g} {nv:14.6g} {rel:9.2e}")
    return rows


def _flat(x) -> list[float]:
    if isinstance(x, (int, float)):
        return [float(x)]
    out: list[float] = []
    for v in x:
        out.extend(_flat(v))
    return out


def diff(base_records: list[dict], new_records: list[dict], *,
         max_time_ratio: float = 1.30, min_wall: float = 0.5,
         rtol: float = 1e-6, atol: float = 1e-12) -> tuple[list[str], list[str]]:
    """Returns ``(failures, notes)`` — empty ``failures`` means pass."""
    base_ix = _index(base_records)
    new_ix = _index(new_records)
    failures: list[str] = []
    notes: list[str] = []

    for key, base in base_ix.items():
        new = new_ix.get(key)
        label = _label(base)
        if new is None:
            notes.append(f"coverage lost (no new record): {label}")
            continue
        bw, nw = float(base.get("wall_s") or 0.0), float(new.get("wall_s") or 0.0)
        if bw >= min_wall and nw > bw * max_time_ratio:
            failures.append(
                f"wall-time regression {nw / bw:.2f}x "
                f"(>{max_time_ratio:.2f}x): {label} "
                f"[{bw:.2f}s -> {nw:.2f}s]"
            )
        elif bw > 0:
            notes.append(f"wall {nw / bw:.2f}x ({bw:.2f}s -> {nw:.2f}s): {label}")
        if base.get("kind") == "sweep":
            drifts = _metric_drifts(base, new, rtol, atol)
            if drifts:
                n_cells = sum(1 for d in drifts if d[2] is not None)
                n_shape = len(drifts) - n_cells
                head = (f"metric drift: {label} — {n_cells} cell(s) "
                        f"out of tolerance")
                if n_shape:
                    head += f", {n_shape} shape/coverage change(s)"
                failures.append("\n".join([head, *_drift_table(drifts)]))
    for key, new in new_ix.items():
        if key not in base_ix:
            notes.append(f"new record (no baseline): {_label(new)}")
    return failures, notes


def _load(path: str) -> tuple[list[dict], int | None]:
    """Read an artifact, tolerating every vintage of the format: a bare
    record list (pre-``records``-key), an unstamped ``{"records": [...]}``
    (schema v1, implicit), and the stamped v2+ form.  Returns
    ``(records, schema_version)`` with ``None`` for unstamped files."""
    with open(path) as f:
        doc = json.load(f)
    if isinstance(doc, list):
        return doc, None
    version = doc.get("schema_version")
    return doc.get("records", []), int(version) if version is not None else None


def main(argv: list[str]) -> int:
    argv = list(argv)

    def opt(name: str, default: float) -> float:
        flag = f"--{name}"
        if flag not in argv:
            return default
        i = argv.index(flag)
        value = float(argv[i + 1])
        del argv[i : i + 2]
        return value

    kw = dict(
        max_time_ratio=opt("max-time-ratio", 1.30),
        min_wall=opt("min-wall", 0.5),
        rtol=opt("rtol", 1e-6), atol=opt("atol", 1e-12),
    )
    if len(argv) != 2:
        print("usage: python -m tools.bench_diff BASELINE NEW "
              "[--max-time-ratio R] [--min-wall S] [--rtol R] [--atol A]")
        return 2
    base_records, base_schema = _load(argv[0])
    new_records, new_schema = _load(argv[1])
    failures, notes = diff(base_records, new_records, **kw)
    if base_schema != new_schema:
        notes.append(
            f"schema_version: baseline={base_schema!r} new={new_schema!r} "
            "(records matched on spec, stamps ignored)"
        )
    for line in notes:
        print(f"  note: {line}")
    for line in failures:
        head, *rest = line.split("\n")
        print(f"  FAIL: {head}")
        for row in rest:
            print(f"        {row}")
    print(f"bench-diff: {len(failures)} failure(s), {len(notes)} note(s)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
