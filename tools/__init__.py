"""Repo tooling that is neither library (src/) nor benchmark (benchmarks/)."""
