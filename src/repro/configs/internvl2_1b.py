"""internvl2-1b — VLM: InternViT frontend (stub) + InternLM2 backbone,
GQA(14q/2kv). [arXiv:2404.16821; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,  # d_model / n_heads
    d_ff=4864,
    vocab_size=151655,
    n_patches=256,  # precomputed patch embeddings from the stubbed ViT
    source="[arXiv:2404.16821; hf]",
)
