"""Architecture & shape registry.

``get_config(arch_id)`` returns the exact published full-size config;
``smoke_config(arch_id)`` returns a reduced config of the same family that
runs a forward/train step on one CPU device in a test.
"""

from __future__ import annotations

from repro.configs import (
    internvl2_1b,
    mamba2_130m,
    mixtral_8x7b,
    phi4_mini,
    qwen15_110b,
    qwen25_14b,
    qwen3_moe_235b,
    recurrentgemma_9b,
    stablelm_12b,
    whisper_base,
)
from repro.configs.base import (
    SHAPE_BY_NAME,
    SHAPES,
    ModelConfig,
    ShapeConfig,
    cell_applicable,
)

_REGISTRY = {
    m.CONFIG.name: m.CONFIG
    for m in (
        qwen25_14b,
        phi4_mini,
        stablelm_12b,
        qwen15_110b,
        mamba2_130m,
        internvl2_1b,
        recurrentgemma_9b,
        mixtral_8x7b,
        qwen3_moe_235b,
        whisper_base,
    )
}

ARCH_IDS = tuple(_REGISTRY)


def get_config(arch_id: str) -> ModelConfig:
    try:
        return _REGISTRY[arch_id]
    except KeyError:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}") from None


def smoke_config(arch_id: str) -> ModelConfig:
    """Reduced config of the same family: few layers, narrow width, tiny
    vocab, few experts — runs a fwd/train step on one CPU device."""
    cfg = get_config(arch_id)
    small = dict(
        n_layers=2,
        d_model=64,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        rope_theta=10000.0,
    )
    if cfg.n_heads:
        small.update(n_heads=4, n_kv_heads=max(1, min(cfg.n_kv_heads, 2)), head_dim=16)
    if cfg.n_experts:
        small.update(n_experts=4, top_k=min(cfg.top_k, 2))
    if cfg.family == "ssm":
        small.update(ssm_state=16, ssm_head_dim=16)
    if cfg.family == "hybrid":
        small.update(n_layers=3, lru_width=64, window=16)
    elif cfg.window:
        small.update(window=16)
    if cfg.is_encdec:
        small.update(encoder_layers=2, encoder_seq=8)
    if cfg.n_patches:
        small.update(n_patches=4)
    return cfg.scaled(**small)


__all__ = [
    "ARCH_IDS",
    "SHAPES",
    "SHAPE_BY_NAME",
    "ModelConfig",
    "ShapeConfig",
    "cell_applicable",
    "get_config",
    "smoke_config",
]
