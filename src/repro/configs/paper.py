"""Scheduler-experiment configs for the paper's own evaluation (Fig 3/4).

These are the *paper's* experiment knobs, kept alongside the architecture
configs so every experiment in EXPERIMENTS.md is reproducible from a config.
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class SchedulerExperiment:
    name: str
    n_servers: float
    n_jobs: int
    pareto_shape: float
    p_values: tuple[float, ...]
    n_seeds: int
    policies: tuple[str, ...]


# Figure 4: N = 1e6 servers, M = 500 jobs, Pareto(1.5) sizes, 10 seeds,
# median of mean flow times, p in {.05, .3, .5, .9, .99}.
FIG4 = SchedulerExperiment(
    name="fig4",
    n_servers=1e6,
    n_jobs=500,
    pareto_shape=1.5,
    p_values=(0.05, 0.3, 0.5, 0.9, 0.99),
    n_seeds=10,
    policies=("hesrpt", "srpt", "equi", "hell", "knee"),
)

# Figure 3: 3-job trace, s(k) = k^0.5, N = 500.
FIG3 = SchedulerExperiment(
    name="fig3",
    n_servers=500.0,
    n_jobs=3,
    pareto_shape=0.0,  # fixed sizes, see benchmarks/fig3_trace.py
    p_values=(0.5,),
    n_seeds=1,
    policies=("hesrpt",),
)
