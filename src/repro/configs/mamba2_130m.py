"""mamba2-130m — attention-free SSM, SSD dual form. [arXiv:2405.21060; unverified]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,  # attn-free, MLP-free mamba2 block
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    tie_embeddings=True,
    source="[arXiv:2405.21060; unverified]",
)
