"""recurrentgemma-9b — hybrid: RG-LRU + local attention 1:2 (two recurrent
blocks per local-attention block), MQA(16q/1kv). [arXiv:2402.19427; unverified]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,  # 38 = 12 patterns of (rglru, rglru, attn) + 2 extra rglru
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,  # d_model / n_heads
    d_ff=12288,
    vocab_size=256000,
    layer_pattern=("rglru", "rglru", "attn"),
    lru_width=4096,
    window=2048,  # local attention window
    tie_embeddings=True,
    source="[arXiv:2402.19427; unverified]",
)
