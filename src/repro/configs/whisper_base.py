"""whisper-base — encoder-decoder; conv/audio frontend is a STUB
(``input_specs`` hands the encoder precomputed frame embeddings).
[arXiv:2212.04356; unverified]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,  # decoder layers
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    encoder_layers=6,
    encoder_seq=1500,  # 30 s of audio after the (stubbed) conv frontend
    tie_embeddings=True,
    source="[arXiv:2212.04356; unverified]",
)
