"""stablelm-12b — dense, GQA(32q/8kv). [hf:stabilityai/stablelm-2-1_6b; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=160,  # d_model / n_heads
    d_ff=13824,
    vocab_size=100352,
    source="[hf:stabilityai/stablelm-2-1_6b; hf]",
)
