"""Config dataclasses shared by every architecture and shape.

``ModelConfig`` is the single source of truth a model is built from —
``models.model.build_model(cfg)`` dispatches on ``cfg.family``.  ``ShapeConfig``
describes one cell of the assigned (architecture x input-shape) grid.

Everything is a frozen dataclass (hashable -> usable as a jit static arg).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

FAMILIES = ("dense", "moe", "ssm", "hybrid", "vlm", "audio")


@dataclass(frozen=True)
class ModelConfig:
    """One architecture.  Field semantics:

    - ``family``: dispatch key — dense | moe | ssm | hybrid | vlm | audio.
    - ``n_heads`` / ``n_kv_heads``: GQA query / key-value head counts.
    - ``head_dim``: per-head dim (decoupled from ``d_model // n_heads`` —
      qwen3-moe uses 128 with d_model=4096, 64 heads).
    - ``d_ff``: MLP hidden (for MoE: the *per-expert* hidden).
    - ``window``: sliding-window size for SWA / local attention; 0 = full.
    - ``layer_pattern``: repeating mixer pattern for hybrids, e.g.
      ``("rglru", "rglru", "attn")`` for recurrentgemma's 2:1.
    - ``encoder_layers`` / ``encoder_seq``: whisper-style encoder stack; the
      conv/audio frontend is a stub — ``input_specs`` hands the encoder
      precomputed frame embeddings of length ``encoder_seq``.
    - ``n_patches``: vlm stub — precomputed patch embeddings prepended to the
      token sequence.
    """

    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    qkv_bias: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    # SSM (mamba2 SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    # hybrid (recurrentgemma)
    layer_pattern: tuple[str, ...] = ()
    lru_width: int = 0
    # attention variant
    window: int = 0
    rope_theta: float = 10000.0
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0
    # vlm
    n_patches: int = 0
    # numerics
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    param_dtype: str = "float32"  # master copy; compute casts per train config
    source: str = ""  # provenance tag: [hf:... | arXiv:... ; tier]

    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family!r}")
        if self.family == "hybrid" and not self.layer_pattern:
            raise ValueError("hybrid family needs a layer_pattern")

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attention_is_subquadratic(self) -> bool:
        """True iff the arch can decode at 500k context without O(S^2) attention
        or an unbounded KV cache: SSM, or every attention layer windowed."""
        if self.family == "ssm":
            return True
        if self.family == "hybrid":
            return self.window > 0  # local attention layers are windowed
        return self.window > 0  # SWA (mixtral)

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS = 6*N*D)."""
        return _param_count(self)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top_k experts only)."""
        return _param_count(self, active_only=True)

    def scaled(self, **overrides) -> "ModelConfig":
        """A reduced-config variant of the same family (for smoke tests)."""
        return dataclasses.replace(self, **overrides)


def _attn_params(cfg: ModelConfig) -> int:
    d, hd = cfg.d_model, cfg.head_dim
    q = d * cfg.n_heads * hd
    kv = 2 * d * cfg.n_kv_heads * hd
    o = cfg.n_heads * hd * d
    bias = (cfg.n_heads + 2 * cfg.n_kv_heads) * hd if cfg.qkv_bias else 0
    return q + kv + o + bias


def _mlp_params(cfg: ModelConfig) -> int:
    if cfg.family == "audio":
        return 2 * cfg.d_model * cfg.d_ff  # whisper: 2-matrix GELU MLP
    return 3 * cfg.d_model * cfg.d_ff  # SwiGLU: gate + up + down


def _param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    d = cfg.d_model
    emb = cfg.vocab_size * d
    out = 0 if cfg.tie_embeddings else cfg.vocab_size * d
    n = emb + out + d  # final norm

    if cfg.family == "ssm":
        # mamba2 block: in_proj (z, x, B, C, dt) + conv + out_proj + norm.
        di, ds, nh = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
        in_proj = d * (2 * di + 2 * ds + nh)
        conv = cfg.ssm_conv * (di + 2 * ds)
        out_proj = di * d
        per_layer = in_proj + conv + out_proj + nh * 2 + di + d  # A,D,gnorm,norm
        return n + cfg.n_layers * per_layer

    def attn_block():
        return _attn_params(cfg) + 2 * d  # two norms

    def mlp_block(active: bool):
        if cfg.n_experts:
            experts = cfg.top_k if (active and active_only) else cfg.n_experts
            return experts * _mlp_params(cfg) + d * cfg.n_experts  # + router
        return _mlp_params(cfg)

    if cfg.family == "hybrid":
        lw = cfg.lru_width or d
        # rglru mixer: rec-in + gelu-gate + out projections, depthwise conv,
        # diagonal recurrence/input gates + Lambda, two norms (mixer + mlp).
        rglru = 3 * d * lw + 4 * lw + 5 * lw + 2 * d
        per_pattern = 0
        for kind in cfg.layer_pattern:
            per_pattern += (attn_block() if kind == "attn" else rglru) + mlp_block(
                active_only
            )
        n_pat = cfg.n_layers // len(cfg.layer_pattern)
        tail = cfg.n_layers - n_pat * len(cfg.layer_pattern)
        return n + n_pat * per_pattern + tail * (rglru + mlp_block(active_only))

    per_layer = attn_block() + mlp_block(active_only)
    total = n + cfg.n_layers * per_layer
    if cfg.is_encdec:
        # encoder self-attn + mlp, decoder adds cross-attn per layer.
        enc_layer = attn_block() + _mlp_params(cfg)
        total += cfg.encoder_layers * enc_layer + cfg.n_layers * attn_block()
    return total


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell.  ``kind`` picks which step gets lowered:
    train -> train_step; prefill -> prefill step; decode -> serve_step (one
    new token against a KV cache of ``seq_len``)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    def __post_init__(self):
        if self.kind not in ("train", "prefill", "decode"):
            raise ValueError(f"bad shape kind {self.kind}")


SHAPES: tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", seq_len=4_096, global_batch=256, kind="train"),
    ShapeConfig("prefill_32k", seq_len=32_768, global_batch=32, kind="prefill"),
    ShapeConfig("decode_32k", seq_len=32_768, global_batch=128, kind="decode"),
    ShapeConfig("long_500k", seq_len=524_288, global_batch=1, kind="decode"),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}


def cell_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runs?, reason).  long_500k needs sub-quadratic attention; every arch
    here has a decoder so decode shapes always run (whisper's 32k KV is far
    beyond its 448 positions — exercised mechanically per the grid spec)."""
    if shape.name == "long_500k" and not cfg.attention_is_subquadratic:
        return False, "pure full-attention stack: 500k decode needs sub-quadratic attention"
    return True, ""
