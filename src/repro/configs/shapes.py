"""Input-shape grid (re-export; definitions live in base.py next to
ModelConfig so the two dataclasses stay in one import)."""

from repro.configs.base import SHAPES, SHAPE_BY_NAME, ShapeConfig, cell_applicable

__all__ = ["SHAPES", "SHAPE_BY_NAME", "ShapeConfig", "cell_applicable"]
