"""qwen3-moe-235b-a22b — MoE 128 experts top-8, GQA(64q/4kv).
[hf:Qwen/Qwen3-30B-A3B; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,  # decoupled from d_model/n_heads, per the hf config family
    d_ff=1536,  # per-expert hidden
    vocab_size=151936,
    n_experts=128,
    top_k=8,
    rope_theta=1_000_000.0,
    source="[hf:Qwen/Qwen3-30B-A3B; hf]",
)
