"""Multi-class workload subsystem: per-class speedup, sizes, arrivals.

The paper proves heSRPT optimal for ONE job class — a single speedup
exponent ``p`` shared by every job.  The follow-up line of work shows the
production-relevant regime is heterogeneous: "Asymptotically Optimal
Scheduling of Multiple Parallelizable Job Classes" (Berg, Moseley, Wang,
Harchol-Balter 2024) derives class-aware fluid allocations when classes
differ in speedup and size distribution, and "heSRPT: Parallel Scheduling
to Minimize Mean Slowdown" (Berg, Vesilo, Harchol-Balter 2020) changes the
objective itself.  This module is the repo's home for that regime:

- :class:`ClassSpec` — one job class: speedup exponent ``p``, arrival-rate
  share ``mix``, Pareto size distribution (``size_alpha``/``size_scale``),
  policy ``weight``, and burstiness.
- Multi-class scenario samplers (``multiclass_poisson`` — superposed
  per-class Poisson streams via i.i.d. class marks; ``multiclass_bursty``
  — per-class 2-state MAP on-off streams, merged), registered into the
  ``core/scenarios.py`` registry so ``make_scenario("multiclass_poisson",
  classes=...)`` works everywhere a scenario name does, including the
  per-class ``sigma_size``/``sigma_p`` estimation-noise knobs.
- :func:`class_theta` — the ONE pure allocation function shared by the
  engine's scan rule and the per-event ``ClusterScheduler`` oracle, so
  cross-checks are exact (identical jnp ops, identical bits):
  ``hesrpt_pc`` (per-class heSRPT brackets), ``waterfill`` (the
  class-weighted water-filling fluid allocation), ``hesrpt_sd``
  (slowdown-weighted heSRPT), ``hesrpt_blind`` (class-blind heSRPT that
  assumes the active-average exponent — the baseline the class-aware
  policies are measured against).
- :func:`simulate_multiclass` — runs a multi-class scenario through the
  unified engine (``core/engine.py``) with per-job ``p`` vectors,
  continuous or whole-chips (optionally slice-snapped) allocation.  When
  every class shares one exponent it statically dispatches back to the
  single-class engine path, so the K-classes-with-equal-``p`` case
  reproduces the single-class engine **bit-for-bit**.
- :func:`multiclass_sweep` — seeds x loads x policies in one jit+vmap
  device call per policy, reporting overall and per-class mean flow time
  and mean slowdown (the Berg 2020 objective).

The per-event NumPy oracle lives in ``sched/cluster.py``
(``ClusterScheduler(class_aware=True)``); ``benchmarks/multiclass.py``
cross-checks the engine against it event-for-event.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core.analysis import per_class_mean
from repro.core.arrivals import (
    OnlineSimResult,
    _finalize,
    simulate_online,
    simulate_online_quantized,
)
from repro.core.policies import (
    hesrpt,
    hesrpt_per_class,
    make_policy,
    waterfill,
    weighted_hesrpt,
)
from repro.core.scenarios import (
    SCENARIOS,
    Scenario,
    bursty_arrivals,
    poisson_arrivals,
)

#: Class-aware policy names accepted by :func:`class_theta` and friends.
MULTICLASS_POLICY_NAMES = ("hesrpt_pc", "waterfill", "hesrpt_sd", "hesrpt_blind")


class ClassSpec(NamedTuple):
    """One job class: static Python floats, hashable for jit caches."""

    p: float = 0.5  # speedup exponent of the class
    mix: float = 1.0  # arrival-rate share (normalized over classes)
    size_alpha: float = 1.5  # Pareto tail of the class's size distribution
    size_scale: float = 1.0  # multiplicative size scale (the Pareto x_m)
    weight: float = 1.0  # class weight for weighted policies
    burst: float = 4.0  # MAP on/off rate ratio (multiclass_bursty only)


def as_specs(classes) -> tuple[ClassSpec, ...]:
    """Coerce a sequence of ClassSpec / tuples / dicts into ClassSpec."""
    out = []
    for c in classes:
        if isinstance(c, ClassSpec):
            out.append(c)
        elif isinstance(c, dict):
            out.append(ClassSpec(**c))
        else:
            out.append(ClassSpec(*c))
    if not out:
        raise ValueError("need at least one job class")
    return tuple(out)


def uniform_p(classes) -> float | None:
    """The shared exponent when every class has the same ``p``, else None."""
    ps = {float(c.p) for c in as_specs(classes)}
    return ps.pop() if len(ps) == 1 else None


# ----------------------------------------------------- multi-class sampling
def _class_fields(specs, field, dtype=None):
    return jnp.asarray([getattr(c, field) for c in specs], dtype)


def _pareto_mixture_sizes(key, cls, specs):
    """Per-job Pareto sizes: x = scale_k * U^(-1/alpha_k) for job class k
    (inverse-CDF so per-job tail exponents vectorize in one draw)."""
    alphas = _class_fields(specs, "size_alpha")[cls]
    scales = _class_fields(specs, "size_scale")[cls]
    u = jax.random.uniform(
        key, cls.shape, minval=jnp.finfo(jnp.result_type(float)).tiny, maxval=1.0
    )
    return scales * u ** (-1.0 / alphas)


def _multiclass_poisson(key, n_jobs, rate, *, classes, size_alpha=None, **_):
    """Superposed per-class Poisson streams: a Poisson(rate) stream with
    i.i.d. class marks drawn from the mix (exact superposition identity).
    ``size_alpha`` from ``make_scenario`` is ignored — classes carry their
    own size distributions."""
    del size_alpha
    specs = as_specs(classes)
    mixes = _class_fields(specs, "mix")
    k_cls, k_arr, k_size = jax.random.split(key, 3)
    cls = jax.random.choice(
        k_cls, len(specs), (n_jobs,), p=mixes / jnp.sum(mixes)
    ).astype(jnp.int32)
    arr = poisson_arrivals(k_arr, n_jobs, rate)
    x0 = _pareto_mixture_sizes(k_size, cls, specs)
    return Scenario(
        x0=x0,
        arrival_times=arr,
        class_ids=cls,
        p_job=_class_fields(specs, "p", x0.dtype)[cls],
    )


def _class_counts(specs, n_jobs: int) -> list[int]:
    """Largest-remainder split of ``n_jobs`` across the class mix (static)."""
    total = sum(c.mix for c in specs)
    raw = [n_jobs * c.mix / total for c in specs]
    counts = [int(r) for r in raw]
    fracs = sorted(
        range(len(specs)), key=lambda k: (raw[k] - counts[k], -k), reverse=True
    )
    for k in fracs[: n_jobs - sum(counts)]:
        counts[k] += 1
    return counts


def _multiclass_bursty(
    key, n_jobs, rate, *, classes, p_stay=0.95, size_alpha=None, **_
):
    """Per-class bursty MAP on-off streams, superposed.

    Each class k runs its own 2-state MAP stream at long-run intensity
    ``rate * mix_k`` with its own ``burst`` ratio (see
    ``scenarios.bursty_arrivals`` for the normalization); the engine's
    arrival sort merges the streams.  Job counts split by largest
    remainder of the mix, so the drawn class census is deterministic.
    """
    del size_alpha
    specs = as_specs(classes)
    total_mix = sum(c.mix for c in specs)
    counts = _class_counts(specs, n_jobs)
    # Per-class streams live under fold_in(key, 3): ``_with_noise`` reserves
    # fold_in(key, 1)/fold_in(key, 2) on the SAME base key for the
    # estimation-noise draws, so deriving class streams directly from
    # ``key`` would correlate the noise with the workload.
    base = jax.random.fold_in(key, 3)
    arrs, sizes, ids, ps = [], [], [], []
    for k, (spec, n_k) in enumerate(zip(specs, counts, strict=True)):
        if n_k == 0:
            continue
        rate_k = rate * spec.mix / total_mix
        norm = 0.5 * (spec.burst + 1.0 / spec.burst)
        k_arr = jax.random.fold_in(base, 2 * k)
        k_size = jax.random.fold_in(base, 2 * k + 1)
        arrs.append(
            bursty_arrivals(
                k_arr,
                n_k,
                rate_k * spec.burst * norm,
                rate_k / spec.burst * norm,
                p_stay=p_stay,
            )
        )
        cls_k = jnp.full((n_k,), k, jnp.int32)
        sizes.append(_pareto_mixture_sizes(k_size, cls_k, specs))
        ids.append(cls_k)
        ps.append(jnp.full((n_k,), spec.p, sizes[-1].dtype))
    return Scenario(
        x0=jnp.concatenate(sizes),
        arrival_times=jnp.concatenate(arrs),
        class_ids=jnp.concatenate(ids),
        p_job=jnp.concatenate(ps),
    )


def _drift_multiclass(
    key, n_jobs, rate, *, classes, p1, drift_frac=0.5, size_alpha=None, **_
):
    """Per-class time-varying drift: the ROADMAP "Next" regime.

    A ``multiclass_poisson`` draw whose TRUE exponents change mid-stream:
    class ``k`` drifts from its ``ClassSpec.p`` to ``p1[k]`` at
    ``drift_frac`` of the stream's nominal span ``n_jobs / rate`` (the same
    placement rule as the single-class drift scenarios, so the drift lands
    mid-stream at every load of a sweep).  The scenario's ``PDrift`` uses
    the per-job rows form (``values`` shape ``[2, M]``) — each job's
    physics follow its OWN class's regime schedule, e.g. only the
    communication-bound class degrades.  ``scn.p_job`` keeps the PRE-drift
    exponents (what a stale scheduler believes); the engine's physics
    follow ``p_drift`` wherever it is set.
    """
    del size_alpha
    specs = as_specs(classes)
    if len(p1) != len(specs):
        raise ValueError(
            f"p1 needs one post-drift exponent per class "
            f"({len(p1)} != {len(specs)})"
        )
    scn = _multiclass_poisson(key, n_jobs, rate, classes=specs)
    dtype = scn.x0.dtype
    p1_job = jnp.asarray(p1, dtype)[scn.class_ids]
    t_d = jnp.asarray(drift_frac * n_jobs / rate, dtype)
    drift = engine.PDrift(
        times=t_d[None],
        values=jnp.stack([jnp.asarray(scn.p_job, dtype), p1_job]),
    )
    return scn._replace(p_drift=drift)


SCENARIOS.setdefault("multiclass_poisson", _multiclass_poisson)
SCENARIOS.setdefault("multiclass_bursty", _multiclass_bursty)
SCENARIOS.setdefault("drift_multiclass", _drift_multiclass)


# ------------------------------------------------- class-aware allocation
def class_theta(
    name: str,
    x: jax.Array,
    p: jax.Array,
    *,
    n_servers,
    w: jax.Array | None = None,
) -> jax.Array:
    """The shared pure allocation ``(x, p_vec[, w]) -> theta``.

    One function used verbatim by the engine's scan rule AND the per-event
    ``ClusterScheduler`` oracle, so the two paths run identical jnp ops and
    the cross-checks can demand exact agreement.  ``w`` is the per-job
    weight vector :func:`policy_weights` builds (ignored by unweighted
    policies); ``hesrpt_blind`` re-derives the active-average exponent at
    every call — exactly the class-blind scheduler's view.
    """
    name = name.lower()
    if name == "hesrpt_pc":
        return hesrpt_per_class(x, p)
    if name == "waterfill":
        return waterfill(x, p, n_servers, w)
    if name == "hesrpt_sd":
        if w is None:
            raise ValueError("hesrpt_sd needs per-job weights (1/x0)")
        return weighted_hesrpt(x, p, w)
    if name == "hesrpt_blind":
        active = x > 0
        m = jnp.maximum(jnp.sum(active), 1).astype(x.dtype)
        p_blind = jnp.sum(jnp.where(active, p, 0.0)) / m
        return hesrpt(x, p_blind)
    raise ValueError(
        f"unknown multi-class policy {name!r}; known: {MULTICLASS_POLICY_NAMES}"
    )


def policy_weights(
    name: str,
    *,
    x0: jax.Array | None = None,
    class_w: jax.Array | None = None,
) -> jax.Array | None:
    """Per-job weight vector ``name`` expects, or None.

    ``hesrpt_sd`` weights each job by ``class_weight / x0`` (original size:
    the mean-slowdown objective weights flow time by 1/size); ``waterfill``
    takes the bare class weights.  Other policies are unweighted.
    """
    name = name.lower()
    if name == "hesrpt_sd":
        if x0 is None:
            raise ValueError("hesrpt_sd weights need the original sizes x0")
        return (1.0 if class_w is None else class_w) / x0
    if name == "waterfill":
        return class_w
    return None


def class_rule(
    name: str,
    *,
    n_servers: float | None = None,
    n_chips: int | None = None,
    min_chips: int = 1,
    snap_slices: bool = False,
    dtype,
    w: jax.Array | None = None,
    size_factors: jax.Array | None = None,
    p_hat: jax.Array | None = None,
) -> engine.AllocRule:
    """Build the engine :data:`~repro.core.engine.AllocRule` for a
    class-aware policy: continuous when ``n_chips`` is None, else whole
    chips (largest-remainder + min-chips floor, optionally slice-snapped).

    All captured per-job vectors (``w``, ``size_factors``, vector
    ``p_hat``) must be in the engine's arrival-sorted order — the same
    contract as ``engine.continuous_rule``.
    """
    n_alloc = float(n_chips) if n_chips is not None else float(n_servers)

    def rule(x_act, p):
        x_seen = x_act if size_factors is None else x_act * size_factors
        p_seen = p if p_hat is None else p_hat
        theta = class_theta(name, x_seen, p_seen, n_servers=n_alloc, w=w)
        return engine.finish_alloc(
            theta, p, n_alloc=n_alloc, n_chips=n_chips, min_chips=min_chips,
            snap_slices=snap_slices, dtype=dtype,
        )

    return rule


# ----------------------------------------------------- engine entry points
def simulate_multiclass(
    scn: Scenario,
    *,
    classes=None,
    policy: str = "hesrpt_pc",
    n_servers: float = 256.0,
    n_chips: int | None = None,
    min_chips: int = 1,
    snap_slices: bool = False,
    rel_tol: float = 1e-9,
    horizon: int | None = None,
    estimator_kw: dict | None = None,
) -> OnlineSimResult:
    """Run a multi-class scenario through the unified engine.

    Per-job exponents come from ``scn.p_job`` (drawn by the multi-class
    samplers); physics use them always, while what the *policy* sees flows
    through the usual estimation-noise channel (``scn.size_factors`` /
    ``scn.p_hat``).  ``n_chips`` switches to whole-chips allocation,
    ``snap_slices`` additionally restricts jobs to power-of-two slices.

    ``estimator_kw`` switches the policy's exponents from the drawn truth
    to *online estimates*: the engine runs the stateful
    ``estimation.estimating_class_rule`` — per-class p̂_k recursively fit
    from observed throughput inside the scan, priors and forgetting from
    the dict (``prior_p`` per class, ``prior_weight``, ``discount``) —
    while the physics keep ``scn.p_job``.  This is the class-aware
    estimation regime (``ClusterScheduler(class_aware=True,
    use_estimator=True)`` is its per-event oracle).

    **Class-blind reduction (static):** when ``classes`` is given and every
    class shares one exponent, ``hesrpt_pc``/``hesrpt_blind`` degenerate to
    plain heSRPT — this dispatches to the *single-class* engine wrappers at
    trace time, so K equal-``p`` classes reproduce the single-class engine
    bit-for-bit (property-tested in tests/test_multiclass.py).
    """
    specs = as_specs(classes) if classes is not None else None
    x0 = jnp.asarray(scn.x0)
    dtype = jnp.result_type(x0.dtype, jnp.float32)
    x0 = x0.astype(dtype)
    arr = jnp.asarray(scn.arrival_times).astype(dtype)

    p_shared = uniform_p(specs) if specs is not None else None
    noiseless = scn.size_factors is None and scn.p_hat is None
    if (
        p_shared is not None
        and noiseless
        and scn.p_drift is None  # drift physics need the generic engine run
        and estimator_kw is None
        and policy.lower() in ("hesrpt", "hesrpt_pc", "hesrpt_blind")
        and not (n_chips is not None and snap_slices)
    ):
        pol = make_policy(
            "hesrpt", n_servers=float(n_chips if n_chips is not None else n_servers)
        )
        if n_chips is None:
            return simulate_online(
                x0, arr, p_shared, n_servers, pol, rel_tol=rel_tol, horizon=horizon
            )
        return simulate_online_quantized(
            x0, arr, p_shared, n_chips, pol,
            min_chips=min_chips, rel_tol=rel_tol, horizon=horizon,
        )

    p_job = scn.p_job
    if p_job is None:
        if p_shared is None:
            raise ValueError(
                "scenario has no p_job; draw it with a multi-class sampler "
                "or pass uniform classes"
            )
        p_job = jnp.full(x0.shape, p_shared, dtype)
    p_job = jnp.asarray(p_job).astype(dtype)

    order = jnp.argsort(arr)  # engine scans in arrival order; pre-sort
    factors = scn.size_factors
    if factors is not None:
        factors = jnp.asarray(factors, dtype)[order]
    p_hat = scn.p_hat
    if p_hat is not None and jnp.ndim(p_hat) >= 1:
        p_hat = jnp.asarray(p_hat, dtype)[order]
    class_w = None
    if specs is not None and scn.class_ids is not None:
        class_w = _class_fields(specs, "weight", dtype)[scn.class_ids]
    x0_seen = x0 if scn.size_factors is None else x0 * jnp.asarray(
        scn.size_factors, dtype
    )
    w = policy_weights(policy, x0=x0_seen, class_w=class_w)
    if w is not None:
        w = jnp.asarray(w, dtype)[order]

    if estimator_kw is not None:
        from repro.core import estimation as est

        if scn.class_ids is None:
            raise ValueError("estimator_kw needs a multi-class scenario")
        kw = dict(estimator_kw)
        kw.setdefault("prior_p", jnp.mean(p_job))
        rule = est.estimating_class_rule(
            policy,
            class_ids=jnp.asarray(scn.class_ids, jnp.int32)[order],
            n_classes=len(specs) if specs is not None else
            int(jnp.max(scn.class_ids)) + 1,
            dtype=dtype,
            n_servers=float(n_servers),
            n_chips=n_chips,
            min_chips=min_chips,
            snap_slices=snap_slices,
            w=w,
            **kw,
        )
    else:
        rule = class_rule(
            policy,
            n_servers=float(n_servers),
            n_chips=n_chips,
            min_chips=min_chips,
            snap_slices=snap_slices,
            dtype=dtype,
            w=w,
            size_factors=factors,
            p_hat=p_hat,
        )
    res = engine.run(
        x0, arr, p_job, rule, horizon=horizon, rel_tol=rel_tol,
        p_drift=scn.p_drift,
    )
    n_alone = n_chips if n_chips is not None else n_servers
    return _finalize(x0, arr, res.completion_times, p_job, n_alone)


def per_class_metrics(
    res: OnlineSimResult, class_ids: jax.Array, n_classes: int
) -> dict[str, jax.Array]:
    """Per-class mean flow time / slowdown arrays (shape ``[K]``)."""
    return {
        "mean_flowtime": per_class_mean(res.flow_times, class_ids, n_classes),
        "mean_slowdown": per_class_mean(res.slowdowns, class_ids, n_classes),
    }


def multiclass_sweep(
    policies,
    rates,
    *,
    classes,
    n_jobs: int = 1000,
    n_seeds: int = 10,
    n_servers: float = 256.0,
    seed: int = 0,
    scenario: str = "multiclass_poisson",
    scenario_kw: dict | None = None,
    n_chips: int | None = None,
    min_chips: int = 1,
    snap_slices: bool = False,
    chunk_seeds: int | None = None,
    max_jobs_in_flight: int | None = None,
    shard: bool = False,
) -> dict:
    """Sweep seeds x loads x class-aware policies: ONE compiled device call
    per policy (the quantized-benchmark shape, now with per-job ``p``).

    Seeds are shared across rates and policies (paired sample paths).
    Returns ``{policy: {"mean_flowtime": [R,S], "mean_slowdown": [R,S],
    "class_flowtime": [R,S,K], "class_slowdown": [R,S,K]}}``.

    Since the sweep-subsystem refactor this is a thin spec over
    ``core/sweeps.py`` (golden-pinned bit-for-bit against the historical
    jit+vmap path); ``chunk_seeds``/``max_jobs_in_flight``/``shard`` are
    that engine's memory/device scale knobs.
    """
    from repro.core.sweeps import Sweep, run_sweep

    spec = Sweep.create(
        policies, rates, scenario=scenario, scenario_kw=scenario_kw,
        n_jobs=n_jobs, n_seeds=n_seeds, seed=seed, n_servers=n_servers,
        n_chips=n_chips, min_chips=min_chips, snap_slices=snap_slices,
        classes=as_specs(classes),
        metrics=("mean_flowtime", "mean_slowdown", "class_flowtime",
                 "class_slowdown"),
    )
    res = run_sweep(spec, chunk_seeds=chunk_seeds,
                    max_jobs_in_flight=max_jobs_in_flight, shard=shard)
    return {name: dict(res.stats[name]) for name in spec.policies}


__all__ = [
    "MULTICLASS_POLICY_NAMES",
    "ClassSpec",
    "as_specs",
    "class_rule",
    "class_theta",
    "multiclass_sweep",
    "per_class_metrics",
    "policy_weights",
    "simulate_multiclass",
    "uniform_p",
]
