"""In-scan telemetry probes: per-event derived metrics inside the jit.

The paper's whole argument is a *trajectory* claim — heSRPT trades
momentary system efficiency for size-order completions at every instant
(Thm 3's epoch structure) — yet until this module the repo could only
observe end-of-run scalars, or dump the raw ``record=True`` trace and
post-process it on the host (O(events × jobs) memory, a non-starter for
2M-job sweeps).  A *probe* composes with the engine's event scan
(``core/engine.py``: ``run(telemetry=)``) and computes derived metrics at
every epoch, **inside** the compiled scan:

- ``efficiency`` — the paper's system efficiency ``Σ θ_i^p``
  (:func:`~repro.core.analysis.system_efficiency`), total service rate
  relative to embarrassingly-parallel capacity;
- ``utilization`` — allocated fraction of the system, ``Σ θ_i``;
- ``queue`` — active-job count (arrived, unfinished);
- ``entropy`` — allocation entropy ``-Σ s_i ln s_i`` of the allocation
  shares (0 = one job holds everything, ``ln m`` = EQUI split);
- ``p_hat_err`` — absolute error of the online speedup-exponent estimate
  under an estimating rule (``core/estimation.py``), read from the rule's
  scan-carried :class:`~repro.core.estimation.EstState` without the rule
  knowing it is being watched.

Two accumulation modes, one probe contract (:class:`Probe` — ``init`` /
``step`` / ``finalize``, mirroring the engine's ``StatefulRule`` shape):

- ``mode="series"`` — the full per-event time series ``[E]`` per metric
  (plus epoch starts and lengths).  Memory is O(events × metrics): right
  for ``record=True``-sized runs, and what the Perfetto exporter
  (``launch/trace_export.py``) turns into counter tracks.
- ``mode="stream"`` — O(1) streaming aggregates carried through the scan:
  time-weighted means (``Σ m·dt / Σ dt``), maxima over positive-length
  epochs, and fixed-bin time-weighted histograms via scatter-add
  (``hist.at[bin].add(dt)``).  Memory is independent of the event count,
  so 2M-job sweeps get telemetry columns (``core/sweeps.py``:
  ``Sweep.create(telemetry=)``) for the cost of a few carried scalars.

Both modes share the same metric functions, so the streaming aggregates
are checkable against the series (tests do exactly that).  Probes never
touch the trajectory: ``run(telemetry=None)`` compiles to the identical
probe-free program, and with a probe attached the dynamics ops are
unchanged — golden pins hold either way.
"""

from __future__ import annotations

import math
from collections.abc import Callable
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.analysis import system_efficiency
from repro.core.engine import ProbeEvent

#: Metrics every probe knows how to derive from a :class:`ProbeEvent`.
#: ``p_hat_err`` additionally needs the estimating-rule reader
#: (:func:`p_hat_error_metric`) wired in via ``make_probe(p_hat_reader=)``.
METRICS = ("efficiency", "utilization", "queue", "entropy", "p_hat_err")

#: The default metric set (``p_hat_err`` is opt-in — it only means
#: something under an estimating rule).
DEFAULT_METRICS = ("efficiency", "utilization", "queue", "entropy")


class Probe(NamedTuple):
    """The engine-facing probe contract: ``(init, step, finalize)``.

    ``init()`` builds the carried accumulator pytree; ``step(state, ev)``
    folds one :class:`~repro.core.engine.ProbeEvent` and returns
    ``(new_state, per_event_out)`` (``()`` in stream mode); ``finalize
    (final_state, stacked_outs)`` shapes the post-scan read-out — still
    inside the jit, pure pytree work.  Build instances with
    :func:`make_probe`.
    """

    init: Callable[[], Any]
    step: Callable[[Any, ProbeEvent], tuple[Any, Any]]
    finalize: Callable[[Any, Any], Any]


class TelemetryResult(NamedTuple):
    """What a probe hands back on ``EngineResult.telemetry``.

    Exactly one of ``series`` / ``aggregates`` is populated (by mode).
    ``series`` maps ``"t"`` / ``"dt"`` and each metric name to ``[E]``
    arrays (event order, no-op tail epochs carry ``dt == 0``).
    ``aggregates`` maps ``"time"`` (total simulated span) and, per metric
    ``m``, ``"{m}_mean"`` (time-weighted), ``"{m}_max"`` and ``"{m}_hist"``
    (``[bins]`` time-weighted occupancy).  ``hist_edges`` carries the
    static ``[bins+1]`` bin edges per metric in stream mode.
    """

    series: dict[str, jax.Array] | None
    aggregates: dict[str, jax.Array] | None
    hist_edges: dict[str, jax.Array] | None


def _true_p_scalar(ev: ProbeEvent) -> jax.Array:
    """The scalar truth an estimator is judged against: the active-job mean
    of the per-job exponent (a no-op for the paper's scalar ``p``)."""
    p = jnp.asarray(ev.p)
    if p.ndim == 0:
        return p
    n = jnp.maximum(jnp.sum(ev.active), 1)
    return jnp.sum(jnp.where(ev.active, p, 0.0)) / n


def p_hat_error_metric(prior_p, *, prior_weight=1.0) -> Callable:
    """Reader for the ``p_hat_err`` metric under ``estimating_rule``.

    Recomputes the blended p̂ the rule allocates with (same read-out the
    rule itself uses: work-weighted over active jobs, same prior blend)
    from the rule state the engine exposes on the probe event, and returns
    ``|p̂ - p_true|`` with the *current* true exponent — under drift the
    error is against the regime in effect, which is what "did the
    estimator track the change" means.
    """
    from repro.core.estimation import blended_p_hat

    def err(ev: ProbeEvent) -> jax.Array:
        x_act = jnp.where(ev.active, ev.x, 0.0)
        p_hat = blended_p_hat(
            ev.rule_state, x_act, prior_p, prior_weight=prior_weight
        )
        return jnp.abs(p_hat - _true_p_scalar(ev))

    return err


def _metric_fns(
    metrics, alloc_unit: float, p_hat_reader: Callable | None
) -> dict[str, Callable]:
    """Bind the metric functions; ``alloc_unit`` converts the event's
    allocation to theta shares (1.0 for continuous rules — alloc *is*
    theta — and ``n_chips`` for quantized rules)."""

    def theta_of(ev):
        return ev.alloc.astype(ev.x.dtype) / alloc_unit

    def efficiency(ev):
        return system_efficiency(theta_of(ev), ev.p)

    def utilization(ev):
        return jnp.sum(theta_of(ev))

    def queue(ev):
        return jnp.sum(ev.active).astype(ev.x.dtype)

    def entropy(ev):
        th = theta_of(ev)
        tot = jnp.sum(th)
        s = th / jnp.maximum(tot, jnp.finfo(th.dtype).tiny)
        return -jnp.sum(jnp.where(s > 0, s * jnp.log(jnp.where(s > 0, s, 1.0)), 0.0))

    fns: dict[str, Callable] = {
        "efficiency": efficiency,
        "utilization": utilization,
        "queue": queue,
        "entropy": entropy,
    }
    out = {}
    for name in metrics:
        if name == "p_hat_err":
            if p_hat_reader is None:
                raise ValueError(
                    "metric 'p_hat_err' needs p_hat_reader= (built with "
                    "p_hat_error_metric; only meaningful under an "
                    "estimating rule)"
                )
            out[name] = p_hat_reader
        elif name in fns:
            out[name] = fns[name]
        else:
            raise ValueError(f"unknown telemetry metric {name!r}; known: {METRICS}")
    return out


def default_hist_ranges(n_jobs: int) -> dict[str, tuple[float, float]]:
    """Static histogram supports per metric, sized to the job count.

    ``efficiency``'s upper bound ``m^{1-p}`` is taken at the paper's
    reference ``p = 0.5`` (``sqrt(m)``); runs with much smaller ``p``
    should pass their own range — out-of-range values clip into the edge
    bins, they are never dropped.
    """
    m = max(int(n_jobs), 1)
    return {
        "efficiency": (0.0, float(m) ** 0.5),
        "utilization": (0.0, 1.0),
        "queue": (0.0, float(m)),
        # math.log, not jnp.log: this must stay a Python float so probes can
        # be built inside a jitted cell (a staged constant is not float()-able)
        "entropy": (0.0, math.log(max(m, 2))),
        "p_hat_err": (0.0, 1.0),
    }


def make_probe(
    metrics=DEFAULT_METRICS,
    *,
    mode: str = "stream",
    alloc_unit: float = 1.0,
    n_jobs: int | None = None,
    hist_bins: int = 32,
    hist_ranges: dict[str, tuple[float, float]] | None = None,
    p_hat_reader: Callable | None = None,
    window: tuple[Any, Any] | None = None,
    dtype=jnp.float64,
) -> Probe:
    """Build a :class:`Probe` for ``engine.run(telemetry=)``.

    ``metrics`` is an ordered subset of :data:`METRICS`; ``alloc_unit``
    is 1.0 for continuous rules and ``n_chips`` for quantized rules (the
    divisor that turns the event's allocation back into theta shares).
    ``mode="series"`` emits ``[E]`` per-event arrays; ``mode="stream"``
    carries O(1) aggregates (``n_jobs`` then sizes the default histogram
    supports; override any of them with ``hist_ranges``).  ``dtype`` is
    the accumulator dtype — match the engine's (f64 under the benchmark
    x64 flag) so time weights don't lose precision against it.

    ``window=(lo, hi)`` (stream mode; values may be traced scalars)
    restricts every time weight to the stationary window: each epoch
    contributes ``|[t, t+dt) ∩ [lo, hi)|`` instead of ``dt``, so means,
    maxima and histogram mass describe the windowed span only — the
    warm-up (and drain) transients of a streaming run are discarded
    without a second pass.  An epoch *straddling* an edge contributes
    exactly its overlap.  ``window=None`` is byte-identical to the
    pre-window probe (the branch resolves at trace time).
    """
    metrics = tuple(metrics)
    if mode not in ("series", "stream"):
        raise ValueError(f"mode must be 'series' or 'stream', not {mode!r}")
    if window is not None and mode != "stream":
        raise ValueError(
            "window= is stream-mode only (a series is windowed host-side)"
        )
    fns = _metric_fns(metrics, float(alloc_unit), p_hat_reader)

    if mode == "series":

        def init_series():
            return ()

        def step_series(state, ev: ProbeEvent):
            vals = tuple(fns[m](ev).astype(dtype) for m in metrics)
            return state, (ev.t.astype(dtype), ev.dt.astype(dtype), *vals)

        def finalize_series(state, outs):
            series = {"t": outs[0], "dt": outs[1]}
            for i, m in enumerate(metrics):
                series[m] = outs[2 + i]
            return TelemetryResult(
                series=series, aggregates=None, hist_edges=None
            )

        return Probe(init=init_series, step=step_series, finalize=finalize_series)

    if n_jobs is None:
        raise ValueError("mode='stream' needs n_jobs (default hist supports)")
    ranges = dict(default_hist_ranges(n_jobs))
    ranges.update(hist_ranges or {})
    B = int(hist_bins)

    def init_stream():
        state: dict[str, Any] = {"t_sum": jnp.zeros((), dtype)}
        for m in metrics:
            state[m] = {
                "wsum": jnp.zeros((), dtype),
                "max": jnp.full((), -jnp.inf, dtype),
                "hist": jnp.zeros(B, dtype),
            }
        return state

    if window is not None:
        w_lo, w_hi = window
        w_lo = jnp.asarray(w_lo, dtype)
        w_hi = jnp.asarray(w_hi, dtype)

    def step_stream(state, ev: ProbeEvent):
        dt = ev.dt.astype(dtype)
        if window is not None:  # overlap of [t, t+dt) with the window
            t_ev = ev.t.astype(dtype)
            dt = jnp.clip(
                jnp.minimum(t_ev + dt, w_hi) - jnp.maximum(t_ev, w_lo),
                0.0, None,
            )
        live = dt > 0  # no-op tail epochs and zero-length arrival batches
        new: dict[str, Any] = {"t_sum": state["t_sum"] + dt}
        for m in metrics:
            v = fns[m](ev).astype(dtype)
            lo, hi = ranges[m]
            span = max(hi - lo, 1e-12)
            vb = jnp.where(live, v, lo)  # keep the index finite on no-ops
            b = jnp.clip(((vb - lo) / span * B).astype(jnp.int32), 0, B - 1)
            s = state[m]
            new[m] = {
                "wsum": s["wsum"] + v * dt,
                "max": jnp.maximum(s["max"], jnp.where(live, v, -jnp.inf)),
                "hist": s["hist"].at[b].add(dt),
            }
        return new, ()

    def finalize_stream(state, outs):
        t = state["t_sum"]
        agg: dict[str, jax.Array] = {"time": t}
        edges: dict[str, jax.Array] = {}
        for m in metrics:
            s = state[m]
            agg[f"{m}_mean"] = s["wsum"] / jnp.maximum(
                t, jnp.finfo(dtype).tiny
            )
            mx = s["max"]
            agg[f"{m}_max"] = jnp.where(jnp.isfinite(mx), mx, 0.0)
            agg[f"{m}_hist"] = s["hist"]
            lo, hi = ranges[m]
            edges[m] = jnp.linspace(lo, hi, B + 1, dtype=dtype)
        return TelemetryResult(series=None, aggregates=agg, hist_edges=edges)

    return Probe(init=init_stream, step=step_stream, finalize=finalize_stream)


def scalar_columns(metrics) -> tuple[str, ...]:
    """The per-cell column names a stream probe contributes to a sweep:
    time-weighted mean and max per metric (histograms stay out of the
    sweep artifact — they are per-run read-outs, not per-cell scalars)."""
    names: list[str] = []
    for m in tuple(metrics):
        names.append(f"tel_{m}_mean")
        names.append(f"tel_{m}_max")
    return tuple(names)


def scalar_values(tel: TelemetryResult, metrics) -> tuple[jax.Array, ...]:
    """The values matching :func:`scalar_columns`, from a stream result."""
    if tel.aggregates is None:
        raise ValueError("scalar_values needs a stream-mode TelemetryResult")
    out: list[jax.Array] = []
    for m in tuple(metrics):
        out.append(tel.aggregates[f"{m}_mean"])
        out.append(tel.aggregates[f"{m}_max"])
    return tuple(out)


__all__ = [
    "DEFAULT_METRICS",
    "METRICS",
    "Probe",
    "TelemetryResult",
    "default_hist_ranges",
    "make_probe",
    "p_hat_error_metric",
    "scalar_columns",
    "scalar_values",
]
