"""Online (arrival-stream) fluid simulator — fully JAX-native.

The paper proves heSRPT optimal when every job is present at t=0 and leaves
the arrival-stream case as a heuristic (§4.3): re-run the policy on the
active set at every arrival and departure.  The follow-up heavy-traffic work
(Berg et al. 2020 on mean slowdown, Berg et al. 2024 on multiple job
classes) studies exactly this online regime, which is why it is the
foundation for every heavy-traffic scenario in this repo.

``simulate_online`` generalizes ``core/simulator.py``'s batch-only
``simulate`` to an *event-driven* trajectory over arrivals *and* departures
in one ``jax.lax.scan``:

- Theorem 3 still applies between events: the allocation is a pure function
  of the remaining-size vector of the *arrived, unfinished* jobs, so the
  fluid trajectory is piecewise linear with breakpoints only at arrivals and
  departures.  An M-job stream therefore has at most ``2M`` events, and a
  fixed-length scan of ``2M`` steps simulates it exactly — no Python event
  loop, no per-event device dispatch.
- Each scan step advances to the next event: ``dt = min(next departure,
  next arrival)``.  Departures zero the finishing job (with the same
  relative-tolerance clamp as the batch simulator); arrivals are admitted by
  a ``searchsorted`` on the arrival times, so any number of simultaneous
  arrivals costs a single step.
- Everything is ``jit``-able and ``vmap``-able: one device call sweeps
  thousands of seeds × loads × policies (see ``load_sweep``).

Arrival processes: ``poisson_arrivals`` (the classic M/G stream),
``deterministic_arrivals`` (fixed spacing), or any user-supplied trace —
``simulate_online`` takes the raw arrival-time vector, so trace-driven
replay is the base case, not an extension.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core.flowtime import speedup
from repro.core.policies import Policy, make_policy, make_rank_policy


class OnlineSimResult(NamedTuple):
    completion_times: jax.Array  # [M] absolute departure time of each job
    flow_times: jax.Array  # [M] completion - arrival, per job
    slowdowns: jax.Array  # [M] flow / (x0 / s(N)): 1.0 == ran alone at full N
    total_flowtime: jax.Array  # scalar
    mean_flowtime: jax.Array  # scalar
    mean_slowdown: jax.Array  # scalar
    makespan: jax.Array  # scalar, last departure time


def simulate_online(
    x0: jax.Array,
    arrival_times: jax.Array,
    p: jax.Array,
    n_servers: jax.Array,
    policy: Policy,
    *,
    rel_tol: float = 1e-9,
    horizon: int | None = None,
) -> OnlineSimResult:
    """Run ``policy`` online over an arrival stream to completion.

    ``x0[i]`` is the size of job ``i`` and ``arrival_times[i]`` its arrival
    epoch (any order; ties allowed — simultaneous arrivals are admitted in
    one event step).  The policy sees only jobs that have arrived and not
    yet finished; it is re-evaluated at every arrival and departure, which
    is the paper's §4.3 heuristic made exact for the fluid model.

    ``horizon`` bounds the scan length; the default ``2M`` (M arrivals + M
    departures, each step processing at least one event) is always enough.
    Jobs that never depart within the horizon report ``inf`` times.
    """
    x0 = jnp.asarray(x0)
    M = x0.shape[0]
    E = 2 * M if horizon is None else horizon
    dtype = jnp.result_type(x0.dtype, jnp.float32)
    x0 = x0.astype(dtype)
    arrival_times = jnp.asarray(arrival_times).astype(dtype)
    tol = rel_tol * jnp.max(x0)

    # Event logic walks arrivals in time order; un-sort at the end.
    order = jnp.argsort(arrival_times)
    arr = arrival_times[order]
    xs = x0[order]
    idx = jnp.arange(M)

    def body(carry, _):
        x, t, i, times = carry
        active = (idx < i) & (x > 0)
        x_act = jnp.where(active, x, 0.0)
        theta = policy(x_act, p).astype(dtype)
        rate = speedup(theta * n_servers, p)
        tt = jnp.where(active & (rate > 0), x / rate, jnp.inf)
        dt_dep = jnp.min(tt)  # inf when nothing is active
        t_next_arr = jnp.where(i < M, arr[jnp.minimum(i, M - 1)], jnp.inf)
        dt_arr = jnp.maximum(t_next_arr - t, 0.0)
        dt = jnp.minimum(dt_dep, dt_arr)
        any_event = jnp.isfinite(dt)
        dt = jnp.where(any_event, dt, 0.0)
        # Landing on an arrival pins t to the exact arrival time so the
        # searchsorted admission below cannot miss it to float rounding.
        admit = any_event & (dt_arr <= dt_dep)
        t_new = jnp.where(admit, t_next_arr, t + dt)
        x_new = jnp.where(active, x - dt * rate, x)
        # As in the batch simulator: the argmin job departs by construction
        # when the departure is the next event; fp residue must not keep it.
        take_dep = any_event & (dt_dep <= dt_arr)
        departing = (idx == jnp.argmin(tt)) & active & take_dep
        x_new = jnp.where(departing | (active & (x_new <= tol)), 0.0, x_new)
        newly_done = active & (x_new == 0.0)
        times = jnp.where(newly_done, t_new, times)
        i_new = jnp.searchsorted(arr, t_new, side="right").astype(i.dtype)
        i_new = jnp.maximum(i, i_new)  # monotone even on no-op steps
        return (x_new, t_new, i_new, times), None

    init = (xs, jnp.zeros((), dtype), jnp.zeros((), jnp.int32),
            jnp.zeros(M, dtype))
    (x_fin, _, _, times), _ = jax.lax.scan(body, init, None, length=E)
    times = jnp.where(x_fin > 0, jnp.inf, times)
    times = jnp.zeros(M, dtype).at[order].set(times)  # back to input order

    flows = times - arrival_times
    alone = x0 / speedup(jnp.asarray(n_servers, dtype), p)
    slow = flows / alone
    return OnlineSimResult(
        completion_times=times,
        flow_times=flows,
        slowdowns=slow,
        total_flowtime=jnp.sum(flows),
        mean_flowtime=jnp.mean(flows),
        mean_slowdown=jnp.mean(slow),
        makespan=jnp.max(times),
    )


def simulate_online_ranked(
    x0: jax.Array,
    arrival_times: jax.Array,
    p: jax.Array,
    n_servers: jax.Array,
    rank_policy,
    *,
    horizon: int | None = None,
) -> OnlineSimResult:
    """Sort-free fast path of ``simulate_online`` for rank-space policies.

    ``rank_policy(ranks, m, p) -> theta`` must be a pure function of the
    descending-size ranks (Thm 6 size-invariance), with rates non-increasing
    in remaining size — true for heSRPT, EQUI and SRPT (see
    ``core.policies.RANK_POLICIES``).  Those two properties give two
    invariants this scan exploits:

    - the size order of active jobs never changes between events, so the
      rank vector can be *carried* and updated in O(M) per event (an arrival
      inserts one rank, a departure removes the highest) instead of
      re-sorted — XLA's per-step sort is what makes the generic path ~20x
      slower at M=1000;
    - the next departure is always the current-smallest active job (rank m),
      so no argmin over per-job finish times is needed.

    Admissions are one job per step, so the default ``2M`` horizon (M
    arrivals + M departures) is exact.  Agreement with the generic path is
    property-tested in tests/test_arrivals.py.

    Tie handling: jobs with *exactly* equal remaining sizes get distinct
    adjacent ranks (ties break by arrival order, as in
    ``size_ranks_desc``).  For SRPT this serves tied jobs in the opposite
    order to the generic path's ``argmin`` — per-job times permute within
    the tied group, while totals/means are exchange-invariant.  Ties are
    measure-zero for continuous size distributions.
    """
    x0 = jnp.asarray(x0)
    M = x0.shape[0]
    E = 2 * M if horizon is None else horizon
    dtype = jnp.result_type(x0.dtype, jnp.float32)
    x0 = x0.astype(dtype)
    arrival_times = jnp.asarray(arrival_times).astype(dtype)

    order = jnp.argsort(arrival_times)  # one sort total, not one per event
    arr = arrival_times[order]
    xs = x0[order]
    idx = jnp.arange(M)

    def body(carry, _):
        x, t, i, ranks, m, times = carry
        theta = rank_policy(ranks, m, p, dtype=dtype)
        rate = speedup(theta * n_servers, p)
        # Next departure: the smallest active job, i.e. rank m, found by
        # argmax since ranks are unique with maximum m (0 when inactive).
        small = jnp.argmax(ranks)
        has_active = m > 0
        x_s = x[small]
        r_s = rate[small]
        dt_dep = jnp.where(has_active & (r_s > 0), x_s / r_s, jnp.inf)
        t_next_arr = jnp.where(i < M, arr[jnp.minimum(i, M - 1)], jnp.inf)
        dt_arr = jnp.maximum(t_next_arr - t, 0.0)
        dt = jnp.minimum(dt_dep, dt_arr)
        any_event = jnp.isfinite(dt)
        dt = jnp.where(any_event, dt, 0.0)
        admit = any_event & (dt_arr <= dt_dep)
        take_dep = any_event & (dt_dep <= dt_arr)
        t_new = jnp.where(admit, t_next_arr, t + dt)
        active = ranks > 0
        x_new = jnp.where(active, jnp.maximum(x - dt * rate, 0.0), x)
        # Departure: drop rank m; every other active rank stays valid.
        departing = (idx == small) & active & take_dep
        x_new = jnp.where(departing, 0.0, x_new)
        times = jnp.where(departing, t_new, times)
        ranks = jnp.where(departing, 0, ranks)
        m = m - jnp.where(take_dep & has_active, 1, 0)
        # Arrival: insert job i at its rank among the (post-departure)
        # active set; ties break by index, matching size_ranks_desc.
        i_c = jnp.minimum(i, M - 1)
        x_a = xs[i_c]
        still = ranks > 0
        ahead = still & ((x_new > x_a) | ((x_new == x_a) & (idx < i_c)))
        r_a = 1 + jnp.sum(ahead, dtype=jnp.int32)
        bumped = jnp.where(still & (ranks >= r_a), ranks + 1, ranks)
        inserted = bumped.at[i_c].set(r_a)
        ranks = jnp.where(admit, inserted, ranks)
        m = m + jnp.where(admit, 1, 0)
        i = i + jnp.where(admit, 1, 0)
        return (x_new, t_new, i, ranks, m, times), None

    init = (
        xs,
        jnp.zeros((), dtype),
        jnp.zeros((), jnp.int32),
        jnp.zeros(M, jnp.int32),
        jnp.zeros((), jnp.int32),
        jnp.zeros(M, dtype),
    )
    (x_fin, _, _, ranks_fin, _, times), _ = jax.lax.scan(
        body, init, None, length=E
    )
    times = jnp.where((x_fin > 0) | (ranks_fin > 0), jnp.inf, times)
    times = jnp.zeros(M, dtype).at[order].set(times)

    flows = times - arrival_times
    alone = x0 / speedup(jnp.asarray(n_servers, dtype), p)
    slow = flows / alone
    return OnlineSimResult(
        completion_times=times,
        flow_times=flows,
        slowdowns=slow,
        total_flowtime=jnp.sum(flows),
        mean_flowtime=jnp.mean(flows),
        mean_slowdown=jnp.mean(slow),
        makespan=jnp.max(times),
    )


# --------------------------------------------------------- arrival processes
def poisson_arrivals(key: jax.Array, n_jobs: int, rate) -> jax.Array:
    """Arrival epochs of a Poisson(rate) stream: cumsum of Exp(rate) gaps."""
    gaps = jax.random.exponential(key, (n_jobs,)) / rate
    return jnp.cumsum(gaps)


def deterministic_arrivals(n_jobs: int, rate) -> jax.Array:
    """Evenly spaced arrivals at interval 1/rate (first arrival at 1/rate)."""
    return jnp.arange(1, n_jobs + 1) / rate


def pareto_sizes(key: jax.Array, n_jobs: int, alpha: float = 1.5) -> jax.Array:
    """Pareto(alpha) job sizes with minimum 1 — the benchmarks' heavy tail.

    Matches ``numpy.random.Generator.pareto(alpha) + 1`` in distribution
    (classical Pareto with x_m = 1).
    """
    return jax.random.pareto(key, alpha, (n_jobs,))


# --------------------------------------------------------------- load sweeps
def load_sweep(
    policies: Sequence[str],
    rates: Sequence[float],
    *,
    n_jobs: int = 1000,
    n_seeds: int = 100,
    p: float = 0.5,
    n_servers: float = 256.0,
    size_alpha: float = 1.5,
    seed: int = 0,
    metric: str = "mean_flowtime",
) -> dict:
    """Sweep arrival rates × seeds × policies in one device call per policy.

    Seeds are shared across rates and policies (paired comparison), so
    "heSRPT beats EQUI at every load" is tested on identical sample paths.
    Returns ``{rate: {policy: mean-over-seeds of `metric`}}``.
    """
    per_seed = load_sweep_raw(
        policies, rates, n_jobs=n_jobs, n_seeds=n_seeds, p=p,
        n_servers=n_servers, size_alpha=size_alpha, seed=seed, metric=metric,
    )
    out = {}
    for ri, rate in enumerate(rates):
        out[float(rate)] = {
            name: float(jnp.mean(per_seed[name][ri])) for name in policies
        }
    return out


def load_sweep_raw(
    policies: Sequence[str],
    rates: Sequence[float],
    *,
    n_jobs: int = 1000,
    n_seeds: int = 100,
    p: float = 0.5,
    n_servers: float = 256.0,
    size_alpha: float = 1.5,
    seed: int = 0,
    metric: str = "mean_flowtime",
) -> dict:
    """Like ``load_sweep`` but returns the full ``[n_rates, n_seeds]`` array
    of per-seed metrics for each policy (for CIs, paired tests, plotting)."""
    if metric not in OnlineSimResult._fields:
        raise ValueError(f"unknown metric {metric!r}")
    keys = jax.random.split(jax.random.PRNGKey(seed), n_seeds)
    rates_arr = jnp.asarray(rates, dtype=jnp.result_type(float))

    out = {}
    for name in policies:
        f = _sweep_fn(name, n_jobs, p, float(n_servers), size_alpha, metric)
        out[name] = f(keys, rates_arr)  # [n_rates, n_seeds]
    return out


@functools.lru_cache(maxsize=64)
def _sweep_fn(name, n_jobs, p, n_servers, size_alpha, metric):
    """Persistent jitted sweep per parameter set, so repeat calls (and a
    warmup before timing) hit XLA's compilation cache instead of rebuilding
    a fresh ``jax.jit`` object each time."""
    # Sort-free ranked scan where the policy allows it (heSRPT, EQUI,
    # SRPT — ~20x faster at M=1000); generic sort-per-event otherwise.
    rank_pol = make_rank_policy(name)
    pol = None if rank_pol else make_policy(name, n_servers=n_servers)

    def one(key, rate):
        k1, k2 = jax.random.split(key)
        arr = poisson_arrivals(k1, n_jobs, rate)
        x0 = pareto_sizes(k2, n_jobs, size_alpha)
        if rank_pol is not None:
            res = simulate_online_ranked(x0, arr, p, n_servers, rank_pol)
        else:
            res = simulate_online(x0, arr, p, n_servers, pol)
        return getattr(res, metric)

    return jax.jit(jax.vmap(jax.vmap(one, in_axes=(0, None)),
                            in_axes=(None, 0)))
