"""Online (arrival-stream) simulation — thin wrappers over ``core/engine.py``.

The paper proves heSRPT optimal when every job is present at t=0 and leaves
the arrival-stream case as a heuristic (§4.3): re-run the policy on the
active set at every arrival and departure.  The follow-up heavy-traffic work
(Berg et al. 2020 on mean slowdown, Berg et al. 2024 on multiple job
classes) studies exactly this online regime, which is why it is the
foundation for every heavy-traffic scenario in this repo.

The event-driven ``lax.scan`` itself lives in ``core/engine.py`` (one
engine for batch, online, and quantized-chips trajectories); this module
keeps the historical public API —

- :func:`simulate_online` — generic sort-per-event path for any policy,
- :func:`simulate_online_ranked` — sort-free incremental-rank fast path for
  rank-space policies (heSRPT/EQUI/SRPT),
- :func:`simulate_online_superstep` — the closed-form arrival-superstep
  path (``core/superstep.py``): one scan step per arrival instead of per
  event, zero for batches,
- :func:`simulate_online_quantized` — whole-chips allocation (the
  ``ClusterScheduler`` integer regime) in the same scan,
- :func:`load_sweep` / :func:`load_sweep_raw` — seeds × loads sweeps for
  any registered scenario (Poisson, bursty MAP, estimation noise, ...; see
  ``core/scenarios.py``), thin specs over the sweep subsystem
  (``core/sweeps.py``: chunked/sharded executors, ``SweepResult``
  artifacts),

— and converts engine trajectories into per-job flow times and slowdowns
(:class:`OnlineSimResult`).  Arrival processes and size distributions come
from the scenario registry; ``poisson_arrivals`` & co are re-exported here
for compatibility.
"""

from __future__ import annotations

from collections.abc import Sequence
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core.flowtime import speedup
from repro.core.policies import Policy
from repro.core.scenarios import (  # noqa: F401  (re-exported public API)
    Scenario,
    deterministic_arrivals,
    make_scenario,
    pareto_sizes,
    poisson_arrivals,
)


class OnlineSimResult(NamedTuple):
    completion_times: jax.Array  # [M] absolute departure time of each job
    flow_times: jax.Array  # [M] completion - arrival, per job
    slowdowns: jax.Array  # [M] flow / (x0 / s(N)): 1.0 == ran alone at full N
    total_flowtime: jax.Array  # scalar
    mean_flowtime: jax.Array  # scalar
    mean_slowdown: jax.Array  # scalar
    makespan: jax.Array  # scalar, last departure time


def _finalize(x0, arrival_times, times, p, n_servers) -> OnlineSimResult:
    """Per-job flow times / slowdowns from completion times (input order)."""
    flows = times - arrival_times
    alone = x0 / speedup(jnp.asarray(n_servers, x0.dtype), p)
    slow = flows / alone
    return OnlineSimResult(
        completion_times=times,
        flow_times=flows,
        slowdowns=slow,
        total_flowtime=jnp.sum(flows),
        mean_flowtime=jnp.mean(flows),
        mean_slowdown=jnp.mean(slow),
        makespan=jnp.max(times),
    )


def simulate_online(
    x0: jax.Array,
    arrival_times: jax.Array,
    p: jax.Array,
    n_servers: jax.Array,
    policy: Policy,
    *,
    rel_tol: float = 1e-9,
    horizon: int | None = None,
) -> OnlineSimResult:
    """Run ``policy`` online over an arrival stream to completion.

    ``x0[i]`` is the size of job ``i`` and ``arrival_times[i]`` its arrival
    epoch (any order; ties allowed — simultaneous arrivals are admitted in
    one event step).  The policy sees only jobs that have arrived and not
    yet finished; it is re-evaluated at every arrival and departure, which
    is the paper's §4.3 heuristic made exact for the fluid model.

    ``horizon`` bounds the scan length; the default ``2M`` (M arrivals + M
    departures, each step processing at least one event) is always enough.
    Jobs that never depart within the horizon report ``inf`` times.
    """
    x0 = jnp.asarray(x0)
    dtype = jnp.result_type(x0.dtype, jnp.float32)
    x0 = x0.astype(dtype)
    arrival_times = jnp.asarray(arrival_times).astype(dtype)
    res = engine.run(
        x0,
        arrival_times,
        p,
        engine.continuous_rule(policy, n_servers, dtype=dtype),
        horizon=horizon,
        rel_tol=rel_tol,
    )
    return _finalize(x0, arrival_times, res.completion_times, p, n_servers)


def simulate_online_ranked(
    x0: jax.Array,
    arrival_times: jax.Array,
    p: jax.Array,
    n_servers: jax.Array,
    rank_policy,
    *,
    horizon: int | None = None,
) -> OnlineSimResult:
    """Sort-free fast path of ``simulate_online`` for rank-space policies.

    See ``engine.run_ranked`` for the invariants this exploits (carried
    descending-size ranks instead of a per-event sort — ~20x the generic
    path at M=1000) and for tie-handling semantics.
    """
    x0 = jnp.asarray(x0)
    dtype = jnp.result_type(x0.dtype, jnp.float32)
    x0 = x0.astype(dtype)
    arrival_times = jnp.asarray(arrival_times).astype(dtype)
    times = engine.run_ranked(
        x0, arrival_times, p, n_servers, rank_policy, horizon=horizon
    )
    return _finalize(x0, arrival_times, times, p, n_servers)


def simulate_online_superstep(
    x0: jax.Array,
    arrival_times: jax.Array,
    p: jax.Array,
    n_servers: jax.Array,
    policy: str = "hesrpt",
    *,
    weights: jax.Array | None = None,
    pre_arrived: bool = False,
    horizon: int | None = None,
    p_drift=None,
) -> OnlineSimResult:
    """Closed-form superstep fast path of ``simulate_online``.

    One scan step per arrival (plus one per drift boundary) instead of one
    per event, and zero steps for ``pre_arrived`` batches — every departure
    inside an inter-arrival gap is computed analytically from the Thm-3/8
    bracket geometry.  ``policy`` is a name from
    ``core.superstep.SUPERSTEP_POLICIES`` (heSRPT/EQUI/SRPT and the
    cumulative-weight ``weighted_hesrpt``, which reads per-job
    ``weights``).  See ``core/superstep.py`` for the supported-config
    decision table; everything else raises at trace time and takes
    :func:`simulate_online` / :func:`simulate_scenario`.
    """
    from repro.core.superstep import run_superstep

    x0 = jnp.asarray(x0)
    dtype = jnp.result_type(x0.dtype, jnp.float32)
    x0 = x0.astype(dtype)
    arrival_times = jnp.asarray(arrival_times).astype(dtype)
    res = run_superstep(
        x0, arrival_times, p, n_servers, policy, weights=weights,
        pre_arrived=pre_arrived, horizon=horizon, p_drift=p_drift,
    )
    return _finalize(x0, arrival_times, res.completion_times, p, n_servers)


def simulate_online_quantized(
    x0: jax.Array,
    arrival_times: jax.Array,
    p: jax.Array,
    n_chips: int,
    policy: Policy,
    *,
    min_chips: int = 1,
    rel_tol: float = 1e-9,
    horizon: int | None = None,
    record: bool = False,
    fused: bool = False,
):
    """Online simulation with whole-chip allocations (integer regime).

    Each event re-runs ``policy`` and rounds ``theta * n_chips`` to integer
    chips by largest-remainder apportionment with a ``min_chips`` floor —
    bit-for-bit the ``ClusterScheduler`` decision epoch, but inside the
    engine's scan so thousands of seeds × loads sweep in one device call
    (see ``benchmarks/quantized.py``).  With ``record=True`` returns
    ``(OnlineSimResult, EngineResult)`` where the engine trace carries the
    per-event chips/time/sizes trajectory (arrival-sorted job order).
    ``fused=True`` takes the ``kernels/alloc.py`` fused allocate (heSRPT
    only; chip-exact vs the unfused rule).
    """
    x0 = jnp.asarray(x0)
    dtype = jnp.result_type(x0.dtype, jnp.float32)
    x0 = x0.astype(dtype)
    arrival_times = jnp.asarray(arrival_times).astype(dtype)
    res = engine.run(
        x0,
        arrival_times,
        p,
        engine.quantized_rule(policy, n_chips, min_chips=min_chips, dtype=dtype),
        horizon=horizon,
        rel_tol=rel_tol,
        record=record,
        fused=fused,
    )
    out = _finalize(x0, arrival_times, res.completion_times, p, n_chips)
    return (out, res) if record else out


def simulate_scenario(
    scn: Scenario,
    p,
    n_servers,
    policy: Policy,
    *,
    n_chips: int | None = None,
    min_chips: int = 1,
    rel_tol: float = 1e-9,
    horizon: int | None = None,
    fused: bool = False,
    telemetry=None,
) -> OnlineSimResult:
    """Run one drawn :class:`Scenario` through the engine.

    Estimation noise (``scn.size_factors``/``scn.p_hat``) reaches only the
    allocation rule; the dynamics use the true sizes and exponent.  Pass
    ``n_chips`` for the quantized (whole-chips) regime, else the
    continuously-divisible system with ``n_servers`` is simulated.

    Multi-class scenarios (``scn.p_job`` set) run with each job's true
    class exponent in the *physics* while the policy keeps seeing the
    scalar ``p`` (or ``scn.p_hat``) — i.e. this wrapper is the class-BLIND
    baseline; class-aware policies live in ``core/multiclass.py``.

    Drift scenarios (``scn.p_drift`` set) make the true exponent
    piecewise-constant in time; the policy then sees the *current* true
    regime (the oracle arm) unless ``scn.p_hat`` pins what it believes
    (the stale arm).  The arm that has to *earn* its estimate —
    allocating with an online p-hat fit from observed throughput — is
    ``estimation.simulate_scenario_estimated``.

    ``fused=True`` runs the engine on the ``kernels/alloc.py`` fused
    allocate (heSRPT only — other policies raise): fewer sorts per event
    on CPU, the Pallas kernel on TPU, chip-exact either way.

    ``telemetry`` takes a probe (``core/telemetry.py``); the return value
    is then ``(OnlineSimResult, TelemetryResult)``.  The trajectory is
    bit-for-bit the probe-free run either way.
    """
    x0 = jnp.asarray(scn.x0)
    dtype = jnp.result_type(x0.dtype, jnp.float32)
    x0 = x0.astype(dtype)
    arrival_times = jnp.asarray(scn.arrival_times).astype(dtype)
    order = jnp.argsort(arrival_times)
    factors = scn.size_factors
    if factors is not None:
        # The engine scans jobs in arrival order; permute to match.
        factors = jnp.asarray(factors, dtype)[order]
    p_phys = p
    p_hat = scn.p_hat
    if scn.p_job is not None:
        p_phys = jnp.asarray(scn.p_job, dtype)
        if p_hat is None:
            p_hat = p  # the class-blind policy still assumes the scalar p
    if p_hat is not None and jnp.ndim(p_hat) >= 1:
        # A per-job p_hat vector (multi-class sigma_p noise) cannot be fed
        # to the single-class policies — their rank brackets only telescope
        # to sum(theta)=1 for ONE exponent.  The class-blind scheduler this
        # wrapper models holds a single estimate anyway: the mean of its
        # per-job noisy estimates.  (Class-aware per-job p_hat handling
        # lives in core/multiclass.py, whose policies renormalize.)
        p_hat = jnp.mean(jnp.asarray(p_hat, dtype))
    if n_chips is not None:
        rule = engine.quantized_rule(
            policy, n_chips, min_chips=min_chips, dtype=dtype,
            size_factors=factors, p_hat=p_hat,
        )
        n_alone = n_chips
    else:
        rule = engine.continuous_rule(
            policy, n_servers, dtype=dtype,
            size_factors=factors, p_hat=p_hat,
        )
        n_alone = n_servers
    res = engine.run(
        x0, arrival_times, p_phys, rule, horizon=horizon, rel_tol=rel_tol,
        p_drift=scn.p_drift, fused=fused, telemetry=telemetry,
    )
    out = _finalize(x0, arrival_times, res.completion_times, p_phys, n_alone)
    return (out, res.telemetry) if telemetry is not None else out


def simulate_stream(
    scn: Scenario,
    p,
    n_servers,
    policy: Policy,
    *,
    n_slots: int,
    window=None,
    n_chips: int | None = None,
    min_chips: int = 1,
    rel_tol: float = 1e-9,
    horizon: int | None = None,
    record_times: bool = False,
    fused: bool = False,
    telemetry=None,
) -> engine.StreamResult:
    """Run one drawn :class:`Scenario` through the bounded-slot engine.

    The streaming counterpart of :func:`simulate_scenario`: the same
    regime split (``n_chips`` = whole chips, else the continuous system
    on ``n_servers``), but the event scan carries ``[n_slots]`` recycled
    slots instead of ``[n_jobs]`` state, and the read-out is the
    stationary-window :class:`~repro.core.engine.StreamResult` (windowed
    mean flow/slowdown, occupancy, blocked-admission counters) rather
    than per-job arrays.  Scenarios carrying per-job tape state —
    estimation noise, classes, drift — raise in
    :func:`~repro.core.scenarios.stream_tape`; they stay on the
    finite-tape path.
    """
    from repro.core.scenarios import stream_tape

    x0, arrival_times = stream_tape(scn)
    dtype = jnp.result_type(jnp.asarray(x0).dtype, jnp.float32)
    if n_chips is not None:
        rule = engine.quantized_rule(
            policy, n_chips, min_chips=min_chips, dtype=dtype
        )
        n_alone = n_chips
    else:
        rule = engine.continuous_rule(policy, n_servers, dtype=dtype)
        n_alone = n_servers
    return engine.run_stream(
        x0, arrival_times, p, rule, n_slots=n_slots, window=window,
        n_alone=n_alone, horizon=horizon, rel_tol=rel_tol,
        record_times=record_times, fused=fused, telemetry=telemetry,
    )


# --------------------------------------------------------------- load sweeps
def load_sweep(
    policies: Sequence[str],
    rates: Sequence[float],
    *,
    n_jobs: int = 1000,
    n_seeds: int = 100,
    p: float = 0.5,
    n_servers: float = 256.0,
    size_alpha: float = 1.5,
    seed: int = 0,
    metric: str = "mean_flowtime",
    scenario: str = "poisson",
    scenario_kw: dict | None = None,
    n_chips: int | None = None,
    min_chips: int = 1,
    chunk_seeds: int | None = None,
    max_jobs_in_flight: int | None = None,
    shard: bool = False,
) -> dict:
    """Sweep arrival rates × seeds × policies in one device call per policy.

    Seeds are shared across rates and policies (paired comparison), so
    "heSRPT beats EQUI at every load" is tested on identical sample paths.
    ``scenario`` selects the workload generator from the registry
    (``core/scenarios.py``); ``n_chips`` switches to the quantized
    whole-chips engine.  Returns ``{rate: {policy: mean-over-seeds of
    `metric`}}``.  The execution-scale knobs (seed chunking, device
    sharding) pass through to ``core/sweeps.py``.
    """
    per_seed = load_sweep_raw(
        policies, rates, n_jobs=n_jobs, n_seeds=n_seeds, p=p,
        n_servers=n_servers, size_alpha=size_alpha, seed=seed, metric=metric,
        scenario=scenario, scenario_kw=scenario_kw, n_chips=n_chips,
        min_chips=min_chips, chunk_seeds=chunk_seeds,
        max_jobs_in_flight=max_jobs_in_flight, shard=shard,
    )
    out = {}
    for ri, rate in enumerate(rates):
        out[float(rate)] = {
            name: float(jnp.mean(per_seed[name][ri])) for name in policies
        }
    return out


def load_sweep_raw(
    policies: Sequence[str],
    rates: Sequence[float],
    *,
    n_jobs: int = 1000,
    n_seeds: int = 100,
    p: float = 0.5,
    n_servers: float = 256.0,
    size_alpha: float = 1.5,
    seed: int = 0,
    metric: str = "mean_flowtime",
    scenario: str = "poisson",
    scenario_kw: dict | None = None,
    n_chips: int | None = None,
    min_chips: int = 1,
    chunk_seeds: int | None = None,
    max_jobs_in_flight: int | None = None,
    shard: bool = False,
) -> dict:
    """Like ``load_sweep`` but returns the full ``[n_rates, n_seeds]`` array
    of per-seed metrics for each policy (for CIs, paired tests, plotting).

    Since the sweep-subsystem refactor this is a thin spec over
    ``core/sweeps.py`` (golden-pinned bit-for-bit against the historical
    jit+vmap path), which is also where the scale knobs live:
    ``chunk_seeds``/``max_jobs_in_flight`` bound memory via seed-chunked
    ``lax.map`` execution, ``shard=True`` splits seeds across devices.
    """
    from repro.core.sweeps import Sweep, run_sweep

    spec = Sweep.create(
        policies, rates, scenario=scenario, scenario_kw=scenario_kw,
        n_jobs=n_jobs, n_seeds=n_seeds, seed=seed, p=p, n_servers=n_servers,
        size_alpha=size_alpha, n_chips=n_chips, min_chips=min_chips,
        metrics=(metric,),
    )
    res = run_sweep(spec, chunk_seeds=chunk_seeds,
                    max_jobs_in_flight=max_jobs_in_flight, shard=shard)
    return {name: res.stats[name][metric] for name in spec.policies}
