"""Scenario registry: where the engine's jobs and arrival epochs come from.

A *scenario* is a pure sampler ``(key, n_jobs, rate) -> Scenario`` drawing
the workload the allocation engine (``core/engine.py``) is run against:

- ``batch`` — every job present at t=0, Pareto sizes (the paper's setting).
- ``poisson`` — Poisson(rate) arrivals, Pareto sizes: the classic M/G
  heavy-traffic stream used by ``load_sweep`` (bit-identical draws to the
  historical ``core/arrivals.py`` sweep).
- ``deterministic`` — evenly spaced arrivals at interval 1/rate.
- ``bursty`` — a 2-state MAP (Markov-modulated) on-off stream: interarrival
  gaps are Exp(rate_on) or Exp(rate_off) according to a persistent hidden
  state, producing the correlated bursts heavy-traffic studies care about.
- ``multiclass_poisson`` / ``multiclass_bursty`` / ``drift_multiclass`` —
  K-class mixtures with per-class speedup exponent, size distribution and
  arrival share (``drift_multiclass`` additionally drifts every class's
  true exponent mid-stream via per-job ``PDrift`` rows); the samplers live
  in ``core/multiclass.py`` and register here lazily.
- ``drift_poisson`` / ``drift_bursty`` — the estimation regime: the TRUE
  speedup exponent changes mid-run (``p0`` → ``p1`` at ``drift_frac`` of
  the stream's nominal span, e.g. the workload turning
  communication-bound), carried as an ``engine.PDrift`` on the scenario.
  An oracle scheduler re-reads the current truth, a stale one keeps
  ``p0``; only an online estimator (``core/estimation.py``) can *track*
  it — the three arms ``benchmarks/estimation.py`` compares.

Every sampler accepts ``sigma_size``/``sigma_p`` estimation noise (scalars
or per-class sequences): the returned ``size_factors`` (lognormal, median
1) and ``p_hat`` perturb what the *policy* sees while the true dynamics
keep ``x0`` and ``p`` — see ``engine.continuous_rule``.  ``trace_scenario``
wraps externally supplied arrival/size vectors so trace-driven replay is
the base case.

The registry is deliberately small and flat: benchmarks address scenarios
by name (``make_scenario("bursty", p=0.5, sigma_size=0.3)``), and adding a
scenario is adding one sampler function and one registry line.
"""

from __future__ import annotations

from collections.abc import Callable
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.engine import PDrift


class Scenario(NamedTuple):
    """One drawn workload, in input (unsorted) job order.

    ``size_factors``/``p_hat`` are ``None`` when the scenario carries no
    estimation noise — the policy then sees the true sizes and exponent.
    ``class_ids``/``p_job`` are ``None`` for single-class scenarios; the
    multi-class samplers (``core/multiclass.py``) fill them so every job
    carries its class id and its class's true speedup exponent.
    ``p_drift`` (``engine.PDrift``) makes the true exponent
    piecewise-constant in time — it then supersedes the scalar ``p`` the
    simulation wrappers are called with.
    """

    x0: jax.Array  # [M] true job sizes
    arrival_times: jax.Array  # [M] arrival epochs (zeros for batch)
    size_factors: jax.Array | None = None  # [M] policy sees x * factors
    p_hat: jax.Array | None = None  # scalar or [M]; policy sees p_hat
    class_ids: jax.Array | None = None  # [M] int32 job class ids
    p_job: jax.Array | None = None  # [M] per-job true speedup exponent
    p_drift: PDrift | None = None  # piecewise-constant true exponent


# A sampler draws a Scenario; ``rate`` is the sweep knob (arrivals per unit
# time; ignored by batch/trace scenarios).
ScenarioSampler = Callable[[jax.Array, int, float], Scenario]


# ------------------------------------------------------- arrival primitives
def poisson_arrivals(key: jax.Array, n_jobs: int, rate) -> jax.Array:
    """Arrival epochs of a Poisson(rate) stream: cumsum of Exp(rate) gaps."""
    gaps = jax.random.exponential(key, (n_jobs,)) / rate
    return jnp.cumsum(gaps)


def deterministic_arrivals(n_jobs: int, rate) -> jax.Array:
    """Evenly spaced arrivals at interval 1/rate (first arrival at 1/rate)."""
    return jnp.arange(1, n_jobs + 1) / rate


def bursty_arrivals(
    key: jax.Array,
    n_jobs: int,
    rate_on,
    rate_off,
    *,
    p_stay: float = 0.95,
) -> jax.Array:
    """2-state MAP on-off stream: gap ~ Exp(rate of the current state).

    The hidden state persists with probability ``p_stay`` per arrival and
    flips otherwise, so bursts have geometric length 1/(1-p_stay).  The
    state path is the parity of a cumulative flip count — no scan needed.
    """
    k_flip, k_init, k_gap = jax.random.split(key, 3)
    flips = jax.random.uniform(k_flip, (n_jobs,)) > p_stay
    s0 = jax.random.bernoulli(k_init)
    state = (s0.astype(jnp.int32) + jnp.cumsum(flips.astype(jnp.int32))) % 2
    rate = jnp.where(state == 1, rate_on, rate_off)
    gaps = jax.random.exponential(k_gap, (n_jobs,)) / rate
    return jnp.cumsum(gaps)


def pareto_sizes(key: jax.Array, n_jobs: int, alpha: float = 1.5) -> jax.Array:
    """Pareto(alpha) job sizes with minimum 1 — the benchmarks' heavy tail.

    Matches ``numpy.random.Generator.pareto(alpha) + 1`` in distribution
    (classical Pareto with x_m = 1).
    """
    return jax.random.pareto(key, alpha, (n_jobs,))


# -------------------------------------------------------------- the registry
def _any_pos(sigma) -> bool:
    """True when a scalar or per-class sequence sigma carries any noise."""
    if isinstance(sigma, (tuple, list)):
        return any(s > 0 for s in sigma)
    return sigma > 0


def _with_noise(
    scn: Scenario, key: jax.Array, p, sigma_size, sigma_p
) -> Scenario:
    """Attach estimation noise drawn from fold_in streams of ``key`` (the
    base draw consumed ``key`` itself, so noiseless runs stay bit-identical
    to the historical samplers).

    ``sigma_size``/``sigma_p`` may be scalars or per-class sequences (one
    entry per class id, requires ``scn.class_ids``).  For multi-class
    scenarios the ``p_hat`` perturbation is per-job, centered on each job's
    true class exponent ``scn.p_job``.
    """
    size_factors, p_hat = scn.size_factors, scn.p_hat
    n = scn.x0.shape[0]

    def per_job(sigma):
        if isinstance(sigma, (tuple, list)):
            if scn.class_ids is None:
                raise ValueError("per-class sigma needs a multi-class scenario")
            return jnp.asarray(sigma, scn.x0.dtype)[scn.class_ids]
        return sigma

    if _any_pos(sigma_size):
        kf = jax.random.fold_in(key, 1)
        size_factors = jnp.exp(per_job(sigma_size) * jax.random.normal(kf, (n,)))
    if _any_pos(sigma_p):
        kp = jax.random.fold_in(key, 2)
        center = scn.p_job if scn.p_job is not None else p
        per_job_hat = scn.p_job is not None or isinstance(sigma_p, (tuple, list))
        shape = (n,) if per_job_hat else ()
        p_hat = jnp.clip(
            center + per_job(sigma_p) * jax.random.normal(kp, shape), 0.05, 0.95
        )
    return scn._replace(size_factors=size_factors, p_hat=p_hat)


def _batch(key, n_jobs, rate, *, size_alpha):
    del rate
    x0 = pareto_sizes(key, n_jobs, size_alpha)
    return Scenario(x0=x0, arrival_times=jnp.zeros(n_jobs, x0.dtype))


def _poisson(key, n_jobs, rate, *, size_alpha):
    # Key discipline matches the historical load_sweep draw exactly, so the
    # default sweep is bit-identical to pre-registry results.
    k1, k2 = jax.random.split(key)
    arr = poisson_arrivals(k1, n_jobs, rate)
    x0 = pareto_sizes(k2, n_jobs, size_alpha)
    return Scenario(x0=x0, arrival_times=arr)


def _deterministic(key, n_jobs, rate, *, size_alpha):
    arr = deterministic_arrivals(n_jobs, rate)
    x0 = pareto_sizes(key, n_jobs, size_alpha)
    return Scenario(x0=x0, arrival_times=jnp.asarray(arr, x0.dtype))


def _bursty(key, n_jobs, rate, *, size_alpha, burst=4.0, p_stay=0.95):
    # rate_on/off bracket the nominal rate by ``burst``; the states are
    # visited 50/50 in steady state, so the raw mean gap would be
    # (1/burst + burst)/(2*rate) — scale both rates by that factor so the
    # long-run intensity equals the nominal ``rate`` and bursty rows are
    # load-comparable to the poisson scenario's.
    k1, k2 = jax.random.split(key)
    norm = 0.5 * (burst + 1.0 / burst)
    arr = bursty_arrivals(k1, n_jobs, rate * burst * norm,
                          rate / burst * norm, p_stay=p_stay)
    x0 = pareto_sizes(k2, n_jobs, size_alpha)
    return Scenario(x0=x0, arrival_times=arr)


def _with_drift(scn: Scenario, n_jobs, rate, *, p0, p1, drift_frac):
    """Attach a single regime change ``p0 -> p1`` at ``drift_frac`` of the
    stream's nominal span ``n_jobs / rate`` (the mean time to draw all
    arrivals), so the drift lands mid-stream at every load of a sweep."""
    dtype = scn.x0.dtype
    t_d = jnp.asarray(drift_frac * n_jobs / rate, dtype)
    drift = PDrift(
        times=t_d[None], values=jnp.asarray([p0, p1], dtype)
    )
    return scn._replace(p_drift=drift)


def _drift_poisson(
    key, n_jobs, rate, *, size_alpha, p0=0.8, p1=0.3, drift_frac=0.5
):
    scn = _poisson(key, n_jobs, rate, size_alpha=size_alpha)
    return _with_drift(scn, n_jobs, rate, p0=p0, p1=p1, drift_frac=drift_frac)


def _drift_bursty(
    key, n_jobs, rate, *, size_alpha, p0=0.8, p1=0.3, drift_frac=0.5,
    burst=4.0, p_stay=0.95,
):
    scn = _bursty(key, n_jobs, rate, size_alpha=size_alpha, burst=burst,
                  p_stay=p_stay)
    return _with_drift(scn, n_jobs, rate, p0=p0, p1=p1, drift_frac=drift_frac)


SCENARIOS: dict[str, Callable[..., Scenario]] = {
    "batch": _batch,
    "poisson": _poisson,
    "deterministic": _deterministic,
    "bursty": _bursty,
    "drift_poisson": _drift_poisson,
    "drift_bursty": _drift_bursty,
}


def make_scenario(
    name: str,
    *,
    size_alpha: float = 1.5,
    sigma_size: float = 0.0,
    sigma_p: float = 0.0,
    p: float = 0.5,
    **cfg,
) -> ScenarioSampler:
    """Build a sampler ``(key, n_jobs, rate) -> Scenario`` from the registry.

    ``sigma_size`` is the lognormal sd of the multiplicative size-estimation
    error; ``sigma_p`` the sd of the additive error on the speedup exponent
    the policy assumes (clipped to (0.05, 0.95)) — each a scalar, or a
    per-class sequence for multi-class scenarios.  ``p`` is only used as the
    center of the ``p_hat`` perturbation (multi-class scenarios center on
    each job's true class exponent instead).  Extra ``cfg`` kwargs go to the
    scenario function (e.g. ``burst``/``p_stay`` for ``bursty``, ``classes``
    for the multi-class samplers).
    """
    fn = SCENARIOS.get(name.lower())
    if fn is None:
        # The multi-class samplers register themselves on import; resolve
        # them lazily so `make_scenario("multiclass_poisson", ...)` works
        # without the caller importing core.multiclass first.
        from repro.core import multiclass  # noqa: F401  (registers samplers)

        fn = SCENARIOS.get(name.lower())
    if fn is None:
        raise ValueError(f"unknown scenario {name!r}; known: {sorted(SCENARIOS)}")

    def sample(key, n_jobs, rate):
        scn = fn(key, n_jobs, rate, size_alpha=size_alpha, **cfg)
        if _any_pos(sigma_size) or _any_pos(sigma_p):
            scn = _with_noise(scn, key, p, sigma_size, sigma_p)
        return scn

    return sample


def stream_tape(scn: Scenario) -> tuple[jax.Array, jax.Array]:
    """Reduce a :class:`Scenario` to the plain ``(sizes, arrivals)`` tape
    the bounded-slot engine (``engine.run_stream``) consumes.

    The streaming scan carries per-job state in *recycled slots*, so
    scenario features that attach per-job vectors to the whole tape have
    nothing to ride in: estimation noise (``size_factors``/``p_hat``),
    per-job class exponents (``p_job``) and drift schedules (``p_drift``)
    all raise here rather than silently dropping their physics.  Those
    regimes stay on the finite-tape ``engine.run`` path until per-slot
    state recycling grows to carry them.
    """
    for field, why in (
        ("size_factors", "estimation noise is per-job tape state"),
        ("p_hat", "estimation noise is per-job tape state"),
        ("p_job", "per-job class exponents do not ride in slots yet"),
        ("p_drift", "the drift clock belongs to the finite-tape engine"),
    ):
        if getattr(scn, field) is not None:
            raise ValueError(
                f"scenario with {field} cannot stream: {why} "
                "(use the finite-tape engine.run path)"
            )
    return scn.x0, scn.arrival_times


def trace_scenario(arrival_times, sizes) -> ScenarioSampler:
    """Replay externally supplied arrivals/sizes (key and rate are ignored)."""
    x0 = jnp.asarray(sizes)
    arr = jnp.asarray(arrival_times)

    def sample(key, n_jobs, rate):
        del key, rate
        if n_jobs != x0.shape[0]:
            raise ValueError(f"trace has {x0.shape[0]} jobs, asked for {n_jobs}")
        return Scenario(x0=x0, arrival_times=arr)

    return sample


__all__ = [
    "SCENARIOS",
    "Scenario",
    "ScenarioSampler",
    "bursty_arrivals",
    "deterministic_arrivals",
    "make_scenario",
    "pareto_sizes",
    "poisson_arrivals",
    "stream_tape",
    "trace_scenario",
]
