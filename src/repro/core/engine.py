"""One scan-based allocation engine behind every simulator in the repo.

Theorem 3 of the paper proves the optimal allocation is constant between
decision epochs, so *every* fluid trajectory this repo simulates — batch
(all jobs at t=0), online arrival streams, and the integer-chips cluster
regime — is the same loop: query an allocation rule at an event, advance
every job linearly, repeat.  This module is that loop, written once as a
single ``jax.lax.scan`` and parameterized along two axes:

- **Allocation rule** (:class:`StatefulRule`): a triple ``(init, observe,
  allocate)`` whose state threads through the event scan's carry.
  ``allocate`` maps ``(state, x_active, p)`` to ``(alloc, rate)`` per job;
  ``observe`` folds the epoch's realized :class:`Observation` (allocation,
  throughput, epoch length) back into the state — which is what lets
  *online estimation* (``core/estimation.py`` fits the speedup exponent
  p̂ from observed throughput) run jit-safe inside the scan instead of on
  a per-event Python loop.  A plain callable ``(x_active, p) -> (alloc,
  rate)`` is accepted everywhere and wrapped by :func:`as_stateful` into
  the trivial stateless instance (empty state, identity ``observe``) —
  with trivial state the scan is bit-for-bit the pre-stateful engine.
  The speedup exponent may be a scalar (the paper) or a per-job vector
  (multi-class workloads, ``core/multiclass.py``); quantized rules can
  additionally snap chip counts to power-of-two ICI slices
  (:func:`snap_to_slices_jax`).

  * :func:`continuous_rule` — the paper's continuously-divisible system:
    ``theta`` from any ``core/policies.py`` policy, rate ``s(theta_i N)``.
    Optional size-estimation noise (the scheduler acts on perturbed sizes
    ``x * size_factors`` and a perturbed exponent ``p_hat`` while the true
    dynamics use ``x`` and ``p``).
  * :func:`quantized_rule` — whole chips: ``theta`` is rounded to integer
    chip counts by :func:`quantize_allocation_jax`, the vectorized-jnp port
    of ``sched/quantize.py``'s largest-remainder apportionment with a
    min-chips floor (the NumPy version remains the oracle it is
    property-tested against).  Rate is ``s(chips_i) = chips_i ** p``.
  * :func:`run_ranked` — the sort-free rank-space fast path for policies in
    ``core.policies.RANK_POLICIES`` (heSRPT/EQUI/SRPT); it carries the
    descending-size ranks through the scan instead of re-sorting per event.

- **Scenario** (``core/scenarios.py``): where the jobs and arrival epochs
  come from — batch, trace/Poisson, bursty MAP on-off streams, size
  estimation noise — exposed through a small registry usable from the
  benchmarks.

``core/simulator.py`` (batch) and ``core/arrivals.py`` (online) are thin
wrappers over :func:`run`; ``sched/cluster.py`` delegates its fluid advance
and quantization here so integer-allocation sweeps run jit+vmap at
``load_sweep`` scale instead of one Python event at a time.

Everything is jit-able and vmap-able over seeds/loads/configs.
"""

from __future__ import annotations

from collections.abc import Callable
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.flowtime import speedup
from repro.core.policies import Policy, equi, hesrpt, knee, srpt
from repro.core.ranking import inv_rank

# (x_active, p) -> (alloc, rate); ``alloc`` is theta for continuous rules
# and integer chips for quantized rules, ``rate`` the per-job service rate.
# ``p`` may be a scalar (single class) or a per-job vector (multi-class, in
# the engine's arrival-sorted order — see :func:`run`).
AllocRule = Callable[[jax.Array, jax.Array], tuple[jax.Array, jax.Array]]


class Observation(NamedTuple):
    """What an allocation rule gets to see after each epoch.

    The fluid model's observable is exactly what a production scheduler
    measures between decision epochs: which allocation each job held
    (``alloc`` — theta for continuous rules, integer chips for quantized
    ones), the realized throughput (``rate`` = work done / wall time, the
    fluid service rate), and for how long (``dt``).  ``active`` marks the
    jobs that were present and unfinished during the epoch; rules must
    ignore inactive rows.
    """

    alloc: jax.Array  # [M] allocation held during the epoch
    rate: jax.Array  # [M] realized service rate (work per unit time)
    dt: jax.Array  # scalar epoch length (0 on no-op steps)
    active: jax.Array  # [M] bool, job arrived & unfinished this epoch


class ProbeEvent(NamedTuple):
    """What a telemetry probe (``core/telemetry.py``) sees at each event.

    A strict superset of :class:`Observation`: probes additionally read the
    epoch-start clock, the remaining sizes, the true exponent in effect
    (post-drift), and the allocation rule's carry state — which is how the
    p̂-error probe reaches an :class:`~repro.core.estimation.EstState`
    without the rule knowing it is being watched.  All per-job arrays are
    in the engine's arrival-sorted order.
    """

    t: jax.Array  # scalar epoch-start time
    dt: jax.Array  # scalar epoch length (0 on no-op steps)
    alloc: jax.Array  # [M] allocation held during the epoch
    rate: jax.Array  # [M] realized service rate
    active: jax.Array  # [M] bool, job arrived & unfinished this epoch
    x: jax.Array  # [M] remaining sizes at epoch start
    p: Any  # scalar or [M] true exponent in effect this epoch
    rule_state: Any  # the allocation rule's carry state at epoch start


class StatefulRule(NamedTuple):
    """An allocation rule with scan-carried state: ``(init, observe,
    allocate)``.

    ``init()`` builds the state pytree; ``allocate(state, x_active, p)``
    returns ``(alloc, rate)`` for the epoch; ``observe(state, obs)`` folds
    the epoch's :class:`Observation` back into the state.  The stateless
    rules (:func:`continuous_rule`, :func:`quantized_rule`) are the trivial
    instances via :func:`as_stateful`; ``core/estimation.py`` builds the
    estimating instances (online p̂ from observed throughput).
    """

    init: Callable[[], Any]
    observe: Callable[[Any, Observation], Any]
    allocate: Callable[[Any, jax.Array, jax.Array], tuple[jax.Array, jax.Array]]


def as_stateful(rule: AllocRule | StatefulRule) -> StatefulRule:
    """Wrap a plain ``(x_active, p) -> (alloc, rate)`` callable as the
    trivial :class:`StatefulRule` (empty state, identity ``observe``) —
    the wrapped scan runs the exact same ops, so stateless trajectories
    are bit-for-bit unchanged.  Already-stateful rules pass through."""
    if isinstance(rule, StatefulRule):
        return rule
    return StatefulRule(
        init=lambda: (),
        observe=lambda state, obs: state,
        allocate=lambda state, x_act, p: rule(x_act, p),
    )


class PDrift(NamedTuple):
    """Piecewise-constant true speedup exponent: regime changes mid-run.

    ``times`` are the ``D`` regime-change epochs (ascending); ``values``
    holds the ``D + 1`` regimes — scalars (shape ``[D+1]``) or per-job
    rows (shape ``[D+1, M]``, input job order; :func:`run` permutes the
    columns into arrival-sorted order).  Between ``times[r-1]`` and
    ``times[r]`` the *physics* (and the ``p`` an allocation rule is shown)
    use ``values[r]`` — e.g. a job set turning communication-bound has its
    effective ``p`` drop.  A stale scheduler keeps allocating with the old
    exponent; an online estimator (``core/estimation.py``) re-fits it from
    observed throughput.  ``core/scenarios.py``'s drift scenarios draw
    these.
    """

    times: jax.Array  # [D] regime-change epochs, ascending
    values: jax.Array  # [D+1] or [D+1, M] exponent per regime

# Power-of-two ICI-friendly slice sizes shared with ``sched.quantize``'s
# ``snap_to_slices`` NumPy oracle (single source of truth lives here so the
# engine's scan and the per-event cluster path can never disagree).
DEFAULT_SLICES = (1, 2, 4, 8, 16, 32, 64, 128, 256)


class EngineTrace(NamedTuple):
    """Per-event trajectory (in arrival-sorted job order, see ``order``)."""

    alloc: jax.Array  # [E, M] allocation chosen at each event (theta / chips)
    times: jax.Array  # [E] event start times
    sizes: jax.Array  # [E, M] remaining sizes at each event start


class EngineResult(NamedTuple):
    completion_times: jax.Array  # [M] absolute departure times, input order
    x_final: jax.Array  # [M] remaining sizes at horizon, arrival-sorted order
    order: jax.Array  # [M] arrival-sorted permutation used internally
    trace: EngineTrace | None = None  # populated when ``record=True``
    telemetry: Any = None  # probe read-out when ``run(telemetry=)`` is set


# ----------------------------------------------------------- allocation rules
def finish_alloc(
    theta: jax.Array,
    p,
    *,
    n_alloc,
    n_chips: int | None,
    min_chips: int = 1,
    snap_slices: bool = False,
    slices: tuple[int, ...] = DEFAULT_SLICES,
    dtype,
):
    """The ONE ``theta -> (alloc, rate)`` tail every allocation rule shares.

    Continuous regime (``n_chips`` is None): the allocation is ``theta``
    itself and the rate is ``s(theta * n_alloc)``.  Whole-chips regime:
    largest-remainder rounding (:func:`quantize_allocation_jax`) with a
    ``min_chips`` floor, optionally snapped to power-of-two ICI slices
    (:func:`snap_to_slices_jax`), rate ``s(chips)``.  Centralized so the
    stateless rules here, :func:`knee_rule`, the class-aware rules
    (``core/multiclass.py``) and the estimating rules
    (``core/estimation.py``) cannot desynchronize on quantization order or
    the chip unit.
    """
    theta = theta.astype(dtype)
    if n_chips is None:
        return theta, speedup(theta * n_alloc, p)
    chips = quantize_allocation_jax(theta, n_chips, min_chips=min_chips)
    if snap_slices:
        chips = snap_to_slices_jax(chips, n_chips, slices=slices)
    return chips, speedup(chips.astype(dtype), p)


def continuous_rule(
    policy: Policy,
    n_servers,
    *,
    dtype,
    size_factors: jax.Array | None = None,
    p_hat=None,
) -> AllocRule:
    """The paper's continuously-divisible allocation: ``rate = s(theta N)``.

    ``size_factors``/``p_hat`` inject estimation error: the *policy* sees
    ``x * size_factors`` and ``p_hat`` while the *dynamics* keep the true
    ``x`` and ``p`` — the scheduler mis-ranks jobs, the physics don't lie.
    NOTE: ``size_factors`` must be in arrival-sorted job order (the order
    the engine's scan runs in).

    For the heSRPT policy the returned rule carries a ``fused_variant``
    attribute — the ``kernels/alloc.py`` fused path :func:`run` swaps in
    under ``fused=True`` (bit-for-bit on CPU, on-chip on TPU).  For the
    noise-free rank family (heSRPT/EQUI/SRPT) it also carries a
    ``superstep_spec`` — the closed-form arrival-superstep path
    (``core/superstep.py``) :func:`run` dispatches to under
    ``superstep=True``.
    """

    def rule(x_act, p):
        x_seen = x_act if size_factors is None else x_act * size_factors
        p_seen = p if p_hat is None else p_hat
        return finish_alloc(
            policy(x_seen, p_seen), p, n_alloc=n_servers, n_chips=None,
            dtype=dtype,
        )

    if size_factors is None and p_hat is None:
        # Estimation noise desynchronizes the policy's ranking from the
        # physics, which breaks the closed form's departure-order premise.
        for fn, sname in ((hesrpt, "hesrpt"), (equi, "equi"), (srpt, "srpt")):
            if policy is fn:
                setattr(rule, "superstep_spec", (sname, n_servers))  # noqa: B010
                break
    if policy is hesrpt:
        from repro.kernels.alloc import hesrpt_theta_fused

        def fused(x_act, p):
            x_seen = x_act if size_factors is None else x_act * size_factors
            p_seen = p if p_hat is None else p_hat
            theta = hesrpt_theta_fused(x_seen, p_seen).astype(dtype)
            return theta, speedup(theta * n_servers, p)

        setattr(rule, "fused_variant", fused)  # noqa: B010
    return rule


def quantized_rule(
    policy: Policy,
    n_chips: int,
    *,
    min_chips: int = 1,
    dtype,
    size_factors: jax.Array | None = None,
    p_hat=None,
    snap_slices: bool = False,
    slices: tuple[int, ...] = DEFAULT_SLICES,
) -> AllocRule:
    """Whole-chips allocation: largest-remainder rounding of ``theta * N``.

    This is ``sched/cluster.py``'s decision epoch — policy then quantize —
    as a pure scan step, so the integer-allocation regime can be swept
    jit+vmap instead of one Python event at a time.  ``snap_slices=True``
    additionally restricts every job to ICI-friendly power-of-two slice
    sizes (:func:`snap_to_slices_jax`, exact vs the NumPy
    ``sched.quantize.snap_to_slices`` oracle), making the slice-snapped
    regime sweepable too.

    For the heSRPT policy the returned rule carries a ``fused_variant``
    attribute: the ``kernels/alloc.py`` fused rank -> theta -> chips pass
    (2 sorts per event instead of 3 on CPU, 0 on TPU), chip-exact vs this
    rule, selected by :func:`run`'s ``fused=True``.
    """

    def rule(x_act, p):
        x_seen = x_act if size_factors is None else x_act * size_factors
        p_seen = p if p_hat is None else p_hat
        return finish_alloc(
            policy(x_seen, p_seen), p, n_alloc=n_chips, n_chips=n_chips,
            min_chips=min_chips, snap_slices=snap_slices, slices=slices,
            dtype=dtype,
        )

    if policy is hesrpt:
        from repro.kernels.alloc import hesrpt_alloc_fused

        def fused(x_act, p):
            x_seen = x_act if size_factors is None else x_act * size_factors
            p_seen = p if p_hat is None else p_hat
            _theta, chips = hesrpt_alloc_fused(
                x_seen, p_seen, n_chips, min_chips=min_chips
            )
            if snap_slices:
                chips = snap_to_slices_jax(chips, n_chips, slices=slices)
            return chips, speedup(chips.astype(dtype), p)

        setattr(rule, "fused_variant", fused)  # noqa: B010
    return rule


def knee_rule(
    n_servers,
    *,
    n_chips: int | None = None,
    min_chips: int = 1,
    snap_slices: bool = False,
    dtype,
) -> StatefulRule:
    """KNEE with its per-epoch ``alpha`` refit, as an engine rule.

    The per-event ``ClusterScheduler`` loop re-derives KNEE's knob at every
    decision epoch — ``alpha = median(remaining work of active jobs) * p /
    N`` — which made KNEE the last policy stuck on the Python-only path:
    ``make_policy("knee")`` closes over a *static* alpha.  The refit is a
    pure function of the epoch's active set, so inside the scan it is simply
    recomputed by ``allocate`` each step; the returned
    :class:`StatefulRule` therefore carries the trivial (empty) state — the
    statefulness lives in the per-epoch recomputation, not the carry.  The
    masked median matches ``np.median`` over the active subset exactly
    (average of the two middle order statistics), so the per-event Python
    loop remains the bit-for-bit cross-check oracle.

    Continuous when ``n_chips`` is None, else whole chips (largest-remainder
    + min-chips floor, optionally slice-snapped) — the same regime split as
    :func:`continuous_rule` / :func:`quantized_rule`.
    """
    n_alloc = float(n_chips) if n_chips is not None else float(n_servers)

    def rule(x_act, p):
        active = x_act > 0
        m = jnp.maximum(jnp.sum(active, dtype=jnp.int32), 1)
        v = jnp.sort(jnp.where(active, x_act, jnp.inf))
        med = 0.5 * (v[(m - 1) // 2] + v[m // 2])
        alpha = med * p / n_alloc
        theta = knee(x_act, p, jnp.asarray(n_alloc, dtype), alpha)
        return finish_alloc(
            theta, p, n_alloc=n_alloc, n_chips=n_chips, min_chips=min_chips,
            snap_slices=snap_slices, dtype=dtype,
        )

    return as_stateful(rule)


def _resolve_fused(rule, fused: bool):
    """Swap in the rule's kernel-fused allocate when ``fused=True``."""
    if not fused:
        return rule
    fused_rule = getattr(rule, "fused_variant", None)
    if fused_rule is None:
        raise ValueError(
            "fused=True needs a rule with a fused_variant — built by "
            "continuous_rule/quantized_rule over the heSRPT policy"
        )
    return fused_rule


def _resolve_superstep(rule, *, fused, record, telemetry, p, p_drift):
    """Trace-time gate for ``run(superstep=True)``.

    Returns the rule's ``(policy_name, n_servers)`` superstep spec, or
    raises ``ValueError`` for every configuration whose physics the
    closed form cannot represent — those take the generic per-event scan
    (just drop ``superstep=True``; see ``core/superstep.py`` for the
    decision table).
    """
    fallback = " — this configuration takes the generic per-event scan"
    spec = getattr(rule, "superstep_spec", None)
    if spec is None:
        raise ValueError(
            "superstep=True needs a rule with a superstep_spec — built by "
            "continuous_rule over heSRPT/EQUI/SRPT without estimation "
            "noise (quantized and stateful/estimating rules have none)"
            + fallback
        )
    if fused:
        raise ValueError(
            "superstep=True already replaces the scan; fused= fuses the "
            "quantized per-event allocate" + fallback
        )
    if record:
        raise ValueError(
            "record=True needs the per-event trajectory" + fallback
        )
    if telemetry is not None:
        raise ValueError(
            "telemetry probes ride the per-event scan" + fallback
        )
    if jnp.ndim(p) >= 1:
        raise ValueError(
            "superstep=True needs a scalar p (per-job exponents break the "
            "rank-order departure invariant)" + fallback
        )
    if p_drift is not None and jnp.asarray(p_drift.values).ndim != 1:
        raise ValueError(
            "superstep=True supports scalar drift regimes only" + fallback
        )
    return spec


# ------------------------------------------------------------ the event scan
def run(
    x0: jax.Array,
    arrival_times: jax.Array,
    p,
    rule: AllocRule | StatefulRule,
    *,
    pre_arrived: bool = False,
    horizon: int | None = None,
    rel_tol: float = 1e-9,
    t0=0.0,
    record: bool = False,
    p_drift: PDrift | None = None,
    fused: bool = False,
    superstep: bool = False,
    telemetry: Any = None,
) -> EngineResult:
    """Run the event-driven fluid trajectory to completion in one scan.

    Each step advances to the next event (``min`` of next departure and next
    arrival), re-querying ``rule`` on the active set — the paper's Thm 3
    epoch structure, with arrivals as the §4.3 heuristic.  An M-job stream
    has at most ``2M`` events (``M`` with ``pre_arrived=True``, at least one
    job departing per step for work-conserving rules), which bounds the scan
    length; steps after the last event are no-ops.

    ``rule`` is a :class:`StatefulRule` or a plain ``(x_active, p) ->
    (alloc, rate)`` callable (wrapped via :func:`as_stateful`; bit-for-bit
    the stateless scan).  A stateful rule's state rides in the scan carry:
    each step calls ``allocate`` on the epoch-start state and ``observe``
    on the realized epoch, so estimators update once per event — the same
    observation schedule a per-event scheduler loop would produce.

    ``pre_arrived=True`` marks every job as already present (the batch
    case): ``arrival_times`` then only defines the job order and flow-time
    zero points.  Jobs that never depart within the horizon report ``inf``.
    ``record=True`` additionally returns the full per-event trajectory
    (allocations, event times, remaining sizes) in arrival-sorted order.

    ``p`` may be a scalar (the paper's single job class) or a per-job
    vector in *input* order (the multi-class case: each job carries its
    class's speedup exponent).  A vector ``p`` is permuted into the
    engine's arrival-sorted order alongside the sizes before it reaches
    ``rule`` — rule closures over per-job vectors (weights, noise factors)
    must be pre-sorted the same way by the caller.

    ``p_drift`` makes the *true* exponent piecewise-constant in time
    (:class:`PDrift`; it then supersedes ``p``): regime boundaries become
    events of their own — ``dt`` is clamped so no epoch straddles one, the
    next epoch re-queries the rule under the new exponent — which costs at
    most one extra scan step per boundary (the default horizon accounts
    for them).

    ``fused=True`` swaps in the rule's ``fused_variant`` — the
    ``kernels/alloc.py`` single-pass allocate attached by
    :func:`continuous_rule` / :func:`quantized_rule` for the heSRPT policy
    (chip-exact; see that module for the collapse) — and raises
    ``ValueError`` for rules without one.

    ``superstep=True`` dispatches to the closed-form arrival-superstep
    path (``core/superstep.py``): zero scan steps for ``pre_arrived``
    batches, one step per arrival/drift boundary online — for the rules
    that carry a ``superstep_spec`` (:func:`continuous_rule` over
    heSRPT/EQUI/SRPT, noise-free).  Everything else — quantized chips,
    stateful/estimating rules, per-job ``p``, per-job drift rows,
    ``record``, ``telemetry``, ``fused`` — raises at trace time and takes
    this generic per-event scan instead.  ``rel_tol`` is ignored there
    (the analytic trajectory has no float residue to clamp).

    ``telemetry`` takes a probe (``core/telemetry.py``: ``(init, step,
    finalize)``) whose state rides in the scan carry; each step sees the
    epoch's :class:`ProbeEvent` and the finalized read-out is returned on
    ``EngineResult.telemetry``.  The branch is resolved at trace time:
    with ``telemetry=None`` the compiled program is *exactly* the probe-
    free scan — trajectories stay bit-for-bit identical (tested against
    the golden pins).
    """
    if superstep:
        pol_name, n_srv = _resolve_superstep(
            rule, fused=fused, record=record, telemetry=telemetry, p=p,
            p_drift=p_drift,
        )
        from repro.core.superstep import run_superstep

        return run_superstep(
            x0, arrival_times, p, n_srv, pol_name,
            pre_arrived=pre_arrived, horizon=horizon, t0=t0,
            p_drift=p_drift,
        )
    rule = _resolve_fused(rule, fused)
    x0 = jnp.asarray(x0)
    M = x0.shape[0]
    n_drift = 0 if p_drift is None else p_drift.times.shape[0]
    E = ((M if pre_arrived else 2 * M) + n_drift) if horizon is None else horizon
    dtype = jnp.result_type(x0.dtype, jnp.float32)
    x0 = x0.astype(dtype)
    arrival_times = jnp.asarray(arrival_times).astype(dtype)
    tol = rel_tol * jnp.max(x0)

    # Event logic walks arrivals in time order; un-sort at the end.
    order = jnp.argsort(arrival_times)
    arr = arrival_times[order]
    xs = x0[order]
    if jnp.ndim(p) >= 1:  # per-job exponents travel with their jobs
        p = jnp.asarray(p)[order]
    if p_drift is not None:
        drift_t = jnp.asarray(p_drift.times).astype(dtype)
        drift_v = jnp.asarray(p_drift.values).astype(dtype)
        if drift_v.ndim == 2:  # per-job regime rows travel with their jobs
            drift_v = drift_v[:, order]
    idx = jnp.arange(M)
    i0 = jnp.asarray(M if pre_arrived else 0, jnp.int32)
    srule = as_stateful(rule)

    def body(carry, _):
        if telemetry is None:
            x, t, i, times, st = carry
        else:
            x, t, i, times, st, tel = carry
        active = (idx < i) & (x > 0)
        x_act = jnp.where(active, x, 0.0)
        if p_drift is None:
            p_now = p
            dt_drift = jnp.inf
            t_next_drift = jnp.inf
        else:
            r = jnp.searchsorted(drift_t, t, side="right")
            p_now = drift_v[r]
            n_d = drift_t.shape[0]
            t_next_drift = jnp.where(
                r < n_d, drift_t[jnp.minimum(r, n_d - 1)], jnp.inf
            )
            dt_drift = jnp.maximum(t_next_drift - t, 0.0)
        alloc, rate = srule.allocate(st, x_act, p_now)
        tt = jnp.where(active & (rate > 0), x / rate, jnp.inf)
        dt_dep = jnp.min(tt)  # inf when nothing is active
        t_next_arr = jnp.where(i < M, arr[jnp.minimum(i, M - 1)], jnp.inf)
        dt_arr = jnp.maximum(t_next_arr - t, 0.0)
        dt = jnp.minimum(jnp.minimum(dt_dep, dt_arr), dt_drift)
        any_event = jnp.isfinite(dt)
        dt = jnp.where(any_event, dt, 0.0)
        # Landing on an arrival pins t to the exact arrival time so the
        # searchsorted admission below cannot miss it to float rounding
        # (same for a drift boundary: the next epoch's regime lookup uses
        # side="right", so t == boundary already reads the new exponent).
        admit = any_event & (dt_arr <= jnp.minimum(dt_dep, dt_drift))
        take_dep = any_event & (dt_dep <= jnp.minimum(dt_arr, dt_drift))
        take_drift = any_event & ~admit & ~take_dep
        t_new = jnp.where(
            admit, t_next_arr, jnp.where(take_drift, t_next_drift, t + dt)
        )
        x_new = jnp.where(active, x - dt * rate, x)
        # The argmin job departs BY CONSTRUCTION when the departure is the
        # next event; float residue (~eps*x) must not be allowed to keep it.
        departing = (idx == jnp.argmin(tt)) & active & take_dep
        x_new = jnp.where(departing | (active & (x_new <= tol)), 0.0, x_new)
        newly_done = active & (x_new == 0.0)
        times = jnp.where(newly_done, t_new, times)
        i_new = jnp.searchsorted(arr, t_new, side="right").astype(i.dtype)
        i_new = jnp.maximum(i, i_new)  # monotone even on no-op steps
        st_new = srule.observe(
            st, Observation(alloc=alloc, rate=rate, dt=dt, active=active)
        )
        out = (alloc, t, x) if record else None
        if telemetry is None:
            return (x_new, t_new, i_new, times, st_new), out
        tel_new, tel_out = telemetry.step(
            tel,
            ProbeEvent(
                t=t, dt=dt, alloc=alloc, rate=rate, active=active, x=x,
                p=p_now, rule_state=st,
            ),
        )
        return (x_new, t_new, i_new, times, st_new, tel_new), (out, tel_out)

    init = (xs, jnp.asarray(t0, dtype), i0, jnp.zeros(M, dtype), srule.init())
    if telemetry is not None:
        init = (*init, telemetry.init())
    carry_fin, ys = jax.lax.scan(body, init, None, length=E)
    x_fin, _, _, times = carry_fin[:4]
    tel_result = None
    if telemetry is not None:
        ys, tel_ys = ys
        tel_result = telemetry.finalize(carry_fin[5], tel_ys)
    # Safety: any job that never departed (pathological rule) -> inf.
    times = jnp.where(x_fin > 0, jnp.inf, times)
    times_in = jnp.zeros(M, dtype).at[order].set(times)  # back to input order
    trace = EngineTrace(alloc=ys[0], times=ys[1], sizes=ys[2]) if record else None
    return EngineResult(
        completion_times=times_in, x_final=x_fin, order=order, trace=trace,
        telemetry=tel_result,
    )


def run_ranked(
    x0: jax.Array,
    arrival_times: jax.Array,
    p,
    n_servers,
    rank_policy,
    *,
    horizon: int | None = None,
) -> jax.Array:
    """Sort-free fast path of :func:`run` for rank-space policies.

    ``rank_policy(ranks, m, p) -> theta`` must be a pure function of the
    descending-size ranks (Thm 6 size-invariance), with rates non-increasing
    in remaining size — true for heSRPT, EQUI and SRPT (see
    ``core.policies.RANK_POLICIES``).  Those two properties give two
    invariants this scan exploits:

    - the size order of active jobs never changes between events, so the
      rank vector can be *carried* and updated in O(M) per event (an arrival
      inserts one rank, a departure removes the highest) instead of
      re-sorted — XLA's per-step sort is what makes the generic path ~20x
      slower at M=1000;
    - the next departure is always the current-smallest active job (rank m),
      so no argmin over per-job finish times is needed.

    Admissions are one job per step, so the default ``2M`` horizon (M
    arrivals + M departures) is exact.  Agreement with the generic path is
    property-tested in tests/test_arrivals.py.

    Tie handling: jobs with *exactly* equal remaining sizes get distinct
    adjacent ranks (ties break by arrival order, as in
    ``size_ranks_desc``).  For SRPT this serves tied jobs in the opposite
    order to the generic path's ``argmin`` — per-job times permute within
    the tied group, while totals/means are exchange-invariant.  Ties are
    measure-zero for continuous size distributions.

    Returns the per-job completion times in input order (``inf`` if never
    departed).

    ``p`` must be a *scalar*: with per-job exponents (multi-class) the
    service rate is no longer monotone in remaining size, so neither
    carried invariant survives — multi-class streams take the generic
    :func:`run` path (or are statically dispatched back here when every
    class shares one exponent, see ``core/multiclass.py``).
    """
    x0 = jnp.asarray(x0)
    M = x0.shape[0]
    E = 2 * M if horizon is None else horizon
    dtype = jnp.result_type(x0.dtype, jnp.float32)
    x0 = x0.astype(dtype)
    arrival_times = jnp.asarray(arrival_times).astype(dtype)

    order = jnp.argsort(arrival_times)  # one sort total, not one per event
    arr = arrival_times[order]
    xs = x0[order]
    idx = jnp.arange(M)

    def body(carry, _):
        x, t, i, ranks, m, times = carry
        theta = rank_policy(ranks, m, p, dtype=dtype)
        rate = speedup(theta * n_servers, p)
        # Next departure: the smallest active job, i.e. rank m, found by
        # argmax since ranks are unique with maximum m (0 when inactive).
        small = jnp.argmax(ranks)
        has_active = m > 0
        x_s = x[small]
        r_s = rate[small]
        dt_dep = jnp.where(has_active & (r_s > 0), x_s / r_s, jnp.inf)
        t_next_arr = jnp.where(i < M, arr[jnp.minimum(i, M - 1)], jnp.inf)
        dt_arr = jnp.maximum(t_next_arr - t, 0.0)
        dt = jnp.minimum(dt_dep, dt_arr)
        any_event = jnp.isfinite(dt)
        dt = jnp.where(any_event, dt, 0.0)
        admit = any_event & (dt_arr <= dt_dep)
        take_dep = any_event & (dt_dep <= dt_arr)
        t_new = jnp.where(admit, t_next_arr, t + dt)
        active = ranks > 0
        x_new = jnp.where(active, jnp.maximum(x - dt * rate, 0.0), x)
        # Departure: drop rank m; every other active rank stays valid.
        departing = (idx == small) & active & take_dep
        x_new = jnp.where(departing, 0.0, x_new)
        times = jnp.where(departing, t_new, times)
        ranks = jnp.where(departing, 0, ranks)
        m = m - jnp.where(take_dep & has_active, 1, 0)
        # Arrival: insert job i at its rank among the (post-departure)
        # active set; ties break by index, matching size_ranks_desc.
        i_c = jnp.minimum(i, M - 1)
        x_a = xs[i_c]
        still = ranks > 0
        ahead = still & ((x_new > x_a) | ((x_new == x_a) & (idx < i_c)))
        r_a = 1 + jnp.sum(ahead, dtype=jnp.int32)
        bumped = jnp.where(still & (ranks >= r_a), ranks + 1, ranks)
        inserted = bumped.at[i_c].set(r_a)
        ranks = jnp.where(admit, inserted, ranks)
        m = m + jnp.where(admit, 1, 0)
        i = i + jnp.where(admit, 1, 0)
        return (x_new, t_new, i, ranks, m, times), None

    init = (
        xs,
        jnp.zeros((), dtype),
        jnp.zeros((), jnp.int32),
        jnp.zeros(M, jnp.int32),
        jnp.zeros((), jnp.int32),
        jnp.zeros(M, dtype),
    )
    (x_fin, _, _, ranks_fin, _, times), _ = jax.lax.scan(
        body, init, None, length=E
    )
    times = jnp.where((x_fin > 0) | (ranks_fin > 0), jnp.inf, times)
    return jnp.zeros(M, dtype).at[order].set(times)


# ----------------------------------------------------- bounded-slot streaming
class StreamSource(NamedTuple):
    """Pull-based arrival stream for the bounded-slot engine.

    ``init()`` builds the carried stream state; ``peek(state)`` reads the
    next arrival's ``(time, size)`` without consuming it (``time = inf``
    once exhausted); ``advance(state)`` consumes it.  The peek/advance
    split is what lets :func:`run_stream` defer an arrival for any number
    of events while the slot pool is full and still admit it later — the
    recorded arrival time stays the stream's true one, so blocked wait
    counts toward flow time.
    """

    init: Callable[[], Any]
    peek: Callable[[Any], tuple[jax.Array, jax.Array]]
    advance: Callable[[Any], Any]


def tape_source(x0_sorted: jax.Array, arrivals_sorted: jax.Array) -> StreamSource:
    """A finite, arrival-sorted ``(sizes, times)`` tape as a StreamSource.

    State is the next tape index; :func:`run_stream`'s admission counter
    then equals the tape position, which is what lets it scatter
    completion times back to jobs (``record_times=True``).
    """
    x0_sorted = jnp.asarray(x0_sorted)
    arrivals_sorted = jnp.asarray(arrivals_sorted)
    T = x0_sorted.shape[0]

    def init():
        return jnp.zeros((), jnp.int32)

    def peek(i):
        j = jnp.minimum(i, T - 1)
        t_next = jnp.where(i < T, arrivals_sorted[j], jnp.inf)
        return t_next, x0_sorted[j]

    def advance(i):
        return i + 1

    return StreamSource(init=init, peek=peek, advance=advance)


def poisson_source(key: jax.Array, rate, *, size_alpha: float = 1.5, dtype) -> StreamSource:
    """A truly unbounded Poisson/Pareto arrival stream in O(1) state.

    State is ``(key, t_next, x_next)`` — one PRNG key plus the peeked
    arrival — so no tape is ever materialized: through
    :func:`run_stream_source` the whole simulation is O(n_slots) memory
    for any event budget.  Gaps are Exp(``rate``), sizes Pareto
    (``size_alpha``, minimum 1) — the same laws the ``poisson`` scenario
    samples, equal in distribution but not sample-path equal (the tape
    sampler draws one batch from two keys; this stream splits a fresh key
    per arrival).
    """

    def draw(k):
        k_next, k_gap, k_size = jax.random.split(k, 3)
        gap = jax.random.exponential(k_gap, dtype=dtype) / rate
        size = jax.random.pareto(k_size, size_alpha, dtype=dtype)
        return k_next, gap, size

    def init():
        k_next, gap, size = draw(key)
        return (k_next, jnp.asarray(gap, dtype), jnp.asarray(size, dtype))

    def peek(state):
        _, t_next, x_next = state
        return t_next, x_next

    def advance(state):
        k, t_next, _ = state
        k_next, gap, size = draw(k)
        return (k_next, t_next + gap, size)

    return StreamSource(init=init, peek=peek, advance=advance)


class StreamResult(NamedTuple):
    """Read-out of a bounded-slot streaming run.

    The ``w``-prefixed docs below mean the stationary window ``[lo, hi)``
    (``window=None`` = the whole stream): flow/slowdown aggregates count
    jobs that *arrived* inside the window and completed within the event
    budget, so near a window's trailing edge long jobs are right-censored
    exactly as a finite-horizon measurement would censor them — pick
    windows (and budgets) that let the tail drain when that matters.
    Slowdown compares against running alone on ``n_alone`` servers:
    ``flow / (size / s(n_alone))``.
    """

    mean_flow: jax.Array  # windowed mean flow time
    mean_slowdown: jax.Array  # windowed mean slowdown
    n_window: jax.Array  # completions counted into the window
    n_arrived_window: jax.Array  # admissions whose arrival fell in the window
    flow_sum: jax.Array  # windowed flow-time sum
    slow_sum: jax.Array  # windowed slowdown sum
    n_admitted: jax.Array  # arrivals admitted to a slot
    n_completed: jax.Array  # total departures
    blocked_steps: jax.Array  # events where a full pool deferred an arrival
    occupancy_max: jax.Array  # peak in-flight jobs (epoch-start census)
    t_final: jax.Array  # clock at the end of the scan
    x_final: jax.Array  # [n_slots] remaining sizes (0 = free slot)
    completion_times: jax.Array | None  # [n_jobs] input order (record_times)
    telemetry: Any  # TelemetryResult when a probe was attached


def _window_bounds(window, dtype):
    if window is None:
        return jnp.asarray(-jnp.inf, dtype), jnp.asarray(jnp.inf, dtype)
    lo, hi = window
    return jnp.asarray(lo, dtype), jnp.asarray(hi, dtype)


def _finalize_stream(acc, t_fin, x_fin, comp, tel, dtype) -> StreamResult:
    n_w = jnp.maximum(acc["w_count"], 1).astype(dtype)
    return StreamResult(
        mean_flow=acc["w_flow"] / n_w,
        mean_slowdown=acc["w_slow"] / n_w,
        n_window=acc["w_count"],
        n_arrived_window=acc["w_arrived"],
        flow_sum=acc["w_flow"],
        slow_sum=acc["w_slow"],
        n_admitted=acc["n_admitted"],
        n_completed=acc["n_completed"],
        blocked_steps=acc["blocked"],
        occupancy_max=acc["occ_max"],
        t_final=t_fin,
        x_final=x_fin,
        completion_times=comp,
        telemetry=tel,
    )


def _stream_scan(
    source: StreamSource, p, srule: StatefulRule, *, n_slots: int,
    n_events: int, w_lo, w_hi, alone_rate, tol, t0, dtype, n_times: int,
    telemetry,
):
    """The bounded-slot event scan shared by the tape and source runners.

    Carries only ``[n_slots]`` per-job state (remaining size, original
    size, arrival time, job id) plus O(1) scalars, so memory and per-event
    cost are flat in the number of jobs ever streamed.  Slot lifecycle:
    a slot is *free* iff its remaining size is 0; an admitted arrival
    claims the free slot with the smallest cyclic offset after a rotating
    ring pointer and a completion simply zeroes its slot.  With
    ``n_slots >= n_jobs`` the pointer never wraps, slot ``i`` is the
    ``i``-th arrival, and every per-step quantity equals :func:`run`'s —
    the bit-for-bit reduction the tests pin.  When the pool is full the
    next arrival is *deferred* (the arrival leg of the event race drops
    out) and admitted — at its true arrival time, so the wait counts
    toward flow — on a later event once a departure frees a slot.
    """
    S = int(n_slots)
    idx = jnp.arange(S)
    zi = jnp.zeros((), jnp.int32)
    acc0 = {
        "n_admitted": zi, "n_completed": zi, "w_count": zi,
        "w_arrived": zi, "blocked": zi, "occ_max": zi,
        "w_flow": jnp.zeros((), dtype), "w_slow": jnp.zeros((), dtype),
    }

    def body(carry, _):
        if telemetry is None:
            slots, t, ptr, src, st, acc, times = carry
        else:
            slots, t, ptr, src, st, acc, times, tel = carry
        x, sx0, sarr, sid = slots
        active = x > 0  # free slots hold exactly 0, like completed jobs
        x_act = jnp.where(active, x, 0.0)
        alloc, rate = srule.allocate(st, x_act, p)
        tt = jnp.where(active & (rate > 0), x / rate, jnp.inf)
        dt_dep = jnp.min(tt)
        t_next, x_next = source.peek(src)
        dt_arr = jnp.maximum(t_next - t, 0.0)
        free = ~active
        has_free = jnp.any(free)
        # A full pool defers the arrival: it drops out of the event race
        # until a departure frees a slot.
        eff_dt_arr = jnp.where(has_free, dt_arr, jnp.inf)
        dt = jnp.minimum(dt_dep, eff_dt_arr)
        any_event = jnp.isfinite(dt)
        dt = jnp.where(any_event, dt, 0.0)
        admit = any_event & has_free & (dt_arr <= dt_dep)
        take_dep = any_event & (dt_dep <= eff_dt_arr)
        blocked_now = jnp.isfinite(dt_dep) & ~has_free & (dt_arr < dt_dep)
        # On-time admissions pin t to the exact arrival time (as in `run`);
        # a deferred arrival is admitted at the later clock t.
        t_new = jnp.where(admit, jnp.maximum(t_next, t), t + dt)
        x_new = jnp.where(active, x - dt * rate, x)
        departing = (idx == jnp.argmin(tt)) & active & take_dep
        x_new = jnp.where(departing | (active & (x_new <= tol)), 0.0, x_new)
        newly_done = active & (x_new == 0.0)
        # Windowed flow/slowdown, vectorized: the tol clamp can finish
        # several stragglers in one step.  sx0 init 1.0 keeps idle slots'
        # (masked-out) slowdown read free of 0/0.
        flow = t_new - sarr
        slow = flow * alone_rate / sx0
        done_w = newly_done & (sarr >= w_lo) & (sarr < w_hi)
        if times is not None:
            tix = jnp.where(newly_done, sid, n_times)
            times = times.at[tix].set(t_new, mode="drop")
        # Claim: the free slot at the smallest cyclic offset after the
        # ring pointer (epoch-start free mask — the departing slot is
        # claimable from the *next* event, matching the admit gate above).
        offs = (idx - ptr) % S
        cand = jnp.argmin(jnp.where(free, offs, S)).astype(jnp.int32)
        claimed = admit & (idx == cand)
        arr_id = acc["n_admitted"]
        x_new = jnp.where(claimed, x_next, x_new)
        acc_new = {
            "n_admitted": arr_id + admit,
            "n_completed": acc["n_completed"]
            + jnp.sum(newly_done, dtype=jnp.int32),
            "w_count": acc["w_count"] + jnp.sum(done_w, dtype=jnp.int32),
            "w_arrived": acc["w_arrived"]
            + (admit & (t_next >= w_lo) & (t_next < w_hi)),
            "blocked": acc["blocked"] + blocked_now,
            "occ_max": jnp.maximum(
                acc["occ_max"], jnp.sum(active, dtype=jnp.int32)
            ),
            "w_flow": acc["w_flow"] + jnp.sum(jnp.where(done_w, flow, 0.0)),
            "w_slow": acc["w_slow"] + jnp.sum(jnp.where(done_w, slow, 0.0)),
        }
        slots_new = (
            x_new,
            jnp.where(claimed, x_next, sx0),
            jnp.where(claimed, t_next, sarr),
            jnp.where(claimed, arr_id, sid),
        )
        ptr_new = jnp.where(admit, (cand + 1) % S, ptr)
        src_adv = source.advance(src)
        src_new = jax.tree.map(
            lambda a, b: jnp.where(admit, a, b), src_adv, src
        )
        st_new = srule.observe(
            st, Observation(alloc=alloc, rate=rate, dt=dt, active=active)
        )
        if telemetry is None:
            carry = (slots_new, t_new, ptr_new, src_new, st_new, acc_new, times)
            return carry, None
        tel_new, tel_out = telemetry.step(
            tel,
            ProbeEvent(
                t=t, dt=dt, alloc=alloc, rate=rate, active=active, x=x,
                p=p, rule_state=st,
            ),
        )
        carry = (
            slots_new, t_new, ptr_new, src_new, st_new, acc_new, times, tel_new
        )
        return carry, tel_out

    slots0 = (
        jnp.zeros(S, dtype),  # remaining size: free slots hold 0
        jnp.ones(S, dtype),  # original size (1.0: see slowdown note above)
        jnp.zeros(S, dtype),  # arrival time
        jnp.full(S, n_times, jnp.int32),  # job id (sentinel = never used)
    )
    times0 = jnp.full(n_times, jnp.inf, dtype) if n_times else None
    init = (slots0, jnp.asarray(t0, dtype), zi, source.init(), srule.init(),
            acc0, times0)
    if telemetry is not None:
        init = (*init, telemetry.init())
    carry_fin, tel_ys = jax.lax.scan(body, init, None, length=n_events)
    tel_result = None
    if telemetry is not None:
        tel_result = telemetry.finalize(carry_fin[7], tel_ys)
    slots_fin, t_fin = carry_fin[0], carry_fin[1]
    return slots_fin[0], t_fin, carry_fin[5], carry_fin[6], tel_result


def run_stream(
    x0: jax.Array,
    arrival_times: jax.Array,
    p,
    rule: AllocRule | StatefulRule,
    *,
    n_slots: int,
    window: tuple[Any, Any] | None = None,
    n_alone=1.0,
    horizon: int | None = None,
    rel_tol: float = 1e-9,
    t0=0.0,
    record_times: bool = False,
    fused: bool = False,
    telemetry: Any = None,
) -> StreamResult:
    """:func:`run` over a fixed pool of ``n_slots`` recycled job slots.

    Same event loop, same rules (stateful, fused, telemetry all compose),
    but the scan carries ``[n_slots]`` state instead of ``[n_jobs]``: the
    tape can be arbitrarily long while memory stays O(n_slots) and each
    event pays O(n_slots log n_slots) in the rule's sort instead of
    O(n_jobs log n_jobs).  At any stable load the in-flight population is
    O(load), not O(horizon), so ``n_slots`` is a small constant — see
    :func:`_stream_scan` for the slot lifecycle and the full-pool
    (deferred-admission) semantics, and :func:`run_stream_source` for the
    tape-free unbounded variant.

    Reduction: with ``n_slots >= n_jobs`` the trajectory is value-
    identical to :func:`run` on the same tape (tested bit-for-bit), with
    two measure-zero caveats — exactly tied arrival times are admitted
    one per event here (extra zero-length epochs; `run` batch-admits
    them), and a departure epoch whose float rounding overshoots the next
    arrival time admits that arrival one epoch later.

    ``window=(lo, hi)`` selects the stationary measurement window (see
    :class:`StreamResult`); ``record_times=True`` additionally scatters
    per-job completion times (input order) through an ``[n_jobs]`` carry
    — parity/debug tooling, not the O(n_slots) production path.  ``p``
    must be a scalar: per-job exponents would have to ride in the slots
    (future work), and ``p_drift``'s global regime clock belongs to the
    finite-tape engine.
    """
    if jnp.ndim(p) != 0:
        raise ValueError(
            "run_stream needs a scalar p — per-job exponents do not ride "
            "in slots yet; multi-class streams take the finite-tape run()"
        )
    rule = _resolve_fused(rule, fused)
    x0 = jnp.asarray(x0)
    T = x0.shape[0]
    E = 2 * T if horizon is None else horizon
    dtype = jnp.result_type(x0.dtype, jnp.float32)
    x0 = x0.astype(dtype)
    arrival_times = jnp.asarray(arrival_times).astype(dtype)
    tol = rel_tol * jnp.max(x0)
    order = jnp.argsort(arrival_times)
    source = tape_source(x0[order], arrival_times[order])
    w_lo, w_hi = _window_bounds(window, dtype)
    x_fin, t_fin, acc, times, tel = _stream_scan(
        source, p, as_stateful(rule), n_slots=n_slots, n_events=E,
        w_lo=w_lo, w_hi=w_hi, alone_rate=speedup(jnp.asarray(n_alone, dtype), p),
        tol=tol, t0=jnp.asarray(t0, dtype), dtype=dtype,
        n_times=T if record_times else 0, telemetry=telemetry,
    )
    comp = None
    if record_times:
        comp = jnp.zeros(T, dtype).at[order].set(times)
    return _finalize_stream(acc, t_fin, x_fin, comp, tel, dtype)


def run_stream_source(
    source: StreamSource,
    p,
    rule: AllocRule | StatefulRule,
    *,
    n_slots: int,
    n_events: int,
    window: tuple[Any, Any] | None = None,
    n_alone=1.0,
    x_scale=1.0,
    rel_tol: float = 1e-9,
    t0=0.0,
    dtype=jnp.float64,
    fused: bool = False,
    telemetry: Any = None,
) -> StreamResult:
    """:func:`run_stream` for an unbounded :class:`StreamSource`.

    Runs exactly ``n_events`` scan steps against a generator source (e.g.
    :func:`poisson_source`), so nothing anywhere is sized by a job count:
    the millions-of-users regime in O(n_slots) memory.  The completion
    tolerance is absolute — ``rel_tol * x_scale``, with ``x_scale`` the
    caller's typical-size scale (there is no tape to take a max over).
    Per-job completion times are not recorded (no finite job set to
    scatter into); windowed aggregates and telemetry are the read-out.
    """
    if jnp.ndim(p) != 0:
        raise ValueError(
            "run_stream_source needs a scalar p — per-job exponents do "
            "not ride in slots yet"
        )
    rule = _resolve_fused(rule, fused)
    w_lo, w_hi = _window_bounds(window, dtype)
    x_fin, t_fin, acc, _, tel = _stream_scan(
        source, p, as_stateful(rule), n_slots=n_slots, n_events=n_events,
        w_lo=w_lo, w_hi=w_hi,
        alone_rate=speedup(jnp.asarray(n_alone, dtype), p),
        tol=jnp.asarray(rel_tol * x_scale, dtype),
        t0=jnp.asarray(t0, dtype), dtype=dtype, n_times=0,
        telemetry=telemetry,
    )
    return _finalize_stream(acc, t_fin, x_fin, None, tel, dtype)


def run_stream_ranked(
    x0: jax.Array,
    arrival_times: jax.Array,
    p,
    n_servers,
    rank_policy,
    *,
    n_slots: int,
    window: tuple[Any, Any] | None = None,
    n_alone=1.0,
    horizon: int | None = None,
    t0=0.0,
    record_times: bool = False,
) -> StreamResult:
    """:func:`run_ranked` over a fixed pool of recycled job slots.

    The rank-space fast path and the bounded-slot refactor compose: ranks
    live on slots (0 = free, which is also how :func:`run_ranked` marks
    inactive jobs), a departure drops rank ``m``, an arrival inserts one
    rank and claims a slot from the ring pointer.  Per-event cost is
    O(n_slots) with no sort at all.  Admission, deferral and windowed
    accounting follow :func:`run_stream` exactly (same reduction to
    :func:`run_ranked` when ``n_slots >= n_jobs``, same blocked-arrival
    semantics when smaller), so the two streaming paths agree the same
    way the two finite-tape paths do.
    """
    if jnp.ndim(p) != 0:
        raise ValueError("run_stream_ranked needs a scalar p (see run_ranked)")
    x0 = jnp.asarray(x0)
    T = x0.shape[0]
    S = int(n_slots)
    E = 2 * T if horizon is None else horizon
    dtype = jnp.result_type(x0.dtype, jnp.float32)
    x0 = x0.astype(dtype)
    arrival_times = jnp.asarray(arrival_times).astype(dtype)
    order = jnp.argsort(arrival_times)
    arr = arrival_times[order]
    xs = x0[order]
    idx = jnp.arange(S)
    w_lo, w_hi = _window_bounds(window, dtype)
    alone_rate = speedup(jnp.asarray(n_alone, dtype), p)
    n_times = T if record_times else 0
    zi = jnp.zeros((), jnp.int32)

    def body(carry, _):
        slots, ranks, m, t, i, ptr, acc, times = carry
        x, sx0, sarr, sid = slots
        theta = rank_policy(ranks, m, p, dtype=dtype)
        rate = speedup(theta * n_servers, p)
        small = jnp.argmax(ranks)
        has_active = m > 0
        x_s = x[small]
        r_s = rate[small]
        dt_dep = jnp.where(has_active & (r_s > 0), x_s / r_s, jnp.inf)
        t_next = jnp.where(i < T, arr[jnp.minimum(i, T - 1)], jnp.inf)
        dt_arr = jnp.maximum(t_next - t, 0.0)
        has_free = m < S
        eff_dt_arr = jnp.where(has_free, dt_arr, jnp.inf)
        dt = jnp.minimum(dt_dep, eff_dt_arr)
        any_event = jnp.isfinite(dt)
        dt = jnp.where(any_event, dt, 0.0)
        admit = any_event & has_free & (dt_arr <= dt_dep)
        take_dep = any_event & (dt_dep <= eff_dt_arr)
        blocked_now = jnp.isfinite(dt_dep) & ~has_free & (dt_arr < dt_dep)
        t_new = jnp.where(admit, jnp.maximum(t_next, t), t + dt)
        active = ranks > 0
        x_new = jnp.where(active, jnp.maximum(x - dt * rate, 0.0), x)
        departing = (idx == small) & active & take_dep
        dep_real = take_dep & has_active
        x_new = jnp.where(departing, 0.0, x_new)
        # Windowed accounting on the single departer (rank m).
        arr_s = sarr[small]
        flow = t_new - arr_s
        slow = flow * alone_rate / sx0[small]
        cw = dep_real & (arr_s >= w_lo) & (arr_s < w_hi)
        if times is not None:
            tj = jnp.where(dep_real, sid[small], n_times)
            times = times.at[tj].set(t_new, mode="drop")
        ranks = jnp.where(departing, 0, ranks)
        m_mid = m - jnp.where(dep_real, 1, 0)
        # Arrival: claim a slot from the ring pointer (epoch-start free
        # mask, as in _stream_scan) and insert its rank among the post-
        # departure active set.  Every active job arrived earlier, so the
        # arriving job loses exact-size ties — the same predicate as
        # run_ranked's ``idx < i_c`` (see its tie-handling note).
        free = ~active
        offs = (idx - ptr) % S
        cand = jnp.argmin(jnp.where(free, offs, S)).astype(jnp.int32)
        x_a = xs[jnp.minimum(i, T - 1)]
        still = ranks > 0
        ahead = still & (x_new >= x_a)
        r_a = 1 + jnp.sum(ahead, dtype=jnp.int32)
        bumped = jnp.where(still & (ranks >= r_a), ranks + 1, ranks)
        inserted = bumped.at[cand].set(r_a)
        ranks = jnp.where(admit, inserted, ranks)
        claimed = admit & (idx == cand)
        slots_new = (
            jnp.where(claimed, x_a, x_new),
            jnp.where(claimed, x_a, sx0),
            jnp.where(claimed, t_next, sarr),
            jnp.where(claimed, i, sid),
        )
        acc_new = {
            "n_admitted": acc["n_admitted"] + admit,
            "n_completed": acc["n_completed"] + dep_real,
            "w_count": acc["w_count"] + cw,
            "w_arrived": acc["w_arrived"]
            + (admit & (t_next >= w_lo) & (t_next < w_hi)),
            "blocked": acc["blocked"] + blocked_now,
            "occ_max": jnp.maximum(acc["occ_max"], m),
            "w_flow": acc["w_flow"] + jnp.where(cw, flow, 0.0),
            "w_slow": acc["w_slow"] + jnp.where(cw, slow, 0.0),
        }
        m_new = m_mid + jnp.where(admit, 1, 0)
        i_new = i + jnp.where(admit, 1, 0)
        ptr_new = jnp.where(admit, (cand + 1) % S, ptr)
        return (slots_new, ranks, m_new, t_new, i_new, ptr_new, acc_new,
                times), None

    slots0 = (
        jnp.zeros(S, dtype),
        jnp.ones(S, dtype),
        jnp.zeros(S, dtype),
        jnp.full(S, n_times, jnp.int32),
    )
    acc0 = {
        "n_admitted": zi, "n_completed": zi, "w_count": zi,
        "w_arrived": zi, "blocked": zi, "occ_max": zi,
        "w_flow": jnp.zeros((), dtype), "w_slow": jnp.zeros((), dtype),
    }
    times0 = jnp.full(n_times, jnp.inf, dtype) if record_times else None
    init = (slots0, jnp.zeros(S, jnp.int32), zi, jnp.asarray(t0, dtype), zi,
            zi, acc0, times0)
    (slots_fin, _, _, t_fin, _, _, acc_fin, times_fin), _ = jax.lax.scan(
        body, init, None, length=E
    )
    comp = None
    if record_times:
        comp = jnp.zeros(T, dtype).at[order].set(times_fin)
    return _finalize_stream(acc_fin, t_fin, slots_fin[0], comp, None, dtype)


# -------------------------------------------------- JAX-native quantization
def quantize_allocation_jax(
    theta: jax.Array, n_chips: int, *, min_chips: int = 1
) -> jax.Array:
    """Vectorized-jnp port of ``sched.quantize.quantize_allocation``.

    Largest-remainder rounding of ``theta * n_chips`` (``theta`` sums to
    ~1 over the active jobs, ``theta <= 0`` means inactive) with a
    ``min_chips`` floor, matching the NumPy oracle *exactly* — including
    its greedy trim order and stable tie-breaking — but with every
    data-dependent loop replaced by sorts and a static-length binary
    search, so it jit/vmaps inside the engine's scan:

    - **Oversubscription** (more active jobs than ``n_chips // min_chips``
      can hold): keep the largest-theta jobs, queue the rest at 0 chips,
      renormalize.  The oracle recurses once; a single unrolled pass
      suffices because the restriction can't oversubscribe again.
    - **Min-chips overflow trim**: the oracle greedily decrements the job
      maximizing ``base - raw``.  Candidate ``j``'s successive priorities
      are ``-(frac_j + k)``, which fall in disjoint unit bands per trim
      round ``k`` — so the greedy is exactly "full rounds + one partial
      round in ascending-frac order".  The number of full rounds is found
      by binary search on ``T(r) = sum_j min(cap_j, r)`` (monotone in
      ``r``), ``ceil(log2(n_chips))`` iterations, each O(M).
    - **Leftover distribution**: +1 chip to the largest fractional parts
      (stable on ties), active jobs only.

    The trim and the leftover passes are *mutually exclusive* (a trim ends
    with ``sum(base) == n_chips`` exactly, so the remainder is 0; no trim
    means ``K == 0`` and nothing was removed), so one argsort on a
    conditionally-selected key serves both — two sorts per call, not the
    three the first port paid.  Tie-breaking is unchanged: each branch
    sorts the exact key (and stable order) it sorted before.

    ``n_chips``/``min_chips`` are static Python ints.  Returns int32 chips.
    """
    theta = jnp.asarray(theta)
    M = theta.shape[0]
    if n_chips <= 0 or min_chips <= 0 or M == 0:
        return jnp.zeros(M, jnp.int32)
    cap = n_chips // min_chips  # most jobs the floor allows us to serve

    active0 = theta > 0
    n_active = jnp.sum(active0, dtype=jnp.int32)
    # Oversubscribed: serve the largest-theta jobs (stable on ties), queue
    # the rest with 0, renormalize — the oracle's single recursion, unrolled.
    desc = inv_rank(jnp.argsort(jnp.where(active0, -theta, jnp.inf)))
    servable = active0 & (desc < cap)
    over = n_active * min_chips > n_chips
    sub = jnp.where(servable, theta, 0.0)
    tot = jnp.sum(sub)
    theta_eff = jnp.where(over, jnp.where(tot > 0, sub / tot, 0.0), theta)
    active = theta_eff > 0

    raw = theta_eff * n_chips
    fl = jnp.floor(raw)
    frac = raw - fl
    base = jnp.where(active, jnp.maximum(fl, min_chips), 0.0).astype(jnp.int32)

    # Min-chips floor oversubscribed the pool: trim K chips from the
    # largest holdings, exactly as the oracle's greedy (see docstring).
    K = jnp.maximum(jnp.sum(base) - n_chips, 0)
    capj = jnp.maximum(base - min_chips, 0) * (base > min_chips)

    def bisect(_, lohi):
        lo, hi = lohi
        mid = (lo + hi) // 2
        ge = jnp.sum(jnp.minimum(capj, mid)) >= K
        return jnp.where(ge, lo, mid + 1), jnp.where(ge, mid, hi)

    n_bits = (n_chips + 1).bit_length()
    lo, _hi = jax.lax.fori_loop(
        0, n_bits, bisect, (jnp.int32(0), jnp.int32(n_chips))
    )
    r_star = lo  # smallest r with T(r) >= K (0 when K == 0)
    full = jnp.minimum(capj, jnp.maximum(r_star - 1, 0))
    extra_needed = K - jnp.sum(full)
    elig = capj >= jnp.maximum(r_star, 1)
    # One sort serves the partial trim round (ascending frac among eligible
    # jobs, taken when K > 0) AND the leftover distribution (descending
    # frac among active jobs, only reachable when K == 0) — the branches
    # are mutually exclusive, see the docstring.
    trim = K > 0
    key = jnp.where(
        trim, jnp.where(elig, frac, jnp.inf), jnp.where(active, -frac, jnp.inf)
    )
    pos = inv_rank(jnp.argsort(key))
    extra = (elig & (pos < extra_needed)).astype(jnp.int32)
    base = base - full - extra

    # Leftover chips (only when no trim happened): largest fracs first.
    remainder = n_chips - jnp.sum(base)
    base = base + (active & (pos < remainder)).astype(jnp.int32)
    return base


def snap_to_slices_jax(
    chips: jax.Array, n_chips: int, *, slices: tuple[int, ...] = DEFAULT_SLICES
) -> jax.Array:
    """Vectorized-jnp port of ``sched.quantize.snap_to_slices``.

    Snap each job's chip count DOWN to the largest slice size ``<= count``
    (0 if below the smallest slice), then hand leftover chips back greedily:
    at each round, among jobs whose next slice step still fits the leftover
    pool and whose *lost* allocation (original chips - snapped) is
    non-negative, upgrade the job with the largest lost allocation (ties
    break toward the higher index, matching the oracle's ``>=`` scan).  The
    leftover pool strictly shrinks every round, so the ``while_loop`` is
    bounded by ``n_chips`` iterations.

    ``n_chips``/``slices`` are static; returns int32 chips.  Exact
    agreement with the NumPy oracle is property-tested in
    tests/test_quantize.py.
    """
    sl = jnp.asarray(sorted(slices), jnp.int32)
    S = sl.shape[0]
    chips0 = jnp.asarray(chips).astype(jnp.int32)
    M = chips0.shape[0]
    if M == 0:
        return chips0
    idx = jnp.arange(M, dtype=jnp.int32)

    # Snap down: largest slice <= count (0 when count < slices[0]).
    down = jnp.searchsorted(sl, chips0, side="right") - 1
    snapped0 = jnp.where(down >= 0, sl[jnp.maximum(down, 0)], 0)
    left0 = jnp.int32(n_chips) - jnp.sum(snapped0)

    def candidate(snapped, left):
        nxt_i = jnp.searchsorted(sl, snapped, side="right")
        nxt = sl[jnp.minimum(nxt_i, S - 1)]
        step = nxt - snapped
        lost = chips0 - snapped
        elig = (
            (nxt_i < S)
            & (step <= left)
            & (lost >= 0)
            & ~((snapped == 0) & (chips0 == 0))
        )
        # Max lost, ties to the highest index — the oracle's `>=` scan.
        key = jnp.where(elig, lost * M + idx, -1)
        j = jnp.argmax(key)
        return j, nxt[j], step[j], key[j] >= 0

    # The chosen candidate rides in the carry so each round computes it
    # once (the next candidate is derived at the end of body, not re-done
    # in cond) — this runs inside every quantized scan step.
    def cond(state):
        _, left, _, _, _, any_elig = state
        return any_elig & (left > 0)

    def body(state):
        snapped, left, j, nxt_j, step_j, _ = state
        snapped = snapped.at[j].set(nxt_j)
        left = left - step_j
        return (snapped, left, *candidate(snapped, left))

    init = (snapped0, left0, *candidate(snapped0, left0))
    snapped, *_ = jax.lax.while_loop(cond, body, init)
    return snapped


__all__ = [
    "AllocRule",
    "DEFAULT_SLICES",
    "EngineResult",
    "EngineTrace",
    "Observation",
    "PDrift",
    "ProbeEvent",
    "StatefulRule",
    "StreamResult",
    "StreamSource",
    "as_stateful",
    "continuous_rule",
    "finish_alloc",
    "knee_rule",
    "poisson_source",
    "quantize_allocation_jax",
    "quantized_rule",
    "run",
    "run_ranked",
    "run_stream",
    "run_stream_ranked",
    "run_stream_source",
    "snap_to_slices_jax",
    "tape_source",
]
