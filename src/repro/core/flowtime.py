"""Closed forms from the paper: Theorem 2 (makespan) and Theorem 8 (flow time).

These are the ground truth the event-driven simulator is validated against
(tests/test_flowtime.py) and the scheduler uses for instant what-if
evaluation of job sets without simulating.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def speedup(k: jax.Array, p: jax.Array) -> jax.Array:
    """s(k) = k^p, the paper's sublinear concave speedup family."""
    return jnp.where(k > 0, k ** p, 0.0)


def omega_star(m: int, p: jax.Array, dtype=jnp.float64) -> jax.Array:
    """Scale-free constants of the optimal policy (Thm 5/8).

    omega*_1 = 0 and, for 1 < k <= m::

        omega*_k = 1 / ((k/(k-1))^(1/(1-p)) - 1)

    Returned as shape ``[m]`` with index 0 <-> k=1.
    """
    k = jnp.arange(1, m + 1, dtype=dtype)
    c = 1.0 / (1.0 - p)
    ratio = jnp.where(k > 1, k / jnp.maximum(k - 1.0, 1e-300), jnp.inf)
    om = jnp.where(k > 1, 1.0 / (ratio ** c - 1.0), 0.0)
    return om


def hesrpt_total_flowtime(
    x_desc: jax.Array, p: jax.Array, n_servers: jax.Array
) -> jax.Array:
    """Theorem 8: optimal total flow time for sizes ``x_desc`` (descending).

    ``T* = (1/s(N)) * sum_k x_k [ k s(1+w_k) - (k-1) s(w_k) ]`` with the
    ``omega_star`` constants.  ``x_desc[k-1]`` is the k-th *largest* job.
    """
    m = x_desc.shape[0]
    k = jnp.arange(1, m + 1, dtype=x_desc.dtype)
    om = omega_star(m, p, dtype=x_desc.dtype)
    coeff = k * speedup(1.0 + om, p) - (k - 1.0) * speedup(om, p)
    return jnp.sum(x_desc * coeff) / speedup(n_servers, p)


def hesrpt_mean_flowtime(
    x_desc: jax.Array, p: jax.Array, n_servers: jax.Array
) -> jax.Array:
    return hesrpt_total_flowtime(x_desc, p, n_servers) / x_desc.shape[0]


def optimal_makespan(x: jax.Array, p: jax.Array, n_servers: jax.Array) -> jax.Array:
    """Theorem 2: T*_max = ||X||_{1/p} in a unit-rate system of size N.

    ``||X||_{1/p} = (sum_i x_i^(1/p))^p``; dividing by ``s(N)`` converts to a
    system whose single-server rate is 1 and which has ``N`` servers.
    """
    active = x > 0
    xmax = jnp.maximum(jnp.max(jnp.where(active, x, 0.0)), jnp.finfo(x.dtype).tiny)
    norm = (jnp.sum(jnp.where(active, (x / xmax) ** (1.0 / p), 0.0))) ** p * xmax
    return norm / speedup(n_servers, p)


def hesrpt_completion_times(
    x_desc: jax.Array, p: jax.Array, n_servers: jax.Array
) -> jax.Array:
    """Per-job completion times under heSRPT (jobs indexed largest..smallest).

    Derived epoch-by-epoch: while ``m`` jobs remain (jobs ``1..m``), job ``i``
    holds ``theta_i(m) = (i/m)^c - ((i-1)/m)^c`` and the *smallest* active job
    (rank m) departs next.  Between the departure of job ``m+1`` and job
    ``m``, every active job's remaining size shrinks at rate
    ``s(theta_i(m) N)``.  This runs the recursion in closed form (it is the
    fluid trajectory, not a numerical integration).
    """
    M = x_desc.shape[0]
    c = 1.0 / (1.0 - p)

    def theta(i, m):  # i, m float arrays; rank i in 1..m
        return (i / m) ** c - ((i - 1.0) / m) ** c

    x = x_desc.astype(jnp.result_type(x_desc.dtype, jnp.float32))
    t = jnp.zeros((), x.dtype)
    times = jnp.zeros(M, x.dtype)

    def body(carry, m):
        # m runs M, M-1, ..., 1 (number of active jobs this epoch).
        x, t, times = carry
        mf = m.astype(x.dtype)
        i = jnp.arange(1, M + 1, dtype=x.dtype)
        active = i <= mf
        th = jnp.where(active, theta(jnp.minimum(i, mf), mf), 0.0)
        rate = speedup(th * n_servers, p)
        # Smallest active job is rank m; it departs next.
        x_small = x[m - 1]
        r_small = rate[m - 1]
        dt = x_small / r_small
        x = jnp.where(active, jnp.maximum(x - dt * rate, 0.0), x)
        t = t + dt
        times = times.at[m - 1].set(t)
        return (x, t, times), None

    (x, t, times), _ = jax.lax.scan(
        body, (x, t, times), jnp.arange(M, 0, -1, dtype=jnp.int32)
    )
    return times
