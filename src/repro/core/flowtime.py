"""Closed forms: Theorem 2 (makespan), Theorem 8 (flow time), and the
weighted Thm-8 analogue behind Berg et al. 2020's mean-slowdown objective.

These are the ground truth the event-driven simulator is validated against
(tests/test_flowtime.py, benchmarks/theorem8.py) and the scheduler uses
for instant what-if evaluation of job sets without simulating.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def speedup(k: jax.Array, p: jax.Array) -> jax.Array:
    """s(k) = k^p, the paper's sublinear concave speedup family."""
    return jnp.where(k > 0, k ** p, 0.0)


def omega_star(m: int, p: jax.Array, dtype=jnp.float64) -> jax.Array:
    """Scale-free constants of the optimal policy (Thm 5/8).

    omega*_1 = 0 and, for 1 < k <= m::

        omega*_k = 1 / ((k/(k-1))^(1/(1-p)) - 1)

    Returned as shape ``[m]`` with index 0 <-> k=1.
    """
    k = jnp.arange(1, m + 1, dtype=dtype)
    c = 1.0 / (1.0 - p)
    ratio = jnp.where(k > 1, k / jnp.maximum(k - 1.0, 1e-300), jnp.inf)
    om = jnp.where(k > 1, 1.0 / (ratio ** c - 1.0), 0.0)
    return om


def hesrpt_total_flowtime(
    x_desc: jax.Array, p: jax.Array, n_servers: jax.Array
) -> jax.Array:
    """Theorem 8: optimal total flow time for sizes ``x_desc`` (descending).

    ``T* = (1/s(N)) * sum_k x_k [ k s(1+w_k) - (k-1) s(w_k) ]`` with the
    ``omega_star`` constants.  ``x_desc[k-1]`` is the k-th *largest* job.
    """
    m = x_desc.shape[0]
    k = jnp.arange(1, m + 1, dtype=x_desc.dtype)
    om = omega_star(m, p, dtype=x_desc.dtype)
    coeff = k * speedup(1.0 + om, p) - (k - 1.0) * speedup(om, p)
    return jnp.sum(x_desc * coeff) / speedup(n_servers, p)


def hesrpt_mean_flowtime(
    x_desc: jax.Array, p: jax.Array, n_servers: jax.Array
) -> jax.Array:
    return hesrpt_total_flowtime(x_desc, p, n_servers) / x_desc.shape[0]


def omega_weighted(w: jax.Array, p: jax.Array) -> jax.Array:
    """Scale-free constants of the *weighted* bracket policy.

    Generalizes :func:`omega_star` from count fractions to cumulative
    weight fractions: with jobs ranked ``k = 1..m`` largest..smallest and
    ``W_k = w_1 + ... + w_k``,

        omega_k = W_{k-1}^c / (W_k^c - W_{k-1}^c),      c = 1/(1-p)

    which is the constant ratio ``sum_{j<k} theta_j / theta_k`` during job
    k's lifetime under :func:`~repro.core.policies.weighted_hesrpt` (the
    Thm-4 scale-free property survives weighting because the brackets
    depend on ``m`` only through the common factor ``W_m^{-c}``).
    Uniform weights reduce to :func:`omega_star` exactly.
    """
    w = jnp.asarray(w)
    c = 1.0 / (1.0 - p)
    W = jnp.cumsum(w)
    W_lo = W - w
    gap = jnp.maximum(W ** c - W_lo ** c, jnp.finfo(W.dtype).tiny)
    return W_lo ** c / gap


def weighted_total_flowtime(
    x_desc: jax.Array, w: jax.Array, p: jax.Array, n_servers: jax.Array
) -> jax.Array:
    """Weighted Thm-8 analogue: ``sum_k w_k T_k`` under the weighted
    bracket policy (:func:`~repro.core.policies.weighted_hesrpt`), in
    closed form::

        sum_k w_k T_k = (1/s(N)) * sum_k x_k (W_k^c - W_{k-1}^c)^(1-p)

    with ``c = 1/(1-p)``, jobs ranked largest..smallest (``x_desc``), and
    ``W_k`` the cumulative weight down the ranking.  Equivalently (the
    Thm-8 shape) the k-th coefficient is ``W_k s(1+omega_k) - W_{k-1}
    s(omega_k)`` with the :func:`omega_weighted` constants — the two forms
    collapse because ``1 + c p = c``.  Uniform weights recover Theorem 8's
    optimal total flow time exactly.

    Valid when departures follow the size ranking (smallest remaining job
    first), which holds whenever weights are non-increasing in size —
    in particular the Berg et al. 2020 slowdown weights ``w = 1/x``.
    Validated against the event-driven simulator in tests/test_flowtime.py
    and benchmarks/theorem8.py.
    """
    w = jnp.asarray(w, x_desc.dtype)
    c = 1.0 / (1.0 - p)
    W = jnp.cumsum(w)
    W_lo = W - w
    return jnp.sum(x_desc * (W ** c - W_lo ** c) ** (1.0 - p)) / speedup(
        n_servers, p
    )


def hesrpt_sd_mean_slowdown(
    x_desc: jax.Array, p: jax.Array, n_servers: jax.Array
) -> jax.Array:
    """Berg et al. 2020's batch objective in closed form: the mean slowdown
    achieved by the slowdown-weighted policy (``hesrpt_sd``, i.e.
    :func:`weighted_total_flowtime` with weights ``w = 1/x``).

    Slowdown of job k is ``T_k / (x_k / s(N))``, so the mean is
    ``s(N)/M * sum_k T_k / x_k`` — the weighted total with ``w_k = 1/x_k``
    rescaled by ``s(N)/M``.  This is the validation oracle for the
    ``hesrpt_sd`` simulation path (``core/multiclass.py``).
    """
    M = x_desc.shape[0]
    total = weighted_total_flowtime(x_desc, 1.0 / x_desc, p, n_servers)
    return total * speedup(n_servers, p) / M


def optimal_makespan(x: jax.Array, p: jax.Array, n_servers: jax.Array) -> jax.Array:
    """Theorem 2: T*_max = ||X||_{1/p} in a unit-rate system of size N.

    ``||X||_{1/p} = (sum_i x_i^(1/p))^p``; dividing by ``s(N)`` converts to a
    system whose single-server rate is 1 and which has ``N`` servers.
    """
    active = x > 0
    xmax = jnp.maximum(jnp.max(jnp.where(active, x, 0.0)), jnp.finfo(x.dtype).tiny)
    norm = (jnp.sum(jnp.where(active, (x / xmax) ** (1.0 / p), 0.0))) ** p * xmax
    return norm / speedup(n_servers, p)


def hesrpt_completion_times(
    x_desc: jax.Array, p: jax.Array, n_servers: jax.Array
) -> jax.Array:
    """Per-job completion times under heSRPT (jobs indexed largest..smallest).

    Derived epoch-by-epoch: while ``m`` jobs remain (jobs ``1..m``), job ``i``
    holds ``theta_i(m) = (i/m)^c - ((i-1)/m)^c`` and the *smallest* active job
    (rank m) departs next.  Between the departure of job ``m+1`` and job
    ``m``, every active job's remaining size shrinks at rate
    ``s(theta_i(m) N)``.  This runs the recursion in closed form (it is the
    fluid trajectory, not a numerical integration).
    """
    M = x_desc.shape[0]
    c = 1.0 / (1.0 - p)

    def theta(i, m):  # i, m float arrays; rank i in 1..m
        return (i / m) ** c - ((i - 1.0) / m) ** c

    x = x_desc.astype(jnp.result_type(x_desc.dtype, jnp.float32))
    t = jnp.zeros((), x.dtype)
    times = jnp.zeros(M, x.dtype)

    def body(carry, m):
        # m runs M, M-1, ..., 1 (number of active jobs this epoch).
        x, t, times = carry
        mf = m.astype(x.dtype)
        i = jnp.arange(1, M + 1, dtype=x.dtype)
        active = i <= mf
        th = jnp.where(active, theta(jnp.minimum(i, mf), mf), 0.0)
        rate = speedup(th * n_servers, p)
        # Smallest active job is rank m; it departs next.
        x_small = x[m - 1]
        r_small = rate[m - 1]
        dt = x_small / r_small
        x = jnp.where(active, jnp.maximum(x - dt * rate, 0.0), x)
        t = t + dt
        times = times.at[m - 1].set(t)
        return (x, t, times), None

    (x, t, times), _ = jax.lax.scan(
        body, (x, t, times), jnp.arange(M, 0, -1, dtype=jnp.int32)
    )
    return times
