"""Closed forms: Theorem 2 (makespan), Theorem 8 (flow time), and the
weighted Thm-8 analogue behind Berg et al. 2020's mean-slowdown objective.

These are the ground truth the event-driven simulator is validated against
(tests/test_flowtime.py, benchmarks/theorem8.py) and the scheduler uses
for instant what-if evaluation of job sets without simulating.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def speedup(k: jax.Array, p: jax.Array) -> jax.Array:
    """s(k) = k^p, the paper's sublinear concave speedup family."""
    return jnp.where(k > 0, k ** p, 0.0)


def omega_star(m: int, p: jax.Array, dtype=jnp.float64) -> jax.Array:
    """Scale-free constants of the optimal policy (Thm 5/8).

    omega*_1 = 0 and, for 1 < k <= m::

        omega*_k = 1 / ((k/(k-1))^(1/(1-p)) - 1)

    Returned as shape ``[m]`` with index 0 <-> k=1.
    """
    k = jnp.arange(1, m + 1, dtype=dtype)
    c = 1.0 / (1.0 - p)
    ratio = jnp.where(k > 1, k / jnp.maximum(k - 1.0, 1e-300), jnp.inf)
    om = jnp.where(k > 1, 1.0 / (ratio ** c - 1.0), 0.0)
    return om


def hesrpt_total_flowtime(
    x_desc: jax.Array, p: jax.Array, n_servers: jax.Array
) -> jax.Array:
    """Theorem 8: optimal total flow time for sizes ``x_desc`` (descending).

    ``T* = (1/s(N)) * sum_k x_k [ k s(1+w_k) - (k-1) s(w_k) ]`` with the
    ``omega_star`` constants.  ``x_desc[k-1]`` is the k-th *largest* job.
    """
    m = x_desc.shape[0]
    k = jnp.arange(1, m + 1, dtype=x_desc.dtype)
    om = omega_star(m, p, dtype=x_desc.dtype)
    coeff = k * speedup(1.0 + om, p) - (k - 1.0) * speedup(om, p)
    return jnp.sum(x_desc * coeff) / speedup(n_servers, p)


def hesrpt_mean_flowtime(
    x_desc: jax.Array, p: jax.Array, n_servers: jax.Array
) -> jax.Array:
    return hesrpt_total_flowtime(x_desc, p, n_servers) / x_desc.shape[0]


def omega_weighted(w: jax.Array, p: jax.Array) -> jax.Array:
    """Scale-free constants of the *weighted* bracket policy.

    Generalizes :func:`omega_star` from count fractions to cumulative
    weight fractions: with jobs ranked ``k = 1..m`` largest..smallest and
    ``W_k = w_1 + ... + w_k``,

        omega_k = W_{k-1}^c / (W_k^c - W_{k-1}^c),      c = 1/(1-p)

    which is the constant ratio ``sum_{j<k} theta_j / theta_k`` during job
    k's lifetime under :func:`~repro.core.policies.weighted_hesrpt` (the
    Thm-4 scale-free property survives weighting because the brackets
    depend on ``m`` only through the common factor ``W_m^{-c}``).
    Uniform weights reduce to :func:`omega_star` exactly.
    """
    w = jnp.asarray(w)
    c = 1.0 / (1.0 - p)
    W = jnp.cumsum(w)
    W_lo = W - w
    gap = jnp.maximum(W ** c - W_lo ** c, jnp.finfo(W.dtype).tiny)
    return W_lo ** c / gap


def weighted_total_flowtime(
    x_desc: jax.Array, w: jax.Array, p: jax.Array, n_servers: jax.Array
) -> jax.Array:
    """Weighted Thm-8 analogue: ``sum_k w_k T_k`` under the weighted
    bracket policy (:func:`~repro.core.policies.weighted_hesrpt`), in
    closed form::

        sum_k w_k T_k = (1/s(N)) * sum_k x_k (W_k^c - W_{k-1}^c)^(1-p)

    with ``c = 1/(1-p)``, jobs ranked largest..smallest (``x_desc``), and
    ``W_k`` the cumulative weight down the ranking.  Equivalently (the
    Thm-8 shape) the k-th coefficient is ``W_k s(1+omega_k) - W_{k-1}
    s(omega_k)`` with the :func:`omega_weighted` constants — the two forms
    collapse because ``1 + c p = c``.  Uniform weights recover Theorem 8's
    optimal total flow time exactly.

    Valid when departures follow the size ranking (smallest remaining job
    first), which holds whenever weights are non-increasing in size —
    in particular the Berg et al. 2020 slowdown weights ``w = 1/x``.
    Validated against the event-driven simulator in tests/test_flowtime.py
    and benchmarks/theorem8.py.
    """
    w = jnp.asarray(w, x_desc.dtype)
    c = 1.0 / (1.0 - p)
    W = jnp.cumsum(w)
    W_lo = W - w
    return jnp.sum(x_desc * (W ** c - W_lo ** c) ** (1.0 - p)) / speedup(
        n_servers, p
    )


def hesrpt_sd_mean_slowdown(
    x_desc: jax.Array, p: jax.Array, n_servers: jax.Array
) -> jax.Array:
    """Berg et al. 2020's batch objective in closed form: the mean slowdown
    achieved by the slowdown-weighted policy (``hesrpt_sd``, i.e.
    :func:`weighted_total_flowtime` with weights ``w = 1/x``).

    Slowdown of job k is ``T_k / (x_k / s(N))``, so the mean is
    ``s(N)/M * sum_k T_k / x_k`` — the weighted total with ``w_k = 1/x_k``
    rescaled by ``s(N)/M``.  This is the validation oracle for the
    ``hesrpt_sd`` simulation path (``core/multiclass.py``).
    """
    M = x_desc.shape[0]
    total = weighted_total_flowtime(x_desc, 1.0 / x_desc, p, n_servers)
    return total * speedup(n_servers, p) / M


def optimal_makespan(x: jax.Array, p: jax.Array, n_servers: jax.Array) -> jax.Array:
    """Theorem 2: T*_max = ||X||_{1/p} in a unit-rate system of size N.

    ``||X||_{1/p} = (sum_i x_i^(1/p))^p``; dividing by ``s(N)`` converts to a
    system whose single-server rate is 1 and which has ``N`` servers.
    """
    active = x > 0
    xmax = jnp.maximum(jnp.max(jnp.where(active, x, 0.0)), jnp.finfo(x.dtype).tiny)
    norm = (jnp.sum(jnp.where(active, (x / xmax) ** (1.0 / p), 0.0))) ** p * xmax
    return norm / speedup(n_servers, p)


# ----------------------------------------------- rank-space bracket geometry
#
# The whole power-law family (heSRPT / EQUI / weighted brackets) shares one
# structural fact: with ``c = 1/(1-p)`` and per-rank bracket numerators
# ``a_r`` (heSRPT: r^c - (r-1)^c; EQUI: 1; weighted: W_r^c - W_{r-1}^c),
# the allocation while ``m`` jobs are active is ``theta_r = a_r / A_m``
# with ``A_m = sum_{j<=m} a_j``, so the service rate of rank ``r`` is
# ``(a_r/A_m)^p s(N)``.  Because ``c p = c - 1``, the *ratios* of rates
# across ranks never depend on ``m`` — each departure rescales every rate
# by the same factor.  In the virtual time ``tau`` with ``dtau/dt =
# s(N) / A_m^p``, every rank therefore shrinks linearly, ``x_r(tau) =
# x_r - a_r^p tau``, for its whole lifetime: rank ``r`` departs at ``tau =
# v_r := x_r / a_r^p`` (non-increasing in ``r`` for descending sizes), and
# the epoch with ``m`` jobs active spans ``tau`` in ``[v_{m+1}, v_m]``
# (``v_{m+1} := 0``), i.e. wall-clock ``delta_m = (v_m - v_{m+1}) A_m^p /
# s(N)``.  Completion times are suffix sums ``T_r = sum_{j>=r} delta_j``
# — one O(M) pass, no per-departure recursion.  SRPT is the degenerate
# bracket (all of N to rank m): ``delta_r = x_r / s(N)`` directly.
#
# This geometry is what ``core/superstep.py`` scans over arrivals only;
# here it replaces the per-departure recursion of the original
# ``hesrpt_completion_times``.


def rank_bracket_powers(
    M: int, p, policy: str = "hesrpt", *, weights_rank=None, dtype=jnp.float64
) -> tuple[jax.Array, jax.Array]:
    """``(a_r^p, A_r^p)`` for descending-size ranks ``r = 1..M``.

    ``policy`` is ``"hesrpt"`` (``a_r = r^c - (r-1)^c``), ``"equi"``
    (``a_r = 1``) or ``"weighted_hesrpt"`` (``a_r = W_r^c - W_{r-1}^c``
    with ``weights_rank`` the per-rank weights, cumulated here).  SRPT has
    no bracket form — its epoch geometry is handled directly by the
    callers.  ``1 + c p = c`` collapses every ``A_r^p`` to a single power.
    """
    c = 1.0 / (1.0 - p)
    if policy == "equi":
        r = jnp.arange(1, M + 1, dtype=dtype)
        return jnp.ones(M, dtype), r ** p
    if policy == "hesrpt":
        r = jnp.arange(0, M + 1, dtype=dtype)
        rc = r ** c
        return (rc[1:] - rc[:-1]) ** p, r[1:] ** (c - 1.0)
    if policy == "weighted_hesrpt":
        if weights_rank is None:
            raise ValueError("weighted_hesrpt bracket powers need weights_rank")
        W = jnp.cumsum(jnp.asarray(weights_rank, dtype))
        Wc = W ** c
        gap = Wc - jnp.concatenate([jnp.zeros(1, dtype), Wc[:-1]])
        # Ranks past the active set may carry zero weight; keep their a^p
        # finite (they are masked out by every caller).
        return jnp.maximum(gap, 0.0) ** p, jnp.maximum(W, 0.0) ** (c - 1.0)
    raise ValueError(f"no bracket form for policy {policy!r}")


def epoch_schedule(
    x_rank: jax.Array,
    ap: jax.Array,
    Ap: jax.Array,
    rank_active: jax.Array,
    p,
    n_servers,
    *,
    srpt: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Virtual departure thresholds ``v_r`` and completion offsets ``T_r``.

    ``x_rank[r-1]`` is the remaining size of the rank-``r`` job (descending
    sizes, ``rank_active`` masking ranks ``1..m``); ``(ap, Ap)`` come from
    :func:`rank_bracket_powers`.  Returns ``(v, T)`` where ``T[r-1]`` is
    the wall-clock offset (from now) at which rank ``r`` departs — the
    suffix sums of the per-epoch durations — and ``v`` the virtual-time
    thresholds (zeros for SRPT, whose epochs are served sequentially).
    """
    sN = speedup(jnp.asarray(n_servers, x_rank.dtype), p)
    if srpt:
        v = jnp.zeros_like(x_rank)
        delta = jnp.where(rank_active, x_rank, 0.0) / sN
    else:
        v = jnp.where(rank_active, x_rank / ap, 0.0)
        v_next = jnp.concatenate([v[1:], jnp.zeros(1, v.dtype)])
        # Rounding can leave (v_r - v_{r+1}) at -eps on exact size ties.
        delta = jnp.maximum(v - v_next, 0.0) * jnp.where(rank_active, Ap, 0.0)
        delta = delta / sN
    T = jnp.flip(jnp.cumsum(jnp.flip(delta)))
    return v, T


def hesrpt_completion_times(
    x_desc: jax.Array, p: jax.Array, n_servers: jax.Array
) -> jax.Array:
    """Per-job completion times under heSRPT (jobs indexed largest..smallest).

    The Theorem-3 epoch recursion in closed form: one O(M) suffix-sum pass
    over the rank-space bracket geometry (see :func:`epoch_schedule`) —
    the per-departure ``lax.scan`` this function used to run is gone.
    """
    x = x_desc.astype(jnp.result_type(x_desc.dtype, jnp.float32))
    M = x.shape[0]
    ap, Ap = rank_bracket_powers(M, p, "hesrpt", dtype=x.dtype)
    _, T = epoch_schedule(x, ap, Ap, jnp.ones(M, bool), p, n_servers)
    return T
