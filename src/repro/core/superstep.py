"""Closed-form epoch fusion: the arrival-superstep fast path.

The generic engine (``core/engine.py::run``) pays one ``lax.scan`` step —
a full ``rule.allocate`` over all M slots — for *every* event, departures
included.  But for the continuous uniform-p power-law family the paper
(Thm 3/4) and the slowdown companion (Thm 8, ``core/flowtime.py``) give
the whole trajectory of an all-present batch in closed form, so every
departure that falls between two arrivals is computable analytically.
This module exploits that twice:

- :func:`batch_result_closed_form` — the ``pre_arrived=True`` batch needs
  **no scan at all**: one stable sort, one suffix-sum pass over the
  rank-space bracket geometry (``flowtime.epoch_schedule``), O(M log M)
  total, plus the closed-form remaining-size trajectory ``x_i(t)`` at any
  requested evaluation times.

- :func:`run_superstep` — online streams scan over **arrival (and
  drift-boundary) events only**: each step treats the currently-present
  jobs as a batch, computes every analytic departure offset in the
  inter-arrival gap, counts how many land before the next arrival, and
  advances every survivor through the gap in one closed-form update.
  Scan length collapses from ``2M`` events to ``M + 1`` supersteps (plus
  one per drift boundary).  Like ``engine.run_ranked`` it carries
  descending-size ranks instead of sorting per step — departures always
  drop the *highest* ranks, so survivors keep their ranks and an arrival
  inserts one — and the per-rank bracket coefficients are precomputed
  outside the scan, so a superstep body is pure O(M) elementwise work
  with no sort and no transcendentals.

Supported exactly here (everything else takes the generic per-event
scan — ``engine.run`` raises at trace time pointing back to it):
continuous allocation, scalar ``p`` (or scalar-regime :class:`PDrift`),
the rank family heSRPT / EQUI / SRPT, and the cumulative-weight
``weighted_hesrpt`` brackets (valid, like ``weighted_total_flowtime``,
when weights are non-increasing in size so departures follow the size
ranking; weighted + drift is not wired).  Quantized chips, stateful /
estimating rules, estimation noise (``size_factors`` / ``p_hat``),
per-job exponents, ``record=True`` traces and per-event telemetry all
need the event-by-event scan.

Tie semantics match ``run_ranked``: exactly-tied sizes get distinct
adjacent ranks (ties break by arrival order), so under SRPT per-job times
permute within a tied group relative to the generic path's ``argmin``
while totals are exchange-invariant; under heSRPT/EQUI the tied jobs'
times agree.  A departure landing exactly on an arrival completes at the
arrival instant, as in the generic scan.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.engine import EngineResult, PDrift
from repro.core.flowtime import epoch_schedule, rank_bracket_powers, speedup
from repro.core.policies import size_ranks_desc

SUPERSTEP_POLICIES = ("hesrpt", "equi", "srpt", "weighted_hesrpt")


def _validate(policy: str, p, weights, p_drift) -> None:
    """Trace-time gate: reject configs whose physics the closed form
    cannot represent, pointing at the generic-scan fallback."""
    if policy not in SUPERSTEP_POLICIES:
        raise ValueError(
            f"superstep path supports {SUPERSTEP_POLICIES}, got {policy!r} "
            "— other policies take the generic per-event scan (engine.run)"
        )
    if policy == "weighted_hesrpt" and weights is None:
        raise ValueError("weighted_hesrpt needs per-job weights")
    if jnp.ndim(p) != 0:
        raise ValueError(
            "superstep path needs a scalar p — per-job exponents break the "
            "rank-order departure invariant; use the generic engine.run scan"
        )
    if p_drift is not None:
        if policy == "weighted_hesrpt":
            raise ValueError(
                "weighted_hesrpt + p_drift is not wired on the superstep "
                "path; use the generic engine.run scan"
            )
        if jnp.asarray(p_drift.values).ndim != 1:
            raise ValueError(
                "superstep path needs scalar drift regimes — per-job drift "
                "rows take the generic engine.run scan"
            )


def _bracket_powers(M, p, policy, dtype, weights_rank=None):
    """(a_r^p, A_r^p) per rank, with SRPT's degenerate all-ones stand-in
    (its epoch geometry never reads them)."""
    if policy == "srpt":
        one = jnp.ones(M, dtype)
        return one, one
    return rank_bracket_powers(
        M, p, policy, weights_rank=weights_rank, dtype=dtype
    )


def _gap_advance(x_rank, v, T, ap, Ap, rank_active, dt, sN, *, srpt: bool):
    """Advance the rank-space batch through an elapsed time ``dt``.

    ``(v, T)`` from :func:`~repro.core.flowtime.epoch_schedule`.  Ranks
    whose departure offset ``T_r <= dt`` go to zero; survivors move to
    their exact analytic remaining size at ``dt``: for bracket policies
    via the virtual time ``tau(dt) = v_{m'+1} + (dt - T_{m'+1}) s(N) /
    A_{m'}^p`` (``m'`` the surviving count, ``x_r -> x_r - a_r^p tau``);
    for SRPT only the currently-served rank ``m'`` shrinks, by the work
    budget ``(dt - T_{m'+1}) s(N)``.  Returns ``(x_rank_new, departed)``.
    """
    M = x_rank.shape[0]
    dep = rank_active & (T <= dt)
    n_dep = jnp.sum(dep, dtype=jnp.int32)
    m2 = jnp.sum(rank_active, dtype=jnp.int32) - n_dep
    i_last = jnp.clip(m2, 0, M - 1)  # rank m2+1 <-> index m2
    T_start = jnp.where(n_dep > 0, T[i_last], 0.0)
    elapsed = jnp.maximum(dt - T_start, 0.0)
    if srpt:
        served = jnp.arange(M) == m2 - 1
        x_new = jnp.where(served, x_rank - elapsed * sN, x_rank)
    else:
        v_start = jnp.where(n_dep > 0, v[i_last], 0.0)
        tau = jnp.where(
            m2 > 0, v_start + elapsed * sN / Ap[jnp.maximum(m2 - 1, 0)], 0.0
        )
        x_new = x_rank - ap * tau
    return jnp.where(dep | ~rank_active, 0.0, jnp.maximum(x_new, 0.0)), dep


class BatchClosedForm(NamedTuple):
    completion_times: jax.Array  # [M] absolute, input order
    sizes_at: jax.Array | None  # [K, M] remaining sizes at eval_times


def batch_result_closed_form(
    x: jax.Array,
    p,
    policy: str = "hesrpt",
    *,
    n_servers,
    weights: jax.Array | None = None,
    t0=0.0,
    eval_times=None,
) -> BatchClosedForm:
    """Theorem-3/8 completion times and trajectory for an all-present batch.

    One stable descending sort, then the O(M) suffix-sum geometry of
    ``flowtime.epoch_schedule`` — no scan.  ``completion_times`` come back
    in input order (zero-size jobs report ``0.0``, matching the generic
    engine, which never activates them).  With ``eval_times`` (shape
    ``[K]``, absolute), ``sizes_at[k, i]`` is job ``i``'s exact remaining
    size at ``eval_times[k]`` — the closed-form ``x_i(t)``.

    ``policy`` is one of :data:`SUPERSTEP_POLICIES`; ``weighted_hesrpt``
    reads per-job ``weights`` (input order) and is exact when weights are
    non-increasing in size (``sum_i w_i T_i`` then equals
    ``flowtime.weighted_total_flowtime``).
    """
    _validate(policy, p, weights, None)
    x = jnp.asarray(x)
    dtype = jnp.result_type(x.dtype, jnp.float32)
    x = x.astype(dtype)
    M = x.shape[0]
    order = jnp.argsort(-x)  # stable: ties by index, zeros last
    x_desc = x[order]
    rank_active = x_desc > 0
    srpt = policy == "srpt"
    w_rank = None
    if policy == "weighted_hesrpt":
        w_rank = jnp.where(
            rank_active, jnp.asarray(weights, dtype)[order], 0.0
        )
    ap, Ap = _bracket_powers(M, p, policy, dtype, weights_rank=w_rank)
    v, T = epoch_schedule(x_desc, ap, Ap, rank_active, p, n_servers, srpt=srpt)
    t0 = jnp.asarray(t0, dtype)
    times = jnp.zeros(M, dtype).at[order].set(
        jnp.where(rank_active, t0 + T, 0.0)
    )
    sizes = None
    if eval_times is not None:
        sN = speedup(jnp.asarray(n_servers, dtype), p)
        ts = jnp.atleast_1d(jnp.asarray(eval_times, dtype))

        def at(tq):
            x_new, _ = _gap_advance(
                x_desc, v, T, ap, Ap, rank_active,
                jnp.maximum(tq - t0, 0.0), sN, srpt=srpt,
            )
            return jnp.zeros(M, dtype).at[order].set(x_new)

        sizes = jax.vmap(at)(ts)
    return BatchClosedForm(completion_times=times, sizes_at=sizes)


def run_superstep(
    x0: jax.Array,
    arrival_times: jax.Array,
    p,
    n_servers,
    policy: str = "hesrpt",
    *,
    weights: jax.Array | None = None,
    pre_arrived: bool = False,
    horizon: int | None = None,
    t0=0.0,
    p_drift: PDrift | None = None,
) -> EngineResult:
    """The arrival-superstep scan: one step per arrival / drift boundary.

    Same contract as ``engine.run`` over ``continuous_rule`` for the
    supported family (see the module docstring), same
    :class:`~repro.core.engine.EngineResult` shape (``trace`` and
    ``telemetry`` always ``None``).  ``pre_arrived=True`` without drift
    needs **zero** scan steps (:func:`batch_result_closed_form`); online
    streams need ``M + 1`` (+ one per drift boundary) instead of the
    generic ``2M`` — the default horizon.  A superstep admits one arrival,
    so simultaneous arrivals each take a (zero-gap) step of their own.
    """
    _validate(policy, p, weights, p_drift)
    x0 = jnp.asarray(x0)
    M = x0.shape[0]
    dtype = jnp.result_type(x0.dtype, jnp.float32)
    x0 = x0.astype(dtype)
    arrival_times = jnp.asarray(arrival_times).astype(dtype)
    order = jnp.argsort(arrival_times)

    if pre_arrived and p_drift is None:
        batch = batch_result_closed_form(
            x0, p, policy, n_servers=n_servers, weights=weights, t0=t0
        )
        return EngineResult(
            completion_times=batch.completion_times,
            x_final=jnp.zeros(M, dtype),
            order=order,
            trace=None,
            telemetry=None,
        )

    arr = arrival_times[order]
    xs = x0[order]
    idx = jnp.arange(M)
    srpt = policy == "srpt"
    weighted = policy == "weighted_hesrpt"
    n_drift = 0 if p_drift is None else p_drift.times.shape[0]
    E = ((0 if pre_arrived else M) + n_drift + 1) if horizon is None else horizon

    w_arr = None
    if weighted:
        w_arr = jnp.asarray(weights, dtype)[order]
    if p_drift is None:
        ap_c, Ap_c = (None, None) if weighted else _bracket_powers(
            M, p, policy, dtype
        )
    else:
        drift_t = jnp.asarray(p_drift.times).astype(dtype)
        drift_v = jnp.asarray(p_drift.values).astype(dtype)
        ap_tab, Ap_tab = jax.vmap(
            lambda pv: _bracket_powers(M, pv, policy, dtype)
        )(drift_v)

    if pre_arrived:
        ranks0 = size_ranks_desc(xs)
        m0 = jnp.sum(xs > 0, dtype=jnp.int32)
        i0 = jnp.asarray(M, jnp.int32)
        # Descending sort puts the zero-size (never-active) jobs last —
        # exactly the rank layout size_ranks_desc assigns.
        x_rank0 = jnp.sort(xs)[::-1]
        w_rank0 = (
            jnp.zeros(M, dtype).at[
                jnp.where(xs > 0, ranks0 - 1, M)
            ].set(jnp.where(xs > 0, w_arr, 0.0), mode="drop")
            if weighted else None
        )
    else:
        ranks0 = jnp.zeros(M, jnp.int32)
        m0 = jnp.zeros((), jnp.int32)
        i0 = jnp.zeros((), jnp.int32)
        x_rank0 = jnp.zeros(M, dtype)
        w_rank0 = jnp.zeros(M, dtype) if weighted else None

    def body(carry, _):
        x_rank, w_rank, t, i, ranks, m, times = carry
        active = ranks > 0
        if p_drift is None:
            p_now = p
            ap, Ap = ap_c, Ap_c
            t_next_drift = jnp.inf
        else:
            r = jnp.searchsorted(drift_t, t, side="right")
            p_now = drift_v[r]
            ap = ap_tab[r]
            Ap = Ap_tab[r]
            t_next_drift = jnp.where(
                r < n_drift, drift_t[jnp.minimum(r, n_drift - 1)], jnp.inf
            )
        # The batch state lives *in rank space* across steps (no per-step
        # scatter — XLA CPU serializes scatters at ~100x a gather's cost):
        # departures always drop the highest ranks, i.e. zero a suffix of
        # the active prefix, and an arrival inserts one slot via a
        # shift-by-one gather below.  The carried job-space ranks only
        # serve the per-job read-back of departure offsets.
        rank_active = idx < m
        if weighted:
            ap, Ap = _bracket_powers(
                M, p_now, policy, dtype, weights_rank=w_rank
            )
        v, T = epoch_schedule(
            x_rank, ap, Ap, rank_active, p_now, n_servers, srpt=srpt
        )
        sN = speedup(jnp.asarray(n_servers, dtype), p_now)
        # The gap to the next event; with none left, every active job
        # departs analytically in this final drain step.
        t_next_arr = jnp.where(i < M, arr[jnp.minimum(i, M - 1)], jnp.inf)
        gap_arr = jnp.maximum(t_next_arr - t, 0.0)
        gap_drift = jnp.maximum(t_next_drift - t, 0.0)
        gap = jnp.minimum(gap_arr, gap_drift)
        has_event = jnp.isfinite(gap)
        dt_gap = jnp.where(has_event, gap, jnp.inf)
        x_rank_adv, dep_rank = _gap_advance(
            x_rank, v, T, ap, Ap, rank_active, dt_gap, sN, srpt=srpt
        )
        m2 = m - jnp.sum(dep_rank, dtype=jnp.int32)
        # Per-job read-back through the carried ranks.
        gslot = jnp.where(active, ranks - 1, 0)
        T_job = T[gslot]
        dep_job = active & (T_job <= dt_gap)
        times = jnp.where(dep_job, t + T_job, times)
        ranks = jnp.where(dep_job, 0, ranks)
        # Clock: pin to the exact arrival / boundary time (so admission
        # and the drift-regime lookup cannot miss it to rounding); on the
        # final drain step jump to the last departure (T[0] is rank 1's).
        t_new = jnp.where(
            has_event,
            jnp.where(gap_arr <= gap_drift, t_next_arr, t_next_drift),
            t + T[0],
        )
        # Admission, as in run_ranked: insert job i at its rank among the
        # survivors; every active job arrived earlier, so the arriving job
        # loses exact-size ties (survivors with x == x_a count as ahead).
        # Zero-size arrivals never activate (the generic scan's `x > 0`
        # gate), but still consume their event.
        admit = has_event & (gap_arr <= gap_drift)
        i_c = jnp.minimum(i, M - 1)
        x_a = xs[i_c]
        r_a = 1 + jnp.sum(x_rank_adv >= x_a, dtype=jnp.int32)
        place = admit & (x_a > 0)
        bumped = jnp.where((ranks > 0) & (ranks >= r_a), ranks + 1, ranks)
        inserted = bumped.at[i_c].set(r_a)
        ranks = jnp.where(place, inserted, ranks)
        # Rank-space insert: slots >= r_a shift right by one (the survivor
        # suffix past the active prefix is all zeros, so the shift is safe).
        shift = x_rank_adv[jnp.maximum(idx - 1, 0)]
        ins_x = jnp.where(
            idx == r_a - 1, x_a, jnp.where(idx < r_a - 1, x_rank_adv, shift)
        )
        x_rank = jnp.where(place, ins_x, x_rank_adv)
        if weighted:
            w_adv = jnp.where(idx < m2, w_rank, 0.0)
            w_a = w_arr[i_c]
            w_shift = w_adv[jnp.maximum(idx - 1, 0)]
            ins_w = jnp.where(
                idx == r_a - 1, w_a, jnp.where(idx < r_a - 1, w_adv, w_shift)
            )
            w_rank = jnp.where(place, ins_w, w_adv)
        m = m2 + jnp.where(place, 1, 0)
        i = i + jnp.where(admit, 1, 0)
        return (x_rank, w_rank, t_new, i, ranks, m, times), None

    init = (
        x_rank0, w_rank0, jnp.asarray(t0, dtype), i0, ranks0, m0,
        jnp.zeros(M, dtype),
    )
    (x_rank_fin, _, _, i_fin, ranks_fin, _, times), _ = jax.lax.scan(
        body, init, None, length=E
    )
    # Never-departed (horizon cut) and never-admitted jobs report inf,
    # matching the generic scan's safety net (admissions happen strictly
    # in arrival order, so job j was admitted iff j < i_fin).
    never_admitted = (idx >= i_fin) & (xs > 0)
    times = jnp.where((ranks_fin > 0) | never_admitted, jnp.inf, times)
    times_in = jnp.zeros(M, dtype).at[order].set(times)
    # Remaining sizes in the generic result's (arrival-sorted) job order.
    x_fin = jnp.where(
        ranks_fin > 0,
        x_rank_fin[jnp.where(ranks_fin > 0, ranks_fin - 1, 0)],
        jnp.where(never_admitted, xs, 0.0),
    )
    return EngineResult(
        completion_times=times_in, x_final=x_fin, order=order, trace=None,
        telemetry=None,
    )
