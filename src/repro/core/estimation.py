"""Online speedup-exponent estimation as a scan-carried rule state.

The paper assumes the speedup exponent ``p`` of ``s(k) = k^p`` is known a
priori; production fits it from observed throughput (Li et al. 2025 study
allocation when the speedup curve is known only approximately — exactly
the regime this module simulates).  ``sched/estimator.py`` does that fit
as a per-event NumPy loop over an explicit ``(log k, log T, weight)``
history; this module is its JAX port, rewritten as **closed-form recursive
weighted least squares over sufficient statistics** so the update is O(1)
per observation and jit-safe inside the engine's event scan
(``core/engine.py``): with ``s(k) = c k^p``, every observation satisfies
``log T = log c + p log k``, and the discounted WLS slope needs only the
running moments ``(Σw, Σw·lk, Σw·lt, Σw·lk², Σw·lk·lt)`` per job.

The fit matches the (fixed) NumPy estimator's ridge blend exactly: the
slope is pulled toward the prior with strength ``prior_weight``,

    p̂ = (cov + prior_weight · prior_p) / (var + prior_weight + 1e-12)

which equals ``α·OLS + (1-α)·prior`` with ``α = var/(var+prior_weight)``
— the blend-by-effective-sample-size the NumPy docstring promises.
Exponential ``discount`` (applied to a job's past moments each time *that
job* observes, the NumPy semantics) lets p̂ track regime changes
(:class:`~repro.core.engine.PDrift`).

Three read-outs, all pure functions of an :class:`EstState`:

- :func:`p_hat_jobs` — per-job p̂ (the NumPy ``SpeedupEstimator.p_hat``);
- :func:`blended_p_hat` — the work-weighted scalar blend heSRPT needs
  (``sched.estimator.blended_p``);
- :func:`p_hat_classes` — per-class p̂ from *pooled* class statistics
  (all jobs of a class share one exponent, so pooling their sufficient
  statistics is the exact WLS on the concatenated histories — the NumPy
  twin is ``sched.estimator.pooled_p_hat``).

On top sit the two stateful engine rules: :func:`estimating_rule`
(single-class policies see the blended p̂) and
:func:`estimating_class_rule` (``core/multiclass.py`` policies see the
per-class p̂ vector).  Both allocate with the *estimate* while the engine
physics keep the true exponent — the scheduler can be wrong, the hardware
isn't.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core.policies import Policy

#: Clip bounds shared with the NumPy estimator (p=0 and p=1 are both
#: degenerate for the Thm-7 brackets).
P_CLIP = (0.01, 0.999)


class EstState(NamedTuple):
    """Per-job sufficient statistics of the discounted log-log WLS.

    All arrays are shape ``[M]`` in the engine's arrival-sorted job order.
    ``n`` counts raw observations (undiscounted) — the fit falls back to
    the prior until a job has two, matching the NumPy estimator.
    """

    n: jax.Array  # [M] int32 observation counts
    s_w: jax.Array  # [M] Σ w
    s_k: jax.Array  # [M] Σ w · log k
    s_t: jax.Array  # [M] Σ w · log T
    s_kk: jax.Array  # [M] Σ w · (log k)²
    s_kt: jax.Array  # [M] Σ w · log k · log T


def init_est_state(n_jobs: int, dtype=jnp.float64) -> EstState:
    z = jnp.zeros(n_jobs, dtype)
    return EstState(
        n=jnp.zeros(n_jobs, jnp.int32), s_w=z, s_k=z, s_t=z, s_kk=z, s_kt=z
    )


def est_state_from_history(histories, dtype=jnp.float64) -> EstState:
    """Host-side constructor: fold existing NumPy estimator histories
    (lists of ``(log k, log T, weight)`` per job) into an :class:`EstState`
    — how ``sched/cluster.py`` seeds the engine when jobs have already
    observed throughput through ``report_progress``."""
    import numpy as np

    M = len(histories)
    n = np.zeros(M, np.int32)
    s = np.zeros((5, M), np.float64)
    for j, hist in enumerate(histories):
        for lk, lt, w in hist:
            n[j] += 1
            s[:, j] += (w, w * lk, w * lt, w * lk * lk, w * lk * lt)
    return EstState(
        n=jnp.asarray(n),
        s_w=jnp.asarray(s[0], dtype),
        s_k=jnp.asarray(s[1], dtype),
        s_t=jnp.asarray(s[2], dtype),
        s_kk=jnp.asarray(s[3], dtype),
        s_kt=jnp.asarray(s[4], dtype),
    )


def observe_throughput(
    state: EstState, obs: engine.Observation, *, discount=1.0
) -> EstState:
    """Fold one epoch's ``(alloc, rate)`` into the running moments.

    Mirrors ``SpeedupEstimator.observe``: a job only observes when it held
    a positive allocation and made positive progress (``alloc > 0`` and
    ``rate > 0`` — queued jobs learn nothing), and only *its* past moments
    are discounted when it does.  No-op epochs (``dt == 0``) observe
    nothing; the observed throughput is the fluid rate itself (work done /
    epoch length), independent of the epoch's duration, so every new
    sample enters with weight 1 exactly as in the NumPy history.
    """
    ok = obs.active & (obs.alloc > 0) & (obs.rate > 0) & (obs.dt > 0)
    lk = jnp.log(jnp.where(obs.alloc > 0, obs.alloc, 1.0).astype(state.s_w.dtype))
    lt = jnp.log(jnp.where(obs.rate > 0, obs.rate, 1.0).astype(state.s_w.dtype))
    d = jnp.where(ok, jnp.asarray(discount, state.s_w.dtype), 1.0)
    okf = ok.astype(state.s_w.dtype)
    return EstState(
        n=state.n + ok.astype(jnp.int32),
        s_w=state.s_w * d + okf,
        s_k=state.s_k * d + okf * lk,
        s_t=state.s_t * d + okf * lt,
        s_kk=state.s_kk * d + okf * lk * lk,
        s_kt=state.s_kt * d + okf * lk * lt,
    )


def _ridge_slope(n, s_w, s_k, s_t, s_kk, s_kt, prior_p, prior_weight):
    """The fixed ridge fit on raw moments (see module docstring): falls
    back to the prior with <2 samples or an unidentifiable design (all
    samples at one allocation)."""
    s_w_safe = jnp.maximum(s_w, jnp.finfo(s_w.dtype).tiny)
    var = s_kk - s_k * (s_k / s_w_safe)
    cov = s_kt - s_k * (s_t / s_w_safe)
    slope = (cov + prior_weight * prior_p) / (var + prior_weight + 1e-12)
    p = jnp.clip(slope, *P_CLIP)
    return jnp.where((n >= 2) & (var >= 1e-12), p, prior_p)


def p_hat_jobs(state: EstState, prior_p, *, prior_weight=1.0) -> jax.Array:
    """Per-job p̂, shape ``[M]`` (the jit-safe ``SpeedupEstimator.p_hat``).

    ``prior_p``/``prior_weight`` broadcast: scalars or per-job vectors in
    the same (arrival-sorted) job order as the state.
    """
    return _ridge_slope(
        state.n, state.s_w, state.s_k, state.s_t, state.s_kk, state.s_kt,
        jnp.asarray(prior_p, state.s_w.dtype), prior_weight,
    )


def blended_p_hat(
    state: EstState, x_act: jax.Array, prior_p, *, prior_weight=1.0
) -> jax.Array:
    """Work-weighted scalar blend of the active jobs' p̂ — what a
    single-exponent policy (heSRPT) acts on (``sched.estimator.blended_p``
    with the remaining sizes as weights; inactive jobs have ``x_act == 0``
    and drop out)."""
    ps = p_hat_jobs(state, prior_p, prior_weight=prior_weight)
    wsum = jnp.sum(x_act)
    return jnp.sum(ps * x_act) / jnp.maximum(wsum, jnp.finfo(x_act.dtype).tiny)


def pool_by_class(
    state: EstState, class_ids: jax.Array, n_classes: int
) -> EstState:
    """Sum per-job sufficient statistics into per-class ``[K]`` stats."""

    def pool(a):
        return jax.ops.segment_sum(a, class_ids, num_segments=n_classes)

    return EstState(*(pool(f) for f in state))


def p_hat_classes(
    state: EstState,
    class_ids: jax.Array,
    n_classes: int,
    prior_p,
    *,
    prior_weight=1.0,
    base: EstState | None = None,
) -> jax.Array:
    """Per-class p̂, shape ``[K]``, from class-pooled sufficient statistics.

    Jobs of one class share one true exponent, so the right estimator is
    the WLS over their *concatenated* histories — which is exactly the sum
    of their sufficient statistics.  ``class_ids`` must be in the state's
    (arrival-sorted) job order; ``prior_p``/``prior_weight`` are scalars
    or per-class ``[K]`` vectors.  ``base`` adds already-pooled ``[K]``
    stats for jobs *outside* the state — departed jobs keep contributing
    (observations don't expire with their job), which is how
    ``sched/cluster.py`` carries earlier runs' observations into a
    delegated run.
    """
    pooled = pool_by_class(state, class_ids, n_classes)
    if base is not None:
        pooled = EstState(*(a + b for a, b in zip(pooled, base, strict=True)))
    return _ridge_slope(
        pooled.n, pooled.s_w, pooled.s_k, pooled.s_t, pooled.s_kk,
        pooled.s_kt, jnp.asarray(prior_p, state.s_w.dtype), prior_weight,
    )


# ------------------------------------------------------ the stateful rules
def _rule_parts(n_alloc, n_chips, min_chips, snap_slices, dtype, discount):
    """The allocate tail (theta -> alloc, true-p rate) and the observe
    closure shared by both estimating rules — delegating the tail to
    ``engine.finish_alloc``, the ONE implementation every rule family
    uses, so the paths cannot desynchronize on quantization order or the
    observation's chip unit."""

    def finish(theta, p):
        return engine.finish_alloc(
            theta, p, n_alloc=n_alloc, n_chips=n_chips, min_chips=min_chips,
            snap_slices=snap_slices, dtype=dtype,
        )

    def observe(state, obs):
        # Continuous rules allocate theta; the estimator regresses on the
        # chip count theta * N (what the NumPy path stores in Job.chips).
        alloc = obs.alloc if n_chips is not None else obs.alloc * n_alloc
        return observe_throughput(
            state, obs._replace(alloc=alloc), discount=discount
        )

    return finish, observe


def estimating_rule(
    policy: Policy,
    n_servers,
    *,
    prior_p,
    prior_weight=1.0,
    discount=1.0,
    dtype,
    n_jobs: int | None = None,
    n_chips: int | None = None,
    min_chips: int = 1,
    snap_slices: bool = False,
    init_state: EstState | None = None,
) -> engine.StatefulRule:
    """Single-class estimating rule: the policy sees the blended p̂, the
    physics keep the true (possibly per-job, possibly drifting) ``p``.

    Continuous when ``n_chips`` is None (``alloc`` is theta, the observed
    "chips" are ``theta * n_servers``), whole chips otherwise (the
    ``ClusterScheduler`` decision epoch with online estimation — the
    regime that used to force the per-event Python loop).  ``prior_p`` and
    ``prior_weight`` may be per-job vectors in arrival-sorted order;
    ``init_state`` seeds pre-existing observation history (defaults to
    empty, sized by ``n_jobs``).
    """
    if init_state is None:
        if n_jobs is None:
            raise ValueError("estimating_rule needs n_jobs or init_state")
        init_state = init_est_state(n_jobs, dtype)
    n_alloc = float(n_chips) if n_chips is not None else float(n_servers)
    finish, observe = _rule_parts(
        n_alloc, n_chips, min_chips, snap_slices, dtype, discount
    )

    def allocate(state, x_act, p):
        p_seen = blended_p_hat(state, x_act, prior_p, prior_weight=prior_weight)
        return finish(policy(x_act, p_seen), p)

    return engine.StatefulRule(
        init=lambda: init_state, observe=observe, allocate=allocate
    )


def estimating_class_rule(
    name: str,
    *,
    class_ids: jax.Array,
    n_classes: int,
    prior_p,
    prior_weight=1.0,
    discount=1.0,
    dtype,
    n_servers: float | None = None,
    n_chips: int | None = None,
    min_chips: int = 1,
    snap_slices: bool = False,
    w: jax.Array | None = None,
    init_state: EstState | None = None,
    base_class_state: EstState | None = None,
) -> engine.StatefulRule:
    """Class-aware estimating rule: ``core/multiclass.py`` policies see the
    per-class p̂ vector (pooled statistics, mapped back to jobs through
    ``class_ids``), the physics keep each job's true exponent.

    ``class_ids``/``w`` follow the usual contract: per-job vectors in the
    engine's arrival-sorted order.  ``prior_p``/``prior_weight`` are
    per-class ``[K]`` (or scalar).  ``base_class_state`` folds in
    already-pooled ``[K]`` statistics of jobs that are NOT in this run
    (e.g. departed jobs of an earlier ``ClusterScheduler`` run, whose
    observations still inform their class's p̂).
    """
    from repro.core.multiclass import class_theta

    if init_state is None:
        init_state = init_est_state(class_ids.shape[0], dtype)
    n_alloc = float(n_chips) if n_chips is not None else float(n_servers)
    finish, observe = _rule_parts(
        n_alloc, n_chips, min_chips, snap_slices, dtype, discount
    )

    def allocate(state, x_act, p):
        p_k = p_hat_classes(
            state, class_ids, n_classes, prior_p,
            prior_weight=prior_weight, base=base_class_state,
        )
        p_seen = p_k[class_ids]
        return finish(class_theta(name, x_act, p_seen, n_servers=n_alloc, w=w), p)

    return engine.StatefulRule(
        init=lambda: init_state, observe=observe, allocate=allocate
    )


def simulate_scenario_estimated(
    scn,
    p,
    n_servers,
    policy: Policy,
    *,
    prior_p,
    prior_weight=1.0,
    discount=1.0,
    n_chips: int | None = None,
    min_chips: int = 1,
    rel_tol: float = 1e-9,
    horizon: int | None = None,
    telemetry=None,
):
    """Run a drawn :class:`~repro.core.scenarios.Scenario` with the
    estimator in the loop: the policy allocates with the blended p̂ fit
    online from observed throughput, while the physics use the scenario's
    true exponent — per-job ``scn.p_job`` and/or the piecewise drift
    ``scn.p_drift`` (the regime only an online estimator can track).

    The estimator-free arms of the same comparison (oracle-p, stale-p)
    are ``arrivals.simulate_scenario`` with/without a pinned ``p_hat`` —
    see ``benchmarks/estimation.py``.

    ``telemetry`` takes a probe (``core/telemetry.py``); the return is
    then ``(OnlineSimResult, TelemetryResult)``.  This is the wrapper
    where the ``p_hat_err`` metric earns its keep: a probe built with
    ``p_hat_reader=p_hat_error_metric(prior_p, prior_weight=...)`` reads
    the blended p̂ straight out of the rule's scan-carried
    :class:`EstState`.
    """
    from repro.core.arrivals import _finalize

    x0 = jnp.asarray(scn.x0)
    dtype = jnp.result_type(x0.dtype, jnp.float32)
    x0 = x0.astype(dtype)
    arr = jnp.asarray(scn.arrival_times).astype(dtype)
    p_phys = p if scn.p_job is None else jnp.asarray(scn.p_job, dtype)
    rule = estimating_rule(
        policy, n_servers, prior_p=prior_p, prior_weight=prior_weight,
        discount=discount, dtype=dtype, n_jobs=x0.shape[0], n_chips=n_chips,
        min_chips=min_chips,
    )
    res = engine.run(
        x0, arr, p_phys, rule, horizon=horizon, rel_tol=rel_tol,
        p_drift=scn.p_drift, telemetry=telemetry,
    )
    n_alone = n_chips if n_chips is not None else n_servers
    out = _finalize(x0, arr, res.completion_times, p_phys, n_alone)
    return (out, res.telemetry) if telemetry is not None else out


__all__ = [
    "EstState",
    "P_CLIP",
    "blended_p_hat",
    "est_state_from_history",
    "estimating_class_rule",
    "estimating_rule",
    "init_est_state",
    "observe_throughput",
    "p_hat_classes",
    "p_hat_jobs",
    "pool_by_class",
    "simulate_scenario_estimated",
]
