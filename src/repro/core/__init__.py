"""Core heSRPT math: policies, closed forms, fluid simulators (batch and
online/arrival-stream), diagnostics."""

from repro.core.arrivals import (
    OnlineSimResult,
    deterministic_arrivals,
    load_sweep,
    load_sweep_raw,
    pareto_sizes,
    poisson_arrivals,
    simulate_online,
    simulate_online_ranked,
)
from repro.core.flowtime import (
    hesrpt_completion_times,
    hesrpt_mean_flowtime,
    hesrpt_total_flowtime,
    omega_star,
    optimal_makespan,
    speedup,
)
from repro.core.policies import (
    POLICY_NAMES,
    RANK_POLICIES,
    equi,
    helrpt,
    hell,
    hesrpt,
    knee,
    make_policy,
    make_rank_policy,
    size_ranks_desc,
    srpt,
)
from repro.core.simulator import SimResult, simulate, total_flowtime

__all__ = [
    "OnlineSimResult",
    "POLICY_NAMES",
    "RANK_POLICIES",
    "SimResult",
    "deterministic_arrivals",
    "equi",
    "helrpt",
    "hell",
    "hesrpt",
    "hesrpt_completion_times",
    "hesrpt_mean_flowtime",
    "hesrpt_total_flowtime",
    "knee",
    "load_sweep",
    "load_sweep_raw",
    "make_policy",
    "make_rank_policy",
    "omega_star",
    "optimal_makespan",
    "pareto_sizes",
    "poisson_arrivals",
    "simulate",
    "simulate_online",
    "simulate_online_ranked",
    "size_ranks_desc",
    "speedup",
    "srpt",
    "total_flowtime",
]
