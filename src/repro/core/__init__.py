"""Core heSRPT math: policies, closed forms, fluid simulator, diagnostics."""

from repro.core.flowtime import (
    hesrpt_completion_times,
    hesrpt_mean_flowtime,
    hesrpt_total_flowtime,
    omega_star,
    optimal_makespan,
    speedup,
)
from repro.core.policies import (
    POLICY_NAMES,
    equi,
    helrpt,
    hell,
    hesrpt,
    knee,
    make_policy,
    size_ranks_desc,
    srpt,
)
from repro.core.simulator import SimResult, simulate, total_flowtime

__all__ = [
    "POLICY_NAMES",
    "SimResult",
    "equi",
    "helrpt",
    "hell",
    "hesrpt",
    "hesrpt_completion_times",
    "hesrpt_mean_flowtime",
    "hesrpt_total_flowtime",
    "knee",
    "make_policy",
    "omega_star",
    "optimal_makespan",
    "simulate",
    "size_ranks_desc",
    "speedup",
    "srpt",
    "total_flowtime",
]
