"""Shared inverse-permutation / ranking helpers.

Three modules used to carry their own copy of the same two-line scatter
(``engine._inv_rank``, ``policies.size_ranks_desc``'s rank scatter and
``policies.weighted_hesrpt``'s inline inverse permutation).  They live here
now — a leaf module importable by both ``core.policies`` and
``core.engine`` (policies cannot import engine: engine imports policies)
and by ``kernels.alloc``, whose fused allocation path must produce
bit-identical ranks to the unfused one.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def inv_rank(order: jax.Array) -> jax.Array:
    """Position of each element in its own argsort (the inverse permutation).

    ``inv_rank(jnp.argsort(key))[i]`` is the 0-based position job ``i``
    takes when sorted by ``key`` — the scatter form is O(M) where a second
    argsort would pay another O(M log M) sort.
    """
    M = order.shape[0]
    return (
        jnp.zeros(M, jnp.int32).at[order].set(jnp.arange(M, dtype=jnp.int32))
    )


def size_order_desc(x: jax.Array) -> jax.Array:
    """Argsort of the active jobs by remaining size, descending.

    Active (``x > 0``) jobs come first, largest first; inactive jobs sort
    last.  Ties break by index (stable argsort).  This is THE sorted order
    of the per-event hot path: ``ranks_from_order`` turns it into the
    1-based descending-size ranks every rank-space policy consumes, and the
    fused allocation kernel (``kernels.alloc``) reuses it for the
    oversubscription cut instead of re-sorting.
    """
    return jnp.argsort(jnp.where(x > 0, -x, jnp.inf))


def ranks_from_order(order: jax.Array, active: jax.Array) -> jax.Array:
    """1-based ranks from a :func:`size_order_desc` order (0 = inactive).

    Bit-identical to the historical ``size_ranks_desc`` scatter: the
    largest active job gets rank 1, the smallest rank ``m``; every rank is
    ``inv_rank + 1`` masked to the active set.
    """
    return jnp.where(active, inv_rank(order) + 1, 0)
