"""Server-allocation policies from the paper (and its competitors).

Every policy maps the *remaining* job sizes ``x`` (shape ``[M]``, entries
``<= 0`` mean "job already departed") and the speedup exponent ``p`` to an
allocation vector ``theta`` (shape ``[M]``, ``theta_i in [0, 1]``,
``sum(theta) <= 1``).  ``theta_i`` is the *fraction* of the ``N``-server
system granted to job ``i``; the job then progresses at rate
``s(theta_i * N) = (theta_i * N) ** p``.

All functions are pure, vectorized and ``jax.jit``-able; they are the
building block used by both the fluid simulator (``core/simulator.py``) and
the cluster scheduler (``sched/cluster.py``).

Paper: Berg, Vesilo, Harchol-Balter, "heSRPT: Optimal Parallel Scheduling of
Jobs With Known Sizes", 2019.
"""

from __future__ import annotations

import functools
from collections.abc import Callable

import jax
import jax.numpy as jnp

from repro.core.ranking import inv_rank, ranks_from_order, size_order_desc

Policy = Callable[..., jax.Array]  # (x, p, ...) -> theta


def _active(x: jax.Array) -> jax.Array:
    return x > 0


def size_ranks_desc(x: jax.Array) -> jax.Array:
    """Rank of each *active* job when sorted by remaining size, descending.

    The largest active job gets rank 1, the smallest active job gets rank
    ``m`` (the number of active jobs).  Inactive jobs get rank 0.  Ties are
    broken by index (stable argsort), which is WLOG optimal by symmetry.
    """
    # Inactive jobs sort last (key = -inf after negation -> +inf); the
    # order -> rank conversion is the shared inverse-permutation scatter.
    return ranks_from_order(size_order_desc(x), _active(x))


# Rank-space policy forms.  Theorem 6 proves the optimal allocation is
# size-invariant: it depends on the remaining sizes only through their
# descending-size *ranks* and the active count ``m``.  These helpers take
# the ranks directly (rank 0 == inactive), which is what lets the online
# simulator's fast path (core/arrivals.py) carry ranks incrementally
# through its scan instead of re-sorting at every event.
def hesrpt_theta_from_ranks(
    ranks: jax.Array, m: jax.Array, p: jax.Array, *, dtype=None
) -> jax.Array:
    """Theorem 7 in rank space: theta_i = (r/m)^(1/(1-p)) - ((r-1)/m)^(1/(1-p))."""
    dtype = dtype or jnp.result_type(float)
    active = ranks > 0
    rf = ranks.astype(dtype)
    c = 1.0 / (1.0 - p)
    m_safe = jnp.maximum(m, 1).astype(dtype)
    hi = (rf / m_safe) ** c
    lo = ((rf - 1.0) / m_safe) ** c
    return jnp.where(active, hi - lo, 0.0)


def equi_theta_from_ranks(
    ranks: jax.Array, m: jax.Array, p: jax.Array | None = None, *, dtype=None
) -> jax.Array:
    dtype = dtype or jnp.result_type(float)
    active = ranks > 0
    m_safe = jnp.maximum(m, 1).astype(dtype)
    return jnp.where(active, 1.0 / m_safe, jnp.zeros((), dtype))


def srpt_theta_from_ranks(
    ranks: jax.Array, m: jax.Array, p: jax.Array | None = None, *, dtype=None
) -> jax.Array:
    """The whole system to the smallest active job — rank m by definition."""
    dtype = dtype or jnp.result_type(float)
    return jnp.where((ranks == m) & (m > 0), jnp.ones((), dtype),
                     jnp.zeros((), dtype))


def hesrpt(x: jax.Array, p: jax.Array) -> jax.Array:
    """heSRPT (Theorem 7): the optimal allocation for total flow time.

    With ``m`` jobs remaining, ranked ``i = 1..m`` from largest to smallest
    remaining size::

        theta_i = (i/m)^(1/(1-p)) - ((i-1)/m)^(1/(1-p))

    Allocations are increasing in rank: the *smallest* job gets the largest
    share, but every active job gets a non-zero share (high efficiency).
    Size-invariant (Thm 6): depends only on the size *ordering* and ``m``.
    """
    active = _active(x)
    m = jnp.sum(active)
    ranks = size_ranks_desc(x)
    return hesrpt_theta_from_ranks(ranks, m, p, dtype=x.dtype)


def helrpt(x: jax.Array, p: jax.Array) -> jax.Array:
    """heLRPT (Theorem 2): the optimal allocation for makespan.

    ``gamma_i = x_i^(1/p) / sum_j x_j^(1/p)`` over active jobs.  All jobs
    complete simultaneously at ``||X||_{1/p}`` (Thm 1/2).  The allocation is
    stable under recomputation from remaining sizes, because remaining sizes
    stay proportional to the originals (x_i(t) = x_i (1 - t/T*)).
    """
    active = _active(x)
    xs = jnp.where(active, x, 1.0)
    # Normalize by the max for overflow safety before the 1/p power.
    xmax = jnp.max(jnp.where(active, x, 0.0))
    xmax = jnp.maximum(xmax, jnp.finfo(x.dtype).tiny)
    w = jnp.where(active, (xs / xmax) ** (1.0 / p), 0.0)
    total = jnp.maximum(jnp.sum(w), jnp.finfo(x.dtype).tiny)
    return w / total


def srpt(x: jax.Array, p: jax.Array | None = None) -> jax.Array:
    """SRPT: the whole system to the single job with the shortest remaining
    size.  Optimal iff p == 1 (embarrassingly parallel)."""
    active = _active(x)
    key = jnp.where(active, x, jnp.inf)
    shortest = jnp.argmin(key)
    theta = jnp.zeros_like(x).at[shortest].set(1.0)
    return jnp.where(jnp.any(active), theta, jnp.zeros_like(x))


def equi(x: jax.Array, p: jax.Array | None = None) -> jax.Array:
    """EQUI: equal split between active jobs.  Optimal for unknown
    exponentially-distributed sizes [5]; a lower-efficiency-loss baseline
    here."""
    active = _active(x)
    m = jnp.sum(active)
    m_safe = jnp.maximum(m, 1).astype(x.dtype)
    return jnp.where(active, 1.0 / m_safe, 0.0)


def hell(x: jax.Array, p: jax.Array, n_servers: jax.Array) -> jax.Array:
    """HELL [21]: greedy efficiency-to-remaining-time heuristic.

    [21] iteratively picks the job maximizing ``(s(k)/k) / (x_i / s(k)) =
    s(k)^2 / (k x_i) = k^(2p-1) / x_i`` and grants it the maximizing ``k``.

    With a continuously divisible system this degenerates into two closed
    forms (documented deviation from the loosely-specified original, see
    DESIGN.md §9):

    * ``p >= 1/2``: the ratio is non-decreasing in ``k`` -> the first pick
      takes *all* servers for the smallest job -> SRPT.
    * ``p < 1/2``: the ratio is decreasing in ``k`` -> greedy water-filling;
      the fixed point equalizes ``k_i^(2p-1) / x_i`` across jobs, giving
      ``k_i \\propto x_i^{-1/(1-2p)}`` (strong bias towards short jobs).
    """
    del n_servers  # continuous limit; the fixed point is N-independent
    active = _active(x)
    p = jnp.asarray(p, dtype=x.dtype)

    def waterfill(_):
        xs = jnp.where(active, x, 1.0)
        xmin = jnp.min(jnp.where(active, x, jnp.inf))
        # Guarded: this branch is only *selected* for p < 1/2, but lax.cond
        # traces it for any p, so keep the denominator non-zero.
        expo = -1.0 / jnp.maximum(1.0 - 2.0 * p, 1e-12)
        w = jnp.where(active, (xs / xmin) ** expo, 0.0)
        total = jnp.maximum(jnp.sum(w), jnp.finfo(x.dtype).tiny)
        return w / total

    def srpt_like(_):
        return srpt(x)

    return jax.lax.cond(p < 0.5, waterfill, srpt_like, operand=None)


def knee(
    x: jax.Array,
    p: jax.Array,
    n_servers: jax.Array,
    alpha: jax.Array,
) -> jax.Array:
    """KNEE [21]: allocate each job its "knee" number of servers.

    A job's knee is where the marginal run-time reduction of one more server
    drops below ``alpha``.  In the continuous relaxation::

        d/dk [x k^-p] = -p x k^-(p+1)   =>   knee_i = (p x_i / alpha)^(1/(1+p))

    Jobs are served in increasing-knee order (== increasing size).  If the
    knees oversubscribe the system, the prefix of shortest jobs get their
    knees and the boundary job gets the remainder.  If the knees
    undersubscribe, [21] repeats the process; the limit of repeated rounds is
    a proportional-to-knee split of all ``N`` servers (see DESIGN.md §9).

    ``alpha`` has no principled setting; the benchmark brute-forces it and
    reports the best, mirroring the paper's optimistic treatment of KNEE.
    """
    active = _active(x)
    xs = jnp.where(active, x, 0.0)
    kn = jnp.where(active, (p * xs / alpha) ** (1.0 / (1.0 + p)), 0.0)
    total_knee = jnp.sum(kn)

    def undersub(_):
        tot = jnp.maximum(total_knee, jnp.finfo(x.dtype).tiny)
        return kn / tot  # proportional split of the full system

    def oversub(_):
        # Serve in increasing-knee order until N runs out.
        key = jnp.where(active, kn, jnp.inf)
        order = jnp.argsort(key)
        kn_sorted = kn[order]
        csum = jnp.cumsum(kn_sorted)
        prev = csum - kn_sorted
        grant_sorted = jnp.clip(n_servers - prev, 0.0, kn_sorted)
        grant = jnp.zeros_like(kn).at[order].set(grant_sorted)
        return jnp.where(active, grant / n_servers, 0.0)

    return jax.lax.cond(total_knee <= n_servers, undersub, oversub, None)


# ------------------------------------------------- multi-class (per-job p)
# These policies accept a per-job exponent vector ``p`` (shape [M]) so job
# classes with different speedup curves (Berg et al. 2024) share one system.
# They are also the building blocks of ``core/multiclass.py``, which owns
# the class-id bookkeeping, the static class-blind reduction, and the
# engine/cluster dispatch.
def hesrpt_per_class(x: jax.Array, p: jax.Array) -> jax.Array:
    """Class-aware heSRPT: per-job Thm-7 brackets with each job's own ``p``.

    Jobs are ranked globally by remaining size (descending, as in heSRPT);
    job ``i`` with rank ``r`` and exponent ``p_i`` takes the bracket::

        (r/m)^(1/(1-p_i)) - ((r-1)/m)^(1/(1-p_i))

    i.e. the share Thm 7 would grant it in a homogeneous system of its own
    class — jobs with a *flatter* speedup curve (small ``p_i``) claim
    relatively less of the pool at the same rank, which is the class-aware
    fluid intuition of Berg et al. 2024.  Brackets are renormalized to sum
    to 1 (with uniform ``p`` the brackets telescope to 1 already, so this
    reduces to heSRPT up to a last-ulp renormalization; ``core/multiclass``
    dispatches the uniform case to :func:`hesrpt` statically so the
    reduction is bit-for-bit).
    """
    active = _active(x)
    m = jnp.sum(active)
    ranks = size_ranks_desc(x)
    rf = ranks.astype(x.dtype)
    c = 1.0 / (1.0 - p)  # per-job exponent
    m_safe = jnp.maximum(m, 1).astype(x.dtype)
    th = jnp.where(active, (rf / m_safe) ** c - ((rf - 1.0) / m_safe) ** c, 0.0)
    total = jnp.maximum(jnp.sum(th), jnp.finfo(x.dtype).tiny)
    return th / total


def weighted_hesrpt(x: jax.Array, p: jax.Array, w: jax.Array) -> jax.Array:
    """Weighted heSRPT: Thm-7 brackets over cumulative *weight* fractions.

    Generalizes heSRPT toward weighted flow time ``sum_i w_i T_i``: replace
    the count fraction ``r/m`` by the cumulative weight fraction ``W_r/W``
    of the jobs ranked largest..smallest by remaining size (Berg et al.
    2020 derive this bracket structure for mean slowdown, where
    ``w_i = 1/x_i(0)``)::

        theta_(r) = (W_r/W)^(1/(1-p_r)) - (W_{r-1}/W)^(1/(1-p_r))

    Heavier-weight jobs take a larger jump of the concave bracket curve, so
    they finish sooner; uniform weights reduce to :func:`hesrpt` (the
    cumulative count fraction is exactly ``r/m``) and per-job ``p`` is
    supported the same way as :func:`hesrpt_per_class`.  The brackets are
    renormalized so the allocation always sums to 1.
    """
    active = _active(x)
    order = size_order_desc(x)  # active desc by size, then inactive
    w_act = jnp.where(active, w, 0.0)
    csum_sorted = jnp.cumsum(w_act[order])
    W_hi = csum_sorted[inv_rank(order)]  # cum. weight of jobs at least this large
    W_lo = W_hi - w_act
    W_tot = jnp.maximum(csum_sorted[-1], jnp.finfo(x.dtype).tiny)
    c = 1.0 / (1.0 - p)
    th = jnp.where(active, (W_hi / W_tot) ** c - (W_lo / W_tot) ** c, 0.0)
    total = jnp.maximum(jnp.sum(th), jnp.finfo(x.dtype).tiny)
    return th / total


def waterfill(
    x: jax.Array,
    p: jax.Array,
    n_servers: jax.Array,
    w: jax.Array | None = None,
    *,
    n_iter: int = 64,
) -> jax.Array:
    """Class-weighted water-filling (the Berg et al. 2024 fluid allocation).

    Chooses ``theta`` maximizing the aggregate weighted service rate::

        max  sum_i  w_i / x_i * s(theta_i N)      s.t.  sum theta_i = 1

    over the active jobs (``w_i`` an optional per-job class weight, default
    1; the ``1/x_i`` factor biases toward short remaining work, the myopic
    flow-time/slowdown greedy).  The objective is strictly concave in
    ``theta`` for ``p_i in (0,1)``, so the KKT stationarity condition

        w_i/x_i * p_i * N^{p_i} * theta_i^{p_i - 1} = lambda

    has the closed-form water level ``theta_i(lambda) =
    (g_i/lambda)^{1/(1-p_i)}`` with ``g_i = w_i/x_i * p_i * N^{p_i}``; every
    active job sits in the interior (the marginal rate blows up at 0), so a
    monotone bisection on ``log lambda`` solves ``sum theta = 1`` to float
    precision in ``n_iter`` fixed steps — jit/vmap-safe inside the engine's
    scan.  The result is renormalized for exact conservation.
    """
    active = _active(x)
    dtype = x.dtype
    p = jnp.broadcast_to(jnp.asarray(p, dtype), x.shape)
    xs = jnp.where(active, x, 1.0)
    wv = jnp.ones_like(x) if w is None else jnp.asarray(w, dtype)
    wv = jnp.where(active, jnp.maximum(wv, jnp.finfo(dtype).tiny), 1.0)
    n = jnp.asarray(n_servers, dtype)
    # log g_i, computed in log space for heavy-tailed x
    log_g = jnp.log(wv) - jnp.log(xs) + jnp.log(p) + p * jnp.log(n)
    m = jnp.maximum(jnp.sum(active), 1).astype(dtype)
    one_minus_p = 1.0 - p
    # Bracket: at lam_lo = max_i g_i some theta_i = 1 (sum >= 1); at
    # lam_hi = max_i g_i * m^{1-p_i} every theta_i <= 1/m (sum <= 1).
    neg_inf = jnp.asarray(-jnp.inf, dtype)
    lo = jnp.max(jnp.where(active, log_g, neg_inf))
    hi = jnp.max(jnp.where(active, log_g + one_minus_p * jnp.log(m), neg_inf))

    def theta_of(log_lam):
        t = jnp.exp((log_g - log_lam) / one_minus_p)
        return jnp.where(active, t, 0.0)

    def bisect(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        too_big = jnp.sum(theta_of(mid)) > 1.0
        return jnp.where(too_big, mid, lo), jnp.where(too_big, hi, mid)

    lo, hi = jax.lax.fori_loop(0, n_iter, bisect, (lo, hi))
    th = theta_of(0.5 * (lo + hi))
    total = jnp.maximum(jnp.sum(th), jnp.finfo(dtype).tiny)
    return jnp.where(jnp.any(active), th / total, jnp.zeros_like(x))


# Rank-space registry: policies whose allocation is a pure function of the
# descending-size ranks (Thm 6 size-invariance).  For all three, the rate is
# non-increasing in remaining size, so between decision epochs the size
# order is preserved and the smallest active job departs first — the two
# invariants the online simulator's sort-free fast path relies on
# (core/arrivals.py::simulate_online_ranked).
RANK_POLICIES = {
    "hesrpt": hesrpt_theta_from_ranks,
    "equi": equi_theta_from_ranks,
    "srpt": srpt_theta_from_ranks,
}


def make_rank_policy(name: str):
    """Rank-space form ``(ranks, m, p) -> theta`` or None if unavailable."""
    return RANK_POLICIES.get(name.lower())


# Registry used by the simulator / benchmarks. HELL and KNEE close over the
# discrete system parameters they need.
def make_policy(name: str, *, n_servers: float = 1.0, alpha: float = 1.0) -> Policy:
    name = name.lower()
    if name == "hesrpt":
        return hesrpt
    if name == "helrpt":
        return helrpt
    if name == "srpt":
        # Returned unwrapped so identity checks (the engine's superstep
        # attachment) see the registry function, same as heSRPT.
        return srpt
    if name == "equi":
        return equi
    if name == "hell":
        return functools.partial(hell, n_servers=jnp.asarray(n_servers))
    if name == "waterfill":
        return functools.partial(waterfill, n_servers=jnp.asarray(n_servers))
    if name == "knee":
        return functools.partial(
            knee, n_servers=jnp.asarray(n_servers), alpha=jnp.asarray(alpha)
        )
    raise ValueError(f"unknown policy {name!r}")


POLICY_NAMES = ("hesrpt", "helrpt", "srpt", "equi", "hell", "knee", "waterfill")
