"""Diagnostics for allocation trajectories: the paper's structural properties.

Used by property tests and benchmarks to *verify* (not assume) Theorems 3-6
on simulated trajectories, and by the scheduler to report system efficiency.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.core.simulator import SimResult


def system_efficiency(theta: jax.Array, p: jax.Array) -> jax.Array:
    """Total service rate of the system relative to its embarrassingly
    parallel capacity: sum_i s(theta_i N) / s(N) = sum_i theta_i^p."""
    return jnp.sum(jnp.where(theta > 0, theta ** p, 0.0))


def scale_free_constants(result: SimResult) -> jax.Array:
    """Empirical omega_i per epoch: for the job of rank i (1-indexed, largest
    first) at the epoch where m(t) = i jobs remain, the paper's scale-free
    property (Thm 4) says  sum_{j<i} theta_j(t') / theta_i(t')  is the same
    at every earlier epoch t'.  Returns [E, M]: omega-hat of each job at each
    epoch (nan where the job is inactive)."""
    theta = result.theta_trace  # [E, M]
    sizes = result.sizes_trace  # [E, M]
    active = sizes > 0
    # For heSRPT sizes are already processed in globally fixed SJF order if
    # x0 was sorted descending; callers pass sorted instances for this check
    # (ranks are then static across epochs), so the cumulative theta of the
    # *larger* jobs is a prefix sum along the job axis.
    csum = jnp.cumsum(theta, axis=1) - theta
    return jnp.where(active & (theta > 0), csum / theta, jnp.nan)


# ------------------------------------------------- time-weighted reduction
def time_weighted_stats(values, dts) -> dict[str, float]:
    """Host-side time-weighted summary of one telemetry series.

    ``values``/``dts`` are per-event arrays (``core/telemetry.py`` series
    mode: epoch metric values and epoch lengths, no-op epochs carrying
    ``dt == 0``).  Returns ``{"mean", "max", "time"}`` with the mean
    weighted by epoch length and the max taken over positive-length epochs
    — the same definitions the in-scan streaming probe accumulates, so
    this is the cross-check (and the post-hoc path for ``record=True``
    sized runs).  NumPy on purpose: runs on host artifacts.
    """
    v = np.asarray(values, dtype=np.float64)
    dt = np.asarray(dts, dtype=np.float64)
    t = float(dt.sum())
    live = dt > 0
    return {
        "mean": float((v * dt).sum() / t) if t > 0 else 0.0,
        "max": float(v[live].max()) if live.any() else 0.0,
        "time": t,
    }


# ------------------------------------------------- per-cell aggregation
def seed_axis_stats(values) -> dict[str, list]:
    """Per-cell summary of one sweep stat over its seed axis.

    ``values`` is a ``[n_rates, n_seeds]`` (or ``[n_rates, n_seeds, K]``)
    array as produced by ``core/sweeps.py``; returns JSON-able
    ``{"mean": [...], "std": [...]}`` lists with the seed axis reduced —
    the per-cell unit the ``BENCH_sweeps.json`` trajectory records.
    NumPy on purpose: this runs on host-side artifacts, not in traced code.
    """
    a = np.asarray(values)
    return {"mean": np.mean(a, axis=1).tolist(),
            "std": np.std(a, axis=1).tolist()}


# ------------------------------------------------- per-class aggregation
def per_class_mean(
    values: jax.Array, class_ids: jax.Array, n_classes: int
) -> jax.Array:
    """Mean of ``values`` grouped by class id (shape ``[n_classes]``).

    Pure segment-sum, so it jit/vmaps inside the multi-class sweeps.
    Classes with no jobs report ``nan`` (there is no mean to take).
    """
    ids = jnp.asarray(class_ids)
    vals = jnp.asarray(values)
    sums = jax.ops.segment_sum(vals, ids, num_segments=n_classes)
    counts = jax.ops.segment_sum(
        jnp.ones_like(vals), ids, num_segments=n_classes
    )
    return jnp.where(counts > 0, sums / jnp.maximum(counts, 1), jnp.nan)


def per_class_count(class_ids: jax.Array, n_classes: int) -> jax.Array:
    """Number of jobs per class id (shape ``[n_classes]``, int32)."""
    return jax.ops.segment_sum(
        jnp.ones_like(jnp.asarray(class_ids), jnp.int32),
        jnp.asarray(class_ids),
        num_segments=n_classes,
    )


def per_class_summary(
    flow_times: jax.Array,
    slowdowns: jax.Array,
    completion_times: jax.Array,
    class_ids: jax.Array,
    n_classes: int,
) -> dict[str, jax.Array]:
    """Per-class aggregates of one trajectory: mean flow time, mean
    slowdown, job count, and mean completion *order* (0-based rank of each
    job's departure among all departures, averaged per class — which
    classes the policy clears first)."""
    times = jnp.asarray(completion_times)
    order_rank = jnp.zeros(times.shape[0]).at[jnp.argsort(times)].set(
        jnp.arange(times.shape[0], dtype=times.dtype)
    )
    return {
        "mean_flowtime": per_class_mean(flow_times, class_ids, n_classes),
        "mean_slowdown": per_class_mean(slowdowns, class_ids, n_classes),
        "count": per_class_count(class_ids, n_classes),
        "mean_completion_order": per_class_mean(
            order_rank, class_ids, n_classes
        ),
    }


def summarize(result: SimResult, p: jax.Array) -> dict[str, jax.Array]:
    theta0 = result.theta_trace[0]
    return {
        "total_flowtime": result.total_flowtime,
        "mean_flowtime": result.total_flowtime / result.completion_times.shape[0],
        "makespan": result.makespan,
        "initial_efficiency": system_efficiency(theta0, p),
    }
