"""Diagnostics for allocation trajectories: the paper's structural properties.

Used by property tests and benchmarks to *verify* (not assume) Theorems 3-6
on simulated trajectories, and by the scheduler to report system efficiency.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.core.simulator import SimResult


def system_efficiency(theta: jax.Array, p: jax.Array) -> jax.Array:
    """Total service rate of the system relative to its embarrassingly
    parallel capacity: sum_i s(theta_i N) / s(N) = sum_i theta_i^p."""
    return jnp.sum(jnp.where(theta > 0, theta ** p, 0.0))


def scale_free_constants(result: SimResult) -> jax.Array:
    """Empirical omega_i per epoch: for the job of rank i (1-indexed, largest
    first) at the epoch where m(t) = i jobs remain, the paper's scale-free
    property (Thm 4) says  sum_{j<i} theta_j(t') / theta_i(t')  is the same
    at every earlier epoch t'.  Returns [E, M]: omega-hat of each job at each
    epoch (nan where the job is inactive)."""
    theta = result.theta_trace  # [E, M]
    sizes = result.sizes_trace  # [E, M]
    active = sizes > 0

    def per_epoch(th, act):
        # rank jobs by remaining size descending within this epoch
        order = jnp.argsort(jnp.where(act, -sizes[0], 0.0))
        del order  # ranks are static across epochs for heSRPT (SJF order)
        csum = jnp.cumsum(th) - th  # sum of thetas of *larger* jobs if sorted
        return jnp.where(act & (th > 0), csum / th, jnp.nan)

    # For heSRPT sizes are already processed in globally fixed SJF order if
    # x0 was sorted descending; callers pass sorted instances for this check.
    csum = jnp.cumsum(theta, axis=1) - theta
    return jnp.where(active & (theta > 0), csum / theta, jnp.nan)


def summarize(result: SimResult, p: jax.Array) -> dict[str, jax.Array]:
    theta0 = result.theta_trace[0]
    return {
        "total_flowtime": result.total_flowtime,
        "mean_flowtime": result.total_flowtime / result.completion_times.shape[0],
        "makespan": result.makespan,
        "initial_efficiency": system_efficiency(theta0, p),
    }
