"""Event-driven fluid simulator for allocation policies.

Theorem 3 proves the optimal allocation is constant between departures, so a
fluid trajectory is fully described by its M departure epochs.  The simulator
exploits this: at each epoch it queries the policy once, advances every job
linearly at rate ``s(theta_i N)`` until the next departure, and records the
departure time.  This is *exact* for any policy that is constant between
departures (all policies in ``core/policies.py`` are — they are deterministic
functions of the remaining-size vector, which only changes order at
departures... and for size-proportional policies like heLRPT the allocation is
additionally constant *within* epochs by construction).

Everything is a single ``jax.lax.scan`` -> jit-able, vmap-able over seeds.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.flowtime import speedup
from repro.core.policies import Policy


class SimResult(NamedTuple):
    completion_times: jax.Array  # [M] absolute departure time of each job
    total_flowtime: jax.Array  # scalar, sum of completion times (arrivals at 0)
    makespan: jax.Array  # scalar, max completion time
    theta_trace: jax.Array  # [E, M] allocation chosen at each epoch
    epoch_times: jax.Array  # [E] start time of each epoch
    sizes_trace: jax.Array  # [E, M] remaining sizes at each epoch start


def simulate(
    x0: jax.Array,
    p: jax.Array,
    n_servers: jax.Array,
    policy: Policy,
    *,
    rel_tol: float = 1e-9,
) -> SimResult:
    """Run ``policy`` to completion on job sizes ``x0`` (any order).

    The scan runs exactly ``M`` iterations; at least one job departs per
    iteration for work-conserving policies, and iterations after the last
    departure are no-ops.  Simultaneous departures (e.g. heLRPT finishes all
    jobs at once) are handled by the relative tolerance ``rel_tol``.
    """
    M = x0.shape[0]
    x0 = jnp.asarray(x0)
    dtype = jnp.result_type(x0.dtype, jnp.float32)
    x0 = x0.astype(dtype)
    tol = rel_tol * jnp.max(x0)

    def body(carry, _):
        x, t, times = carry
        active = x > 0
        theta = policy(x, p).astype(dtype)
        rate = speedup(theta * n_servers, p)
        # Time to the next departure: min over active jobs with rate > 0.
        tt = jnp.where(active & (rate > 0), x / rate, jnp.inf)
        dt = jnp.min(tt)
        any_active = jnp.isfinite(dt)
        dt = jnp.where(any_active, dt, 0.0)  # all done -> no-op
        t_new = t + dt
        x_new = jnp.where(active, x - dt * rate, 0.0)
        # The argmin job departs BY CONSTRUCTION; float rounding must not be
        # allowed to keep it (fp32 residues ~eps*x would leak it) — zero it
        # explicitly along with anything inside tolerance.
        departing = (jnp.arange(M) == jnp.argmin(tt)) & active & any_active
        x_new = jnp.where(departing | (x_new <= tol), 0.0, x_new)
        newly_done = active & (x_new == 0.0) & any_active
        times = jnp.where(newly_done, t_new, times)
        return (x_new, t_new, times), (theta, t, x)

    init = (x0, jnp.zeros((), dtype), jnp.zeros(M, dtype))
    (x_fin, _, times), (theta_tr, t_tr, x_tr) = jax.lax.scan(
        body, init, None, length=M
    )
    # Safety: any job that never departed (pathological policy) -> inf.
    times = jnp.where(x_fin > 0, jnp.inf, times)
    return SimResult(
        completion_times=times,
        total_flowtime=jnp.sum(times),
        makespan=jnp.max(times),
        theta_trace=theta_tr,
        epoch_times=t_tr,
        sizes_trace=x_tr,
    )


def total_flowtime(
    x0: jax.Array, p: jax.Array, n_servers: jax.Array, policy: Policy
) -> jax.Array:
    return simulate(x0, p, n_servers, policy).total_flowtime
