"""Batch fluid simulator — a thin wrapper over ``core/engine.py``.

Theorem 3 proves the optimal allocation is constant between departures, so a
fluid trajectory is fully described by its M departure epochs.  The engine
exploits this; with every job pre-arrived at t=0 its event scan degenerates
into exactly the batch epoch loop this module historically implemented (the
``M``-step scan is bit-for-bit the old ``simulate``), and this wrapper only
repackages the engine trajectory into the public :class:`SimResult`.

Everything is a single ``jax.lax.scan`` -> jit-able, vmap-able over seeds.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core.policies import Policy


class SimResult(NamedTuple):
    completion_times: jax.Array  # [M] absolute departure time of each job
    total_flowtime: jax.Array  # scalar, sum of completion times (arrivals at 0)
    makespan: jax.Array  # scalar, max completion time
    theta_trace: jax.Array  # [E, M] allocation chosen at each epoch
    epoch_times: jax.Array  # [E] start time of each epoch
    sizes_trace: jax.Array  # [E, M] remaining sizes at each epoch start


def simulate(
    x0: jax.Array,
    p: jax.Array,
    n_servers: jax.Array,
    policy: Policy,
    *,
    rel_tol: float = 1e-9,
) -> SimResult:
    """Run ``policy`` to completion on job sizes ``x0`` (any order).

    The scan runs exactly ``M`` iterations; at least one job departs per
    iteration for work-conserving policies, and iterations after the last
    departure are no-ops.  Simultaneous departures (e.g. heLRPT finishes all
    jobs at once) are handled by the relative tolerance ``rel_tol``.
    """
    x0 = jnp.asarray(x0)
    M = x0.shape[0]
    dtype = jnp.result_type(x0.dtype, jnp.float32)
    res = engine.run(
        x0,
        jnp.zeros(M, dtype),
        p,
        engine.continuous_rule(policy, n_servers, dtype=dtype),
        pre_arrived=True,
        horizon=M,
        rel_tol=rel_tol,
        record=True,
    )
    times = res.completion_times
    return SimResult(
        completion_times=times,
        total_flowtime=jnp.sum(times),
        makespan=jnp.max(times),
        theta_trace=res.trace.alloc,
        epoch_times=res.trace.times,
        sizes_trace=res.trace.sizes,
    )


def total_flowtime(
    x0: jax.Array, p: jax.Array, n_servers: jax.Array, policy: Policy
) -> jax.Array:
    return simulate(x0, p, n_servers, policy).total_flowtime
