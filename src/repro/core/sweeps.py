"""One sweep subsystem: the declarative experiment engine behind every
simulator.

Every heavy-traffic experiment in this repo is the same shape — draw a
scenario per ``(seed, rate)`` cell, run it through the allocation engine
(``core/engine.py``), reduce to a few metrics, repeat for a handful of
policies.  Historically each experiment re-implemented its own jit+vmap
scaffolding (``load_sweep``/``load_sweep_raw``, ``multiclass_sweep``, and
three divergent benchmark ``sweep()`` copies); none of them chunked memory,
sharded across devices, or emitted machine-readable results.  This module
is the single replacement path:

- :class:`Sweep` — a hashable, declarative spec of the whole grid
  (policies x rates x seeds, scenario + kwargs, single-class / multi-class
  / estimation-arm regimes).  Specs are pure data: two equal specs share
  one compiled executor.
- :func:`run_sweep` — one compiled executor per policy, with three scale
  layers the hand-rolled versions lacked:

  1. **Chunked execution** — ``lax.map`` over seed-chunks of the inner
     ``vmap`` so the number of simultaneously simulated jobs never exceeds
     a ``max_jobs_in_flight`` memory budget; a 2,000-jobs x 200-seeds x
     5-loads grid (2M simulated jobs per policy) runs on CPU without OOM.
     Chunked results are bit-for-bit the unchunked ``vmap`` (tested).
  2. **Device sharding** — opt-in ``shard_map`` over the seed axis (the
     version-tolerant shims in ``models/common.py``), so multi-device
     hosts split seeds across devices; sharded == single-device (tested
     under ``XLA_FLAGS=--xla_force_host_platform_device_count``).
  3. **Structured artifacts** — every run returns a :class:`SweepResult`
     (spec, per-seed stats, wall/compile time, backend, chunking) that
     serializes to JSON; every ``run_sweep`` call also appends a compact
     record to the module :data:`RUN_LOG`, which ``benchmarks/run.py``
     flushes to ``BENCH_sweeps.json`` so the perf trajectory accumulates
     across commits.

``load_sweep``/``load_sweep_raw`` (``core/arrivals.py``),
``multiclass_sweep`` (``core/multiclass.py``) and the benchmark ``sweep()``
functions are thin spec-plus-formatting wrappers over this module; golden
pins in ``tests/test_sweeps.py`` hold the refactor to bit-for-bit f64
agreement with the pre-refactor outputs.
"""

from __future__ import annotations

import functools
import json
import os
import subprocess
import time
from datetime import datetime, timezone
from typing import Any, NamedTuple

import numpy as np

__all__ = [
    "RUN_LOG",
    "SCHEMA_VERSION",
    "STREAM_KEYS",
    "STREAM_METRICS",
    "Sweep",
    "SweepResult",
    "bench_records",
    "provenance",
    "run_sweep",
    "write_bench_json",
]

#: Version of the ``BENCH_sweeps.json`` record layout.  Bump when a field
#: changes meaning; ``tools/bench_diff.py`` parses rows from any version
#: tolerantly (missing fields are never a failure).
#: v2: provenance stamps + telemetry columns (this layer); v1: unstamped.
SCHEMA_VERSION = 2

#: Metrics computed per class (shape ``[n_rates, n_seeds, K]``); everything
#: else must be a scalar field of ``OnlineSimResult`` (``[n_rates, n_seeds]``).
CLASS_METRICS = {
    "class_flowtime": "flow_times",
    "class_slowdown": "slowdowns",
}

#: Streaming-regime metrics (``Sweep.create(stream=...)``): per-cell scalar
#: read-outs of ``engine.StreamResult`` — stationary-window aggregates from
#: the bounded-slot scan, not per-job reductions (there is no per-job array
#: to reduce; that is the point of the regime).
STREAM_METRICS = {
    "stream_flow": "mean_flow",
    "stream_slowdown": "mean_slowdown",
    "stream_completed": "n_window",
    "stream_arrived": "n_arrived_window",
    "stream_blocked": "blocked_steps",
    "stream_occupancy": "occupancy_max",
}

#: ``Sweep.create(stream=...)`` config keys: the slot-pool size and the
#: stationary window as fractions of the tape's nominal span ``n_jobs/rate``
#: (arrivals inside ``[warmup_frac, end_frac] * span`` are measured, so the
#: warm-up ramp and the drain tail are both discarded).
STREAM_KEYS = ("n_slots", "warmup_frac", "end_frac")

#: Estimation-regime arms (see ``benchmarks/estimation.py``): how the policy
#: learns the speedup exponent on a p-drift scenario.
ARMS = ("oracle", "stale", "estimator")


@functools.lru_cache(maxsize=1)
def _build_info() -> dict:
    """The per-process half of the provenance stamp (git SHA + library
    versions are fixed for the process lifetime; the timestamp is not)."""
    import jax

    try:
        import jaxlib

        jaxlib_version = jaxlib.__version__
    except Exception:  # pragma: no cover - jaxlib always ships with jax
        jaxlib_version = None
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10, check=True,
        ).stdout.strip() or None
    except Exception:
        sha = None  # not a checkout (installed wheel, stripped CI tarball)
    return {
        "git_sha": sha,
        "jax_version": jax.__version__,
        "jaxlib_version": jaxlib_version,
    }


def provenance() -> dict:
    """Provenance stamp for one benchmark record: schema version, git SHA
    (``None`` outside a checkout), jax/jaxlib versions, and the UTC
    creation timestamp — enough to answer "which code produced this row,
    on which stack, when" from the artifact alone."""
    return {
        "schema_version": SCHEMA_VERSION,
        **_build_info(),
        "created_utc": datetime.now(timezone.utc).isoformat(
            timespec="seconds"
        ),
    }


def _hashable(v):
    """Coerce JSON-ish values (lists, dicts, ClassSpec rows) to hashables."""
    if isinstance(v, dict):
        return tuple(sorted((k, _hashable(x)) for k, x in v.items()))
    if isinstance(v, (list, tuple)):
        return tuple(_hashable(x) for x in v)
    return v


class Sweep(NamedTuple):
    """Declarative sweep spec: pure hashable data, no arrays, no closures.

    Use :meth:`Sweep.create` (it normalizes sequences/dicts into the
    hashable tuples jit caching needs).  The spec pins *what* is simulated;
    execution strategy (chunking, sharding) is a :func:`run_sweep` argument
    so the same spec produces identical numbers under any strategy.
    """

    policies: tuple[str, ...]
    rates: tuple[float, ...]
    scenario: str = "poisson"
    scenario_kw: tuple = ()
    n_jobs: int = 1000
    n_seeds: int = 100
    seed: int = 0
    p: float = 0.5
    n_servers: float = 256.0
    size_alpha: float = 1.5
    n_chips: int | None = None
    min_chips: int = 1
    snap_slices: bool = False
    classes: tuple | None = None  # tuple[ClassSpec, ...] for multi-class
    metrics: tuple[str, ...] = ("mean_flowtime",)
    arm: str | None = None  # estimation regime: oracle | stale | estimator
    arm_kw: tuple = ()  # e.g. (("discount", 0.9), ("prior_weight", 1.0))
    fused: bool = False  # kernels/alloc.py fused allocate (quantized heSRPT)
    telemetry: tuple[str, ...] = ()  # in-scan probe metrics -> tel_* columns
    stream: tuple = ()  # bounded-slot regime: (("n_slots", S), ...) kv pairs
    superstep: bool = False  # core/superstep.py closed-form arrival scan

    @classmethod
    def create(
        cls,
        policies,
        rates,
        *,
        scenario: str = "poisson",
        scenario_kw: dict | tuple | None = None,
        n_jobs: int = 1000,
        n_seeds: int = 100,
        seed: int = 0,
        p: float = 0.5,
        n_servers: float = 256.0,
        size_alpha: float = 1.5,
        n_chips: int | None = None,
        min_chips: int = 1,
        snap_slices: bool = False,
        classes=None,
        metrics=None,
        arm: str | None = None,
        arm_kw: dict | tuple | None = None,
        fused: bool = False,
        telemetry=(),
        stream: dict | tuple | None = None,
        superstep: bool = False,
    ) -> "Sweep":
        from repro.core.arrivals import OnlineSimResult
        from repro.core.multiclass import as_specs
        from repro.core.scenarios import _any_pos
        from repro.core.telemetry import DEFAULT_METRICS, METRICS

        if classes is not None:
            classes = as_specs(classes)
        stream = _hashable(stream or {})
        if stream:
            skw = dict(stream)
            unknown_keys = tuple(k for k in skw if k not in STREAM_KEYS)
            if unknown_keys:
                raise ValueError(
                    f"unknown stream key(s) {unknown_keys}; known: {STREAM_KEYS}"
                )
            if "n_slots" not in skw or int(skw["n_slots"]) < 1:
                raise ValueError("stream needs n_slots >= 1 (the slot pool)")
            warm = float(skw.get("warmup_frac", 0.1))
            end = float(skw.get("end_frac", 0.9))
            if not 0.0 <= warm < end:
                raise ValueError(
                    "stream window needs 0 <= warmup_frac < end_frac "
                    f"(got {warm} / {end})"
                )
            if classes is not None or arm is not None:
                raise ValueError(
                    "streaming sweeps are single-class and arm-free — "
                    "per-job class/estimator state does not ride in slots"
                )
            skw_scn = dict(_hashable(scenario_kw or {}))
            if scenario.startswith(("drift_", "multiclass_")) or _any_pos(
                skw_scn.get("sigma_size", 0.0)
            ) or _any_pos(skw_scn.get("sigma_p", 0.0)):
                raise ValueError(
                    "streaming sweeps need a plain tape scenario (no drift, "
                    "classes or estimation noise — see scenarios.stream_tape)"
                )
        if metrics is None:
            if stream:
                metrics = ("stream_flow", "stream_slowdown")
            elif classes is not None:
                metrics = ("mean_flowtime", "mean_slowdown", "class_flowtime",
                           "class_slowdown")
            else:
                metrics = ("mean_flowtime",)
        metrics = tuple(metrics)
        for m in metrics:
            if stream:
                if m not in STREAM_METRICS:
                    raise ValueError(
                        f"metric {m!r} is not a streaming metric; streaming "
                        f"sweeps read {tuple(STREAM_METRICS)}"
                    )
            elif m in STREAM_METRICS:
                raise ValueError(f"metric {m!r} needs a streaming sweep (stream=)")
            elif m in CLASS_METRICS:
                if classes is None:
                    raise ValueError(f"metric {m!r} needs a multi-class sweep")
            elif m not in OnlineSimResult._fields:
                raise ValueError(f"unknown metric {m!r}")
        if arm is not None and arm not in ARMS:
            raise ValueError(f"unknown arm {arm!r}; known: {ARMS}")
        if arm is not None and classes is not None:
            raise ValueError("estimation arms are single-class sweeps")
        if arm is not None and n_chips is not None:
            # The arm cells run the continuous simulators; accepting n_chips
            # would record a "quantized" spec whose physics were continuous.
            raise ValueError("estimation arms are continuous-only (no n_chips)")
        if arm is not None and "p0" not in dict(_hashable(scenario_kw or {})):
            # Without an explicit p0 the stale arm would pin its belief to
            # the generic default ``p`` while the drift sampler uses its
            # OWN p0 default — a silently wrong three-arm comparison.
            raise ValueError(
                "estimation arms need scenario_kw['p0'] (the pre-drift "
                "exponent the stale/estimator arms anchor their belief to)"
            )
        if snap_slices and classes is None:
            raise ValueError("snap_slices is only wired for multi-class sweeps")
        if fused:
            # The fused allocate exists for the quantized heSRPT hot path;
            # continuous heSRPT already dispatches to the (faster) carried-
            # rank scan, and no other policy has a fused variant.
            if classes is not None or arm is not None:
                raise ValueError("fused sweeps are single-class, arm-free")
            if n_chips is None:
                raise ValueError(
                    "fused=True needs n_chips (the quantized regime; "
                    "continuous heSRPT already runs the ranked fast path)"
                )
            bad = tuple(p for p in policies if p != "hesrpt")
            if bad:
                raise ValueError(f"fused sweeps support only heSRPT, got {bad}")
        if superstep:
            # The closed-form superstep path (core/superstep.py) is exact
            # only for the continuous, noise-free, scalar-p rank family;
            # every other regime keeps its per-event scan.
            if classes is not None or arm is not None:
                raise ValueError("superstep sweeps are single-class, arm-free")
            if n_chips is not None:
                raise ValueError(
                    "superstep=True is the continuous closed-form path "
                    "(quantized chips need the per-event scan)"
                )
            if fused or telemetry or stream:
                raise ValueError(
                    "superstep sweeps take no fused/telemetry/stream "
                    "options (all three ride the per-event scan)"
                )
            bad = tuple(q for q in policies if q not in ("hesrpt", "equi",
                                                         "srpt"))
            if bad:
                raise ValueError(
                    f"superstep sweeps support heSRPT/EQUI/SRPT, got {bad}"
                )
            skw_ss = dict(_hashable(scenario_kw or {}))
            if _any_pos(skw_ss.get("sigma_size", 0.0)) or _any_pos(
                skw_ss.get("sigma_p", 0.0)
            ):
                raise ValueError(
                    "superstep sweeps need noise-free scenarios "
                    "(estimation noise takes the generic scan)"
                )
            if scenario.startswith("multiclass_"):
                raise ValueError(
                    "superstep sweeps are single-class (per-job exponents "
                    "take the generic scan)"
                )
        if telemetry is True:
            telemetry = DEFAULT_METRICS
        telemetry = tuple(telemetry or ())
        if telemetry:
            unknown = tuple(m for m in telemetry if m not in METRICS)
            if unknown:
                raise ValueError(
                    f"unknown telemetry metric(s) {unknown}; known: {METRICS}"
                )
            if classes is not None:
                # The multi-class cells run simulate_multiclass, which owns
                # its own engine invocation; telemetry is not threaded
                # through it yet (ROADMAP: windowed per-class aggregates
                # belong to the streaming-engine refactor).
                raise ValueError(
                    "telemetry columns are single-class only for now"
                )
            if "p_hat_err" in telemetry and arm != "estimator":
                raise ValueError(
                    "telemetry metric 'p_hat_err' needs arm='estimator' "
                    "(only an estimating rule carries a p-hat to be wrong)"
                )
        return cls(
            policies=tuple(policies),
            rates=tuple(float(r) for r in rates),
            scenario=scenario,
            scenario_kw=_hashable(scenario_kw or {}),
            n_jobs=int(n_jobs),
            n_seeds=int(n_seeds),
            seed=int(seed),
            p=float(p),
            n_servers=float(n_servers),
            size_alpha=float(size_alpha),
            n_chips=None if n_chips is None else int(n_chips),
            min_chips=int(min_chips),
            snap_slices=bool(snap_slices),
            classes=classes,
            metrics=metrics,
            arm=arm,
            arm_kw=_hashable(arm_kw or {}),
            fused=bool(fused),
            telemetry=telemetry,
            stream=stream,
            superstep=bool(superstep),
        )

    def jobs_per_seed(self) -> int:
        """Simulated jobs one seed contributes across the rate axis."""
        return len(self.rates) * self.n_jobs

    def total_jobs(self) -> int:
        """Simulated jobs in the whole grid, per policy."""
        return self.n_seeds * self.jobs_per_seed()


# --------------------------------------------------------- per-cell functions
def _cell_fn(spec: Sweep, name: str):
    """Build ``one(key, rate) -> tuple_of_metrics`` for one policy.

    These closures are verbatim ports of the per-experiment bodies this
    module replaced (the jit+vmap closures that lived in
    ``core/arrivals.py``, ``core/multiclass.py`` and
    ``benchmarks/estimation.py`` before the refactor) — same sampler
    construction, same fast-path dispatch — which is what lets the
    golden-pin tests demand bit-for-bit f64 agreement with the
    pre-refactor sweeps.
    """
    import jax.numpy as jnp

    from repro.core.analysis import per_class_mean
    from repro.core.scenarios import make_scenario

    kw = dict(spec.scenario_kw)

    tel_probe = None
    if spec.telemetry and not spec.stream:
        # O(1) streaming aggregates in the scan carry — the per-cell
        # scalar columns (tel_*_mean / tel_*_max) cost no per-event
        # memory, so telemetry rides along at any sweep scale.
        from repro.core.telemetry import make_probe, p_hat_error_metric

        reader = None
        if spec.arm == "estimator":
            akw_t = dict(spec.arm_kw)
            reader = p_hat_error_metric(
                kw["p0"], prior_weight=akw_t.get("prior_weight", 1.0)
            )
        tel_probe = make_probe(
            spec.telemetry,
            mode="stream",
            alloc_unit=float(spec.n_chips) if spec.n_chips else 1.0,
            n_jobs=spec.n_jobs,
            p_hat_reader=reader,
            dtype=jnp.result_type(float),
        )

    def tel_values(tel):
        from repro.core.telemetry import scalar_values

        return scalar_values(tel, spec.telemetry)

    def metrics_of(res, scn):
        out = []
        for m in spec.metrics:
            if m in CLASS_METRICS:
                out.append(
                    per_class_mean(
                        getattr(res, CLASS_METRICS[m]),
                        scn.class_ids,
                        len(spec.classes),
                    )
                )
            else:
                out.append(getattr(res, m))
        return tuple(out)

    if spec.stream:
        # Bounded-slot regime: same sampler, but the cell runs the O(n_slots)
        # streaming engine and reads stationary-window aggregates instead of
        # whole-tape means.  The window is a fixed fraction of the expected
        # tape span so every (rate, seed) cell discards the same share of
        # warm-up and tail truncation.
        from repro.core import engine
        from repro.core.arrivals import simulate_stream
        from repro.core.policies import make_policy, make_rank_policy
        from repro.core.scenarios import stream_tape

        sampler = make_scenario(
            spec.scenario, size_alpha=spec.size_alpha, p=spec.p, **kw
        )
        skw = dict(spec.stream)
        n_slots = int(skw["n_slots"])
        warm = float(skw.get("warmup_frac", 0.1))
        end = float(skw.get("end_frac", 0.9))
        dtype = jnp.result_type(float)
        # Carried-rank fast path under the same conditions as the finite-tape
        # branch below (telemetry probes need the generic scan's ProbeEvent).
        rank_pol = (
            make_rank_policy(name)
            if spec.n_chips is None and not spec.telemetry and not spec.fused
            else None
        )
        pol = make_policy(
            name,
            n_servers=(
                spec.n_chips if spec.n_chips is not None else spec.n_servers
            ),
        )

        def one(key, rate):
            scn = sampler(key, spec.n_jobs, rate)
            span = spec.n_jobs / rate  # expected arrival span at this rate
            window = (warm * span, end * span)
            probe = None
            if spec.telemetry:
                from repro.core.telemetry import make_probe

                probe = make_probe(
                    spec.telemetry,
                    mode="stream",
                    alloc_unit=float(spec.n_chips) if spec.n_chips else 1.0,
                    n_jobs=n_slots,
                    window=window,
                    dtype=dtype,
                )
            if rank_pol is not None:
                x0, arr = stream_tape(scn)
                res = engine.run_stream_ranked(
                    x0, arr, spec.p, spec.n_servers, rank_pol,
                    n_slots=n_slots, window=window, n_alone=spec.n_servers,
                )
            else:
                res = simulate_stream(
                    scn, spec.p, spec.n_servers, pol, n_slots=n_slots,
                    window=window, n_chips=spec.n_chips,
                    min_chips=spec.min_chips, fused=spec.fused,
                    telemetry=probe,
                )
            out = tuple(
                jnp.asarray(getattr(res, STREAM_METRICS[m]), dtype)
                for m in spec.metrics
            )
            if probe is not None:
                return out + tel_values(res.telemetry)
            return out

        return one

    if spec.classes is not None:
        from repro.core.multiclass import simulate_multiclass

        sampler = make_scenario(
            spec.scenario, size_alpha=spec.size_alpha, p=spec.p,
            classes=spec.classes, **kw,
        )

        def one(key, rate):
            scn = sampler(key, spec.n_jobs, rate)
            res = simulate_multiclass(
                scn,
                classes=spec.classes,
                policy=name,
                n_servers=spec.n_servers,
                n_chips=spec.n_chips,
                min_chips=spec.min_chips,
                snap_slices=spec.snap_slices,
            )
            return metrics_of(res, scn)

        return one

    sampler = make_scenario(
        spec.scenario, size_alpha=spec.size_alpha, p=spec.p, **kw
    )

    if spec.arm is not None:
        from repro.core.arrivals import simulate_scenario
        from repro.core.estimation import simulate_scenario_estimated
        from repro.core.policies import make_policy

        akw = dict(spec.arm_kw)
        p0 = kw["p0"]  # presence enforced by Sweep.create
        pol = make_policy(name, n_servers=spec.n_servers)

        def one(key, rate):
            scn = sampler(key, spec.n_jobs, rate)
            if spec.arm == "oracle":
                # simulate_scenario shows the rule the CURRENT true regime.
                res = simulate_scenario(
                    scn, p0, spec.n_servers, pol, telemetry=tel_probe
                )
            elif spec.arm == "stale":
                # a pinned p_hat: the scheduler never notices the drift.
                res = simulate_scenario(
                    scn._replace(p_hat=jnp.asarray(p0)), p0, spec.n_servers,
                    pol, telemetry=tel_probe,
                )
            else:  # estimator: allocate with the online blended p-hat
                res = simulate_scenario_estimated(
                    scn, p0, spec.n_servers, pol, prior_p=p0,
                    prior_weight=akw.get("prior_weight", 1.0),
                    discount=akw.get("discount", 1.0), telemetry=tel_probe,
                )
            if tel_probe is not None:
                res, tel = res
                return metrics_of(res, scn) + tel_values(tel)
            return metrics_of(res, scn)

        return one

    from repro.core.arrivals import (
        simulate_online_ranked,
        simulate_online_superstep,
        simulate_scenario,
    )
    from repro.core.policies import make_policy, make_rank_policy
    from repro.core.scenarios import _any_pos

    noisy = _any_pos(kw.get("sigma_size", 0.0)) or _any_pos(
        kw.get("sigma_p", 0.0)
    )
    # Sort-free ranked scan where the policy allows it (heSRPT, EQUI,
    # SRPT — ~20x faster at M=1000); generic sort-per-event otherwise.
    # Estimation noise and chip quantization both break the carried-rank
    # invariants; per-job exponents (``p_job``) and p-drift boundaries
    # (``p_drift``) are static per sampler, so the branch is resolved at
    # trace time.  Telemetry probes hook the generic scan's ProbeEvent,
    # so a telemetry sweep takes that path too.  ``spec.superstep``
    # upgrades further, to the closed-form arrival-superstep scan
    # (core/superstep.py — one step per arrival, departures analytic);
    # Sweep.create has already pinned its supported envelope, including
    # scalar-regime drift.
    rank_pol = (
        make_rank_policy(name)
        if spec.n_chips is None and not noisy and not spec.telemetry
        and not spec.superstep
        else None
    )
    pol = make_policy(
        name,
        n_servers=(
            spec.n_chips if spec.n_chips is not None else spec.n_servers
        ),
    )

    def one(key, rate):
        scn = sampler(key, spec.n_jobs, rate)
        if spec.superstep:
            res = simulate_online_superstep(
                scn.x0, scn.arrival_times, spec.p, spec.n_servers, name,
                p_drift=scn.p_drift,
            )
        elif rank_pol is not None and scn.p_job is None and scn.p_drift is None:
            res = simulate_online_ranked(
                scn.x0, scn.arrival_times, spec.p, spec.n_servers, rank_pol
            )
        else:
            res = simulate_scenario(
                scn, spec.p, spec.n_servers, pol, n_chips=spec.n_chips,
                min_chips=spec.min_chips, fused=spec.fused,
                telemetry=tel_probe,
            )
            if tel_probe is not None:
                res, tel = res
                return metrics_of(res, scn) + tel_values(tel)
        return metrics_of(res, scn)

    return one


# ------------------------------------------------------------- the executors
def _metric_ndim(spec: Sweep, metric: str) -> int:
    """Trailing rank of one cell's value for ``metric`` (0 or 1)."""
    return 1 if metric in CLASS_METRICS else 0


def _out_names(spec: Sweep) -> tuple[str, ...]:
    """Every stat column one cell emits: the simulator metrics followed by
    the telemetry scalar columns (``tel_<metric>_mean`` / ``_max``)."""
    from repro.core.telemetry import scalar_columns

    return spec.metrics + scalar_columns(spec.telemetry)


def _build_fn(
    spec: Sweep, name: str, chunk: int | None, shard: bool,
    shard_axis: str = "seeds",
):
    """The pure ``(keys, rates) -> tuple_of_metric_arrays`` a policy runs.

    ``keys`` (or ``rates``, under ``shard_axis="rates"``) may be padded to
    the shard grid; each metric comes back ``[n_rates, len(keys)(, K)]``.
    """
    import jax
    import jax.numpy as jnp

    one = _cell_fn(spec, name)
    inner = jax.vmap(jax.vmap(one, in_axes=(0, None)), in_axes=(None, 0))

    def over_seeds(keys, rates):
        # Rate-axis shards see a slice of the rate grid, so the rate count
        # comes from the argument, not the spec.
        R = rates.shape[0]
        s_local = keys.shape[0]
        if chunk is None or chunk >= s_local:
            return inner(keys, rates)
        n_chunks = -(-s_local // chunk)
        pad = n_chunks * chunk - s_local
        kp = jnp.concatenate([keys, keys[:1].repeat(pad, axis=0)]) if pad else keys
        kc = kp.reshape(n_chunks, chunk, *keys.shape[1:])
        # lax.map: one chunk of seeds resident at a time — the memory
        # budget — while each chunk still runs the full vmap'd grid.
        outs = jax.lax.map(lambda k: inner(k, rates), kc)
        return tuple(
            jnp.moveaxis(a, 0, 1).reshape(R, n_chunks * chunk, *a.shape[3:])[
                :, :s_local
            ]
            for a in outs
        )

    if not shard:
        return over_seeds

    from jax.sharding import Mesh, PartitionSpec as P

    from repro.models.common import shard_map

    devices = np.asarray(jax.devices())
    mesh = Mesh(devices, (shard_axis,))
    if shard_axis == "rates":
        # Wide load grids: split the rate axis, replicate seeds.  Metric
        # arrays are [n_rates, n_seeds(, K)], so the sharded axis leads.
        in_specs = (P(), P("rates"))
        out_specs = tuple(
            P("rates", None, *(None,) * _metric_ndim(spec, m))
            for m in _out_names(spec)
        )
    else:
        in_specs = (P("seeds"), P())
        out_specs = tuple(
            P(None, "seeds", *(None,) * _metric_ndim(spec, m))
            for m in _out_names(spec)
        )

    def sharded(keys, rates):
        return shard_map(
            over_seeds,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
        )(keys, rates)

    return sharded


# Compiled-executor cache: one AOT-compiled callable per (spec-sans-policies,
# policy, padded seed count, chunk, shard) — repeat run_sweep calls (and the
# benchmarks' warmup-before-timing idiom) reuse it instead of recompiling.
# Bounded like the lru_cache(64) it replaced: oldest entry evicted first
# (dict preserves insertion order), so long-lived processes sweeping many
# distinct configs plateau instead of accumulating executables forever.
_EXECUTORS: dict[tuple, Any] = {}
_EXECUTORS_MAX = 64


def _executor(spec: Sweep, name: str, keys, rates, chunk: int | None,
              shard: bool, shard_axis: str = "seeds"):
    """Return ``(compiled, compile_seconds)`` for one policy column."""
    import jax

    cache_key = (
        spec._replace(policies=()), name, int(keys.shape[0]),
        int(rates.shape[0]), chunk, shard, shard_axis,
        str(keys.dtype), str(rates.dtype),
    )
    hit = _EXECUTORS.get(cache_key)
    if hit is not None:
        # LRU refresh: re-insert so hot executors survive the eviction
        # sweep below (dict preserves insertion order).
        _EXECUTORS[cache_key] = _EXECUTORS.pop(cache_key)
        return hit, 0.0
    f = _build_fn(spec, name, chunk, shard, shard_axis)
    t0 = time.perf_counter()
    compiled = jax.jit(f).lower(keys, rates).compile()
    compile_s = time.perf_counter() - t0
    while len(_EXECUTORS) >= _EXECUTORS_MAX:
        _EXECUTORS.pop(next(iter(_EXECUTORS)))
    _EXECUTORS[cache_key] = compiled
    return compiled, compile_s


def resolve_chunk(spec: Sweep, chunk_seeds: int | None,
                  max_jobs_in_flight: int | None) -> int | None:
    """Seed-chunk size from an explicit count or a jobs-in-flight budget.

    The inner vmap materializes ``chunk * n_rates * n_jobs`` jobs at once;
    ``max_jobs_in_flight`` caps that product (floor: one seed per chunk).
    """
    if chunk_seeds is not None and max_jobs_in_flight is not None:
        raise ValueError("pass chunk_seeds or max_jobs_in_flight, not both")
    if max_jobs_in_flight is not None:
        return max(1, int(max_jobs_in_flight) // spec.jobs_per_seed())
    return None if chunk_seeds is None else max(1, int(chunk_seeds))


class SweepResult(NamedTuple):
    """A completed sweep: the spec, per-seed stats, and how it ran.

    ``stats[policy][metric]`` is a numpy array ``[n_rates, n_seeds]`` (or
    ``[n_rates, n_seeds, K]`` for per-class metrics).  ``compile_s`` is 0.0
    when every executor was already cached.  Serializes with
    :meth:`to_json` / :meth:`from_json` (exact float round-trip) and
    compacts to a ``BENCH_sweeps.json`` record with :meth:`record`.

    ``spec`` is normally a :class:`Sweep`; benchmarks whose grid is not a
    (policies x rates x seeds) sweep — e.g. ``benchmarks/sched_scale.py``
    times decision epochs over job counts M — report through the same
    container with a plain params dict carrying a ``"kind"`` tag (their
    ``stats`` rows are then indexed by that grid instead of rates).
    """

    spec: "Sweep | dict"
    stats: dict[str, dict[str, np.ndarray]]
    wall_s: float
    compile_s: float
    backend: str
    device_count: int
    chunk_seeds: int | None
    sharded: bool

    # ------------------------------------------------------------ read-outs
    def per_seed(self, policy: str, metric: str | None = None) -> np.ndarray:
        metric = metric or self.spec.metrics[0]
        return self.stats[policy][metric]

    def cell_means(self, metric: str | None = None) -> dict:
        """``{rate: {policy: mean-over-seeds}}`` — the ``load_sweep`` shape."""
        metric = metric or self.spec.metrics[0]
        out: dict[float, dict[str, float]] = {}
        for ri, rate in enumerate(self.spec.rates):
            out[float(rate)] = {
                name: float(np.mean(self.stats[name][metric][ri]))
                for name in self.spec.policies
            }
        return out

    # -------------------------------------------------------- serialization
    def _spec_jsonable(self) -> dict:
        if isinstance(self.spec, dict):
            return dict(self.spec)
        d = self.spec._asdict()
        d["scenario_kw"] = [list(kv) for kv in self.spec.scenario_kw]
        d["arm_kw"] = [list(kv) for kv in self.spec.arm_kw]
        d["stream"] = [list(kv) for kv in self.spec.stream]
        if self.spec.classes is not None:
            d["classes"] = [list(c) for c in self.spec.classes]
        d["policies"] = list(self.spec.policies)
        d["rates"] = list(self.spec.rates)
        d["metrics"] = list(self.spec.metrics)
        return d

    def record(self) -> dict:
        """Compact JSON-able record (per-cell mean/std, not per-seed rows) —
        the unit ``BENCH_sweeps.json`` accumulates."""
        from repro.core.analysis import seed_axis_stats

        cells = {
            name: {metric: seed_axis_stats(a) for metric, a in by_m.items()}
            for name, by_m in self.stats.items()
        }
        is_sweep = isinstance(self.spec, Sweep)
        return {
            "kind": "sweep" if is_sweep else self.spec.get("kind", "bench"),
            "provenance": provenance(),
            "spec": self._spec_jsonable(),
            "cells": cells,
            "n_seeds": self.spec.n_seeds if is_sweep else None,
            "total_jobs": (
                self.spec.total_jobs() * len(self.spec.policies)
                if is_sweep else None
            ),
            "wall_s": self.wall_s,
            "compile_s": self.compile_s,
            "backend": self.backend,
            "device_count": self.device_count,
            "chunk_seeds": self.chunk_seeds,
            "sharded": self.sharded,
        }

    def to_json(self) -> str:
        """Full serialization including the per-seed arrays (exact float
        round-trip: ``json`` emits ``repr`` floats)."""
        return json.dumps(
            {
                "spec": self._spec_jsonable(),
                "stats": {
                    name: {m: a.tolist() for m, a in by_m.items()}
                    for name, by_m in self.stats.items()
                },
                "wall_s": self.wall_s,
                "compile_s": self.compile_s,
                "backend": self.backend,
                "device_count": self.device_count,
                "chunk_seeds": self.chunk_seeds,
                "sharded": self.sharded,
            }
        )

    @classmethod
    def from_json(cls, text: str) -> "SweepResult":
        d = json.loads(text)
        s = d["spec"]
        if "policies" not in s:  # dict-spec result (e.g. sched_scale)
            return cls(
                spec=s,
                stats={
                    name: {
                        m: np.asarray(v, dtype=np.float64)
                        for m, v in by_m.items()
                    }
                    for name, by_m in d["stats"].items()
                },
                wall_s=d["wall_s"], compile_s=d["compile_s"],
                backend=d["backend"], device_count=d["device_count"],
                chunk_seeds=d["chunk_seeds"], sharded=d["sharded"],
            )
        spec = Sweep.create(
            s["policies"], s["rates"], scenario=s["scenario"],
            scenario_kw=dict((k, _hashable(v)) for k, v in s["scenario_kw"]),
            n_jobs=s["n_jobs"], n_seeds=s["n_seeds"], seed=s["seed"],
            p=s["p"], n_servers=s["n_servers"], size_alpha=s["size_alpha"],
            n_chips=s["n_chips"], min_chips=s["min_chips"],
            snap_slices=s["snap_slices"], classes=s["classes"],
            metrics=s["metrics"], arm=s["arm"],
            arm_kw=dict((k, _hashable(v)) for k, v in s["arm_kw"]),
            fused=s.get("fused", False),
            telemetry=s.get("telemetry", ()),
            stream=dict((k, _hashable(v)) for k, v in s.get("stream", [])),
            superstep=s.get("superstep", False),
        )
        stats = {
            name: {m: np.asarray(v, dtype=np.float64) for m, v in by_m.items()}
            for name, by_m in d["stats"].items()
        }
        return cls(
            spec=spec, stats=stats, wall_s=d["wall_s"],
            compile_s=d["compile_s"], backend=d["backend"],
            device_count=d["device_count"], chunk_seeds=d["chunk_seeds"],
            sharded=d["sharded"],
        )


#: Every ``run_sweep`` (and ``benchmarks/sched_scale.py``) appends its
#: compact record here; ``benchmarks/run.py`` flushes it to
#: ``BENCH_sweeps.json``.  Process-scoped by design (a benchmark run is
#: one fresh process) and bounded: long-lived sessions hammering
#: ``load_sweep`` keep only the most recent records.
RUN_LOG: list[dict] = []
RUN_LOG_MAX = 512


def bench_records() -> list[dict]:
    return list(RUN_LOG)


def write_bench_json(path: str = "BENCH_sweeps.json") -> str:
    """Flush the run log to ``path`` (the perf-trajectory artifact)."""
    with open(path, "w") as f:
        json.dump(
            {"schema_version": SCHEMA_VERSION, "records": RUN_LOG}, f, indent=1
        )
    return path


def run_sweep(
    spec: Sweep,
    *,
    chunk_seeds: int | None = None,
    max_jobs_in_flight: int | None = None,
    shard: bool = False,
    shard_axis: str = "seeds",
    log: bool = True,
) -> SweepResult:
    """Execute a :class:`Sweep`: one compiled device call per policy.

    Seeds are shared across rates and policies (paired sample paths), so
    "policy A beats policy B at every load" is tested on identical draws.

    ``chunk_seeds`` / ``max_jobs_in_flight`` bound memory by running the
    seed axis in ``lax.map`` chunks (identical results); ``shard=True``
    additionally splits one grid axis across ``jax.devices()`` with
    ``shard_map`` (identical results; pass it on multi-device hosts).
    ``shard_axis`` picks that axis: ``"seeds"`` (default) or ``"rates"``
    for very wide load grids with few seeds (the accelerator-lane shape,
    ``benchmarks/backend_lane.py``).  ``log=False`` keeps the run out of
    :data:`RUN_LOG` (used by tests).
    """
    import jax
    import jax.numpy as jnp

    if shard_axis not in ("seeds", "rates"):
        raise ValueError(f"shard_axis must be 'seeds' or 'rates', not {shard_axis!r}")
    chunk = resolve_chunk(spec, chunk_seeds, max_jobs_in_flight)
    keys = jax.random.split(jax.random.PRNGKey(spec.seed), spec.n_seeds)
    rates = jnp.asarray(spec.rates, dtype=jnp.result_type(float))

    n_dev = jax.device_count() if shard else 1
    S = spec.n_seeds
    R = len(spec.rates)
    if shard and shard_axis == "rates":
        # Pad the rate grid to the device count; padded rows are sliced off
        # below.  Seeds stay whole per device.
        r_pad = -(-R // n_dev) * n_dev
        if r_pad > R:
            rates = jnp.concatenate([rates, rates[:1].repeat(r_pad - R)])
        if chunk is not None and chunk >= S:
            chunk = None
    else:
        s_pad = -(-S // n_dev) * n_dev  # shard grid; chunk pads inside it
        if s_pad > S:
            keys = jnp.concatenate([keys, keys[:1].repeat(s_pad - S, axis=0)])
        if chunk is not None and chunk >= s_pad // n_dev:
            chunk = None  # one chunk == the plain vmap; share its executor

    stats: dict[str, dict[str, np.ndarray]] = {}
    compile_s = 0.0
    wall_s = 0.0
    for step, name in enumerate(spec.policies):
        f, c_s = _executor(spec, name, keys, rates, chunk, shard, shard_axis)
        compile_s += c_s
        t0 = time.perf_counter()
        # StepTraceAnnotation is a no-op unless a jax.profiler trace is
        # active (``benchmarks/run.py --profile-dir``); under one, each
        # policy's executor shows up as its own named step in the
        # Perfetto/TensorBoard timeline.
        with jax.profiler.StepTraceAnnotation(
            "run_sweep", step_num=step, policy=name, scenario=spec.scenario
        ):
            out = f(keys, rates)
            out = tuple(np.asarray(a) for a in out)  # blocks until ready
        wall_s += time.perf_counter() - t0
        stats[name] = {
            m: a[:R, :S] for m, a in zip(_out_names(spec), out, strict=True)
        }
    result = SweepResult(
        spec=spec,
        stats=stats,
        wall_s=wall_s,
        compile_s=compile_s,
        backend=jax.default_backend(),
        device_count=jax.device_count(),
        chunk_seeds=chunk,
        sharded=shard,
    )
    if log:
        RUN_LOG.append(result.record())
        del RUN_LOG[:-RUN_LOG_MAX]
    return result
