"""Tiled flash-attention forward (causal / sliding-window / GQA) for TPU.

TPU adaptation notes (vs the CUDA flash-attention algorithm):
- Tiling is expressed via ``BlockSpec`` so the HBM->VMEM movement is explicit;
  one (block_q x head_dim) query tile and one (block_k x head_dim) KV tile are
  resident in VMEM per grid step, plus fp32 running-max / running-sum / output
  accumulator scratch.
- The KV axis is the innermost ("arbitrary") grid dimension: the scratch
  accumulator carries across KV tiles, mirroring the online-softmax recurrence
  rather than warp-level shuffles.
- Block shapes default to 128 so the matmuls land on MXU-aligned
  (128 x head_dim x 128) shapes.

Only the forward pass is a kernel: the models use remat for the backward, and
the dry-run/roofline path exercises the XLA reference implementation (this
container lowers kernels only in ``interpret=True`` tests).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams

from repro.kernels.ref import NEG_INF


def _flash_kernel(
    q_ref,  # [1, 1, bq, D]
    k_ref,  # [1, 1, bk, D]
    v_ref,  # [1, 1, bk, D]
    o_ref,  # [1, 1, bq, D]
    m_scr,  # [bq, 1] f32 running max
    l_scr,  # [bq, 1] f32 running denominator
    acc_scr,  # [bq, D] f32 output accumulator
    *,
    scale: float,
    causal: bool,
    window: int,
    kv_len: int,
    q_offset: int,
    block_q: int,
    block_k: int,
    n_kv_blocks: int,
):
    iq = pl.program_id(2)
    jk = pl.program_id(3)

    @pl.when(jk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale  # [bq, D]
    k = k_ref[0, 0].astype(jnp.float32)  # [bk, D]
    v = v_ref[0, 0].astype(jnp.float32)

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [bq, bk]

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    q_pos = q_pos + q_offset
    k_pos = jk * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = k_pos < kv_len  # padded KV columns
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_scr[...]  # [bq, 1]
    m_cur = jnp.max(logits, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(logits - m_new)  # [bq, bk]
    correction = jnp.exp(m_prev - m_new)  # [bq, 1]
    l_new = correction * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc = acc_scr[...] * correction + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(jk == n_kv_blocks - 1)
    def _flush():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "q_offset", "scale", "block_q", "block_k", "interpret",
    ),
)
def flash_attention(
    q: jax.Array,  # [B, Hq, Sq, D]
    k: jax.Array,  # [B, Hkv, Skv, D]
    v: jax.Array,  # [B, Hkv, Skv, D]
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Flash-attention forward.  Pads Sq/Skv up to block multiples; padded KV
    columns are masked inside the kernel, padded query rows are sliced off."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    scale = (D ** -0.5) if scale is None else scale

    block_q = min(block_q, max(Sq, 8))
    block_k = min(block_k, max(Sk, 8))
    sq_pad = -Sq % block_q
    sk_pad = -Sk % block_k
    if sq_pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sq_pad), (0, 0)))
    if sk_pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, sk_pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, sk_pad), (0, 0)))
    Sq_p, Sk_p = Sq + sq_pad, Sk + sk_pad
    n_q_blocks = Sq_p // block_q
    n_kv_blocks = Sk_p // block_k

    grid = (B, Hq, n_q_blocks, n_kv_blocks)
    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        window=window,
        kv_len=Sk,
        q_offset=q_offset,
        block_q=block_q,
        block_k=block_k,
        n_kv_blocks=n_kv_blocks,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq_p, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq]
