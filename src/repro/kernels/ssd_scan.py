"""Mamba2 SSD (state-space duality) chunked-scan kernel for TPU.

The SSD insight: the SSM recurrence over a chunk of Q timesteps is a
low-rank-structured matmul, so within a chunk the computation runs on the MXU
as (Q x N)(N x Q) and (Q x Q)(Q x P) matmuls ("the dual/attention form"), and
only the chunk -> chunk state carry is sequential.

TPU adaptation: the chunk axis is the innermost "arbitrary" grid dimension;
the (P x N) state carries across chunks in fp32 VMEM scratch (no cross-SM
shared-memory staging as on GPU — one core just revisits the scratch).  Chunk
length defaults to 128 so all matmuls are MXU-aligned.

Layout: x [B, S, H, P], dt [B, S, H], a [H], b/c [B, S, N] (ngroups = 1).
Outputs y [B, S, H, P] and the final state [B, H, P, N] (fed to decode).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams


def _ssd_kernel(
    x_ref,  # [1, Q, 1, P]
    dt_ref,  # [1, Q, 1]
    a_ref,  # [1]
    b_ref,  # [1, Q, N]
    c_ref,  # [1, Q, N]
    y_ref,  # [1, Q, 1, P]
    state_ref,  # [1, 1, P, N]  final-state output (written at last chunk)
    h_scr,  # [P, N] f32 carried state
    *,
    n_chunks: int,
    seq_len: int,
    block_q: int,
):
    ch = pl.program_id(2)

    @pl.when(ch == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)  # [Q, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # [Q]
    a = a_ref[0].astype(jnp.float32)  # scalar
    b = b_ref[0].astype(jnp.float32)  # [Q, N]
    c = c_ref[0].astype(jnp.float32)  # [Q, N]

    # Mask padded timesteps (same masking the oracle's recurrence implies:
    # dt = 0 -> no state update, no output contribution).
    t_pos = ch * block_q + jax.lax.iota(jnp.int32, block_q)
    valid = (t_pos < seq_len).astype(jnp.float32)
    dt = dt * valid

    da = a * dt  # [Q] per-step log-decay (a < 0)
    s = jnp.cumsum(da)  # inclusive cumsum: decay from step u..t is exp(s_t - s_u)

    # Intra-chunk (dual/attention form): scores[t, u] = exp(s_t - s_u) * <c_t, b_u>
    # for u <= t, multiplied by dt_u; y_intra = scores @ x.
    cb = jax.lax.dot_general(
        c, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [Q, Q]
    decay = jnp.exp(s[:, None] - s[None, :])
    lower = (
        jax.lax.broadcasted_iota(jnp.int32, (block_q, block_q), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (block_q, block_q), 1)
    )
    scores = jnp.where(lower, cb * decay, 0.0) * dt[None, :]
    y = jax.lax.dot_general(
        scores, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # [Q, P]

    # Inter-chunk: y_t += c_t . (exp(s_t) * h_prev)
    h_prev = h_scr[...]  # [P, N]
    y += jnp.exp(s)[:, None] * jax.lax.dot_general(
        c, h_prev, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )

    # State update: h = exp(s_Q) h_prev + sum_u exp(s_Q - s_u) dt_u x_u b_u^T.
    total = s[block_q - 1]
    w = jnp.exp(total - s) * dt  # [Q]
    upd = jax.lax.dot_general(
        x * w[:, None], b, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # [P, N]
    h_scr[...] = jnp.exp(total) * h_prev + upd

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ch == n_chunks - 1)
    def _flush():
        state_ref[0, 0] = h_scr[...]


@functools.partial(jax.jit, static_argnames=("block_q", "interpret", "return_state"))
def ssd_scan(
    x: jax.Array,  # [B, S, H, P]
    dt: jax.Array,  # [B, S, H]
    a: jax.Array,  # [H]
    b: jax.Array,  # [B, S, N]
    c: jax.Array,  # [B, S, N]
    d: jax.Array,  # [H]
    *,
    block_q: int = 128,
    interpret: bool = False,
    return_state: bool = False,
):
    """Chunked SSD forward.  Pads S to a block multiple (masked via dt = 0)."""
    B, S, H, P = x.shape
    N = b.shape[-1]
    block_q = min(block_q, max(S, 8))
    pad = -S % block_q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    S_p = S + pad
    n_chunks = S_p // block_q

    grid = (B, H, n_chunks)
    kernel = functools.partial(
        _ssd_kernel, n_chunks=n_chunks, seq_len=S, block_q=block_q
    )
    y, state = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, P), lambda bi, h, ch: (bi, ch, h, 0)),
            pl.BlockSpec((1, block_q, 1), lambda bi, h, ch: (bi, ch, h)),
            pl.BlockSpec((1,), lambda bi, h, ch: (h,)),
            pl.BlockSpec((1, block_q, N), lambda bi, h, ch: (bi, ch, 0)),
            pl.BlockSpec((1, block_q, N), lambda bi, h, ch: (bi, ch, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, 1, P), lambda bi, h, ch: (bi, ch, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda bi, h, ch: (bi, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S_p, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(x, dt, a, b, c)
    y = y[:, :S] + d.astype(x.dtype)[None, None, :, None] * x[:, :S]
    return (y, state) if return_state else y
