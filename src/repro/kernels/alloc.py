"""Fused heSRPT allocation: ranks -> Thm-7 brackets -> whole chips, one pass.

This is the kernel that finally connects the Pallas stack to the scheduling
core.  The engine's per-event hot path (``core/engine.py``) spends its time
deriving the *same* sorted order over and over: the policy sorts remaining
sizes for the descending ranks, then ``quantize_allocation_jax`` sorts theta
for the oversubscription cut and sorts fractional parts for the
largest-remainder round.  For heSRPT both re-derivations are redundant:

- Theorem 7's brackets ``theta_r = (r/m)^c - ((r-1)/m)^c`` (``c = 1/(1-p) >
  1``) are *strictly increasing in rank r*, so the descending-theta position
  of the job ranked ``r`` is simply ``m - r`` — the oversubscription cut
  needs no theta sort at all;
- the quantizer's trim pass (min-chips floor overflow) and leftover pass
  (largest fractional remainders) are mutually exclusive, so one sort on a
  conditionally-selected key serves both (the same collapse
  ``quantize_allocation_jax`` itself now uses).

``hesrpt_alloc_fused_ref`` is that algorithm in pure jnp: **2 argsorts per
event** (sizes + fractional parts) where the unfused rule pays 3, exact vs
``policies.hesrpt`` + ``engine.quantize_allocation_jax`` by construction —
every floating-point sum runs over the original index order, every integer
step is order-independent, and the one shared sort uses the exact keys and
stable tie-breaks of the sorts it replaces.

``_alloc_pallas`` is the Pallas kernel: **0 argsorts**.  TPUs have no sort
primitive worth using at M ~ 10^3, so ranks and sort positions come from
O(M^2) comparison counting — ``pos_i = #{j : key_j < key_i or (key_j ==
key_i and j < i)}`` — which reproduces a *stable* argsort's positions as
exact integers, chunked over columns so the pairwise tile stays small.  The
whole job vector lives in VMEM (single program, no grid): an [M] f32/f64
vector is tiny next to the matmul workloads the other kernels tile.

Exactness caveats (documented, property-tested):

- The ``m - r`` oversubscription cut assumes the Thm-7 brackets are
  strictly increasing *as floats*.  A tie can only appear when adjacent
  brackets collide at the ulp level (extreme ``p`` -> subnormal brackets);
  the cut then orders tied jobs by rank where the unfused sort orders them
  by index.  Reachable only under ``m * min_chips > n_chips`` AND a tie
  straddling the cut — measure-zero for the sweeps this repo runs.
- The Pallas path pads M to the lane width; the padded zeros cannot change
  any sum's value, but XLA may reshape the reduction tree of the one fp sum
  (the oversubscription renormalizer), which can move chips on knife-edge
  inputs.  The ref path keeps the unpadded reduction and is bit-exact.

``impl`` follows ``kernels/ops.py``: ``auto`` (pallas on TPU, ref
elsewhere), ``ref``, ``pallas``, ``interpret``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.policies import hesrpt, hesrpt_theta_from_ranks
from repro.core.ranking import inv_rank, ranks_from_order, size_order_desc

IMPLS = ("auto", "ref", "pallas", "interpret")


def _resolve(impl: str) -> str:
    if impl not in IMPLS:
        raise ValueError(f"impl must be one of {IMPLS}, got {impl!r}")
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return impl


# ------------------------------------------------------------ jnp reference
def _quantize_from_ranks(
    theta: jax.Array,
    ranks: jax.Array,
    m: jax.Array,
    n_chips: int,
    *,
    min_chips: int = 1,
) -> jax.Array:
    """``quantize_allocation_jax`` given the policy's ranks: one sort saved.

    Bit-exact vs the unfused quantizer for rank-monotone theta (heSRPT):
    the descending-theta position of the job ranked ``r`` is ``m - r``, so
    the oversubscription cut is rank arithmetic instead of an argsort.  All
    other steps are the unfused quantizer's ops in the unfused order.
    """
    M = theta.shape[0]
    if n_chips <= 0 or min_chips <= 0 or M == 0:
        return jnp.zeros(M, jnp.int32)
    cap = n_chips // min_chips

    active0 = theta > 0
    n_active = jnp.sum(active0, dtype=jnp.int32)
    # Rank-space oversubscription cut: keep the cap largest-theta jobs ==
    # the cap highest ranks (theta strictly increasing in rank, see module
    # docstring) — replaces quantize_allocation_jax's theta argsort.
    servable = active0 & (ranks > m - cap)
    over = n_active * min_chips > n_chips
    sub = jnp.where(servable, theta, 0.0)
    tot = jnp.sum(sub)
    theta_eff = jnp.where(over, jnp.where(tot > 0, sub / tot, 0.0), theta)
    active = theta_eff > 0

    raw = theta_eff * n_chips
    fl = jnp.floor(raw)
    frac = raw - fl
    base = jnp.where(active, jnp.maximum(fl, min_chips), 0.0).astype(jnp.int32)

    K = jnp.maximum(jnp.sum(base) - n_chips, 0)
    capj = jnp.maximum(base - min_chips, 0) * (base > min_chips)

    def bisect(_, lohi):
        lo, hi = lohi
        mid = (lo + hi) // 2
        ge = jnp.sum(jnp.minimum(capj, mid)) >= K
        return jnp.where(ge, lo, mid + 1), jnp.where(ge, mid, hi)

    n_bits = (n_chips + 1).bit_length()
    lo, _hi = jax.lax.fori_loop(
        0, n_bits, bisect, (jnp.int32(0), jnp.int32(n_chips))
    )
    r_star = lo
    full = jnp.minimum(capj, jnp.maximum(r_star - 1, 0))
    extra_needed = K - jnp.sum(full)
    elig = capj >= jnp.maximum(r_star, 1)
    trim = K > 0
    key = jnp.where(
        trim, jnp.where(elig, frac, jnp.inf), jnp.where(active, -frac, jnp.inf)
    )
    pos = inv_rank(jnp.argsort(key))
    extra = (elig & (pos < extra_needed)).astype(jnp.int32)
    base = base - full - extra

    remainder = n_chips - jnp.sum(base)
    base = base + (active & (pos < remainder)).astype(jnp.int32)
    return base


def hesrpt_alloc_fused_ref(
    x: jax.Array, p, n_chips: int, *, min_chips: int = 1
) -> tuple[jax.Array, jax.Array]:
    """Fused heSRPT theta + chips in pure jnp, sharing one sorted order.

    Returns ``(theta, chips)``: ``theta`` bit-for-bit ``policies.hesrpt(x,
    p)`` (identical op sequence), ``chips`` exact vs
    ``quantize_allocation_jax(theta, n_chips, min_chips=min_chips)``.
    """
    active = x > 0
    order = size_order_desc(x)
    ranks = ranks_from_order(order, active)
    m = jnp.sum(active)
    theta = hesrpt_theta_from_ranks(ranks, m, p, dtype=x.dtype)
    chips = _quantize_from_ranks(theta, ranks, m, n_chips, min_chips=min_chips)
    return theta, chips


# ------------------------------------------------------------ Pallas kernel
def _alloc_kernel(
    x_ref,  # [1, Mp] remaining sizes (padded with zeros)
    p_ref,  # [1, 1] speedup exponent
    theta_ref,  # [1, Mp] out: Thm-7 allocation fractions
    chips_ref,  # [1, Mp] out: int32 whole-chip allocation
    *,
    M: int,
    n_chips: int,
    min_chips: int,
    block_c: int,
):
    Mp = x_ref.shape[1]
    n_blocks = Mp // block_c
    col = jax.lax.broadcasted_iota(jnp.int32, (1, Mp), 1)

    def positions(key):
        """Stable-argsort position of every column of ``key`` ([1, Mp]).

        O(M^2) comparison counting, chunked so the pairwise tile is
        [block_c, Mp]; the static Python loop unrolls (no sort primitive).
        """
        pos = jnp.zeros((1, Mp), jnp.int32)
        for b in range(n_blocks):
            kj = jnp.swapaxes(key[:, b * block_c : (b + 1) * block_c], 0, 1)
            jrow = (
                jax.lax.broadcasted_iota(jnp.int32, (block_c, 1), 0)
                + b * block_c
            )
            before = (kj < key) | ((kj == key) & (jrow < col))
            pos = pos + jnp.sum(before.astype(jnp.int32), axis=0, keepdims=True)
        return pos

    x = x_ref[...]
    dtype = x.dtype
    inf = jnp.asarray(jnp.inf, dtype)
    active = (x > 0) & (col < M)
    ranks = jnp.where(active, positions(jnp.where(active, -x, inf)) + 1, 0)
    m = jnp.sum(active.astype(jnp.int32), keepdims=True)

    # Thm-7 brackets — the exact op sequence of hesrpt_theta_from_ranks.
    p = p_ref[...]
    rf = ranks.astype(dtype)
    c = 1.0 / (1.0 - p)
    m_safe = jnp.maximum(m, 1).astype(dtype)
    hi = (rf / m_safe) ** c
    lo = ((rf - 1.0) / m_safe) ** c
    theta = jnp.where(active, hi - lo, 0.0)
    theta_ref[...] = theta

    if n_chips <= 0 or min_chips <= 0:
        chips_ref[...] = jnp.zeros((1, Mp), jnp.int32)
        return

    # Largest-remainder quantization: _quantize_from_ranks, positions()
    # replacing its one argsort.
    cap = n_chips // min_chips
    active0 = theta > 0
    n_active = jnp.sum(active0.astype(jnp.int32), keepdims=True)
    servable = active0 & (ranks > m - cap)
    over = n_active * min_chips > n_chips
    sub = jnp.where(servable, theta, 0.0)
    tot = jnp.sum(sub, keepdims=True)
    theta_eff = jnp.where(over, jnp.where(tot > 0, sub / tot, 0.0), theta)
    active_q = theta_eff > 0

    raw = theta_eff * n_chips
    fl = jnp.floor(raw)
    frac = raw - fl
    base = jnp.where(active_q, jnp.maximum(fl, float(min_chips)), 0.0)
    base = base.astype(jnp.int32)

    K = jnp.maximum(jnp.sum(base, keepdims=True) - n_chips, 0)
    capj = jnp.maximum(base - min_chips, 0) * (base > min_chips).astype(jnp.int32)

    def bisect(_, lohi):
        lo_, hi_ = lohi
        mid = (lo_ + hi_) // 2
        ge = jnp.sum(jnp.minimum(capj, mid), keepdims=True) >= K
        return jnp.where(ge, lo_, mid + 1), jnp.where(ge, mid, hi_)

    n_bits = (n_chips + 1).bit_length()
    r_star, _hi2 = jax.lax.fori_loop(
        0,
        n_bits,
        bisect,
        (jnp.zeros((1, 1), jnp.int32), jnp.full((1, 1), n_chips, jnp.int32)),
    )
    full = jnp.minimum(capj, jnp.maximum(r_star - 1, 0))
    extra_needed = K - jnp.sum(full, keepdims=True)
    elig = capj >= jnp.maximum(r_star, 1)
    trim = K > 0
    key_q = jnp.where(
        trim, jnp.where(elig, frac, inf), jnp.where(active_q, -frac, inf)
    )
    pos = positions(key_q)
    extra = (elig & (pos < extra_needed)).astype(jnp.int32)
    base = base - full - extra

    remainder = n_chips - jnp.sum(base, keepdims=True)
    chips_ref[...] = base + (active_q & (pos < remainder)).astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("n_chips", "min_chips", "block_c", "interpret")
)
def _alloc_pallas(
    x: jax.Array,
    p,
    *,
    n_chips: int,
    min_chips: int = 1,
    block_c: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    M = x.shape[0]
    pad = -M % block_c if M else block_c
    Mp = max(M + pad, block_c)
    xp = jnp.pad(x.reshape(1, M), ((0, 0), (0, Mp - M)))
    pv = jnp.asarray(p, x.dtype).reshape(1, 1)
    kernel = functools.partial(
        _alloc_kernel, M=M, n_chips=n_chips, min_chips=min_chips, block_c=block_c
    )
    theta, chips = pl.pallas_call(
        kernel,
        out_shape=[
            jax.ShapeDtypeStruct((1, Mp), x.dtype),
            jax.ShapeDtypeStruct((1, Mp), jnp.int32),
        ],
        interpret=interpret,
    )(xp, pv)
    return theta[0, :M], chips[0, :M]


# ----------------------------------------------------------------- dispatch
def hesrpt_alloc_fused(
    x: jax.Array, p, n_chips: int, *, min_chips: int = 1, impl: str = "auto"
) -> tuple[jax.Array, jax.Array]:
    """Fused heSRPT allocate: ``(theta, chips)`` in one pass over ``x``.

    ``theta`` matches ``policies.hesrpt`` bit-for-bit and ``chips`` matches
    ``engine.quantize_allocation_jax`` exactly (see module docstring for
    the two documented caveats).  ``impl="auto"`` takes the Pallas kernel
    on TPU and the 2-sort jnp reference elsewhere.
    """
    impl = _resolve(impl)
    if impl == "ref":
        return hesrpt_alloc_fused_ref(x, p, n_chips, min_chips=min_chips)
    return _alloc_pallas(
        x, p, n_chips=n_chips, min_chips=min_chips,
        interpret=(impl == "interpret"),
    )


def hesrpt_theta_fused(x: jax.Array, p, *, impl: str = "auto") -> jax.Array:
    """Fused continuous-regime theta (no quantization).

    The ref path *is* ``policies.hesrpt`` — the continuous rule has no
    redundant sort to collapse — so continuous flows are bit-for-bit
    unchanged; the Pallas path exists so accelerator sweeps stay on-chip.
    """
    impl = _resolve(impl)
    if impl == "ref":
        return hesrpt(x, p)
    theta, _ = _alloc_pallas(x, p, n_chips=0, interpret=(impl == "interpret"))
    return theta
