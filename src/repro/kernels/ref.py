"""Pure-jnp oracles for every Pallas kernel.

These are the semantic ground truth: slow, simple, obviously-correct JAX.
Kernel tests sweep shapes/dtypes and assert_allclose against these; the model
code calls them through ``ops.py`` (which dispatches kernel vs ref).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30  # large-negative instead of -inf: keeps softmax NaN-free


def attention_mask(
    q_len: int, kv_len: int, *, causal: bool, window: int, q_offset: int = 0
) -> jax.Array:
    """[q_len, kv_len] boolean mask.  ``q_offset`` is the absolute position of
    query row 0 (for decode, q_offset = kv_len - q_len).  ``window`` > 0
    limits attention to the last ``window`` positions (sliding window);
    position t attends to [t - window + 1, t]."""
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    k_pos = jnp.arange(kv_len)[None, :]
    mask = jnp.ones((q_len, kv_len), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    return mask


def attention(
    q: jax.Array,  # [B, Hq, Sq, D]
    k: jax.Array,  # [B, Hkv, Skv, D]
    v: jax.Array,  # [B, Hkv, Skv, D]
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    scale: float | None = None,
) -> jax.Array:
    """GQA scaled-dot-product attention oracle.  fp32 softmax arithmetic,
    output in q.dtype."""
    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    scale = (D ** -0.5) if scale is None else scale

    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # Broadcast KV heads across the GQA group.
    kf = jnp.repeat(kf, group, axis=1)
    vf = jnp.repeat(vf, group, axis=1)

    logits = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
    mask = attention_mask(Sq, k.shape[2], causal=causal, window=window, q_offset=q_offset)
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vf)
    return out.astype(q.dtype)


def ssd(
    x: jax.Array,  # [B, S, H, P]   inputs per SSM head
    dt: jax.Array,  # [B, S, H]     softplus'd timestep (positive)
    a: jax.Array,  # [H]            negative decay rate (A = -exp(a_log))
    b: jax.Array,  # [B, S, N]      input matrix (ngroups = 1)
    c: jax.Array,  # [B, S, N]      output matrix
    d: jax.Array,  # [H]            skip connection
    *,
    h0: jax.Array | None = None,  # [B, H, P, N] initial state
    return_state: bool = False,
):
    """Mamba2 SSD (state-space dual) oracle: the exact sequential recurrence

        h_t = exp(a * dt_t) * h_{t-1} + dt_t * (x_t b_t^T)
        y_t = h_t c_t + d * x_t

    fp32 state arithmetic, output in x.dtype."""
    B, S, H, P = x.shape
    N = b.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    cf = c.astype(jnp.float32)
    af = a.astype(jnp.float32)

    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp  # [B,H,P], [B,H], [B,N], [B,N]
        decay = jnp.exp(af[None, :] * dtt)  # [B, H]
        upd = jnp.einsum("bhp,bn->bhpn", xt * dtt[..., None], bt)
        h = decay[..., None, None] * h + upd
        y = jnp.einsum("bhpn,bn->bhp", h, ct)
        return h, y

    inputs = (
        jnp.moveaxis(xf, 1, 0),
        jnp.moveaxis(dtf, 1, 0),
        jnp.moveaxis(bf, 1, 0),
        jnp.moveaxis(cf, 1, 0),
    )
    h_fin, ys = jax.lax.scan(step, h0.astype(jnp.float32), inputs)
    y = jnp.moveaxis(ys, 0, 1) + d.astype(jnp.float32)[None, None, :, None] * xf
    y = y.astype(x.dtype)
    return (y, h_fin) if return_state else y


def rglru(
    x: jax.Array,  # [B, S, W]   gated input
    gate_x: jax.Array,  # [B, S, W]  input-gate pre-activation
    gate_a: jax.Array,  # [B, S, W]  recurrence-gate pre-activation
    a_param: jax.Array,  # [W]       learnable Λ (pre-softplus)
    *,
    h0: jax.Array | None = None,  # [B, W]
    return_state: bool = False,
    c: float = 8.0,
):
    """RG-LRU oracle (RecurrentGemma):

        r_t = sigmoid(gate_a_t)                    (recurrence gate)
        i_t = sigmoid(gate_x_t)                    (input gate)
        log_a_t = -c * softplus(a_param) * r_t
        a_t = exp(log_a_t)
        h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

    fp32 state arithmetic, output in x.dtype."""
    B, S, W = x.shape
    xf = x.astype(jnp.float32)
    rf = jax.nn.sigmoid(gate_a.astype(jnp.float32))
    i_f = jax.nn.sigmoid(gate_x.astype(jnp.float32))
    log_a = -c * jax.nn.softplus(a_param.astype(jnp.float32))[None, None, :] * rf
    a_t = jnp.exp(log_a)
    # sqrt(1 - a^2) computed stably via expm1: 1 - exp(2 log_a).
    mult = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    gated = i_f * xf * mult

    if h0 is None:
        h0 = jnp.zeros((B, W), jnp.float32)

    def step(h, inp):
        at, gt = inp
        h = at * h + gt
        return h, h

    h_fin, hs = jax.lax.scan(
        step, h0.astype(jnp.float32), (jnp.moveaxis(a_t, 1, 0), jnp.moveaxis(gated, 1, 0))
    )
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
    return (y, h_fin) if return_state else y
