"""Chunked XLA implementations of the kernels' algorithms.

These are the production lowering path for the dry-run / non-TPU backends:
the SAME blocking/online-softmax/chunk-state algorithms as the Pallas
kernels, expressed in pure jnp + lax.scan so XLA (any backend) lowers them
with bounded working sets.  Semantics are validated against ``ref.py``
exactly like the kernels.

Why they exist (measured in EXPERIMENTS.md §Perf):
- ``attention``: the naive oracle materializes the (Sq x Skv) score matrix —
  at 32k prefill that is 100+ GB/device.  Blockwise online softmax holds one
  (block_q x block_k) tile instead.
- ``ssd``: the oracle scans one timestep at a time (32k trips, state
  re-read per step -> dry-run memory term explodes); the chunked dual form
  does 256x fewer, bigger steps on MXU-shaped matmuls.
- ``rglru``: log-depth associative scan instead of a length-S dependent
  chain.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ref import NEG_INF


def _attention_fwd_impl(
    q: jax.Array,  # [B, Hq, Sq, D]
    k: jax.Array,  # [B, Hkv, Skv, D]
    v: jax.Array,  # [B, Hkv, Skv, D]
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    scale: float | None = None,
    block_q: int = 512,
    block_k: int = 1024,
    return_stats: bool = False,
):
    """Blockwise flash-style attention in pure XLA (fp32 accumulators).
    With ``return_stats`` also returns the log-sum-exp rows the custom
    backward needs."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    g = Hq // Hkv
    scale = (D ** -0.5) if scale is None else scale
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    pq, pk = -Sq % block_q, -Sk % block_k
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0))) if pk else v
    nq, nk = (Sq + pq) // block_q, (Sk + pk) // block_k

    # [B, Hkv, g, nq, bq, D] view of q; KV stays [B, Hkv, nk, bk, D]
    q5 = (qp.reshape(B, Hkv, g, nq, block_q, D) * scale).astype(jnp.float32)
    k5 = kp.reshape(B, Hkv, nk, block_k, D)
    v5 = vp.reshape(B, Hkv, nk, block_k, D)

    q_pos_base = jnp.arange(block_q) + q_offset
    k_pos_base = jnp.arange(block_k)

    def q_block(iq):
        qb = jax.lax.dynamic_index_in_dim(q5, iq, axis=3, keepdims=False)
        q_pos = q_pos_base + iq * block_q  # [bq]

        def kv_step(carry, jk):
            m, lsum, acc = carry
            kb = jax.lax.dynamic_index_in_dim(k5, jk, axis=2, keepdims=False)
            vb = jax.lax.dynamic_index_in_dim(v5, jk, axis=2, keepdims=False)
            logits = jnp.einsum(
                "bhgqd,bhkd->bhgqk", qb, kb.astype(jnp.float32)
            )
            k_pos = k_pos_base + jk * block_k
            mask = k_pos[None, :] < Sk
            if causal:
                mask &= k_pos[None, :] <= q_pos[:, None]
            if window > 0:
                mask &= k_pos[None, :] > q_pos[:, None] - window
            logits = jnp.where(mask[None, None, None], logits, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = corr * lsum + jnp.sum(p, axis=-1)
            acc_new = corr[..., None] * acc + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vb.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        shape = (B, Hkv, g, block_q)
        init = (
            jnp.full(shape, NEG_INF, jnp.float32),
            jnp.zeros(shape, jnp.float32),
            jnp.zeros(shape + (D,), jnp.float32),
        )
        (m, lsum, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(nk))
        out = (acc / jnp.maximum(lsum, 1e-30)[..., None]).astype(q.dtype)
        lse = m + jnp.log(jnp.maximum(lsum, 1e-30))  # [B,Hkv,g,bq]
        return out, lse

    out, lse = jax.lax.map(q_block, jnp.arange(nq))  # [nq, B, Hkv, g, bq, D]
    out = jnp.moveaxis(out, 0, 3).reshape(B, Hq, Sq + pq, D)[:, :, :Sq]
    lse = jnp.moveaxis(lse, 0, 3).reshape(B, Hq, Sq + pq)[:, :, :Sq]
    return (out, lse) if return_stats else out


def _mask_block(q_pos, k_pos, Sk, causal, window):
    mask = k_pos[None, :] < Sk
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    return mask


def _attention_bwd_impl(q, k, v, out, lse, do, *, causal, window, q_offset,
                        scale, block_q, block_k):
    """Flash-style backward: recompute probabilities blockwise from the saved
    log-sum-exp; never materializes the (Sq x Skv) score matrix.

        p    = exp(q k^T * scale - lse)
        dv   = p^T do
        dp   = do v^T
        ds   = p * (dp - rowsum(do * out))          [softmax jacobian]
        dq   = ds k * scale ;  dk = ds^T q * scale
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    g = Hq // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    pq, pk = -Sq % block_q, -Sk % block_k
    def pad_q(t):
        return jnp.pad(t, ((0, 0), (0, 0), (0, pq), (0, 0))) if pq else t

    def pad_k(t):
        return jnp.pad(t, ((0, 0), (0, 0), (0, pk), (0, 0))) if pk else t

    qf = pad_q(q).astype(jnp.float32).reshape(B, Hkv, g, -1, block_q, D)
    dof = pad_q(do).astype(jnp.float32).reshape(B, Hkv, g, -1, block_q, D)
    outf = pad_q(out).astype(jnp.float32).reshape(B, Hkv, g, -1, block_q, D)
    lsef = (jnp.pad(lse, ((0, 0), (0, 0), (0, pq)), constant_values=0.0) if pq
            else lse).reshape(B, Hq, -1, block_q).reshape(B, Hkv, g, -1, block_q)
    kf = pad_k(k).astype(jnp.float32).reshape(B, Hkv, -1, block_k, D)
    vf = pad_k(v).astype(jnp.float32).reshape(B, Hkv, -1, block_k, D)
    nq, nk = qf.shape[3], kf.shape[2]

    delta = jnp.sum(dof * outf, axis=-1)  # [B,Hkv,g,nq,bq]
    q_pos_all = jnp.arange(Sq + pq).reshape(nq, block_q) + q_offset
    k_pos_all = jnp.arange(Sk + pk).reshape(nk, block_k)

    def kv_block(jk):
        kb = kf[:, :, jk]  # [B,Hkv,bk,D]
        vb = vf[:, :, jk]

        def q_step(carry, iq):
            dk_acc, dv_acc = carry
            qb = qf[:, :, :, iq]  # [B,Hkv,g,bq,D]
            logits = jnp.einsum("bhgqd,bhkd->bhgqk", qb * scale, kb)
            mask = _mask_block(q_pos_all[iq], k_pos_all[jk], Sk, causal, window)
            p = jnp.where(mask[None, None, None],
                          jnp.exp(logits - lsef[:, :, :, iq][..., None]), 0.0)
            dob = dof[:, :, :, iq]
            dv_acc += jnp.einsum("bhgqk,bhgqd->bhkd", p, dob)
            dp = jnp.einsum("bhgqd,bhkd->bhgqk", dob, vb)
            ds = p * (dp - delta[:, :, :, iq][..., None])
            dq_b = jnp.einsum("bhgqk,bhkd->bhgqd", ds, kb) * scale
            dk_acc += jnp.einsum("bhgqk,bhgqd->bhkd", ds, qb) * scale
            return (dk_acc, dv_acc), dq_b

        zero = jnp.zeros((B, Hkv, block_k, D), jnp.float32)
        (dk_b, dv_b), dq_parts = jax.lax.scan(q_step, (zero, zero),
                                              jnp.arange(nq))
        return dk_b, dv_b, dq_parts  # dq_parts [nq,B,Hkv,g,bq,D]

    dk_all, dv_all, dq_all = jax.lax.map(kv_block, jnp.arange(nk))
    dq = jnp.sum(dq_all, axis=0)  # [nq,B,Hkv,g,bq,D]
    dq = jnp.moveaxis(dq, 0, 3).reshape(B, Hq, Sq + pq, D)[:, :, :Sq]
    dk = jnp.moveaxis(dk_all, 0, 2).reshape(B, Hkv, Sk + pk, D)[:, :, :Sk]
    dv = jnp.moveaxis(dv_all, 0, 2).reshape(B, Hkv, Sk + pk, D)[:, :, :Sk]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8)
)
def _attention_diff(q, k, v, causal, window, q_offset, scale, block_q, block_k):
    return _attention_fwd_impl(
        q, k, v, causal=causal, window=window, q_offset=q_offset, scale=scale,
        block_q=block_q, block_k=block_k,
    )


def _attention_diff_fwd(q, k, v, causal, window, q_offset, scale, block_q,
                        block_k):
    out, lse = _attention_fwd_impl(
        q, k, v, causal=causal, window=window, q_offset=q_offset, scale=scale,
        block_q=block_q, block_k=block_k, return_stats=True,
    )
    return out, (q, k, v, out, lse)


def _attention_diff_bwd(causal, window, q_offset, scale, block_q, block_k,
                        res, do):
    q, k, v, out, lse = res
    return _attention_bwd_impl(
        q, k, v, out, lse, do, causal=causal, window=window, q_offset=q_offset,
        scale=scale, block_q=block_q, block_k=block_k,
    )


_attention_diff.defvjp(_attention_diff_fwd, _attention_diff_bwd)


def attention(q, k, v, *, causal=True, window=0, q_offset=0, scale=None,
              block_q=512, block_k=1024):
    """Differentiable blockwise attention: flash-style forward AND backward
    (custom VJP recomputes probabilities from saved log-sum-exp; the full
    score matrix never exists in either pass)."""
    D = q.shape[-1]
    scale = (D ** -0.5) if scale is None else scale
    return _attention_diff(q, k, v, causal, window, q_offset, scale,
                           min(block_q, q.shape[2]), min(block_k, k.shape[2]))


def ssd(
    x: jax.Array,  # [B, S, H, P]
    dt: jax.Array,  # [B, S, H]
    a: jax.Array,  # [H]
    b: jax.Array,  # [B, S, N]
    c: jax.Array,  # [B, S, N]
    d: jax.Array,  # [H]
    *,
    h0: jax.Array | None = None,
    block: int = 128,
    return_state: bool = False,
):
    """Chunked SSD dual form (same algorithm as kernels/ssd_scan.py)."""
    B, S, H, P = x.shape
    N = b.shape[-1]
    block = min(block, S)
    pad = -S % block
    xf = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(jnp.float32)
    dtf = jnp.pad(dt, ((0, 0), (0, pad), (0, 0))).astype(jnp.float32)
    bf = jnp.pad(b, ((0, 0), (0, pad), (0, 0))).astype(jnp.float32)
    cf = jnp.pad(c, ((0, 0), (0, pad), (0, 0))).astype(jnp.float32)
    nc = (S + pad) // block

    # chunk views: [nc, B, Q, ...]
    def chunks(t, feat_shape):
        return jnp.moveaxis(t.reshape(B, nc, block, *feat_shape), 1, 0)

    xs = chunks(xf, (H, P))
    dts = chunks(dtf, (H,))
    bs = chunks(bf, (N,))
    cs = chunks(cf, (N,))
    af = a.astype(jnp.float32)

    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)

    lower = (
        jnp.arange(block)[:, None] >= jnp.arange(block)[None, :]
    )  # [Q, Q]

    def chunk_step(h, inp):
        xq, dtq, bq, cq = inp  # [B,Q,H,P], [B,Q,H], [B,Q,N], [B,Q,N]
        da = af[None, None, :] * dtq  # [B,Q,H]
        s = jnp.cumsum(da, axis=1)  # inclusive
        # intra-chunk dual form
        cb = jnp.einsum("bqn,bkn->bqk", cq, bq)  # [B,Q,Q]
        decay = jnp.exp(s[:, :, None, :] - s[:, None, :, :])  # [B,Q,Q,H]
        scores = jnp.where(lower[None, :, :, None], cb[..., None] * decay, 0.0)
        scores = scores * dtq[:, None, :, :]  # weight by dt_u
        y = jnp.einsum("bqkh,bkhp->bqhp", scores, xq)
        # inter-chunk
        y += jnp.exp(s)[..., None] * jnp.einsum("bqn,bhpn->bqhp", cq, h)
        # state update
        total = s[:, -1, :]  # [B,H]
        w = jnp.exp(total[:, None, :] - s) * dtq  # [B,Q,H]
        upd = jnp.einsum("bqhp,bqn->bhpn", xq * w[..., None], bq)
        h_new = jnp.exp(total)[..., None, None] * h + upd
        return h_new, y

    h_fin, ys = jax.lax.scan(chunk_step, h0, (xs, dts, bs, cs))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S + pad, H, P)[:, :S]
    y = y + d.astype(jnp.float32)[None, None, :, None] * x.astype(jnp.float32)
    y = y.astype(x.dtype)
    return (y, h_fin) if return_state else y


def rglru(
    x: jax.Array,  # [B, S, W]
    gate_x: jax.Array,
    gate_a: jax.Array,
    a_param: jax.Array,  # [W]
    *,
    h0: jax.Array | None = None,
    return_state: bool = False,
    c: float = 8.0,
):
    """RG-LRU via log-depth associative scan (first-order recurrence)."""
    xf = x.astype(jnp.float32)
    rf = jax.nn.sigmoid(gate_a.astype(jnp.float32))
    i_f = jax.nn.sigmoid(gate_x.astype(jnp.float32))
    log_a = -c * jax.nn.softplus(a_param.astype(jnp.float32))[None, None, :] * rf
    a_t = jnp.exp(log_a)
    g = i_f * xf * jnp.sqrt(-jnp.expm1(2.0 * log_a))
    if h0 is not None:
        # fold the initial state into step 0: h_0' = a_0 h_init + g_0
        g = g.at[:, 0].add(a_t[:, 0] * h0.astype(jnp.float32))

    def combine(lhs, rhs):
        a1, g1 = lhs
        a2, g2 = rhs
        return a1 * a2, g1 * a2 + g2

    _, h = jax.lax.associative_scan(combine, (a_t, g), axis=1)
    out = h.astype(x.dtype)
    return (out, h[:, -1].astype(jnp.float32)) if return_state else out
