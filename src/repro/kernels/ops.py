"""Public jit'd wrappers over the Pallas kernels with kernel | ref dispatch.

``impl`` semantics (every op):
- ``"auto"``  — Pallas kernel on TPU, jnp reference elsewhere (this CPU
  container always takes the reference path; the kernels are the TPU target).
- ``"ref"``   — pure-jnp oracle (``kernels/ref.py``).
- ``"pallas"`` — the kernel, compiled for the current backend.
- ``"interpret"`` — the kernel body executed in Python (CPU validation path).

Models call these ops; tests sweep shapes/dtypes asserting pallas(interpret)
== ref.
"""

from __future__ import annotations

import jax

from repro.kernels import chunked, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.ssd_scan import ssd_scan

IMPLS = ("auto", "ref", "chunked", "pallas", "interpret")


def _resolve(impl: str) -> str:
    if impl not in IMPLS:
        raise ValueError(f"impl must be one of {IMPLS}, got {impl!r}")
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "chunked"
    return impl


def attention(q, k, v, *, causal=True, window=0, q_offset=0, impl="auto"):
    """GQA attention; q [B,Hq,Sq,D], k/v [B,Hkv,Skv,D] -> [B,Hq,Sq,D]."""
    impl = _resolve(impl)
    if impl == "ref" or q.shape[2] == 1:
        # Single-query decode is a GEMV — the flash tiling buys nothing.
        return ref.attention(q, k, v, causal=causal, window=window, q_offset=q_offset)
    if impl == "chunked":
        return chunked.attention(
            q, k, v, causal=causal, window=window, q_offset=q_offset
        )
    return flash_attention(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        interpret=(impl == "interpret"),
    )


def ssd(x, dt, a, b, c, d, *, impl="auto", return_state=False):
    """Mamba2 SSD; see kernels/ssd_scan.py for layout."""
    impl = _resolve(impl)
    if impl == "ref":
        return ref.ssd(x, dt, a, b, c, d, return_state=return_state)
    if impl == "chunked":
        return chunked.ssd(x, dt, a, b, c, d, return_state=return_state)
    return ssd_scan(
        x, dt, a, b, c, d, interpret=(impl == "interpret"), return_state=return_state
    )


def rglru(x, gate_x, gate_a, a_param, *, impl="auto", return_state=False, c=8.0):
    """RG-LRU; computes the gate nonlinearities at the JAX level (XLA fuses
    them) and runs the first-order recurrence as a kernel when on TPU."""
    import jax.numpy as jnp

    impl = _resolve(impl)
    if impl == "ref":
        return ref.rglru(x, gate_x, gate_a, a_param, return_state=return_state, c=c)
    if impl == "chunked":
        return chunked.rglru(
            x, gate_x, gate_a, a_param, return_state=return_state, c=c
        )
    rf = jax.nn.sigmoid(gate_a.astype(jnp.float32))
    i_f = jax.nn.sigmoid(gate_x.astype(jnp.float32))
    log_a = -c * jax.nn.softplus(a_param.astype(jnp.float32))[None, None, :] * rf
    a_t = jnp.exp(log_a)
    g = i_f * x.astype(jnp.float32) * jnp.sqrt(-jnp.expm1(2.0 * log_a))
    out = rglru_scan(
        a_t.astype(x.dtype), g.astype(x.dtype),
        interpret=(impl == "interpret"), return_state=return_state,
    )
    return out
