"""Pallas TPU kernels (+ jnp oracles) for the substrate's compute hot-spots.

The paper's contribution (heSRPT) is kernel-free scheduler math; these kernels
serve the *scheduled substrate*: flash attention (causal/SWA/GQA), the Mamba2
SSD chunked scan, and the RG-LRU linear recurrence.
"""

import jax.experimental.pallas.tpu as pltpu

# jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``; accept
# either so the kernels build on every jax the toolchain ships.  This alias
# must be defined before the submodule imports below — the kernel modules
# import it from this (then partially-initialized) package.
CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

from repro.kernels import alloc, ops, ref  # noqa: E402
from repro.kernels.flash_attention import flash_attention  # noqa: E402
from repro.kernels.rglru_scan import rglru_scan  # noqa: E402
from repro.kernels.ssd_scan import ssd_scan  # noqa: E402

__all__ = [
    "CompilerParams",
    "alloc",
    "flash_attention",
    "ops",
    "ref",
    "rglru_scan",
    "ssd_scan",
]
