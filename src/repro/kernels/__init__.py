"""Pallas TPU kernels (+ jnp oracles) for the substrate's compute hot-spots.

The paper's contribution (heSRPT) is kernel-free scheduler math; these kernels
serve the *scheduled substrate*: flash attention (causal/SWA/GQA), the Mamba2
SSD chunked scan, and the RG-LRU linear recurrence.
"""

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.ssd_scan import ssd_scan

__all__ = ["ops", "ref", "flash_attention", "rglru_scan", "ssd_scan"]
