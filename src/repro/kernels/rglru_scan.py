"""RG-LRU linear-recurrence kernel for TPU (RecurrentGemma's mixer).

The recurrence  h_t = a_t * h_{t-1} + g_t  is elementwise over the width W —
pure VPU work with zero arithmetic intensity headroom, so the only thing that
matters is doing it in ONE pass over HBM.  XLA lowers ``associative_scan`` to
a log-depth tree (O(S log S) HBM traffic) and ``lax.scan`` to a length-S loop
of tiny kernels; this kernel instead streams (time_block x width_block) tiles
through VMEM with the running state carried in fp32 scratch — O(S) traffic,
one kernel launch.

Gate nonlinearities (sigmoids, sqrt(1-a^2)) are computed *outside* by the
caller (``ops.rglru``): XLA fuses them into the surrounding elementwise ops,
and the kernel stays a pure first-order recurrence, reusable for any gated
linear RNN.

Grid: (batch, width_blocks, time_blocks) with time innermost ("arbitrary");
the [1, width_block] state resets at t-block 0 and carries across t-blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams


def _rglru_kernel(
    a_ref,  # [1, T, Wb] decay in (0, 1]
    g_ref,  # [1, T, Wb] gated input
    y_ref,  # [1, T, Wb]
    h_scr,  # [1, Wb] f32
    *,
    block_t: int,
):
    tb = pl.program_id(2)

    @pl.when(tb == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[0].astype(jnp.float32)  # [T, Wb]
    g = g_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t][None, :] * h + g[t][None, :]
        y_ref[0, t] = h[0].astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_t, step, h_scr[...])
    h_scr[...] = h


@functools.partial(
    jax.jit, static_argnames=("block_t", "block_w", "interpret", "return_state")
)
def rglru_scan(
    a: jax.Array,  # [B, S, W] per-step decay
    g: jax.Array,  # [B, S, W] per-step gated input
    *,
    block_t: int = 256,
    block_w: int = 512,
    interpret: bool = False,
    return_state: bool = False,
):
    """First-order recurrence h_t = a_t h_{t-1} + g_t, streamed in one pass.
    Pads S with a = 1, g = 0 (identity steps) and W with zeros."""
    B, S, W = a.shape
    block_t = min(block_t, max(S, 8))
    block_w = min(block_w, max(W, 8))
    pad_t = -S % block_t
    pad_w = -W % block_w
    if pad_t or pad_w:
        a = jnp.pad(a, ((0, 0), (0, pad_t), (0, pad_w)), constant_values=1.0)
        g = jnp.pad(g, ((0, 0), (0, pad_t), (0, pad_w)))
    S_p, W_p = S + pad_t, W + pad_w

    grid = (B, W_p // block_w, S_p // block_t)
    y = pl.pallas_call(
        functools.partial(_rglru_kernel, block_t=block_t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, block_w), lambda b, w, t: (b, t, w)),
            pl.BlockSpec((1, block_t, block_w), lambda b, w, t: (b, t, w)),
        ],
        out_specs=pl.BlockSpec((1, block_t, block_w), lambda b, w, t: (b, t, w)),
        out_shape=jax.ShapeDtypeStruct((B, S_p, W_p), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, block_w), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, g)
    out = y[:, :S, :W]
    if return_state:
        return out, out[:, -1, :].astype(jnp.float32)
    return out
