"""Model substrate: the 10 assigned architectures behind one build_model API."""

from repro.models.common import ModelOptions, ParallelConfig
from repro.models.model import Model, build_model, cross_entropy

__all__ = ["Model", "ModelOptions", "ParallelConfig", "build_model", "cross_entropy"]
