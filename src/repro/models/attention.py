"""GQA attention block: full-sequence (train/prefill), single-token decode
against a (possibly ring-buffered) KV cache, and encoder-decoder cross
attention.

KV caches are dicts ``{"k": [B, Hkv, C, hd], "v": [B, Hkv, C, hd],
"length": int32}`` where ``C`` is the cache capacity.  For sliding-window
archs (mixtral SWA, recurrentgemma local attention) ``C = window`` and the
cache is a *ring buffer* — decode at 500k context touches only ``window``
slots, which is what makes those archs long-context-servable.  RoPE is
applied to K at insert time (absolute positions), so ring slots never need
re-rotation.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.ref import NEG_INF
from repro.models.layers import rope, split_tree, uniform_scale_init


def attn_init(rng, cfg, dtype, cross: bool = False):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    rq, rk, rv, ro = split_tree(rng, 4)
    p = {
        "wq": uniform_scale_init(rq, (d, hq * hd), dtype),
        "wk": uniform_scale_init(rk, (d, hkv * hd), dtype),
        "wv": uniform_scale_init(rv, (d, hkv * hd), dtype),
        "wo": uniform_scale_init(ro, (hq * hd, d), dtype),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((hq * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    return p


def cache_capacity(cfg, seq_len: int, window: int) -> int:
    return min(seq_len, window) if window > 0 else seq_len


def init_cache(cfg, batch: int, capacity: int, dtype):
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, hkv, capacity, hd), dtype),
        "v": jnp.zeros((batch, hkv, capacity, hd), dtype),
    }


def _slot_positions(capacity: int, length: jax.Array) -> jax.Array:
    """Absolute position held by each ring slot after ``length`` inserts.
    Slots not yet written get -1 (masked)."""
    j = jnp.arange(capacity, dtype=jnp.int32)
    wrapped = length - 1 - jnp.mod(length - 1 - j, capacity)
    pos = jnp.where(length <= capacity, j, wrapped)
    return jnp.where(j < jnp.minimum(length, capacity), pos, -1)


def _project(p, x, name, heads, hd):
    w = p["w" + name]
    out = jnp.einsum("bsd,dh->bsh", x, w.astype(x.dtype))
    if "b" + name in p:
        out = out + p["b" + name].astype(x.dtype)
    b, s, _ = out.shape
    return out.reshape(b, s, heads, hd)


def apply_attn(
    p,
    x: jax.Array,  # [B, S, D]
    *,
    cfg,
    positions: jax.Array,  # [S] absolute positions of the query tokens
    window: int = 0,
    causal: bool = True,
    use_rope: bool = True,
    impl: str = "auto",
    cache: dict | None = None,
    cache_length=None,  # int32 scalar: tokens already in the cache
    return_cache: bool = False,
    cross: bool = False,
    kv_source: jax.Array | None = None,  # encoder output for cross-attn
):
    """Returns ``out [B, S, D]`` (and the new cache when ``return_cache``).

    - full-seq:   cache None, S > 1 (train / prefill)
    - decode:     cache given, S == 1, ``cache_length`` tokens already stored
    - cross-attn: ``cross=True``; KV projected from ``kv_source`` (encoder
      output) once, then reused via the cache (never causal, no rope)
    """
    B, S, D = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    q = _project(p, x, "q", hq, hd)
    if use_rope and not cross:
        q = rope(q, positions, cfg.rope_theta)
    q = q.transpose(0, 2, 1, 3)  # [B, Hq, S, hd]

    if cross:
        if cache is None:
            k = _project(p, kv_source, "k", hkv, hd).transpose(0, 2, 1, 3)
            v = _project(p, kv_source, "v", hkv, hd).transpose(0, 2, 1, 3)
            cache = {"k": k, "v": v}
        out = ops.attention(q, cache["k"], cache["v"], causal=False, impl=impl)
        out = out.transpose(0, 2, 1, 3).reshape(B, S, hq * hd)
        out = jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(x.dtype))
        return (out, cache) if return_cache else out

    k = _project(p, x, "k", hkv, hd)
    v = _project(p, x, "v", hkv, hd)
    if use_rope:
        k = rope(k, positions, cfg.rope_theta)
    k = k.transpose(0, 2, 1, 3)  # [B, Hkv, S, hd]
    v = v.transpose(0, 2, 1, 3)

    if cache is None:
        out = ops.attention(q, k, v, causal=causal, window=window, impl=impl)
        new_cache = {"k": k, "v": v}
    elif S == 1:
        new_cache = _ring_insert(cache, k, v, cache_length)
        out = _decode_attend(q, new_cache, cache_length + 1, window=window)
    else:
        raise NotImplementedError("chunked append-prefill not needed by the grid")

    out = out.transpose(0, 2, 1, 3).reshape(B, S, hq * hd)
    out = jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(x.dtype))
    return (out, new_cache) if return_cache else out


def prefill_cache(p, x, *, cfg, positions, window: int = 0):
    """Project K/V for the whole context and fold them into a ring cache of
    capacity ``min(S, window)`` (or ``S`` when full attention)."""
    B, S, D = x.shape
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    k = _project(p, x, "k", hkv, hd)
    k = rope(k, positions, cfg.rope_theta).transpose(0, 2, 1, 3)
    v = _project(p, x, "v", hkv, hd).transpose(0, 2, 1, 3)
    C = cache_capacity(cfg, S, window)
    if C < S:
        # slot j holds absolute position S-1-((S-1-j) mod C)
        j = jnp.arange(C)
        pos = S - 1 - jnp.mod(S - 1 - j, C)
        k = jnp.take(k, pos, axis=2)
        v = jnp.take(v, pos, axis=2)
    return {"k": k, "v": v}


def _ring_insert(cache: dict, k_new: jax.Array, v_new: jax.Array, t) -> dict:
    """Insert one timestep at slot ``t mod C``.  k_new/v_new [B, Hkv, 1, hd]."""
    C = cache["k"].shape[2]
    idx = jnp.mod(jnp.asarray(t, jnp.int32), C)
    zero = jnp.zeros((), idx.dtype)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (zero, zero, idx, zero))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (zero, zero, idx, zero))
    return {"k": k, "v": v}


def _decode_attend(q, cache, t, *, window: int):
    """Single-query attention over a ring cache holding ``t`` tokens.
    q [B, Hq, 1, hd]."""
    B, Hq, _, hd = q.shape
    Hkv = cache["k"].shape[1]
    group = Hq // Hkv
    C = cache["k"].shape[2]
    t = jnp.asarray(t, jnp.int32)

    pos = _slot_positions(C, t)  # [C]
    valid = pos >= 0
    q_pos = t - 1
    valid &= pos <= q_pos
    if window > 0:
        valid &= pos > q_pos - window

    qf = q.astype(jnp.float32) * (hd ** -0.5)
    qf = qf.reshape(B, Hkv, group, hd)
    logits = jnp.einsum("bhgd,bhcd->bhgc", qf, cache["k"].astype(jnp.float32))
    logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgc,bhcd->bhgd", probs, cache["v"].astype(jnp.float32))
    return out.reshape(B, Hq, 1, hd).astype(q.dtype)
