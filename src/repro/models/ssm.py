"""Mamba2 block (SSD mixer): projections + causal depthwise conv + SSD scan
+ gated RMSNorm + out projection.  Attention-free; decode carries a constant
(conv window, SSM state) cache — this is what makes the family
500k-context-servable.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.models.layers import rms_norm, split_tree, uniform_scale_init


def ssm_init(rng, cfg, dtype):
    d, di, n, h, k = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_conv
    conv_ch = di + 2 * n
    r1, r2, r3, r4 = split_tree(rng, 4)
    return {
        # in_proj emits [z (di), xBC (di + 2N), dt (H)]
        "in_proj": uniform_scale_init(r1, (d, 2 * di + 2 * n + h), dtype),
        "conv_w": uniform_scale_init(r2, (cfg.ssm_conv, conv_ch), dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(dtype),
        "d_skip": jnp.ones((h,), dtype),
        "dt_bias": jnp.asarray(
            jax.random.uniform(r3, (h,), jnp.float32, -4.6, -2.2), dtype
        ),  # softplus^-1 of dt in ~[0.01, 0.1]
        "gnorm": jnp.ones((di,), dtype),
        "out_proj": uniform_scale_init(r4, (di, d), dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over time.  x [B,S,C], w [K,C]."""
    k, c = w.shape
    out = jax.lax.conv_general_dilated(
        x,
        w.astype(x.dtype)[:, None, :],  # [K, 1, C]
        window_strides=(1,),
        padding=[(k - 1, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=c,
    )
    return out + b.astype(x.dtype)


def _split_proj(p, x, cfg):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["in_proj"].astype(x.dtype))
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : 2 * di + 2 * n]
    dt = zxbcdt[..., 2 * di + 2 * n :]
    return z, xbc, dt


def _finish(p, y_flat, z, x_dtype, cfg):
    y = rms_norm(
        y_flat * jax.nn.silu(z.astype(jnp.float32)).astype(x_dtype),
        p["gnorm"],
        cfg.norm_eps,
    )
    return jnp.einsum("bsk,kd->bsd", y, p["out_proj"].astype(x_dtype))


def ssm_apply(p, x, *, cfg, impl="auto", cache=None, return_cache=False):
    """x [B,S,D].  Full-seq when cache is None; single-step decode otherwise.
    Cache: {"conv": [B, K-1, di+2N], "ssm": [B,H,P,N] fp32, "length": i32}."""
    B, S, D = x.shape
    di, n, h, pp = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    z, xbc, dt = _split_proj(p, x, cfg)

    if cache is None:
        conv_tail = xbc[:, -(cfg.ssm_conv - 1) :, :] if return_cache else None
        xbc = jax.nn.silu(
            _causal_conv(xbc, p["conv_w"], p["conv_b"]).astype(jnp.float32)
        ).astype(x.dtype)
        x_ssm = xbc[..., :di].reshape(B, S, h, pp)
        b_mat = xbc[..., di : di + n]
        c_mat = xbc[..., di + n :]
        dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
        a = -jnp.exp(p["a_log"].astype(jnp.float32))
        if return_cache:
            y, state = ops.ssd(
                x_ssm, dt, a, b_mat, c_mat, p["d_skip"].astype(jnp.float32),
                impl=impl, return_state=True,
            )
        else:
            y = ops.ssd(
                x_ssm, dt, a, b_mat, c_mat, p["d_skip"].astype(jnp.float32), impl=impl
            )
        out = _finish(p, y.reshape(B, S, di), z, x.dtype, cfg)
        if return_cache:
            pad = cfg.ssm_conv - 1 - conv_tail.shape[1]
            if pad > 0:
                conv_tail = jnp.pad(conv_tail, ((0, 0), (pad, 0), (0, 0)))
            cache = {"conv": conv_tail, "ssm": state}
            return out, cache
        return out

    # ---- decode: S == 1, sequential-step via the oracle recurrence ----
    conv_win = jnp.concatenate([cache["conv"], xbc], axis=1)  # [B, K, C]
    xbc_t = jnp.einsum("bkc,kc->bc", conv_win, p["conv_w"].astype(x.dtype))
    xbc_t = jax.nn.silu((xbc_t + p["conv_b"].astype(x.dtype)).astype(jnp.float32)).astype(x.dtype)
    xbc_t = xbc_t[:, None, :]  # [B,1,C]
    x_ssm = xbc_t[..., :di].reshape(B, 1, h, pp)
    b_mat = xbc_t[..., di : di + n]
    c_mat = xbc_t[..., di + n :]
    dt_t = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    y, state = ref.ssd(
        x_ssm, dt_t, a, b_mat, c_mat, p["d_skip"].astype(jnp.float32),
        h0=cache["ssm"], return_state=True,
    )
    out = _finish(p, y.reshape(B, 1, di), z, x.dtype, cfg)
    new_cache = {"conv": conv_win[:, 1:, :], "ssm": state}
    return (out, new_cache) if return_cache else out


def ssm_cache_shape(cfg, batch: int, dtype):
    """ShapeDtypeStructs for one layer's decode cache."""
    di, n, h, pp = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    return {
        "conv": jax.ShapeDtypeStruct((batch, cfg.ssm_conv - 1, di + 2 * n), dtype),
        "ssm": jax.ShapeDtypeStruct((batch, h, pp, n), jnp.float32),
    }
