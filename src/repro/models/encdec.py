"""Whisper-style encoder-decoder backbone.

The audio frontend (mel filterbank + conv downsampling) is a STUB per the
grid spec: ``input_specs`` provides precomputed frame embeddings
[B, encoder_seq, d_model].  Everything downstream is real: sinusoidal
positions, LayerNorm/GELU transformer encoder, decoder with causal
self-attention + cross-attention, tied embedding logits.  Both stacks are
scanned (stacked-layer params) like the decoder-only families.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import apply_attn, attn_init
from repro.models.common import constrain_batch
from repro.models.layers import (
    embed_init,
    embed_lookup,
    gelu_mlp,
    gelu_mlp_init,
    layer_norm,
    sinusoidal_positions,
    split_tree,
)


def _ln_init(d, dtype):
    return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def _ln(x, p):
    return layer_norm(x, p["w"], p["b"])


def _enc_block_init(rng, cfg, dtype):
    r1, r2 = split_tree(rng, 2)
    d = cfg.d_model
    return {
        "norm": _ln_init(d, dtype),
        "attn": attn_init(r1, cfg, dtype),
        "mlp_norm": _ln_init(d, dtype),
        "mlp": gelu_mlp_init(r2, d, cfg.d_ff, dtype),
    }


def _dec_block_init(rng, cfg, dtype):
    r1, r2, r3 = split_tree(rng, 3)
    d = cfg.d_model
    return {
        "norm": _ln_init(d, dtype),
        "self_attn": attn_init(r1, cfg, dtype),
        "cross_norm": _ln_init(d, dtype),
        "cross_attn": attn_init(r2, cfg, dtype, cross=True),
        "mlp_norm": _ln_init(d, dtype),
        "mlp": gelu_mlp_init(r3, d, cfg.d_ff, dtype),
    }


def encdec_init(rng, cfg, dtype):
    r_emb, r_enc, r_dec = split_tree(rng, 3)
    enc_rngs = jax.random.split(r_enc, cfg.encoder_layers)
    dec_rngs = jax.random.split(r_dec, cfg.n_layers)
    return {
        "embed": embed_init(r_emb, cfg.vocab_size, cfg.d_model, dtype),
        "enc_blocks": jax.vmap(lambda r: _enc_block_init(r, cfg, dtype))(enc_rngs),
        "enc_final": _ln_init(cfg.d_model, dtype),
        "dec_blocks": jax.vmap(lambda r: _dec_block_init(r, cfg, dtype))(dec_rngs),
        "dec_final": _ln_init(cfg.d_model, dtype),
    }


def encode(params, frames, *, cfg, opts):
    """frames [B, Se, D] (stub frontend output) -> encoder states [B, Se, D]."""
    dtype = frames.dtype
    x = frames + sinusoidal_positions(frames.shape[1], cfg.d_model).astype(dtype)
    zero_pos = jnp.zeros((frames.shape[1],), jnp.int32)

    def block(x, bp):
        x = constrain_batch(x, opts.parallel)
        h = _ln(x, bp["norm"])
        x = x + apply_attn(
            bp["attn"], h, cfg=cfg, positions=zero_pos, causal=False,
            use_rope=False, impl=opts.attn_impl,
        )
        x = x + gelu_mlp(bp["mlp"], _ln(x, bp["mlp_norm"]))
        return x, 0

    x, _ = jax.lax.scan(block, x, params["enc_blocks"])
    return _ln(x, params["enc_final"])


def _dec_block(bp, x, *, cfg, opts, mode, positions, enc_out, cache, cache_length,
               prefill_capacity=None):
    """One decoder block.  cache = {"self": {k,v}, "cross": {k,v}} or None."""
    from repro.models.transformer import resize_kv_cache

    new_cache = {}
    x = constrain_batch(x, opts.parallel)
    h = _ln(x, bp["norm"])
    if mode == "train":
        x = x + apply_attn(
            bp["self_attn"], h, cfg=cfg, positions=positions, use_rope=False,
            impl=opts.attn_impl,
        )
    elif mode == "prefill":
        out, sc = apply_attn(
            bp["self_attn"], h, cfg=cfg, positions=positions, use_rope=False,
            impl=opts.attn_impl, return_cache=True,
        )
        x = x + out
        new_cache["self"] = resize_kv_cache(
            sc, h.shape[1], prefill_capacity or h.shape[1], cfg, 0
        )
    else:
        out, sc = apply_attn(
            bp["self_attn"], h, cfg=cfg, positions=positions, use_rope=False,
            impl=opts.attn_impl, cache=cache["self"], cache_length=cache_length,
            return_cache=True,
        )
        x = x + out
        new_cache["self"] = sc

    h = _ln(x, bp["cross_norm"])
    if mode == "train":
        x = x + apply_attn(
            bp["cross_attn"], h, cfg=cfg, positions=positions, cross=True,
            kv_source=enc_out, impl=opts.attn_impl,
        )
    else:
        out, cc = apply_attn(
            bp["cross_attn"], h, cfg=cfg, positions=positions, cross=True,
            kv_source=enc_out,
            cache=None if cache is None else cache["cross"],
            impl=opts.attn_impl, return_cache=True,
        )
        x = x + out
        new_cache["cross"] = cc

    x = x + gelu_mlp(bp["mlp"], _ln(x, bp["mlp_norm"]))
    return x, (new_cache if mode != "train" else None)


def decode_stack(params, tokens, *, cfg, opts, mode, enc_out=None, caches=None,
                 cache_length=None, prefill_capacity=None):
    """tokens [B, S] -> (hidden [B,S,D], new_caches).  ``enc_out`` required
    for train/prefill; decode reuses the cached cross KV."""
    dtype = enc_out.dtype if enc_out is not None else params["embed"].dtype
    if mode == "decode":
        dtype = caches["blocks"]["self"]["k"].dtype
    x = embed_lookup(params["embed"], tokens, dtype)
    S = tokens.shape[1]
    if mode == "decode":
        positions = jnp.asarray(cache_length)[None]
        x = x + _sinusoidal_at(jnp.asarray(cache_length), cfg.d_model).astype(dtype)
    else:
        positions = jnp.arange(S, dtype=jnp.int32)
        x = x + sinusoidal_positions(S, cfg.d_model).astype(dtype)

    def body(carry, xs):
        x, = carry
        if mode == "decode":
            bp, bc = xs
        else:
            bp, bc = xs, None
        x, nc = _dec_block(
            bp, x, cfg=cfg, opts=opts, mode=mode, positions=positions,
            enc_out=enc_out, cache=bc, cache_length=cache_length,
            prefill_capacity=prefill_capacity,
        )
        return (x,), (nc if mode != "train" else 0)

    xs = (params["dec_blocks"], caches["blocks"]) if mode == "decode" else params["dec_blocks"]
    if mode == "train" and opts.remat == "full":
        inner = body

        def body(carry, xs):  # noqa: F811 — rematted wrapper
            return jax.checkpoint(inner)(carry, xs)

    (x,), new_caches = jax.lax.scan(body, (x,), xs)
    x = _ln(x, params["dec_final"])
    return x, ({"blocks": new_caches} if mode != "train" else None)


def _sinusoidal_at(pos, d: int) -> jax.Array:
    """Sinusoidal embedding for one (traced) position.  -> [d]."""
    dim = jnp.arange(d // 2, dtype=jnp.float32)
    inv = jnp.exp(-dim * (jnp.log(10000.0) / max(d // 2 - 1, 1)))
    ang = pos.astype(jnp.float32) * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])


def encdec_cache_specs(cfg, batch: int, seq_len: int, dtype):
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    L = cfg.n_layers

    def kv(capacity):
        return {
            "k": jax.ShapeDtypeStruct((L, batch, hkv, capacity, hd), dtype),
            "v": jax.ShapeDtypeStruct((L, batch, hkv, capacity, hd), dtype),
        }

    return {"blocks": {"self": kv(seq_len), "cross": kv(cfg.encoder_seq)}}
