"""Mixture-of-Experts MLP: top-k routing with two dispatch implementations.

``impl="dense"`` — every token through every expert, weighted combine.  Pure
einsum, partitions under plain GSPMD with zero custom collectives, but wastes
``n_experts / top_k`` x compute.  This is the BASELINE the roofline tables
record (and what the perf log hillclimbs away from).

``impl="ragged"`` — TPU-native dropless dispatch: tokens are routed
*shard-locally* under ``shard_map`` (no token ever crosses the data axis),
sorted by expert id, and pushed through ``jax.lax.ragged_dot`` grouped GEMMs
(MXU-friendly, FLOPs = active params only).  Expert weights are
tensor-parallel over the model axis on the ``d_ff`` dim; the down-projection
partial sums are combined with one ``psum`` over the model axis — the same
collective volume as a dense TP MLP.

Both implementations return (output, aux_loss) where aux_loss is the
standard switch-style load-balance loss  E * sum_e f_e * p_e.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.common import shard_map
from repro.models.layers import split_tree, uniform_scale_init


def moe_init(rng, cfg, dtype):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    rr, rg, ru, rd = split_tree(rng, 4)
    return {
        "router": uniform_scale_init(rr, (d, e), dtype),
        "gate": uniform_scale_init(rg, (e, d, f), dtype),
        "up": uniform_scale_init(ru, (e, d, f), dtype),
        "down": uniform_scale_init(rd, (e, f, d), dtype),
    }


def _route(p, x, cfg):
    """Router: top-k expert ids + renormalized weights + load-balance loss."""
    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, ids = jax.lax.top_k(probs, cfg.top_k)  # [B,S,k]
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    # Load balance: fraction of routed assignments vs mean router prob.
    e = cfg.n_experts
    assign = jnp.sum(jax.nn.one_hot(ids, e, dtype=jnp.float32), axis=(-2,))  # [B,S,e]
    f_e = jnp.mean(assign, axis=(0, 1)) / cfg.top_k
    p_e = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(f_e * p_e)
    return w, ids, aux


def _expert_sharded(t, cfg, parallel):
    """Constrain a [B,S,E,F] expert intermediate to (experts -> data,
    d_ff -> model).  With expert weights sharded over data, this makes GSPMD
    keep the expert GEMMs where the weights live and move ACTIVATIONS
    (all-gather x over data, ~MBs) instead of gathering expert weights
    (~GBs per layer) — pjit-native expert parallelism."""
    if parallel is None:
        return t
    P = jax.sharding.PartitionSpec
    mesh = parallel.mesh
    nd = 1
    for a in parallel.data_axes:
        nd *= mesh.shape[a]
    nm = mesh.shape[parallel.model_axis]
    e_part = None
    if nd > 1 and cfg.n_experts % nd == 0:
        e_part = (parallel.data_axes if len(parallel.data_axes) > 1
                  else parallel.data_axes[0])
    f_part = parallel.model_axis if (nm > 1 and t.shape[-1] % nm == 0) else None
    if e_part is None and f_part is None:
        return t
    spec = P(None, None, e_part, f_part)
    return jax.lax.with_sharding_constraint(
        t, jax.sharding.NamedSharding(mesh, spec)
    )


def moe_apply_dense(p, x, cfg, parallel=None):
    """All-experts einsum baseline.  x [B,S,D] -> [B,S,D].  With ``parallel``
    given, intermediates are expert-sharded (see _expert_sharded)."""
    w, ids, aux = _route(p, x, cfg)
    cw = jnp.einsum(
        "bske,bsk->bse",
        jax.nn.one_hot(ids, cfg.n_experts, dtype=x.dtype),
        w.astype(x.dtype),
    )  # combine weights [B,S,E]
    g = jnp.einsum("bsd,edf->bsef", x, p["gate"].astype(x.dtype))
    g = _expert_sharded(g, cfg, parallel)
    u = jnp.einsum("bsd,edf->bsef", x, p["up"].astype(x.dtype))
    u = _expert_sharded(u, cfg, parallel)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    # Fold the combine weights into h BEFORE the down-projection and contract
    # (e, f) jointly: the naive two-step 'bsef,efd->bsed' then 'bsed,bse->bsd'
    # materializes a (tokens x E x D) intermediate whose all-reduce/reshard
    # dominated the whole step (~26 TB/device for qwen3) — measured in
    # EXPERIMENTS.md §Perf.
    hw = h * cw[..., None]
    out = jnp.einsum("bsef,efd->bsd", hw, p["down"].astype(x.dtype))
    return out, aux


def _moe_local_ragged(x, router, wg, wu, wd, *, cfg, model_axis, aux_axes=()):
    """Shard-local dropless MoE.  x [b_loc, S, D]; wg/wu/wd are the LOCAL
    d_ff shards (full expert and d_model dims).  Runs inside shard_map."""
    b, s, d = x.shape
    k, e = cfg.top_k, cfg.n_experts
    w, ids, aux = _route({"router": router}, x, cfg)

    t = b * s
    x_flat = x.reshape(t, d)
    flat_ids = ids.reshape(t * k)
    order = jnp.argsort(flat_ids, stable=True)
    xs = jnp.take(x_flat, order // k, axis=0)  # [t*k, D] sorted by expert
    group_sizes = jnp.bincount(flat_ids, length=e).astype(jnp.int32)

    g = jax.lax.ragged_dot(xs, wg.astype(x.dtype), group_sizes)
    u = jax.lax.ragged_dot(xs, wu.astype(x.dtype), group_sizes)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    part = jax.lax.ragged_dot(h, wd.astype(x.dtype), group_sizes)  # [t*k, D]
    if model_axis is not None:
        part = jax.lax.psum(part, model_axis)  # combine d_ff-shard partials

    inv = jnp.argsort(order, stable=True)
    y = jnp.take(part, inv, axis=0).reshape(t, k, d)
    out = jnp.einsum("tkd,tk->td", y, w.reshape(t, k).astype(x.dtype))
    if aux_axes:
        aux = jax.lax.pmean(aux, aux_axes)
    return out.reshape(b, s, d), aux


def moe_apply_ragged(p, x, cfg, parallel):
    """shard_map wrapper: tokens stay on their data shard; experts are
    d_ff-tensor-parallel over the model axis."""
    P = jax.sharding.PartitionSpec
    dp, mp = parallel.data_axes, parallel.model_axis
    fn = functools.partial(
        _moe_local_ragged, cfg=cfg, model_axis=mp, aux_axes=tuple(dp) + (mp,)
    )
    out, aux = shard_map(
        fn,
        mesh=parallel.mesh,
        in_specs=(
            P(dp, None, None),
            P(None, None),
            P(None, None, mp),
            P(None, None, mp),
            P(None, mp, None),
        ),
        out_specs=(P(dp, None, None), P()),
        check_vma=False,
    )(x, p["router"], p["gate"], p["up"], p["down"])
    return out, aux


def moe_apply(p, x, cfg, *, impl="dense", parallel=None):
    if impl == "ragged" and parallel is not None:
        return moe_apply_ragged(p, x, cfg, parallel)
    if impl == "ragged_local":
        # Single-device ragged path (tests): no mesh, no psum.
        return _moe_local_ragged(
            x, p["router"], p["gate"], p["up"], p["down"], cfg=cfg, model_axis=None
        )
    if impl == "dense_ep":
        return moe_apply_dense(p, x, cfg, parallel)
    return moe_apply_dense(p, x, cfg)
