"""The decoder stack: scan-over-blocks with remat, shared by the dense, moe,
ssm, hybrid and vlm families.

A *block* is one repetition of the architecture's mixer pattern:
  dense/moe/vlm -> ("attn",)         ssm -> ("ssm",)
  hybrid        -> cfg.layer_pattern (e.g. ("rglru", "rglru", "attn"))
Block parameters are stacked on a leading ``n_blocks`` axis and the stack is
a single ``lax.scan`` — the compiled HLO contains ONE block body regardless
of depth (fast compiles, small programs, remat applies per block).  Layer
counts not divisible by the pattern get explicit unscanned tail layers.

Decode caches mirror the block structure ({"sub0": {...}, ...}, stacked on
the same leading axis) and flow through the scan as per-iteration inputs /
stacked outputs.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.attention import apply_attn, attn_init, cache_capacity
from repro.models.common import ModelOptions, constrain_batch, constrain_seq
from repro.models.layers import rms_norm, split_tree, swiglu, swiglu_init
from repro.models.moe import moe_apply, moe_init
from repro.models.rglru import rg_apply, rg_cache_shape, rg_init
from repro.models.ssm import ssm_apply, ssm_cache_shape, ssm_init


def pattern_of(cfg) -> tuple:
    if cfg.family == "ssm":
        return ("ssm",)
    if cfg.family == "hybrid":
        return tuple(cfg.layer_pattern)
    return ("attn",)


def block_counts(cfg) -> tuple:
    """(n_scanned_blocks, tail_kinds) — tail layers repeat the pattern prefix."""
    pat = pattern_of(cfg)
    n_blocks = cfg.n_layers // len(pat)
    tail = cfg.n_layers - n_blocks * len(pat)
    return n_blocks, pat[:tail]


def _has_mlp(cfg) -> bool:
    return cfg.d_ff > 0


def _sublayer_init(rng, cfg, kind, dtype):
    d = cfg.d_model
    r_mix, r_mlp = split_tree(rng, 2)
    p = {"norm": jnp.ones((d,), dtype)}
    if kind == "attn":
        p["mix"] = attn_init(r_mix, cfg, dtype)
    elif kind == "ssm":
        p["mix"] = ssm_init(r_mix, cfg, dtype)
    elif kind == "rglru":
        p["mix"] = rg_init(r_mix, cfg, dtype)
    else:
        raise ValueError(kind)
    if _has_mlp(cfg):
        p["mlp_norm"] = jnp.ones((d,), dtype)
        p["mlp"] = (
            moe_init(r_mlp, cfg, dtype)
            if cfg.n_experts
            else swiglu_init(r_mlp, cfg.d_model, cfg.d_ff, dtype)
        )
    return p


def _block_init(rng, cfg, kinds, dtype):
    rngs = split_tree(rng, len(kinds))
    return {f"sub{i}": _sublayer_init(rngs[i], cfg, k, dtype) for i, k in enumerate(kinds)}


def stack_init(rng, cfg, dtype):
    n_blocks, tail = block_counts(cfg)
    pat = pattern_of(cfg)
    r_blocks, r_tail = jax.random.split(rng)
    rngs = jax.random.split(r_blocks, n_blocks)
    blocks = jax.vmap(lambda r: _block_init(r, cfg, pat, dtype))(rngs)
    params = {"blocks": blocks}
    if tail:
        params["tail"] = _block_init(r_tail, cfg, tail, dtype)
    return params


def _apply_sublayer(sp, x, kind, *, cfg, opts, mode, positions, cache,
                    cache_length, prefill_capacity=None):
    """One (mixer + optional MLP) sublayer.  Returns (x, new_cache, aux)."""
    h = rms_norm(x, sp["norm"], cfg.norm_eps)
    new_cache = None
    if kind == "attn":
        window = cfg.window
        if mode == "train":
            out = apply_attn(
                sp["mix"], h, cfg=cfg, positions=positions, window=window,
                impl=opts.attn_impl,
            )
        elif mode == "prefill":
            out, new_cache = apply_attn(
                sp["mix"], h, cfg=cfg, positions=positions, window=window,
                impl=opts.attn_impl, return_cache=True,
            )
            new_cache = resize_kv_cache(
                new_cache, h.shape[1], prefill_capacity or h.shape[1], cfg, window
            )
        else:  # decode
            out, new_cache = apply_attn(
                sp["mix"], h, cfg=cfg, positions=positions, window=window,
                impl=opts.attn_impl, cache=cache, cache_length=cache_length,
                return_cache=True,
            )
    elif kind == "ssm":
        if mode == "train":
            out = ssm_apply(sp["mix"], h, cfg=cfg, impl=opts.mixer_impl)
        else:
            out, new_cache = ssm_apply(
                sp["mix"], h, cfg=cfg, impl=opts.mixer_impl, cache=cache,
                return_cache=True,
            )
    elif kind == "rglru":
        if mode == "train":
            out = rg_apply(sp["mix"], h, cfg=cfg, impl=opts.mixer_impl)
        else:
            out, new_cache = rg_apply(
                sp["mix"], h, cfg=cfg, impl=opts.mixer_impl, cache=cache,
                return_cache=True,
            )
    else:
        raise ValueError(kind)
    x = x + out

    aux = jnp.zeros((), jnp.float32)
    if _has_mlp(cfg):
        h2 = rms_norm(x, sp["mlp_norm"], cfg.norm_eps)
        if cfg.n_experts:
            out2, aux = moe_apply(
                sp["mlp"], h2, cfg, impl=opts.moe_impl, parallel=opts.parallel
            )
        else:
            out2 = swiglu(sp["mlp"], h2)
        x = x + out2
    return x, new_cache, aux


def resize_kv_cache(cache, used: int, target_len: int, cfg, window: int):
    """Fit a freshly-prefilled KV cache (``used`` positions) to the capacity a
    ``target_len``-token conversation needs: ring-fold when the window is
    smaller, zero-pad headroom when larger."""
    C = cache_capacity(cfg, max(target_len, used), window)
    S = cache["k"].shape[2]
    if C < S:  # ring fold: slot j holds absolute position used-1-((used-1-j)%C)
        j = jnp.arange(C)
        pos = used - 1 - jnp.mod(used - 1 - j, C)
        return {
            "k": jnp.take(cache["k"], pos, axis=2),
            "v": jnp.take(cache["v"], pos, axis=2),
        }
    if C > S:  # headroom for future ring inserts at slot (t mod C)
        pad = ((0, 0), (0, 0), (0, C - S), (0, 0))
        return {"k": jnp.pad(cache["k"], pad), "v": jnp.pad(cache["v"], pad)}
    return cache


def _block_apply(bp, x, kinds, *, cfg, opts, mode, positions, caches,
                 cache_length, prefill_capacity=None):
    new_caches = {}
    aux_total = jnp.zeros((), jnp.float32)
    if opts.seq_shard and mode == "train":
        # Block inputs are what remat saves: sharding them over the model
        # axis (sequence parallelism) divides saved-activation memory by TP.
        x = constrain_seq(x, opts.parallel)
    else:
        x = constrain_batch(x, opts.parallel)
    for i, kind in enumerate(kinds):
        c = caches[f"sub{i}"] if caches is not None else None
        x, nc, aux = _apply_sublayer(
            bp[f"sub{i}"], x, kind, cfg=cfg, opts=opts, mode=mode,
            positions=positions, cache=c, cache_length=cache_length,
            prefill_capacity=prefill_capacity,
        )
        x = constrain_batch(x, opts.parallel)
        new_caches[f"sub{i}"] = nc
        aux_total = aux_total + aux
    return x, new_caches, aux_total


def stack_apply(
    params,
    x: jax.Array,  # [B, S, D] embedded inputs
    *,
    cfg,
    opts: ModelOptions,
    mode: str,  # train | prefill | decode
    positions: jax.Array,
    caches=None,  # stacked cache pytree (decode), or None
    cache_length=None,  # int32 scalar (decode)
    prefill_capacity=None,  # total conversation length the caches must hold
):
    """Returns (x, new_caches, aux).  new_caches is None in train mode."""
    pat = pattern_of(cfg)
    n_blocks, tail = block_counts(cfg)
    want_cache = mode != "train"

    def body(x, bp, bc):
        return _block_apply(
            bp, x, pat, cfg=cfg, opts=opts, mode=mode, positions=positions,
            caches=bc, cache_length=cache_length, prefill_capacity=prefill_capacity,
        )

    if mode == "train" and opts.remat == "full":
        body = jax.checkpoint(body, policy=None)

    if mode == "decode":
        def scan_fn(carry, xs):
            x, aux = carry
            bp, bc = xs
            x, nc, aux_i = body(x, bp, bc)
            return (x, aux + aux_i), nc

        (x, aux), new_caches = jax.lax.scan(
            scan_fn, (x, jnp.zeros((), jnp.float32)),
            (params["blocks"], caches["blocks"]),
        )
    else:
        def scan_fn(carry, bp):
            x, aux = carry
            x, nc, aux_i = body(x, bp, None)
            return (x, aux + aux_i), (nc if want_cache else 0)

        (x, aux), new_caches = jax.lax.scan(
            scan_fn, (x, jnp.zeros((), jnp.float32)), params["blocks"]
        )
        if not want_cache:
            new_caches = None

    out_caches = {"blocks": new_caches} if want_cache else None
    if tail:
        tc = caches["tail"] if (caches is not None and "tail" in caches) else None
        x, ntc, aux_t = _block_apply(
            params["tail"], x, tail, cfg=cfg, opts=opts, mode=mode,
            positions=positions, caches=tc, cache_length=cache_length,
            prefill_capacity=prefill_capacity,
        )
        aux = aux + aux_t
        if want_cache:
            out_caches["tail"] = ntc
    return x, out_caches, aux


# ------------------------------------------------------------- cache specs
def _sublayer_cache_spec(cfg, kind, batch, seq_len, dtype):
    if kind == "attn":
        C = cache_capacity(cfg, seq_len, cfg.window)
        return {
            "k": jax.ShapeDtypeStruct((batch, cfg.n_kv_heads, C, cfg.head_dim), dtype),
            "v": jax.ShapeDtypeStruct((batch, cfg.n_kv_heads, C, cfg.head_dim), dtype),
        }
    if kind == "ssm":
        return ssm_cache_shape(cfg, batch, dtype)
    if kind == "rglru":
        return rg_cache_shape(cfg, batch, dtype)
    raise ValueError(kind)


def stack_cache_specs(cfg, batch: int, seq_len: int, dtype):
    """ShapeDtypeStruct pytree matching stack_apply's cache structure."""
    pat = pattern_of(cfg)
    n_blocks, tail = block_counts(cfg)

    def stackify(spec):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n_blocks,) + s.shape, s.dtype), spec
        )

    specs = {
        "blocks": {
            f"sub{i}": stackify(_sublayer_cache_spec(cfg, k, batch, seq_len, dtype))
            for i, k in enumerate(pat)
        }
    }
    if tail:
        specs["tail"] = {
            f"sub{i}": _sublayer_cache_spec(cfg, k, batch, seq_len, dtype)
            for i, k in enumerate(tail)
        }
    return specs
