"""``build_model(cfg, opts)`` — the single public entry point for every
assigned architecture.  Returns a ``Model`` of pure functions:

  init(rng)                                   -> params (fp32 masters)
  loss_fn(params, batch)                      -> (loss, metrics)
  prefill_fn(params, batch)                   -> (last_logits [B,V], caches)
  decode_fn(params, tokens, caches, t)        -> (logits [B,1,V], caches)
  input_specs(shape)                          -> {name: ShapeDtypeStruct}
  cache_specs(shape)                          -> cache pytree of SDS

``input_specs``/``cache_specs`` are the dry-run contract: weak-type-correct
stand-ins for every model input, no device allocation.
"""

from __future__ import annotations

from collections.abc import Callable
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec
from repro.models.common import ModelOptions, constrain_batch
from repro.models.layers import (
    embed_init,
    embed_lookup,
    logits_from_embed,
    rms_norm,
    split_tree,
    uniform_scale_init,
)
from repro.models.transformer import stack_apply, stack_cache_specs, stack_init
from repro.models.vlm import patch_embed_spec, splice_patches, vlm_loss_mask


class Model(NamedTuple):
    cfg: ModelConfig
    opts: ModelOptions
    init: Callable
    loss_fn: Callable
    prefill_fn: Callable
    decode_fn: Callable
    input_specs: Callable
    cache_specs: Callable


def cross_entropy(logits: jax.Array, labels: jax.Array, mask: jax.Array):
    """Mean masked CE.  logits [B,S,V] (any dtype; reduced in fp32)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    ce = (lse - gold) * mask
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(ce) / denom


def _lm_head(cfg, params, x):
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return logits_from_embed(table, x)


def build_model(cfg: ModelConfig, opts: ModelOptions = ModelOptions()) -> Model:
    if cfg.family == "audio":
        return _build_encdec(cfg, opts)
    return _build_decoder_only(cfg, opts)


# --------------------------------------------------------- decoder-only LMs
def _build_decoder_only(cfg: ModelConfig, opts: ModelOptions) -> Model:
    adt = jnp.dtype(opts.activation_dtype)
    pdt = jnp.dtype(cfg.param_dtype)

    def init(rng):
        r_emb, r_stack, r_head = split_tree(rng, 3)
        params = {
            "embed": embed_init(r_emb, cfg.vocab_size, cfg.d_model, pdt),
            "stack": stack_init(r_stack, cfg, pdt),
            "final_norm": jnp.ones((cfg.d_model,), pdt),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = uniform_scale_init(
                r_head, (cfg.vocab_size, cfg.d_model), pdt, scale=0.02
            )
        return params

    def forward(params, tokens, *, mode, caches=None, cache_length=None,
                patch_embeds=None, max_len=None):
        x = embed_lookup(params["embed"], tokens, adt)
        if patch_embeds is not None:
            x = splice_patches(x, patch_embeds)
        x = constrain_batch(x, opts.parallel)
        if mode == "decode":
            positions = jnp.asarray(cache_length, jnp.int32)[None]
        else:
            positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
        x, new_caches, aux = stack_apply(
            params["stack"], x, cfg=cfg, opts=opts, mode=mode,
            positions=positions, caches=caches, cache_length=cache_length,
            prefill_capacity=max_len,
        )
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x, new_caches, aux

    def loss_fn(params, batch):
        x, _, aux = forward(
            params, batch["tokens"], mode="train",
            patch_embeds=batch.get("patch_embeds"),
        )
        logits = _lm_head(cfg, params, x)
        mask = (
            vlm_loss_mask(cfg, batch["tokens"])
            if cfg.family == "vlm"
            else jnp.ones(batch["tokens"].shape, jnp.float32)
        )
        ce = cross_entropy(logits, batch["labels"], mask)
        loss = ce + 0.01 * aux
        return loss, {"ce": ce, "aux_loss": aux}

    def prefill_fn(params, batch, max_len=None):
        x, caches, _ = forward(
            params, batch["tokens"], mode="prefill",
            patch_embeds=batch.get("patch_embeds"), max_len=max_len,
        )
        logits = _lm_head(cfg, params, x[:, -1:, :])[:, 0, :]
        return logits, caches

    def decode_fn(params, tokens, caches, cache_length):
        x, caches, _ = forward(
            params, tokens, mode="decode", caches=caches, cache_length=cache_length
        )
        return _lm_head(cfg, params, x), caches

    def input_specs(shape: ShapeConfig):
        b = shape.global_batch
        i32 = jnp.int32
        if shape.kind == "train":
            specs = {
                "tokens": jax.ShapeDtypeStruct((b, shape.seq_len), i32),
                "labels": jax.ShapeDtypeStruct((b, shape.seq_len), i32),
            }
            if cfg.family == "vlm":
                specs["patch_embeds"] = patch_embed_spec(cfg, b, adt)
            return specs
        if shape.kind == "prefill":
            specs = {"tokens": jax.ShapeDtypeStruct((b, shape.seq_len), i32)}
            if cfg.family == "vlm":
                specs["patch_embeds"] = patch_embed_spec(cfg, b, adt)
            return specs
        # decode: one new token against a cache of shape.seq_len
        return {
            "tokens": jax.ShapeDtypeStruct((b, 1), i32),
            "cache_length": jax.ShapeDtypeStruct((), i32),
        }

    def cache_specs(shape: ShapeConfig):
        return stack_cache_specs(cfg, shape.global_batch, shape.seq_len, adt)

    return Model(cfg, opts, init, loss_fn, prefill_fn, decode_fn, input_specs,
                 cache_specs)


# ------------------------------------------------------------ encoder-decoder
def _build_encdec(cfg: ModelConfig, opts: ModelOptions) -> Model:
    adt = jnp.dtype(opts.activation_dtype)
    pdt = jnp.dtype(cfg.param_dtype)

    def init(rng):
        return encdec.encdec_init(rng, cfg, pdt)

    def loss_fn(params, batch):
        enc_out = encdec.encode(params, batch["frames"].astype(adt), cfg=cfg, opts=opts)
        x, _ = encdec.decode_stack(
            params, batch["tokens"], cfg=cfg, opts=opts, mode="train", enc_out=enc_out
        )
        logits = logits_from_embed(params["embed"], x)
        mask = jnp.ones(batch["tokens"].shape, jnp.float32)
        ce = cross_entropy(logits, batch["labels"], mask)
        return ce, {"ce": ce, "aux_loss": jnp.zeros((), jnp.float32)}

    def prefill_fn(params, batch, max_len=None):
        enc_out = encdec.encode(params, batch["frames"].astype(adt), cfg=cfg, opts=opts)
        x, caches = encdec.decode_stack(
            params, batch["tokens"], cfg=cfg, opts=opts, mode="prefill",
            enc_out=enc_out, prefill_capacity=max_len,
        )
        logits = logits_from_embed(params["embed"], x[:, -1:, :])[:, 0, :]
        return logits, caches

    def decode_fn(params, tokens, caches, cache_length):
        x, caches = encdec.decode_stack(
            params, tokens, cfg=cfg, opts=opts, mode="decode", caches=caches,
            cache_length=cache_length,
        )
        return logits_from_embed(params["embed"], x), caches

    def input_specs(shape: ShapeConfig):
        b = shape.global_batch
        i32 = jnp.int32
        frames = jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model), adt)
        if shape.kind == "train":
            return {
                "frames": frames,
                "tokens": jax.ShapeDtypeStruct((b, shape.seq_len), i32),
                "labels": jax.ShapeDtypeStruct((b, shape.seq_len), i32),
            }
        if shape.kind == "prefill":
            return {
                "frames": frames,
                "tokens": jax.ShapeDtypeStruct((b, shape.seq_len), i32),
            }
        return {
            "tokens": jax.ShapeDtypeStruct((b, 1), i32),
            "cache_length": jax.ShapeDtypeStruct((), i32),
        }

    def cache_specs(shape: ShapeConfig):
        return encdec.encdec_cache_specs(cfg, shape.global_batch, shape.seq_len, adt)

    return Model(cfg, opts, init, loss_fn, prefill_fn, decode_fn, input_specs,
                 cache_specs)
