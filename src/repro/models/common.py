"""Cross-cutting model options and the parallelism handle models receive."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """Version-tolerant ``shard_map`` (same rationale as the
    ``repro.kernels.CompilerParams`` alias): newer jax exposes
    ``jax.shard_map`` with the ``check_vma`` spelling, older jax only has
    ``jax.experimental.shard_map.shard_map`` with ``check_rep`` — and some
    releases in between export the top-level name while still spelling the
    kwarg ``check_rep``, so the accepted kwarg is detected from the
    signature rather than inferred from where the function lives.
    """
    import inspect

    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    try:
        has_vma = "check_vma" in inspect.signature(sm).parameters
    except (TypeError, ValueError):  # C-accelerated / exotic wrappers
        has_vma = hasattr(jax, "shard_map")
    kw = {"check_vma": check_vma} if has_vma else {"check_rep": check_vma}
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def use_mesh(mesh):
    """Version-tolerant ambient-mesh context: newer jax spells it
    ``jax.set_mesh(mesh)``; on older jax the ``Mesh`` object itself is the
    context manager."""
    set_mesh = getattr(jax, "set_mesh", None)
    return set_mesh(mesh) if set_mesh is not None else mesh


@dataclass(frozen=True)
class ParallelConfig:
    """Mesh handle threaded into model code that needs explicit collectives
    (shard_map MoE).  ``data_axes`` may span ("pod", "data") on the multi-pod
    mesh; ``model_axis`` is the tensor-parallel axis."""

    mesh: Any  # jax.sharding.Mesh (unhashable; never a jit static arg)
    data_axes: tuple[str, ...] = ("data",)
    model_axis: str = "model"


@dataclass(frozen=True)
class ModelOptions:
    """How to execute a model — orthogonal to *what* the model is (cfg)."""

    attn_impl: str = "auto"  # ops.attention impl: auto | ref | pallas | interpret
    mixer_impl: str = "auto"  # ops.ssd / ops.rglru impl
    moe_impl: str = "dense"  # dense | ragged | ragged_local
    remat: str = "full"  # full | none (activation checkpointing per block)
    activation_dtype: str = "bfloat16"
    parallel: ParallelConfig | None = None
    # Sequence parallelism at block boundaries: activations (and hence the
    # per-layer tensors remat saves for backward) are sharded over the model
    # axis on the seq dim.  Cuts saved-activation memory by the TP degree at
    # the cost of boundary all-gathers where attention needs the full seq.
    seq_shard: bool = False


def constrain_seq(x, parallel: ParallelConfig | None):
    """Shard [B, S, ...] activations: batch over data axes, seq over model."""
    if parallel is None or x.ndim < 2:
        return x
    b, s = x.shape[0], x.shape[1]
    axes = parallel.data_axes
    nb = 1
    for a in axes:
        nb *= parallel.mesh.shape[a]
    nm = parallel.mesh.shape[parallel.model_axis]
    batch_part = (axes if len(axes) > 1 else axes[0]) if (nb > 1 and b % nb == 0) else None
    seq_part = parallel.model_axis if (nm > 1 and s % nm == 0) else None
    spec = PartitionSpec(batch_part, seq_part, *(None,) * (x.ndim - 2))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(parallel.mesh, spec)
    )


def constrain_batch(x, parallel: ParallelConfig | None):
    """Pin an activation's leading (batch) dim to the data axes.  GSPMD
    propagation occasionally drops batch sharding across gathers/reshapes
    (observed: the embedding gather) — one constraint per block boundary
    keeps activations batch-sharded everywhere without over-constraining."""
    if parallel is None:
        return x
    b = x.shape[0]
    axes = parallel.data_axes
    n = 1
    for a in axes:
        n *= parallel.mesh.shape[a]
    if n <= 1 or b % n:
        return x
    spec = PartitionSpec(axes if len(axes) > 1 else axes[0], *(None,) * (x.ndim - 1))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(parallel.mesh, spec)
    )
