"""InternVL-style VLM support: the vision tower is a STUB per the grid spec.

``input_specs`` hands the LM backbone precomputed patch embeddings
[B, n_patches, d_model] (what InternViT + the MLP projector would emit);
they replace the first ``n_patches`` token embeddings of the sequence, and
the LM loss is masked over those positions.  Everything downstream (the
InternLM2-flavoured GQA decoder) is the real, shared transformer stack.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def splice_patches(token_embeds: jax.Array, patch_embeds: jax.Array) -> jax.Array:
    """Replace the first P positions of the embedded sequence with the
    (stubbed) vision embeddings."""
    p = patch_embeds.shape[1]
    return jnp.concatenate(
        [patch_embeds.astype(token_embeds.dtype), token_embeds[:, p:]], axis=1
    )


def vlm_loss_mask(cfg, batch_tokens: jax.Array) -> jax.Array:
    """Mask out the patch positions: no next-token loss on image slots."""
    b, s = batch_tokens.shape
    pos = jnp.arange(s)[None, :]
    return (pos >= cfg.n_patches).astype(jnp.float32)


def patch_embed_spec(cfg, batch: int, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((batch, cfg.n_patches, cfg.d_model), dtype)
