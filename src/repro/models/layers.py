"""Shared neural-net primitives: norms, RoPE, MLPs, init helpers.

Everything is a pure function over explicit parameter dicts — no module
framework.  Parameter trees use stacked-layer leading dims so the decoder
stacks scan over layers (small HLO, fast compiles, remat-friendly).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------- init utils
def uniform_scale_init(rng, shape, dtype=jnp.float32, scale=None):
    """LeCun-ish uniform init: +-sqrt(3 / fan_in) (fan_in = shape[-2] or [0])."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[0]
    scale = (3.0 / max(fan_in, 1)) ** 0.5 if scale is None else scale
    return jax.random.uniform(rng, shape, dtype, -1.0, 1.0) * scale


def split_tree(rng, n):
    return list(jax.random.split(rng, n))


# --------------------------------------------------------------------- norms
def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + 0.0 * eps) * w.astype(jnp.float32)
    return out.astype(x.dtype)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32) + b.astype(
        jnp.float32
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------- RoPE
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x [..., S, H, D] (D even), positions [..., S]."""
    d = x.shape[-1]
    freqs = theta ** (-jnp.arange(0, d, 2, dtype=jnp.float32) / d)  # [D/2]
    angles = positions.astype(jnp.float32)[..., None] * freqs  # [..., S, D/2]
    cos = jnp.cos(angles)[..., None, :]  # [..., S, 1, D/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings [n, d]."""
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-dim * (jnp.log(10000.0) / max(d // 2 - 1, 1)))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------- MLPs
def swiglu_init(rng, d: int, f: int, dtype):
    r1, r2, r3 = split_tree(rng, 3)
    return {
        "gate": uniform_scale_init(r1, (d, f), dtype),
        "up": uniform_scale_init(r2, (d, f), dtype),
        "down": uniform_scale_init(r3, (f, d), dtype),
    }


def swiglu(p, x: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, p["gate"].astype(x.dtype))
    u = jnp.einsum("...d,df->...f", x, p["up"].astype(x.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, p["down"].astype(x.dtype))


def gelu_mlp_init(rng, d: int, f: int, dtype):
    r1, r2 = split_tree(rng, 2)
    return {
        "w1": uniform_scale_init(r1, (d, f), dtype),
        "b1": jnp.zeros((f,), dtype),
        "w2": uniform_scale_init(r2, (f, d), dtype),
        "b2": jnp.zeros((d,), dtype),
    }


def gelu_mlp(p, x: jax.Array) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, p["w1"].astype(x.dtype)) + p["b1"].astype(x.dtype)
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, p["w2"].astype(x.dtype)) + p["b2"].astype(
        x.dtype
    )


# ----------------------------------------------------------- embedding/logits
def embed_init(rng, vocab: int, d: int, dtype):
    return uniform_scale_init(rng, (vocab, d), dtype, scale=0.02)


def embed_lookup(table: jax.Array, tokens: jax.Array, dtype) -> jax.Array:
    # Gather (0 FLOPs).  With a vocab-sharded table GSPMD lowers this to a
    # local gather + mask + all-reduce over the vocab axis — cheaper than the
    # one-hot-matmul alternative, whose (tokens x vocab) one-hot costs the
    # same FLOPs as the output projection.
    return jnp.take(table, tokens, axis=0).astype(dtype)


def logits_from_embed(table: jax.Array, x: jax.Array) -> jax.Array:
    return jnp.einsum("...d,vd->...v", x, table.astype(x.dtype))
