"""RecurrentGemma recurrent block: parallel GeLU branch x (conv + RG-LRU)
branch, merged and projected back to d_model.  Gates are per-channel
(diagonal) — the simplest member of Griffin's block-diagonal gate family.
Decode carries a constant (conv window, recurrent h) cache.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.models.layers import split_tree, uniform_scale_init
from repro.models.ssm import _causal_conv

RG_CONV = 4


def rg_init(rng, cfg, dtype):
    d, lw = cfg.d_model, cfg.lru_width or cfg.d_model
    r1, r2, r3, r4, r5 = split_tree(rng, 5)
    return {
        "w_rec": uniform_scale_init(r1, (d, lw), dtype),
        "w_gelu": uniform_scale_init(r2, (d, lw), dtype),
        "w_out": uniform_scale_init(r3, (lw, d), dtype),
        "conv_w": uniform_scale_init(r4, (RG_CONV, lw), dtype),
        "conv_b": jnp.zeros((lw,), dtype),
        "wgx": jnp.ones((lw,), dtype),
        "bgx": jnp.zeros((lw,), dtype),
        "wga": jnp.ones((lw,), dtype),
        "bga": jnp.zeros((lw,), dtype),
        # softplus(a_param) ~ U[...] so decay a^c spans (0.9, 0.999)-ish
        "a_param": jnp.asarray(
            jax.random.uniform(r5, (lw,), jnp.float32, -2.0, 1.0), dtype
        ),
    }


def _gates(p, rec, dtype):
    gate_x = rec * p["wgx"].astype(dtype) + p["bgx"].astype(dtype)
    gate_a = rec * p["wga"].astype(dtype) + p["bga"].astype(dtype)
    return gate_x, gate_a


def rg_apply(p, x, *, cfg, impl="auto", cache=None, return_cache=False):
    """x [B,S,D].  Cache: {"conv": [B, K-1, lw], "h": [B, lw] fp32,
    "length": i32}."""
    B, S, D = x.shape
    rec_in = jnp.einsum("bsd,dw->bsw", x, p["w_rec"].astype(x.dtype))
    gel = jax.nn.gelu(
        jnp.einsum("bsd,dw->bsw", x, p["w_gelu"].astype(x.dtype)).astype(jnp.float32)
    ).astype(x.dtype)

    if cache is None:
        conv_tail = rec_in[:, -(RG_CONV - 1) :, :] if return_cache else None
        rec = _causal_conv(rec_in, p["conv_w"], p["conv_b"])
        gate_x, gate_a = _gates(p, rec, x.dtype)
        if return_cache:
            h, h_last = ops.rglru(
                rec, gate_x, gate_a, p["a_param"], impl=impl, return_state=True
            )
        else:
            h = ops.rglru(rec, gate_x, gate_a, p["a_param"], impl=impl)
        out = jnp.einsum("bsw,wd->bsd", h * gel, p["w_out"].astype(x.dtype))
        if return_cache:
            pad = RG_CONV - 1 - conv_tail.shape[1]
            if pad > 0:
                conv_tail = jnp.pad(conv_tail, ((0, 0), (pad, 0), (0, 0)))
            cache = {"conv": conv_tail, "h": h_last.astype(jnp.float32)}
            return out, cache
        return out

    # ---- decode: S == 1 ----
    conv_win = jnp.concatenate([cache["conv"], rec_in], axis=1)  # [B, K, lw]
    rec = jnp.einsum("bkc,kc->bc", conv_win, p["conv_w"].astype(x.dtype))
    rec = (rec + p["conv_b"].astype(x.dtype))[:, None, :]  # [B,1,lw]
    gate_x, gate_a = _gates(p, rec, x.dtype)
    h, h_last = ref.rglru(
        rec, gate_x, gate_a, p["a_param"], h0=cache["h"], return_state=True
    )
    out = jnp.einsum("bsw,wd->bsd", h * gel, p["w_out"].astype(x.dtype))
    new_cache = {"conv": conv_win[:, 1:, :], "h": h_last}
    return (out, new_cache) if return_cache else out


def rg_cache_shape(cfg, batch: int, dtype):
    lw = cfg.lru_width or cfg.d_model
    return {
        "conv": jax.ShapeDtypeStruct((batch, RG_CONV - 1, lw), dtype),
        "h": jax.ShapeDtypeStruct((batch, lw), jnp.float32),
    }
