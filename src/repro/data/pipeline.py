"""Deterministic, host-sharded synthetic data pipeline.

Every batch is a pure function of (seed, host_id, n_hosts, step) — no
filesystem, no global coordination, reproducible across restarts (exactly
what the fault-tolerance loop needs: replaying step ``s`` after recovery
yields bit-identical data on every host).

The token stream is an affine Markov chain ``x[t+1] = (a * x[t] + c) % V``
with per-sequence random starts: fully learnable structure, so smoke-scale
training visibly reduces loss (unlike iid-uniform tokens whose optimal loss
is log V).  Frontend stubs (vlm patches, audio frames) are seeded normals.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    chain_a: int = 31
    chain_c: int = 7


class ShardedSyntheticStream:
    """Yields the host-local slice of each global batch."""

    def __init__(self, cfg: DataConfig, *, host_id: int = 0, n_hosts: int = 1,
                 family: str = "dense", model_cfg=None):
        assert cfg.global_batch % n_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.local_batch = cfg.global_batch // n_hosts
        self.family = family
        self.model_cfg = model_cfg

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed, self.host_id, step)
        )  # independent per (seed, host, step)
        starts = rng.integers(0, cfg.vocab_size, size=(self.local_batch, 1))
        # x[t] = a^t x0 + c (a^t - 1)/(a - 1) mod V, computed iteratively.
        seq = np.empty((self.local_batch, cfg.seq_len + 1), np.int64)
        seq[:, 0] = starts[:, 0]
        for t in range(cfg.seq_len):
            seq[:, t + 1] = (cfg.chain_a * seq[:, t] + cfg.chain_c) % cfg.vocab_size
        out = {
            "tokens": seq[:, :-1].astype(np.int32),
            "labels": seq[:, 1:].astype(np.int32),
        }
        mc = self.model_cfg
        if self.family == "vlm" and mc is not None:
            out["patch_embeds"] = rng.standard_normal(
                (self.local_batch, mc.n_patches, mc.d_model), np.float32
            ) * 0.02
        if self.family == "audio" and mc is not None:
            out["frames"] = rng.standard_normal(
                (self.local_batch, mc.encoder_seq, mc.d_model), np.float32
            ) * 0.02
        return out

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def make_stream_for(model_cfg, seq_len: int, global_batch: int, *, seed: int = 0,
                    host_id: int = 0, n_hosts: int = 1) -> ShardedSyntheticStream:
    return ShardedSyntheticStream(
        DataConfig(model_cfg.vocab_size, seq_len, global_batch, seed=seed),
        host_id=host_id,
        n_hosts=n_hosts,
        family=model_cfg.family,
        model_cfg=model_cfg,
    )
