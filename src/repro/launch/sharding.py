"""Logical-axis sharding rules (MaxText-style) with divisibility fallback.

Every parameter / batch / cache leaf gets a tuple of LOGICAL axis names
matched by path-regex rules; each logical axis maps to an ordered list of
candidate mesh axes.  Assignment walks the dims in order, taking the first
candidate whose size divides the dim and which is not already used by an
earlier dim of the same leaf (a mesh axis may appear at most once per spec);
dims with no viable candidate stay unsharded.  This single mechanism absorbs
every divisibility quirk in the assigned grid (40 q-heads vs 16-way model,
8-expert mixtral vs 16-way data, vocab 51865/151655 not divisible by 16,
batch-1 long-context decode, ...) without per-arch special cases.

Baseline layout (hillclimbs adjust per EXPERIMENTS.md §Perf):
  batch       -> ("pod", "data")      activations follow the batch
  embed dim   -> "data"               FSDP: params+moments sharded over data
  ff/heads/
  vocab dims  -> "model"              tensor parallel
  experts     -> "data" then "model"  EP-style memory sharding for 128-expert
                                      qwen3; mixtral (8 experts) falls back
  kv cache    -> batch over data, then head_dim over model (kv_heads rarely
                 divide 16); ring inserts stay shard-local
"""

from __future__ import annotations

import re
from collections.abc import Sequence

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# logical axis -> ordered mesh-axis candidates
LOGICAL_CANDIDATES = {
    "layers": (),
    "batch": (("pod", "data"),),  # joint axes tuple = shard over both
    "batch_data": (("data",),),
    "embed": ("data",),
    "ff": ("model",),
    "heads": ("model",),
    "vocab": ("model",),
    "experts": ("data", "model"),
    "seq": (),
    "cache_seq": (),
    "kv_heads": ("model",),
    "head_dim": ("model",),
    "conv": (),
    "state": ("model",),
    "lru": ("model",),
    "none": (),
}

# (path regex, logical axes per dim).  First match wins; leaves are matched
# on their '/'-joined tree path.  Missing rule -> fully replicated.
PARAM_RULES: tuple[tuple[str, tuple[str, ...]], ...] = (
    # embeddings / output head
    (r"(^|/)embed$", ("vocab", "embed")),
    (r"(^|/)lm_head$", ("vocab", "embed")),
    # attention (stacked under blocks: leading "layers" dim)
    (r"mix/w[qkv]$", ("layers", "embed", "heads")),
    (r"mix/wo$", ("layers", "heads", "embed")),
    (r"mix/b[qkv]$", ("layers", "heads")),
    (r"(self|cross)_attn/w[qkv]$", ("layers", "embed", "heads")),
    (r"(self|cross)_attn/wo$", ("layers", "heads", "embed")),
    (r"(self|cross)_attn/b[qkv]$", ("layers", "heads")),
    # dense mlp
    (r"mlp/(gate|up|w1)$", ("layers", "embed", "ff")),
    (r"mlp/(down|w2)$", ("layers", "ff", "embed")),
    (r"mlp/b1$", ("layers", "ff")),
    (r"mlp/b2$", ("layers", "embed")),
    # moe
    (r"mlp/router$", ("layers", "embed", "none")),
    (r"mlp/(gate|up)$", ("layers", "experts", "embed", "ff")),  # (unreachable, doc)
    (r"mlp/down$", ("layers", "experts", "ff", "embed")),
    # mamba2
    (r"mix/in_proj$", ("layers", "embed", "ff")),
    (r"mix/out_proj$", ("layers", "ff", "embed")),
    (r"mix/conv_w$", ("layers", "conv", "ff")),
    (r"mix/conv_b$", ("layers", "ff")),
    (r"mix/(a_log|d_skip|dt_bias)$", ("layers", "none")),
    (r"mix/gnorm$", ("layers", "ff")),
    # rg-lru
    (r"mix/(w_rec|w_gelu)$", ("layers", "embed", "lru")),
    (r"mix/w_out$", ("layers", "lru", "embed")),
    (r"mix/(wgx|bgx|wga|bga|a_param)$", ("layers", "lru")),
    # norms (stacked or not) stay replicated on the feature dim
    (r"norm", ("layers", "none")),
)

# MoE gate/up need 4 dims; the generic mlp rule above matches dense first.
MOE_RULES: tuple[tuple[str, tuple[str, ...]], ...] = (
    (r"mlp/(gate|up)$", ("layers", "experts", "embed", "ff")),
    (r"mlp/down$", ("layers", "experts", "ff", "embed")),
)

BATCH_RULES: tuple[tuple[str, tuple[str, ...]], ...] = (
    (r"^(tokens|labels)$", ("batch", "seq")),
    (r"^patch_embeds$", ("batch", "seq", "embed")),
    (r"^frames$", ("batch", "seq", "embed")),
    (r"^cache_length$", ()),
)

CACHE_RULES: tuple[tuple[str, tuple[str, ...]], ...] = (
    (r"/(k|v)$", ("layers", "batch", "kv_heads", "cache_seq", "head_dim")),
    (r"/conv$", ("layers", "batch", "conv", "ff")),
    (r"/ssm$", ("layers", "batch", "none", "head_dim", "state")),
    (r"/h$", ("layers", "batch", "lru")),
)


def _axis_size(mesh, axis) -> int:
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def _mesh_axes_of(axis) -> tuple:
    return axis if isinstance(axis, tuple) else (axis,)


def spec_for(shape: Sequence[int], logical: Sequence[str], mesh) -> P:
    """Greedy assignment of mesh axes to dims with divisibility + reuse checks."""
    ndim = len(shape)
    logical = tuple(logical)[:ndim] + ("none",) * max(0, ndim - len(logical))
    used: set = set()
    out = []
    for dim, name in zip(shape, logical, strict=True):
        placed = None
        for cand in LOGICAL_CANDIDATES.get(name, ()):
            axes = _mesh_axes_of(cand)
            if any(a not in mesh.shape for a in axes):
                # candidate references an axis this mesh lacks (e.g. "pod" on
                # the single-pod mesh): use the surviving sub-axes.
                axes = tuple(a for a in axes if a in mesh.shape)
                if not axes:
                    continue
            if used & set(axes):
                continue
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            if size > 1 and dim % size == 0:
                placed = axes if len(axes) > 1 else axes[0]
                used.update(axes)
                break
        out.append(placed)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def _match(path: str, rules) -> tuple[str, ...] | None:
    for pat, logical in rules:
        if re.search(pat, path):
            return logical
    return None


def _tree_specs(tree, mesh, rules, *, moe: bool = False):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    specs = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        logical = None
        if moe and getattr(leaf, "ndim", 0) == 4:
            logical = _match(key, MOE_RULES)
        if logical is None:
            logical = _match(key, rules)
        if logical is None or getattr(leaf, "ndim", 0) == 0:
            specs.append(P())
        else:
            specs.append(spec_for(leaf.shape, logical, mesh))
    return treedef.unflatten(specs)


def param_specs(params, mesh, cfg=None):
    """PartitionSpec pytree for a parameter tree (arrays or SDS)."""
    moe = bool(cfg is not None and cfg.n_experts)
    return _tree_specs(params, mesh, PARAM_RULES, moe=moe)


def opt_state_specs(params, mesh, cfg=None):
    ps = param_specs(params, mesh, cfg)
    return {"m": ps, "v": jax.tree.map(lambda s: s, ps), "step": P()}


def batch_specs(batch, mesh):
    return _tree_specs(batch, mesh, BATCH_RULES)


def cache_specs_tree(caches, mesh):
    return _tree_specs(caches, mesh, CACHE_RULES)


def named(tree_specs, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )
