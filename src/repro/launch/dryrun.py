"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST set XLA_FLAGS before ANY other import (jax locks the device count at
first init) — hence the first two lines.  Smoke tests and benches never
import this module; they see the real single CPU device.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs import (  # noqa: E402
    ARCH_IDS,
    SHAPE_BY_NAME,
    SHAPES,
    cell_applicable,
    get_config,
)
from repro.launch import sharding as sh  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import ModelOptions, build_model  # noqa: E402
from repro.train import TrainConfig, make_train_step  # noqa: E402
from repro.train.optimizer import init_opt_state  # noqa: E402

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one 'dtype[dims]' or a '(t1, t2, ...)' tuple string."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes moved by each collective op kind, parsed from the
    (post-SPMD-partitioning) HLO.  We count each op's OUTPUT shape — for
    all-reduce that equals the payload; for all-gather it is the gathered
    result (ring traffic ~ (n-1)/n of that); a consistent, comparable proxy."""
    out = {k: 0 for k in COLLECTIVE_OPS}
    counts = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # "%name = SHAPE op-name(...)" — find which collective this line is
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", stripped)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        op = op.rstrip("-start")  # async pairs: count the -start only
        if op in COLLECTIVE_OPS:
            out[op] += _shape_bytes(shape_str)
            counts[op] += 1
    # avoid double counting: "-done" ops carry the same shape; the regex above
    # normalizes "-start" but "-done" ops keep their name -> filter them
    return {"bytes": out, "counts": counts}


def build_cell(arch: str, shape_name: str, mesh, *, microbatches: int = 8,
               moe_impl: str = "dense", remat: str = "full",
               attn_impl: str = "ref", mixer_impl: str = "ref",
               cast_bf16: bool = False, seq_shard: bool = False,
               bf16_params: bool = False):
    """Returns (jitted, example_args) for one grid cell."""
    cfg = get_config(arch)
    shape = SHAPE_BY_NAME[shape_name]
    from repro.models.common import ParallelConfig

    parallel = ParallelConfig(
        mesh,
        data_axes=tuple(a for a in mesh.axis_names if a != "model"),
        model_axis="model",
    )
    opts = ModelOptions(
        attn_impl=attn_impl, mixer_impl=mixer_impl, moe_impl=moe_impl,
        remat=remat, activation_dtype="bfloat16", parallel=parallel,
        seq_shard=seq_shard,
    )
    model = build_model(cfg, opts)
    rng = jax.random.PRNGKey(0)
    params_sds = jax.eval_shape(model.init, rng)
    if bf16_params:
        # mixed-precision layout: bf16 stored params + fp32 masters in opt
        params_sds = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
            if (s.dtype == jnp.float32 and len(s.shape) >= 2)
            else s,
            params_sds,
        )
    pspecs = sh.param_specs(params_sds, mesh, cfg)
    batch_sds = model.input_specs(shape)

    if shape.kind == "train":
        # divisibility: microbatches must divide the global batch
        while shape.global_batch % microbatches:
            microbatches -= 1
        tc = TrainConfig(microbatches=microbatches, cast_params_bf16=cast_bf16)
        step = make_train_step(model, tc)
        opt_sds = jax.eval_shape(
            lambda p: init_opt_state(p, keep_master=bf16_params), params_sds
        )
        ospecs = {"m": pspecs, "v": pspecs, "step": P()}
        if bf16_params:
            ospecs["master"] = pspecs
        bspecs = sh.batch_specs(batch_sds, mesh)
        metric_specs = {"loss": P(), "grad_norm": P(), "lr": P()}
        jitted = jax.jit(
            step,
            in_shardings=(sh.named(pspecs, mesh), sh.named(ospecs, mesh),
                          sh.named(bspecs, mesh)),
            out_shardings=(sh.named(pspecs, mesh), sh.named(ospecs, mesh),
                           sh.named(metric_specs, mesh)),
        )
        return jitted, (params_sds, opt_sds, batch_sds)

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            return model.prefill_fn(params, batch)

        bspecs = sh.batch_specs(batch_sds, mesh)
        logits_spec = sh.spec_for(
            (shape.global_batch, cfg.vocab_size), ("batch", "vocab"), mesh
        )
        cache_sds = jax.eval_shape(
            lambda p, b: model.prefill_fn(p, b)[1], params_sds, batch_sds
        )
        cspecs = sh.cache_specs_tree(cache_sds, mesh)
        jitted = jax.jit(
            prefill_step,
            in_shardings=(sh.named(pspecs, mesh), sh.named(bspecs, mesh)),
            out_shardings=(
                jax.sharding.NamedSharding(mesh, logits_spec),
                sh.named(cspecs, mesh),
            ),
        )
        return jitted, (params_sds, batch_sds)

    # decode: one token against a seq_len cache
    def decode_step(params, tokens, caches, cache_length):
        return model.decode_fn(params, tokens, caches, cache_length)

    cache_sds = model.cache_specs(shape)
    cspecs = sh.cache_specs_tree(cache_sds, mesh)
    tok_sds = batch_sds["tokens"]
    len_sds = batch_sds["cache_length"]
    tok_spec = sh.spec_for(tok_sds.shape, ("batch", "seq"), mesh)
    logits_spec = sh.spec_for(
        (shape.global_batch, 1, cfg.vocab_size), ("batch", "seq", "vocab"), mesh
    )
    jitted = jax.jit(
        decode_step,
        in_shardings=(
            sh.named(pspecs, mesh),
            jax.sharding.NamedSharding(mesh, tok_spec),
            sh.named(cspecs, mesh),
            jax.sharding.NamedSharding(mesh, P()),
        ),
        out_shardings=(
            jax.sharding.NamedSharding(mesh, logits_spec),
            sh.named(cspecs, mesh),
        ),
    )
    return jitted, (params_sds, tok_sds, cache_sds, len_sds)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, out_dir: str,
             microbatches: int = 8, moe_impl: str = "dense",
             remat: str = "full", attn_impl: str = "ref",
             mixer_impl: str = "ref", cast_bf16: bool = False,
             seq_shard: bool = False, bf16_params: bool = False,
             tag: str = "baseline") -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}__{tag}"
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(out_dir, cell_id + ".json")
    if os.path.exists(out_path):
        with open(out_path) as f:
            return json.load(f)

    cfg = get_config(arch)
    shape = SHAPE_BY_NAME[shape_name]
    ok, why = cell_applicable(cfg, shape)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag,
        "microbatches": microbatches, "moe_impl": moe_impl, "remat": remat,
        "attn_impl": attn_impl, "mixer_impl": mixer_impl,
        "cast_bf16": cast_bf16, "seq_shard": seq_shard,
        "bf16_params": bf16_params,
    }
    if not ok:
        rec.update(status="skipped", reason=why)
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=1)
        return rec

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        with mesh:
            jitted, args = build_cell(
                arch, shape_name, mesh, microbatches=microbatches,
                moe_impl=moe_impl, remat=remat, attn_impl=attn_impl,
                mixer_impl=mixer_impl, cast_bf16=cast_bf16,
                seq_shard=seq_shard, bf16_params=bf16_params,
            )
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        cost = {
            k: v
            for k, v in dict(compiled.cost_analysis() or {}).items()
            if k in ("flops", "bytes accessed", "transcendentals", "optimal_seconds")
        }
        try:
            mem = compiled.memory_analysis()
            mem_rec = {
                k: int(getattr(mem, k))
                for k in (
                    "argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "generated_code_size_in_bytes",
                    "alias_size_in_bytes",
                )
                if hasattr(mem, k)
            }
        except Exception as e:  # CPU backend may not implement it
            mem_rec = {"error": str(e)}

        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        from repro.launch.hlo_analysis import analyze_hlo

        try:
            deep = analyze_hlo(hlo)
        except Exception as e:
            deep = {"error": f"{type(e).__name__}: {e}"}
        import gzip

        with gzip.open(out_path.replace(".json", ".hlo.txt.gz"), "wt") as f:
            f.write(hlo)
        rec.update(
            status="ok",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            n_devices=len(mesh.devices.flat),
            cost={k: float(v) for k, v in cost.items()
                  if isinstance(v, (int, float))},
            memory=mem_rec,
            collectives=coll,
            hlo_analysis=deep,
            hlo_lines=hlo.count("\n"),
        )
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--moe-impl", default="dense")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--attn-impl", default="ref")
    ap.add_argument("--mixer-impl", default="ref")
    ap.add_argument("--cast-bf16", action="store_true")
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--bf16-params", action="store_true")
    ap.add_argument("--tag", default="baseline")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    shapes = [s.name for s in SHAPES] if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    for arch in archs:
        for shape_name in shapes:
            for multi_pod in meshes:
                rec = run_cell(
                    arch, shape_name, multi_pod=multi_pod, out_dir=args.out,
                    microbatches=args.microbatches, moe_impl=args.moe_impl,
                    remat=args.remat, attn_impl=args.attn_impl,
                    mixer_impl=args.mixer_impl, cast_bf16=args.cast_bf16,
                    seq_shard=args.seq_shard, bf16_params=args.bf16_params,
                    tag=args.tag,
                )
                status = rec["status"]
                extra = ""
                if status == "ok":
                    extra = (f" flops/dev={rec['cost'].get('flops', 0):.3e}"
                             f" compile={rec['compile_s']}s")
                elif status == "error":
                    extra = " " + rec["error"][:120]
                print(f"[{status:7s}] {arch} x {shape_name} x "
                      f"{'multi' if multi_pod else 'single'}{extra}", flush=True)


if __name__ == "__main__":
    main()
