"""Chrome trace-event (Perfetto) export of engine schedules.

    python -m repro.launch.trace_export --out schedule_trace.json

Converts a recorded engine trajectory (``engine.run(record=True)``) into
the Chrome trace-event JSON format that https://ui.perfetto.dev and
``chrome://tracing`` load directly: one track (tid) per job carrying its
allocation Gantt — consecutive epochs with the same allocation merge into
one ``ph="X"`` slice — with an instant marker at each completion, plus
counter tracks (``ph="C"``) for system efficiency, utilization and queue
length.  Counters come from a ``core/telemetry.py`` series read-out when
one is supplied, else they are derived from the trace itself.

Engine time is abstract (units of work); ``time_scale`` maps it onto the
microsecond ``ts`` axis the format requires (default 1e6: one unit of
simulated time displays as one second).
"""

from __future__ import annotations

import argparse
import json
import math

import numpy as np

PID = 0  # one process == one simulated cluster
COUNTER_METRICS = ("efficiency", "utilization", "queue", "entropy", "p_hat_err")


# ------------------------------------------------------------- event builders
def _meta(name: str, args: dict, tid: int | None = None) -> dict:
    ev = {"ph": "M", "pid": PID, "ts": 0, "name": name, "args": args}
    if tid is not None:
        ev["tid"] = tid
    return ev


def schedule_to_events(
    result,
    *,
    alloc_unit: float = 1.0,
    p: float | None = None,
    telemetry_series: dict | None = None,
    time_scale: float = 1e6,
    job_labels: list[str] | None = None,
    process_name: str = "heSRPT schedule",
) -> list[dict]:
    """Convert an ``EngineResult`` with a recorded trace to trace events.

    ``alloc_unit`` is what "the whole cluster" means in the trace's
    allocation numbers — ``n_chips`` for quantized runs, 1.0 for
    continuous theta fractions; slice names and the utilization counter
    are normalized by it.  ``p`` enables the derived efficiency counter
    (sum of (alloc/unit)^p); a ``telemetry_series`` dict (the
    ``mode="series"`` probe read-out, keys ``t``/``dt``/metric names)
    takes precedence for any metric it carries.  ``job_labels`` names the
    per-job tracks (input order); default ``job 3 (x0=5.2)``.
    """
    if result.trace is None:
        raise ValueError("schedule_to_events needs engine.run(record=True)")
    trace = result.trace
    alloc = np.asarray(trace.alloc, np.float64)  # [E, M] arrival-sorted
    times = np.asarray(trace.times, np.float64)  # [E] epoch starts
    sizes = np.asarray(trace.sizes, np.float64)  # [E, M] at epoch start
    order = np.asarray(result.order)
    done_in = np.asarray(result.completion_times, np.float64)  # input order
    done = done_in[order]  # arrival-sorted, matching trace columns
    E, M = alloc.shape

    finite = done[np.isfinite(done)]
    t_end = float(max(times.max(initial=0.0), finite.max(initial=0.0)))
    starts = times
    ends = np.append(times[1:], t_end)

    if job_labels is None:
        job_labels = [
            f"job {int(order[j])} (x0={sizes[0, j]:g})" for j in range(M)
        ]
    else:
        job_labels = [job_labels[int(order[j])] for j in range(M)]

    events: list[dict] = [
        _meta("process_name", {"name": process_name}),
        _meta("process_sort_index", {"sort_index": 0}),
    ]
    for j in range(M):
        events.append(_meta("thread_name", {"name": job_labels[j]}, tid=j))
        events.append(_meta("thread_sort_index", {"sort_index": j}, tid=j))

    # ------------------------------------------------ per-job Gantt slices
    for j in range(M):
        e = 0
        while e < E:
            a = alloc[e, j]
            if a <= 0 or ends[e] <= starts[e]:
                e += 1
                continue
            # merge the run of consecutive epochs holding this allocation
            k = e
            while (
                k + 1 < E
                and alloc[k + 1, j] == a
                and ends[k] > starts[k]  # no-op epochs end a run
            ):
                k += 1
            t0, t1 = starts[e], ends[k]
            if t1 > t0:
                share = a / alloc_unit
                name = (
                    f"{int(round(a))} chips" if alloc_unit != 1.0
                    else f"theta={share:.3f}"
                )
                events.append({
                    "ph": "X", "pid": PID, "tid": j, "name": name,
                    "cat": "alloc",
                    "ts": t0 * time_scale, "dur": (t1 - t0) * time_scale,
                    "args": {
                        "alloc": float(a),
                        "share": float(share),
                        "remaining": float(sizes[e, j]),
                    },
                })
            e = k + 1
        if np.isfinite(done[j]):
            events.append({
                "ph": "i", "pid": PID, "tid": j, "name": "complete",
                "cat": "completion", "s": "t",
                "ts": float(done[j]) * time_scale,
                "args": {"t": float(done[j])},
            })

    # -------------------------------------------------------- counter tracks
    # Derived-from-trace values; a telemetry series overrides per metric.
    live = ends > starts
    derived = {
        "utilization": alloc.sum(axis=1) / alloc_unit,
        "queue": (alloc > 0).sum(axis=1).astype(np.float64),
    }
    if p is not None:
        share = alloc / alloc_unit
        derived["efficiency"] = np.where(share > 0, share**p, 0.0).sum(axis=1)
    series_t = starts
    counters: dict[str, np.ndarray] = dict(derived)
    if telemetry_series is not None:
        tel_live = np.asarray(telemetry_series["dt"], np.float64) > 0
        for m in COUNTER_METRICS:
            if m in telemetry_series:
                counters[m] = np.asarray(telemetry_series[m], np.float64)
        # the probe ran inside the same scan: epoch axes line up
        if len(next(iter(counters.values()))) == len(tel_live):
            live = tel_live
            series_t = np.asarray(telemetry_series["t"], np.float64)
    for m in COUNTER_METRICS:
        if m not in counters:
            continue
        vals = counters[m]
        for e in range(E):
            if not live[e]:
                continue
            events.append({
                "ph": "C", "pid": PID, "name": m, "cat": "telemetry",
                "ts": float(series_t[e]) * time_scale,
                "args": {m: float(vals[e])},
            })
        # flat-line the counter out to the end of the schedule
        events.append({
            "ph": "C", "pid": PID, "name": m, "cat": "telemetry",
            "ts": t_end * time_scale, "args": {m: 0.0},
        })
    return events


# ---------------------------------------------------------------- validation
_REQUIRED: dict[str, tuple[str, ...]] = {
    "X": ("name", "tid", "dur"),
    "i": ("name", "tid", "s"),
    "C": ("name", "args"),
    "M": ("name", "args"),
}


def validate_trace_events(events) -> None:
    """Schema-check a trace-event list; raises ``ValueError`` on the first
    malformed event.  Covers what Perfetto/catapult require to load: the
    per-phase mandatory keys, finite numeric timestamps, non-negative
    durations, and numeric counter values."""
    if not isinstance(events, list) or not events:
        raise ValueError("trace must be a non-empty list of event dicts")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i}: not a dict")
        ph = ev.get("ph")
        if ph not in _REQUIRED:
            raise ValueError(f"event {i}: unsupported ph {ph!r}")
        missing = [k for k in ("pid", "ts", *_REQUIRED[ph]) if k not in ev]
        if missing:
            raise ValueError(f"event {i} (ph={ph}): missing keys {missing}")
        ts = ev["ts"]
        if not isinstance(ts, int | float) or not math.isfinite(ts) or ts < 0:
            raise ValueError(f"event {i}: bad ts {ts!r}")
        if ph == "X":
            dur = ev["dur"]
            if not isinstance(dur, int | float) or not (dur >= 0):
                raise ValueError(f"event {i}: bad dur {dur!r}")
        if ph == "C":
            args = ev["args"]
            if not args or not all(
                isinstance(v, int | float) and math.isfinite(v)
                for v in args.values()
            ):
                raise ValueError(f"event {i}: counter args must be numbers")
    json.dumps(events)  # must be serializable as-is


def write_trace(events: list[dict], path: str) -> None:
    """Validate and write the Perfetto-loadable JSON object form."""
    validate_trace_events(events)
    with open(path, "w") as f:
        json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, f, indent=1)
        f.write("\n")


# ----------------------------------------------------------------------- CLI
def export_sample(
    *,
    policy: str = "hesrpt",
    scenario: str = "poisson",
    n_jobs: int = 12,
    rate: float = 2.0,
    p: float = 0.5,
    n_chips: int | None = None,
    seed: int = 0,
) -> list[dict]:
    """Draw one scenario, run it recorded + probed, return trace events."""
    import jax

    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp

    from repro.core import engine
    from repro.core.policies import make_policy
    from repro.core.scenarios import make_scenario
    from repro.core.telemetry import DEFAULT_METRICS, make_probe

    scn = make_scenario(scenario, p=p)(jax.random.key(seed), n_jobs, rate)
    pol = make_policy(policy)
    dtype = jnp.result_type(float)
    if n_chips is not None:
        rule = engine.quantized_rule(pol, n_chips, dtype=dtype)
    else:
        rule = engine.continuous_rule(pol, 1.0, dtype=dtype)
    unit = float(n_chips) if n_chips is not None else 1.0
    probe = make_probe(
        DEFAULT_METRICS, mode="series", alloc_unit=unit, dtype=dtype
    )
    res = engine.run(
        scn.x0, scn.arrival_times, p, rule, record=True, telemetry=probe
    )
    return schedule_to_events(
        res,
        alloc_unit=unit,
        p=p,
        telemetry_series=res.telemetry.series,
        process_name=f"{policy} / {scenario} (M={n_jobs}, p={p})",
    )


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="export an engine schedule as Perfetto trace JSON"
    )
    ap.add_argument("--out", default="schedule_trace.json")
    ap.add_argument("--policy", default="hesrpt")
    ap.add_argument("--scenario", default="poisson")
    ap.add_argument("--jobs", type=int, default=12)
    ap.add_argument("--rate", type=float, default=2.0)
    ap.add_argument("--p", type=float, default=0.5)
    ap.add_argument("--n-chips", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    events = export_sample(
        policy=args.policy, scenario=args.scenario, n_jobs=args.jobs,
        rate=args.rate, p=args.p, n_chips=args.n_chips, seed=args.seed,
    )
    write_trace(events, args.out)
    n_slices = sum(1 for e in events if e["ph"] == "X")
    print(
        f"wrote {args.out}: {len(events)} events ({n_slices} slices) — "
        f"load at https://ui.perfetto.dev"
    )


if __name__ == "__main__":
    main()
