"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax init; smoke tests
see the real single CPU device).

Production topology: TPU v5e pods of 16 x 16 = 256 chips.
  single-pod: (16, 16)    axes ("data", "model")
  multi-pod:  (2, 16, 16) axes ("pod", "data", "model")
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_job_mesh(devices, *, model_parallel: int = 1):
    """Mesh over an explicit device subset — what the heSRPT cluster scheduler
    hands each elastic job.  ``len(devices)`` must be divisible by
    ``model_parallel``."""
    import numpy as np

    n = len(devices)
    assert n % model_parallel == 0, (n, model_parallel)
    arr = np.asarray(devices).reshape(n // model_parallel, model_parallel)
    return jax.sharding.Mesh(arr, ("data", "model"))


def data_axes_of(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis_of(mesh) -> str:
    return "model"
