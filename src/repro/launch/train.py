"""Single-job production training entry point.

    PYTHONPATH=src python -m repro.launch.train --arch phi4-mini-3.8b \
        --steps 200 --seq-len 512 --global-batch 8 --smoke

``--smoke`` runs the reduced config on the local device(s); without it the
full published config is built (sized for the production mesh — on this CPU
container you want --smoke).  The loop wires together every substrate layer:
sharded data pipeline, microbatched train step under pjit, checkpointing,
and fault-tolerant restart.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_config
from repro.data.pipeline import make_stream_for
from repro.launch import sharding as sh
from repro.models import ModelOptions, ParallelConfig, build_model
from repro.train import TrainConfig, make_train_step
from repro.train.ft import FailureInjector, run_with_recovery
from repro.train.optimizer import OptimizerConfig, init_opt_state


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject failures at these steps (FT exercise)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev, 1), ("data", "model"))
    parallel = ParallelConfig(mesh, data_axes=("data",), model_axis="model")
    opts = ModelOptions(
        activation_dtype="float32" if args.smoke else "bfloat16",
        remat="none" if args.smoke else "full",
        parallel=parallel if n_dev > 1 else None,
    )
    model = build_model(cfg, opts)
    params = model.init(jax.random.PRNGKey(0))
    opt_state = init_opt_state(params)

    tc = TrainConfig(
        microbatches=args.microbatches,
        optimizer=OptimizerConfig(lr=args.lr, warmup_steps=10,
                                  total_steps=args.steps),
    )
    step_fn = make_train_step(model, tc)
    if n_dev > 1:
        pspecs = sh.param_specs(params, mesh, cfg)
        ospecs = {"m": pspecs, "v": pspecs, "step": jax.sharding.PartitionSpec()}
        step_fn = jax.jit(
            step_fn,
            in_shardings=(sh.named(pspecs, mesh), sh.named(ospecs, mesh), None),
        )
        params = jax.device_put(params, sh.named(pspecs, mesh))
        opt_state = jax.device_put(opt_state, sh.named(ospecs, mesh))
    else:
        step_fn = jax.jit(step_fn)

    stream = make_stream_for(cfg, args.seq_len, args.global_batch)

    def batches(step):
        return {k: jnp.asarray(v) for k, v in stream.batch(step).items()}

    t0 = time.time()

    def on_metrics(step, metrics):
        if step % args.log_every == 0:
            tps = args.global_batch * args.seq_len * (step + 1) / (time.time() - t0)
            print(
                f"step {step:5d} loss {float(metrics['loss']):.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f} "
                f"lr {float(metrics['lr']):.2e} tok/s {tps:,.0f}",
                flush=True,
            )

    injector = FailureInjector(args.fail_at) if args.fail_at else None
    params, opt_state, history = run_with_recovery(
        step_fn, batches, params, opt_state,
        n_steps=args.steps, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, injector=injector, on_metrics=on_metrics,
    )
    print(f"done: {len(history['loss'])} steps, final loss "
          f"{history['loss'][-1]:.4f}, recoveries {len(history['recoveries'])}")
    return history


if __name__ == "__main__":
    main()
