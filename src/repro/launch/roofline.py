"""Three-term roofline analysis from the dry-run artifacts.

Hardware model (TPU v5e target):
  peak bf16:  197 TFLOP/s per chip
  HBM bw:     819 GB/s per chip
  ICI link:   ~50 GB/s per link

Terms (seconds per step, per chip — the compiled program is the per-device
SPMD program, so per-device totals divide by per-chip rates):
  compute    = HLO_FLOPs(dev)       / 197e12
  memory     = HLO_bytes(dev)       / 819e9
  collective = collective_bytes(dev) / 50e9

HLO_* come from the trip-count-aware analyzer (launch/hlo_analysis.py);
``compiled.cost_analysis()``'s raw numbers are also recorded but undercount
scan bodies (counted once per ``while``).  MODEL_FLOPS uses the paper-
standard 6·N·D (train) / 2·N·D (inference) with N = active params for MoE.
roofline_fraction = useful_compute_time / dominant_term — the score a real
profile would report as "fraction of roofline".
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from dataclasses import dataclass

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def model_flops(cfg, shape) -> float:
    """Useful model FLOPs per step (global, forward+backward for train)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


@dataclass
class CellRoofline:
    arch: str
    shape: str
    mesh: str
    tag: str
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops_global: float
    useful_ratio: float
    roofline_fraction: float
    temp_gb: float | None
    note: str = ""


def analyze_record(rec: dict) -> CellRoofline | None:
    if rec.get("status") != "ok" or "hlo_analysis" not in rec:
        return None
    from repro.configs import SHAPE_BY_NAME, get_config

    cfg = get_config(rec["arch"])
    shape = SHAPE_BY_NAME[rec["shape"]]
    chips = rec["n_devices"]
    h = rec["hlo_analysis"]
    if "error" in h:
        return None
    compute_s = h["flops"] / PEAK_FLOPS
    memory_s = h["bytes"] / HBM_BW
    coll_bytes = sum(h["collective_bytes"].values())
    collective_s = coll_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_global = h["flops"] * chips
    useful_ratio = mf / hlo_global if hlo_global else 0.0
    useful_time = mf / (chips * PEAK_FLOPS)
    frac = useful_time / max(terms.values()) if max(terms.values()) > 0 else 0.0
    temp = rec.get("memory", {}).get("temp_size_in_bytes")
    return CellRoofline(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        tag=rec.get("tag", "baseline"), chips=chips,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=mf, hlo_flops_global=hlo_global,
        useful_ratio=useful_ratio, roofline_fraction=frac,
        temp_gb=(temp / 2**30 if temp is not None else None),
        note=suggest(dominant, rec, useful_ratio),
    )


def suggest(dominant: str, rec: dict, useful_ratio: float) -> str:
    shape = rec["shape"]
    if dominant == "collective":
        return ("cast FSDP weight gathers to bf16 / reduce-scatter grads "
                "instead of all-reduce")
    if dominant == "memory":
        if "decode" in shape or "500k" in shape:
            return "KV/state cache streaming dominates: shard cache wider or quantize KV to int8"
        return "weight/activation traffic dominates: bf16 gathers, remat policy 'dots', fuse more"
    if useful_ratio < 0.5:
        return ("compute-bound but >2x waste vs model FLOPs: cut remat "
                "recompute or MoE dense dispatch")
    return "near compute roofline: overlap remaining collectives with compute"


def load_cells(results_dir: str, tag: str | None = None):
    cells, skips, errors = [], [], []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if tag is not None and rec.get("tag") != tag:
            continue
        if rec.get("status") == "skipped":
            skips.append(rec)
        elif rec.get("status") == "error":
            errors.append(rec)
        else:
            c = analyze_record(rec)
            if c:
                cells.append(c)
    return cells, skips, errors


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:7.2f}s"
    return f"{x*1e3:6.1f}ms"


def table(cells, *, mesh_filter: str | None = None) -> str:
    rows = [
        "| arch | shape | mesh | compute | memory | collective | bottleneck "
        "| MODEL/HLO | roofline frac | fits 16G |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for c in sorted(cells, key=lambda c: (c.arch, c.shape, c.mesh)):
        if mesh_filter and c.mesh != mesh_filter:
            continue
        fits = "?" if c.temp_gb is None else ("y" if c.temp_gb < 16 else f"n ({c.temp_gb:.0f}G)")
        rows.append(
            f"| {c.arch} | {c.shape} | {c.mesh} | {fmt_s(c.compute_s)} "
            f"| {fmt_s(c.memory_s)} | {fmt_s(c.collective_s)} | {c.dominant} "
            f"| {c.useful_ratio:.3f} | {c.roofline_fraction:.3f} | {fits} |"
        )
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results/dryrun")
    ap.add_argument("--tag", default=None)
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()
    cells, skips, errors = load_cells(args.results, args.tag)
    print(table(cells, mesh_filter=args.mesh))
    if skips:
        print("\nSkipped cells:")
        for s in skips:
            print(f"- {s['arch']} x {s['shape']} x {s['mesh']}: {s['reason']}")
    if errors:
        print("\nERRORED cells:")
        for e in errors:
            print(f"- {e['arch']} x {e['shape']} x {e['mesh']}: {e['error'][:100]}")
    print(f"\n{len(cells)} ok, {len(skips)} skipped, {len(errors)} errors")


if __name__ == "__main__":
    main()
