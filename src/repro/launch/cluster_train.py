"""Multi-job heSRPT-scheduled elastic cluster driver (the paper, end-to-end).

    python -m repro.launch.cluster_train --devices 8 --policy hesrpt

Spawns N fake CPU devices (set before jax import via env, hence the launcher
re-execs itself), builds a set of training jobs with known sizes, and lets
the heSRPT scheduler allocate chips, resizing jobs at every departure epoch.
Compares achieved flow time against the paper's closed form and against the
competitor policies.
"""

import os
import sys

if "--_respawned" not in sys.argv and "XLA_FLAGS" not in os.environ:
    n = "8"
    for i, a in enumerate(sys.argv):
        if a == "--devices" and i + 1 < len(sys.argv):
            n = sys.argv[i + 1]
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    os.execv(sys.executable, [sys.executable, "-m", "repro.launch.cluster_train",
                              *sys.argv[1:], "--_respawned"])

import argparse  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import smoke_config  # noqa: E402
from repro.core import hesrpt_total_flowtime  # noqa: E402
from repro.sched import ElasticClusterDriver, ElasticJobConfig  # noqa: E402


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--policy", default="hesrpt")
    ap.add_argument("--p", type=float, default=0.5)
    ap.add_argument("--arch", default="phi4-mini-3.8b")
    ap.add_argument("--sizes", type=int, nargs="*", default=[40, 24, 12, 6])
    ap.add_argument("--ckpt-root", default="/tmp/repro_cluster")
    ap.add_argument("--_respawned", action="store_true")
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch)
    jobs = [
        ElasticJobConfig(f"job{i}", cfg, total_steps=s, p=args.p, seed=i)
        for i, s in enumerate(args.sizes)
    ]
    driver = ElasticClusterDriver(
        jobs, jax.devices(), policy=args.policy, ckpt_root=args.ckpt_root
    )
    res = driver.run()

    x_desc = jnp.asarray(sorted((float(s) for s in args.sizes), reverse=True))
    closed = float(
        hesrpt_total_flowtime(x_desc, args.p, float(args.devices))
    )
    print(f"policy={args.policy} devices={args.devices} p={args.p}")
    print(f"  total flow time (achieved): {res['total_flow_time']:.3f}")
    print(f"  total flow time (heSRPT fluid optimum): {closed:.3f}")
    print(f"  resizes: {res['resizes']}")
    for jid, losses in res["losses"].items():
        print(f"  {jid}: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
              f"({len(losses)} steps)")
    for a in res["allocations"]:
        print(f"  t={a['t']:.2f} alloc={a['alloc']}")
    return res


if __name__ == "__main__":
    main()
