"""Recompute hlo_analysis for every dry-run record from its saved HLO —
lets the cost model evolve without recompiling (analysis-from-artifact)."""

import glob
import gzip
import json
import sys

from repro.launch.hlo_analysis import analyze_hlo


def main(dirs):
    n = 0
    for d in dirs:
        for path in sorted(glob.glob(f"{d}/*.json")):
            with open(path) as f:
                rec = json.load(f)
            if rec.get("status") != "ok":
                continue
            hlo_path = path.replace(".json", ".hlo.txt.gz")
            try:
                with gzip.open(hlo_path, "rt") as f:
                    hlo = f.read()
            except FileNotFoundError:
                continue
            rec["hlo_analysis"] = analyze_hlo(hlo)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            n += 1
    print(f"reanalyzed {n} records")


if __name__ == "__main__":
    main(sys.argv[1:] or ["results/dryrun", "results/hillclimb"])
