"""Fill EXPERIMENTS.md's table markers from the results directories."""

from __future__ import annotations

import sys

from repro.launch.roofline import load_cells, table


def opt_comparison(results_dir: str) -> str:
    base, _, _ = load_cells(results_dir, "baseline")
    opt, _, _ = load_cells(results_dir, "opt")
    base_by = {(c.arch, c.shape, c.mesh): c for c in base}
    rows = [
        "| arch | shape | mesh | dominant term (base→opt) | base s | opt s "
        "| win | frac base→opt | fits base→opt |",
        "|---|---|---|---|---|---|---|---|---|",
    ]

    def fits(x):
        if x.temp_gb is None:
            return "?"
        return "y" if x.temp_gb < 16 else f"n({x.temp_gb:.0f}G)"

    for c in sorted(opt, key=lambda c: (c.arch, c.shape, c.mesh)):
        b = base_by.get((c.arch, c.shape, c.mesh))
        if b is None:
            continue
        b_dom = max(b.compute_s, b.memory_s, b.collective_s)
        o_dom = max(c.compute_s, c.memory_s, c.collective_s)
        win = b_dom / o_dom if o_dom > 0 else float("inf")
        rows.append(
            f"| {c.arch} | {c.shape} | {c.mesh} | {b.dominant}→{c.dominant} "
            f"| {b_dom:.2f} | {o_dom:.2f} | {win:.1f}x "
            f"| {b.roofline_fraction:.3f}→{c.roofline_fraction:.3f} "
            f"| {fits(b)}→{fits(c)} |"
        )
    return "\n".join(rows)


def main(results_dir: str = "results/dryrun", md_path: str = "EXPERIMENTS.md"):
    base_cells, skips, errors = load_cells(results_dir, "baseline")
    baseline_md = (
        "### Single-pod (16x16 = 256 chips)\n\n"
        + table(base_cells, mesh_filter="pod16x16")
        + "\n\n### Multi-pod (2x16x16 = 512 chips)\n\n"
        + table(base_cells, mesh_filter="pod2x16x16")
        + "\n\nSkipped cells (recorded): "
        + "; ".join(sorted({f"{s['arch']} x {s['shape']}" for s in skips}))
        + f"\n\n{len(base_cells)} baseline cells ok, {len(errors)} errors.\n"
    )
    opt_md = opt_comparison(results_dir)

    with open(md_path) as f:
        text = f.read()
    text = text.replace("<!-- BASELINE_TABLES -->", baseline_md)
    text = text.replace("<!-- OPT_TABLES -->", opt_md)
    with open(md_path, "w") as f:
        f.write(text)
    print(f"wrote tables into {md_path}: {len(base_cells)} baseline cells")


if __name__ == "__main__":
    main(*sys.argv[1:])
