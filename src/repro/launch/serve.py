"""Serving entry point: batched prefill + decode loop with KV caches.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m --smoke \
        --batch 4 --prompt-len 64 --gen-len 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.models import ModelOptions, build_model


def generate(model, params, batch, *, gen_len: int, greedy: bool = True,
             rng=None):
    """Prefill on the prompt then decode ``gen_len`` tokens.  Returns
    [B, gen_len] generated ids."""
    prompt_len = batch["tokens"].shape[1]
    logits, caches = model.prefill_fn(params, batch, max_len=prompt_len + gen_len)
    decode = jax.jit(model.decode_fn)
    out = []
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    for i in range(gen_len):
        out.append(tok)
        logits, caches = decode(
            params, tok, caches, jnp.asarray(prompt_len + i, jnp.int32)
        )
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(
        cfg, ModelOptions(activation_dtype="float32", remat="none")
    )
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32
        )
    }
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.n_patches, cfg.d_model)), jnp.float32
        ) * 0.02
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.encoder_seq, cfg.d_model)),
            jnp.float32,
        ) * 0.02

    t0 = time.time()
    ids = generate(model, params, batch, gen_len=args.gen_len)
    dt = time.time() - t0
    print(f"generated {ids.shape} in {dt:.2f}s "
          f"({args.batch * args.gen_len / dt:.1f} tok/s)")
    print("sample:", np.asarray(ids[0][:16]))
    return ids


if __name__ == "__main__":
    main()
