"""Trip-count-aware cost analysis of compiled (post-SPMD) HLO text.

Why: ``compiled.cost_analysis()`` counts each ``while`` body ONCE, but our
stacks are ``lax.scan``s (layers x microbatches) — FLOPs/bytes/collective
traffic are undercounted by the trip product (e.g. 640x for qwen1.5-110b
train: 80 layers x 8 microbatches).  This module parses the per-device HLO,
walks the call graph from ENTRY, and multiplies every while body/cond by its
trip count (recovered from the loop-condition's comparison constant).

Accounting model (per device):
- flops:   dot ops: 2 * prod(output dims) * prod(lhs contracting dims);
           convolution: 2 * prod(output) * prod(kernel non-output dims).
- bytes:   HBM traffic proxy at the fusion boundary: every top-level op in a
           computation contributes (operand bytes + output bytes); control
           ops (tuple/gte/parameter/constant/bitcast) are free.  This mirrors
           the TPU execution model where each fused kernel streams operands
           from HBM and writes results back.
- collectives: per kind, output-shape bytes (x trips inside loops).
           ``*-start`` counted, ``*-done`` skipped.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "s32": 4, "u32": 4,
    "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_ARRAY_RE = re.compile(r"(\w+)\[([\d,]*)\](?:\{[\d,]*\})?")
# "  %name = SHAPE opcode(operands...), attrs" (shape may be a tuple)
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|[\w\[\]{},]+)\s+([\w\-]+)\((.*)$"
)
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.+\{\s*$")


def _array_shapes(shape_str: str) -> list[tuple[str, tuple[int, ...]]]:
    out = []
    for m in _ARRAY_RE.finditer(shape_str):
        dims = tuple(int(d) for d in m.group(2).split(",") if d)
        out.append((m.group(1), dims))
    return out


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _array_shapes(shape_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims:
            n *= d
        total += n * DTYPE_BYTES[dt]
    return total


def _prod(xs) -> int:
    out = 1
    for x in xs:
        out *= x
    return out


@dataclass
class Op:
    name: str
    shape_str: str
    opcode: str
    rest: str  # operand list + attributes (raw tail of the line)


@dataclass
class Computation:
    name: str
    ops: list[Op] = field(default_factory=list)
    shapes: dict[str, str] = field(default_factory=dict)  # op name -> shape str


@dataclass
class Totals:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: dict[str, float] = field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS}
    )
    collective_counts: dict[str, float] = field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS}
    )

    def add(self, other: "Totals", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k in COLLECTIVE_KINDS:
            self.collective_bytes[k] += other.collective_bytes[k] * mult
            self.collective_counts[k] += other.collective_counts[k] * mult


def parse_computations(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry_name = None
    for line in hlo.splitlines():
        hdr = _COMP_HDR_RE.match(line)
        if hdr and ("->" in line):
            cur = Computation(hdr.group(1))
            comps[cur.name] = cur
            if line.lstrip().startswith("ENTRY"):
                entry_name = cur.name
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            op = Op(m.group(1), m.group(2), m.group(3), m.group(4))
            cur.ops.append(op)
            cur.shapes[op.name] = op.shape_str
    if entry_name is not None:
        comps["__entry__"] = comps[entry_name]
    return comps


def _called_comps(rest: str) -> list[str]:
    """computation names referenced via calls=/to_apply=/condition=/body=."""
    out = []
    for key in ("calls=", "to_apply=", "condition=", "body="):
        for m in re.finditer(re.escape(key) + r"%?([\w.\-]+)", rest):
            out.append((key[:-1], m.group(1)))
    return out


def _operand_names(rest: str) -> list[str]:
    """Names inside the top-level parens of 'opcode(...), attrs'."""
    depth, end = 1, len(rest)
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return re.findall(r"%([\w.\-]+)", rest[:end])


def _trip_count(cond: Computation) -> int:
    """Loop conditions compare the induction var against a constant bound."""
    consts: dict[str, int] = {}
    for op in cond.ops:
        if op.opcode == "constant":
            m = re.search(r"constant\((\d+)\)", "constant(" + op.rest)
            if m:
                consts[op.name] = int(m.group(1))
    for op in cond.ops:
        if op.opcode == "compare":
            for name in _operand_names(op.rest):
                if name in consts:
                    return max(consts[name], 1)
    if consts:
        return max(consts.values())
    return 1


def _dot_flops(op: Op, comp: Computation) -> float:
    out_elems = sum(_prod(d) for _, d in _array_shapes(op.shape_str))
    operands = _operand_names(op.rest)
    lhs_shape: tuple[int, ...] = ()
    if operands and operands[0] in comp.shapes:
        arrs = _array_shapes(comp.shapes[operands[0]])
        if arrs:
            lhs_shape = arrs[0][1]
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    contract = 1
    if m and lhs_shape:
        for d in m.group(1).split(","):
            if d and int(d) < len(lhs_shape):
                contract *= lhs_shape[int(d)]
    return 2.0 * out_elems * contract


def _conv_flops(op: Op, comp: Computation) -> float:
    out_elems = sum(_prod(d) for _, d in _array_shapes(op.shape_str))
    operands = _operand_names(op.rest)
    kernel = 1
    if len(operands) > 1 and operands[1] in comp.shapes:
        arrs = _array_shapes(comp.shapes[operands[1]])
        if arrs:
            dims = arrs[0][1]
            kernel = _prod(dims) // max(dims[-1], 1)  # all but out-features
    m = re.search(r"feature_group_count=(\d+)", op.rest)
    if m and int(m.group(1)) > 1:
        kernel = max(kernel // 1, 1)  # depthwise: kernel already per-channel
    return 2.0 * out_elems * kernel


_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}

# Ops that mark an HBM round-trip under TPU-like fusion.  Plain elementwise
# chains (add/mul/exp/...) fuse into their producers/consumers on TPU, so
# their traffic is already covered by the neighbouring counted op; XLA:CPU
# fuses less aggressively, and counting every op would overstate TPU traffic
# several-fold.
_MEM_OPS = {
    "fusion", "dot", "convolution", "gather", "scatter", "dynamic-slice",
    "dynamic-update-slice", "copy", "transpose", "reduce", "sort",
    "reduce-window", "select-and-scatter", "concatenate", "slice", "pad",
    "reverse", "custom-call", "rng", "rng-bit-generator", "cholesky",
    "triangular-solve",
}


_SLICE_OPS = ("dynamic-slice", "gather", "slice")


def _sliced_param_bytes(sub: Computation) -> dict[int, int]:
    """For fusion params consumed ONLY by slicing ops, the bytes actually
    read: sum of the consumers' output sizes.  {param_index: bytes}."""
    params: dict[str, int] = {}
    for op in sub.ops:
        if op.opcode == "parameter":
            m = re.search(r"parameter\((\d+)\)", op.name + " = parameter(" + op.rest)
            if m:
                params[op.name] = int(m.group(1))
    out: dict[int, int] = {}
    for pname, pidx in params.items():
        consumers = [
            o for o in sub.ops
            if o.opcode != "parameter" and pname in _operand_names(o.rest)
        ]
        if consumers and all(o.opcode in _SLICE_OPS for o in consumers):
            out[pidx] = sum(_shape_bytes(o.shape_str) for o in consumers)
    return out


def analyze_computation(
    comp: Computation, comps: dict[str, Computation], memo: dict[str, Totals]
) -> Totals:
    if comp.name in memo:
        return memo[comp.name]
    memo[comp.name] = Totals()  # cycle guard
    t = Totals()
    for op in comp.ops:
        called = dict(_called_comps(op.rest))
        if op.opcode == "while":
            body = comps.get(called.get("body", ""))
            cond = comps.get(called.get("condition", ""))
            trips = _trip_count(cond) if cond else 1
            if body:
                t.add(analyze_computation(body, comps, memo), trips)
            if cond:
                t.add(analyze_computation(cond, comps, memo), trips)
            continue
        if op.opcode in ("call", "custom-call") and "to_apply" in called:
            sub = comps.get(called["to_apply"])
            if sub:
                t.add(analyze_computation(sub, comps, memo))
            continue
        if op.opcode == "conditional":
            # count the heavier branch (branches appear as called comps)
            branches = [
                comps[n] for _, n in _called_comps(op.rest) if n in comps
            ]
            if branches:
                subs = [analyze_computation(b, comps, memo) for b in branches]
                t.add(max(subs, key=lambda s: s.flops + s.bytes))
            continue

        base = op.opcode.replace("-start", "")
        if base in COLLECTIVE_KINDS and not op.opcode.endswith("-done"):
            t.collective_bytes[base] += _shape_bytes(op.shape_str)
            t.collective_counts[base] += 1
            t.bytes += 2 * _shape_bytes(op.shape_str)
            continue
        if op.opcode.endswith("-done"):
            continue

        if op.opcode == "fusion" and "calls" in called:
            sub = comps.get(called["calls"])
            if sub:
                inner = analyze_computation(sub, comps, memo)
                t.flops += inner.flops  # dots inside the fusion
            # fusion boundary = HBM traffic: operands + outputs.  Operands
            # that the fused computation only SLICES (dynamic-slice/gather)
            # are charged at slice size — a loop body indexing one block of
            # a stacked tensor reads a block, not the whole stack.
            t.bytes += _shape_bytes(op.shape_str)
            operand_names = _operand_names(op.rest)
            sliced = _sliced_param_bytes(sub) if sub else {}
            for idx, name in enumerate(operand_names):
                if idx in sliced:
                    t.bytes += sliced[idx]
                else:
                    t.bytes += _shape_bytes(comp.shapes.get(name, ""))
            continue

        if op.opcode == "dot":
            t.flops += _dot_flops(op, comp)
        elif op.opcode == "convolution":
            t.flops += _conv_flops(op, comp)
        if op.opcode in _FREE_OPS or op.opcode not in _MEM_OPS:
            continue
        # Index-driven ops touch only the slice, not the whole operand —
        # charging the full operand would bias the model against scan/loop
        # implementations (each trip would "re-read" the entire tensor).
        if op.opcode in ("dynamic-slice", "gather", "slice"):
            t.bytes += 2 * _shape_bytes(op.shape_str)  # read slice + write
            continue
        if op.opcode == "dynamic-update-slice":
            ops_names = _operand_names(op.rest)
            upd = comp.shapes.get(ops_names[1], "") if len(ops_names) > 1 else ""
            t.bytes += 2 * _shape_bytes(upd)  # read update + write window
            continue
        t.bytes += _shape_bytes(op.shape_str)
        for name in _operand_names(op.rest):
            t.bytes += _shape_bytes(comp.shapes.get(name, ""))

    memo[comp.name] = t
    return t


def analyze_hlo(hlo: str) -> dict:
    """Per-device totals with while-trip multiplication."""
    comps = parse_computations(hlo)
    entry = comps.get("__entry__")
    if entry is None:
        raise ValueError("no ENTRY computation found")
    memo: dict[str, Totals] = {}
    t = analyze_computation(entry, comps, memo)
    return {
        "flops": t.flops,
        "bytes": t.bytes,
        "collective_bytes": dict(t.collective_bytes),
        "collective_counts": dict(t.collective_counts),
    }


def op_histogram(hlo: str) -> dict[str, float]:
    """Trip-count-weighted opcode histogram of the ENTRY call graph.

    Counts every op reachable from ENTRY, multiplying while bodies/conds by
    their trip count (the same walk as :func:`analyze_hlo`) — which is what
    makes "how many ``sort``s does one event step pay?" answerable from a
    compiled scan: a per-event sort inside a 2M-trip loop shows up 2M
    times, not once.  Call-like ops (``fusion``, ``call``, ``reduce``,
    ``conditional``) count themselves AND their subcomputations' ops;
    ``conditional`` counts every branch (an upper bound — branches are
    traced, not taken).
    """
    comps = parse_computations(hlo)
    entry = comps.get("__entry__")
    if entry is None:
        raise ValueError("no ENTRY computation found")
    memo: dict[str, dict[str, float]] = {}

    def walk(comp: Computation) -> dict[str, float]:
        if comp.name in memo:
            return memo[comp.name]
        memo[comp.name] = {}  # cycle guard
        h: dict[str, float] = {}

        def bump(d: dict[str, float], mult: float = 1.0) -> None:
            for k, v in d.items():
                h[k] = h.get(k, 0.0) + v * mult

        for op in comp.ops:
            called = dict(_called_comps(op.rest))
            if op.opcode == "while":
                body = comps.get(called.get("body", ""))
                cond = comps.get(called.get("condition", ""))
                trips = _trip_count(cond) if cond else 1
                h["while"] = h.get("while", 0.0) + 1.0
                if body:
                    bump(walk(body), trips)
                if cond:
                    bump(walk(cond), trips)
                continue
            h[op.opcode] = h.get(op.opcode, 0.0) + 1.0
            for _, sub_name in _called_comps(op.rest):
                sub = comps.get(sub_name)
                if sub is not None:
                    bump(walk(sub))

        memo[comp.name] = h
        return h

    return walk(entry)
