"""Cluster scheduler built on the paper's policies: quantization, online
p-estimation, decision epochs, elastic resizing, straggler mitigation."""

from repro.sched.cluster import ClusterScheduler, Job
from repro.sched.elastic import ElasticClusterDriver, ElasticJob, ElasticJobConfig
from repro.sched.estimator import SpeedupEstimator, blended_p, pooled_p_hat
from repro.sched.quantize import quantize_allocation, snap_to_slices
from repro.sched.stragglers import StragglerDetector

__all__ = [
    "ClusterScheduler",
    "ElasticClusterDriver",
    "ElasticJob",
    "ElasticJobConfig",
    "Job",
    "SpeedupEstimator",
    "StragglerDetector",
    "blended_p",
    "pooled_p_hat",
    "quantize_allocation",
    "snap_to_slices",
]
