"""Straggler mitigation: expected-vs-observed throughput per job/worker.

The speedup model gives an expectation: a healthy job on k chips should run
at ~``rate_at(k)``.  A job persistently below ``threshold`` of that (default
70%) for ``patience`` consecutive reports is flagged; the cluster driver
responds by evicting the slow worker (shrinking the job by one chip — the
scheduler re-quantizes) or restarting the job from checkpoint on fresh chips.
This is the classic "detect via model residual" approach rather than
all-pairs timing gossip — it needs no extra communication.
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class StragglerDetector:
    threshold: float = 0.7
    patience: int = 3
    slow_counts: dict[str, int] = field(default_factory=dict)
    events: list[dict] = field(default_factory=list)

    def report(self, job_id: str, observed_rate: float, expected_rate: float,
               step: int = -1) -> bool:
        """Returns True when the job crosses the straggler threshold."""
        if expected_rate <= 0:
            return False
        ratio = observed_rate / expected_rate
        if ratio < self.threshold:
            self.slow_counts[job_id] = self.slow_counts.get(job_id, 0) + 1
        else:
            self.slow_counts[job_id] = 0
        if self.slow_counts.get(job_id, 0) >= self.patience:
            self.events.append(
                {"job": job_id, "step": step, "ratio": ratio, "action": "evict"}
            )
            self.slow_counts[job_id] = 0
            return True
        return False
