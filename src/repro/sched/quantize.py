"""Fractional allocation -> whole chips.

The paper's theta* treats the N servers as a continuously divisible resource
(heSRPT Thm 7); a TPU cluster hands out whole chips (and prefers power-of-two
mesh slices).  ``quantize_allocation`` is largest-remainder apportionment with
a minimum-chips floor; ``snap_to_slices`` optionally restricts every job to
ICI-friendly slice sizes {1, 2, 4, 8, ...}.

Invariants (property-tested in tests/test_quantize.py, which also checks
exact agreement with the vectorized-jnp ports
``core.engine.quantize_allocation_jax`` / ``core.engine.snap_to_slices_jax``
— these NumPy versions are the oracles):
- conservation: sum(chips) == n_chips when every active job can hold >= min
  chips (else the smallest-theta jobs are queued with 0),
- monotone: chips_i is within 1 (or one slice) of theta_i * n_chips
  whenever the min-chips floor does not bind,
- active jobs with theta > 0 get >= min_chips whenever capacity allows.

All sorts are stable so tie-breaking (by job index) is well-defined and
reproducible by the jnp port; chips are only ever granted to active jobs.
"""

from __future__ import annotations

import numpy as np

from repro.core.engine import DEFAULT_SLICES


def quantize_allocation(
    theta: np.ndarray, n_chips: int, *, min_chips: int = 1
) -> np.ndarray:
    """Largest-remainder rounding of ``theta * n_chips`` (theta sums to <= 1)."""
    theta = np.asarray(theta, dtype=np.float64)
    active = theta > 0
    n_active = int(active.sum())
    chips = np.zeros(theta.shape, dtype=np.int64)
    if n_active == 0 or n_chips <= 0:
        return chips

    if n_active * min_chips > n_chips:
        # Oversubscribed: serve the largest-theta jobs, queue the rest.
        order = np.argsort(-theta, kind="stable")
        servable = order[: n_chips // min_chips]
        sub = np.zeros_like(theta)
        sub[servable] = theta[servable]
        tot = sub.sum()
        if tot <= 0:
            return chips
        return quantize_allocation(sub / tot, n_chips, min_chips=min_chips)

    raw = theta * n_chips
    base = np.floor(raw).astype(np.int64)
    base = np.where(active, np.maximum(base, min_chips), 0)
    overflow = int(base.sum()) - n_chips
    if overflow > 0:
        # The min-chips floor oversubscribed: trim from the largest holdings.
        for _ in range(overflow):
            cand = np.where(base > min_chips, base - raw, -np.inf)
            j = int(np.argmax(cand))
            base[j] -= 1
    remainder = n_chips - int(base.sum())
    if remainder > 0:
        frac = np.where(active, raw - np.floor(raw), -1.0)
        # Give the leftover chips to the largest fractional parts (active
        # jobs only — a theta summing well below 1 must not leak chips to
        # departed jobs).
        order = np.argsort(-frac, kind="stable")
        for j in order[: min(remainder, n_active)]:
            base[j] += 1
    return base


def snap_to_slices(chips: np.ndarray, n_chips: int, *, slices=DEFAULT_SLICES) -> np.ndarray:
    """Snap each job's count DOWN to the largest slice size <= count, then
    hand leftovers (largest-first) to jobs whose next slice step fits."""
    slices = sorted(slices)
    chips = np.asarray(chips, dtype=np.int64).copy()

    def snap_down(c):
        out = 0
        for s in slices:
            if s <= c:
                out = s
        return out

    snapped = np.array([snap_down(int(c)) for c in chips], dtype=np.int64)
    left = n_chips - int(snapped.sum())
    # upgrade greedily: job with the largest lost allocation first
    while left > 0:
        best, best_j = 0, -1
        for j in range(len(snapped)):
            if snapped[j] == 0 and chips[j] == 0:
                continue
            nxt = next((s for s in slices if s > snapped[j]), None)
            if nxt is None:
                continue
            step = nxt - snapped[j]
            lost = chips[j] - snapped[j]
            if step <= left and lost >= best:
                best, best_j = lost, j
        if best_j < 0:
            break
        nxt = next(s for s in slices if s > snapped[best_j])
        left -= nxt - snapped[best_j]
        snapped[best_j] = nxt
    return snapped
