"""ClusterScheduler: the paper's policies driving a real chip pool.

The scheduler owns the job table (remaining work, fitted p-hat) and, at every
*decision epoch* (job departure, arrival, failure — Thm 3 says allocations
only need to change at departures; arrivals/failures are the production
extensions, flagged as the paper's §4.3 heuristic), recomputes:

    theta = policy(remaining_sizes, p)        # heSRPT / heLRPT / SRPT / ...
    chips = quantize(theta, N)                # largest-remainder (+ slices)

``run_fluid_to_completion`` delegates the whole fluid trajectory to the
scan-based allocation engine (``core/engine.py``) whenever the instance fits
the engine's pure-function model — one jit'd device call instead of one
Python epoch at a time, with the same integer-chips quantization
(``core.engine.quantize_allocation_jax``) and power-of-two slice snapping
(``core.engine.snap_to_slices_jax``), both property-tested against the
NumPy ``sched/quantize.py`` oracles used by the per-event path.
``class_aware=True`` is the multi-class regime: per-job speedup exponents,
``core.multiclass`` policies, per-job-``p`` fluid physics — this instance
of the per-event loop is the NumPy oracle the multi-class engine path is
cross-checked against (``benchmarks/multiclass.py``).  ``use_estimator=
True`` is the online-estimation regime: the policy allocates with the
blended (single-class) or per-class-pooled (class-aware) p-hat fit from
observed throughput, while the fluid physics keep each job's true
exponent; the engine runs it as a *stateful* allocation rule
(``core/estimation.py`` — recursive WLS carried through the scan), with
this per-event loop demoted to the cross-check oracle (flows agree to
~1e-10 given the identical observation schedule: one observation per job
per epoch, after the advance).  KNEE's per-epoch alpha refit — the last
Python-only policy path — now delegates too (``core.engine.knee_rule``
recomputes the masked median inside the scan).  The per-event Python path
(``allocations`` / ``advance_fluid``) remains both oracle and fallback
for heterogeneous p without ``class_aware`` (and KNEE under
``use_estimator``); ``sched/elastic.py`` uses it to drive real training
jobs through ``report_progress``.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.policies import make_policy
from repro.sched.estimator import SpeedupEstimator, blended_p, pooled_p_hat
from repro.sched.quantize import quantize_allocation, snap_to_slices


@dataclass
class Job:
    job_id: str
    size: float  # total work units (e.g. training steps x step cost)
    p: float = 0.7  # true speedup exponent (the fluid physics)
    remaining: float = -1.0
    arrival_time: float = 0.0
    chips: float = 0  # whole chips normally; fractional when quantize=False
    completion_time: float | None = None
    class_id: int = 0  # job class (multi-class workloads; 0 = default class)
    # What the estimator believes before any observation.  None = the true
    # p (the historical default); set it away from ``p`` to simulate a
    # scheduler whose prior is stale/wrong.
    prior_p: float | None = None
    estimator: SpeedupEstimator = field(default_factory=SpeedupEstimator)

    def __post_init__(self):
        if self.remaining < 0:
            self.remaining = self.size
        self.estimator.prior_p = self.p if self.prior_p is None else self.prior_p


class ClusterScheduler:
    def __init__(
        self,
        n_chips: int,
        *,
        policy: str = "hesrpt",
        min_chips: int = 1,
        snap_slices: bool = False,
        use_estimator: bool = False,
        quantize: bool = True,
        rel_tol: float = 1e-9,
        class_aware: bool = False,
        class_weights: dict[int, float] | None = None,
        est_discount: float = 1.0,
        est_prior_weight: float = 1.0,
    ):
        self.n_chips = n_chips
        self.policy_name = policy
        self.min_chips = min_chips
        self.snap_slices = snap_slices
        self.use_estimator = use_estimator
        # quantize=False keeps the paper's continuously-divisible allocation
        # (fractional chips) — the fluid reference that core/arrivals.py is
        # cross-checked against.
        self.quantize = quantize
        # Same role as the engine's rel_tol: a departure must not be kept
        # alive by float residue (~eps * size) from the linear advance.
        self.rel_tol = rel_tol
        # class_aware=True is the multi-class regime: ``policy`` must be a
        # ``core.multiclass`` name (hesrpt_pc / waterfill / hesrpt_sd /
        # hesrpt_blind), allocations see the per-job exponent vector, and
        # the fluid physics use each job's own p — this is the per-event
        # NumPy oracle the multi-class engine path is cross-checked against.
        self.class_aware = class_aware
        self.class_weights = class_weights or {}
        # Estimation knobs (use_estimator=True): exponential forgetting and
        # ridge prior strength, applied to every job's estimator on
        # admission so the table is uniform (per-job priors still come
        # from ``Job.prior_p``).
        self.est_discount = est_discount
        self.est_prior_weight = est_prior_weight
        self.jobs: dict[str, Job] = {}
        self.time = 0.0
        self.events: list[dict] = []

    # ------------------------------------------------------------- job table
    def add_job(self, job: Job) -> None:
        job.arrival_time = self.time
        if self.use_estimator:
            job.estimator.discount = self.est_discount
            job.estimator.prior_weight = self.est_prior_weight
        self.jobs[job.job_id] = job
        self.events.append({"t": self.time, "event": "arrival", "job": job.job_id})

    def active_jobs(self) -> list[Job]:
        return [j for j in self.jobs.values() if j.remaining > 0]

    def effective_p(self) -> float:
        act = self.active_jobs()
        if not act:
            return 0.7
        if self.use_estimator:
            return blended_p([j.estimator for j in act], [j.remaining for j in act])
        return float(np.mean([j.p for j in act]))

    def _class_inputs(self, act: list[Job], dtype):
        """Per-job exponent vector and policy weight vector for an active
        set — ONE construction shared by the per-event oracle path and the
        engine delegation, so the exactness contract between them (chips
        equal event-for-event) cannot drift apart."""
        import jax.numpy as jnp

        from repro.core import multiclass as mc

        p_vec = jnp.asarray([j.p for j in act], dtype)
        class_w = jnp.asarray(
            [self.class_weights.get(j.class_id, 1.0) for j in act], dtype
        )
        w = mc.policy_weights(
            self.policy_name,
            x0=jnp.asarray([j.size for j in act], dtype),
            class_w=class_w,
        )
        return p_vec, w

    def _class_priors(self):
        """Per-class ridge prior (mean ``prior_p`` over the class's jobs)
        and prior weight, for classes ``0..K-1`` over the WHOLE job table —
        one definition shared by the per-event oracle and the engine
        delegation, so the pooled fits agree."""
        K = max(j.class_id for j in self.jobs.values()) + 1
        prior_p, prior_w = [], []
        for k in range(K):
            ests = [j.estimator for j in self.jobs.values() if j.class_id == k]
            prior_p.append(
                float(np.mean([e.prior_p for e in ests])) if ests else 0.7
            )
            prior_w.append(
                float(np.mean([e.prior_weight for e in ests])) if ests else 1.0
            )
        return K, prior_p, prior_w

    def _class_p_hat(self, act: list[Job]) -> np.ndarray:
        """Estimated per-job exponent vector for an active set: each job
        gets its class's *pooled* p-hat (``sched.estimator.pooled_p_hat``
        over every job of the class, departed ones included — observations
        don't expire with their job)."""
        K, prior_p, prior_w = self._class_priors()
        p_k = np.empty(K)
        for k in range(K):
            ests = [j.estimator for j in self.jobs.values() if j.class_id == k]
            p_k[k] = pooled_p_hat(ests, prior_p[k], prior_w[k])
        return p_k[[j.class_id for j in act]]

    def _class_theta(self, act: list[Job]) -> np.ndarray:
        """Class-aware theta: the SAME jnp allocation function the engine's
        scan rule calls (``core.multiclass.class_theta``), on the per-job
        exponent vector — identical ops, identical bits, so the engine
        cross-check can demand exact chips.  With ``use_estimator`` the
        policy sees the per-class pooled p-hat instead of the truth (the
        physics in ``job_rates`` keep each job's true exponent)."""
        import jax.numpy as jnp

        from repro.core import multiclass as mc

        x = jnp.asarray([j.remaining for j in act])
        p_vec, w = self._class_inputs(act, x.dtype)
        if self.use_estimator:
            p_vec = jnp.asarray(self._class_p_hat(act), x.dtype)
        theta = mc.class_theta(
            self.policy_name, x, p_vec, n_servers=float(self.n_chips), w=w
        )
        return np.asarray(theta, dtype=np.float64)

    # ------------------------------------------------------ decision epochs
    def allocations(self) -> dict[str, float]:
        """Recompute theta -> chips for the current active set (int-valued
        when quantizing, fractional chips when ``quantize=False``)."""
        import jax.numpy as jnp

        act = self.active_jobs()
        if not act:
            return {}
        p = self.effective_p()
        if self.class_aware:
            theta = self._class_theta(act)
        else:
            x = jnp.asarray([j.remaining for j in act])
            pol = make_policy(
                self.policy_name,
                n_servers=float(self.n_chips),
                alpha=float(
                    np.median([j.remaining for j in act]) * p / self.n_chips
                ),
            )
            theta = np.asarray(pol(x, p), dtype=np.float64)
        if self.quantize:
            chips = quantize_allocation(theta, self.n_chips, min_chips=self.min_chips)
            if self.snap_slices:
                chips = snap_to_slices(chips, self.n_chips)
            chips = [int(c) for c in chips]
        else:
            chips = [float(c) for c in theta * self.n_chips]
        out = {}
        for j, c in zip(act, chips, strict=True):
            j.chips = c
            out[j.job_id] = c
        self.events.append(
            {"t": self.time, "event": "allocate", "chips": dict(out), "p": p}
        )
        return out

    # --------------------------------------------------------- progress I/O
    def report_progress(self, job_id: str, work_done: float,
                        wall_dt: float = 0.0) -> None:
        job = self.jobs[job_id]
        job.remaining = max(job.remaining - work_done, 0.0)
        if wall_dt > 0:
            self.time += 0.0  # wall time tracked by the driver
            job.estimator.observe(job.chips, work_done / wall_dt)
        if job.remaining == 0 and job.completion_time is None:
            job.completion_time = self.time
            self.events.append({"t": self.time, "event": "depart", "job": job_id})

    # --------------------------------------------------------- fluid model
    def job_rates(self, act: list[Job]) -> np.ndarray:
        """Per-job fluid service rates s(chips_j).  Class-aware and
        estimator modes use each job's own TRUE exponent (the estimator
        may be wrong about p, the physics never are); the plain
        single-class mode keeps the historical blended-p behaviour."""
        if self.class_aware or self.use_estimator:
            return np.array([max(j.chips, 0) ** j.p for j in act])
        p = self.effective_p()
        return np.array([max(j.chips, 0) ** p for j in act])

    def advance_fluid(self, *, until_departure: bool = True, dt: float = 0.0):
        """Advance the fluid simulation: each job progresses at s(chips) =
        chips^p.  Used by benchmarks and the arrival-stream experiments."""
        act = self.active_jobs()
        if not act:
            return 0.0
        rates = self.job_rates(act)
        if until_departure:
            with np.errstate(divide="ignore"):
                tt = np.where(rates > 0, [j.remaining for j in act] / rates, np.inf)
            step = float(np.min(tt))
        else:
            step = dt
        if not np.isfinite(step):
            raise RuntimeError("no job can make progress (all rates zero)")
        # Float residue (rem - (rem/rate)*rate can land ~eps above zero)
        # must not keep the departing job alive for a micro-epoch — same
        # relative-tolerance clamp as the engine scan.
        tol = self.rel_tol * max(j.size for j in self.jobs.values())
        self.time += step
        for j, r in zip(act, rates, strict=True):
            j.remaining = max(j.remaining - step * r, 0.0)
            if j.remaining <= tol:
                j.remaining = 0.0
            if j.remaining == 0 and j.completion_time is None:
                j.completion_time = self.time
                self.events.append({"t": self.time, "event": "depart", "job": j.job_id})
        if self.use_estimator and step > 0:
            # The observation schedule the engine's stateful rule mirrors:
            # after each epoch, every job that held chips and made progress
            # observes its realized fluid throughput (work/dt == rate).
            for j, r in zip(act, rates, strict=True):
                j.estimator.observe(j.chips, r)
        return step

    def _engine_eligible(self) -> bool:
        """The engine scans any rule expressible as ``(init, observe,
        allocate)`` — since the stateful-rule refactor that includes the
        online speedup estimator (``core/estimation.py``), so
        ``use_estimator=True`` delegates too; only the per-epoch KNEE
        alpha refit remains Python-only.  Slice snapping is engine-native
        (``snap_to_slices_jax``), and ``class_aware`` instances delegate
        with the per-job exponent vector (any p mix) as long as the policy
        is a pure ``core.multiclass`` rule; the plain single-class mode
        still needs uniform p (its blended-p physics are not a pure
        per-job rule — the estimator mode has no such constraint, its
        physics are per-job true p).  It also needs float64 JAX (else the
        trajectory would silently drop to f32 and near-tie chip decisions
        could flip vs the f64 NumPy oracle path) — callers without
        ``jax_enable_x64`` get the Python loop."""
        import jax

        from repro.core.multiclass import MULTICLASS_POLICY_NAMES

        act = self.active_jobs()
        if not jax.config.jax_enable_x64:
            return False
        if self.class_aware:
            return self.policy_name.lower() in MULTICLASS_POLICY_NAMES
        if self.use_estimator:
            # per-job true-p physics: any p mix delegates.  KNEE is the one
            # exception: ``estimating_rule`` wraps a static Policy, and
            # KNEE's per-epoch alpha refit is not threaded through it.
            return self.policy_name.lower() != "knee"
        return len({j.p for j in act}) <= 1

    def _run_fluid_engine(self) -> dict:
        """One device call for the whole trajectory: delegate the epoch loop
        (allocate -> advance -> repeat) to ``core.engine.run`` with the
        quantized (or continuous) allocation rule."""
        import jax.numpy as jnp

        from repro.core import engine as _engine

        act = self.active_jobs()
        ids = [j.job_id for j in act]
        x0 = jnp.asarray([j.remaining for j in act])
        dtype = jnp.result_type(x0.dtype, jnp.float32)
        est_kw = {}
        if self.use_estimator:
            # Batch case: arrival sort is the identity, so the per-job
            # estimator vectors in `act` order satisfy the stateful rule's
            # sorted-order contract; pre-existing observation histories
            # (report_progress) seed the sufficient statistics.
            from repro.core import estimation as est

            est_kw = dict(
                prior_p=jnp.asarray([j.estimator.prior_p for j in act], dtype),
                prior_weight=jnp.asarray(
                    [j.estimator.prior_weight for j in act], dtype
                ),
                discount=jnp.asarray(
                    [j.estimator.discount for j in act], dtype
                ),
                init_state=est.est_state_from_history(
                    [j.estimator.history for j in act], dtype
                ),
            )
        if self.class_aware:
            from repro.core import multiclass as mc

            # Batch case: arrival sort is the identity, so per-job vectors
            # in `act` order satisfy the rule's sorted-order contract.
            p_arg, w = self._class_inputs(act, dtype)
            p = float(np.mean([j.p for j in act]))  # event-log annotation
            if self.use_estimator:
                from repro.core import estimation as est

                K, prior_p_k, prior_w_k = self._class_priors()
                # Departed jobs' observations still inform their class's
                # pooled p-hat (exactly as the oracle's _class_p_hat pools
                # the WHOLE job table): fold them in as static [K] stats.
                inact = [j for j in self.jobs.values() if j.remaining <= 0]
                base = None
                if inact:
                    base = est.pool_by_class(
                        est.est_state_from_history(
                            [j.estimator.history for j in inact], dtype
                        ),
                        jnp.asarray([j.class_id for j in inact], jnp.int32),
                        K,
                    )
                rule = est.estimating_class_rule(
                    self.policy_name,
                    class_ids=jnp.asarray(
                        [j.class_id for j in act], jnp.int32
                    ),
                    n_classes=K,
                    prior_p=jnp.asarray(prior_p_k, dtype),
                    prior_weight=jnp.asarray(prior_w_k, dtype),
                    discount=est_kw["discount"],
                    dtype=dtype,
                    n_servers=float(self.n_chips),
                    n_chips=self.n_chips if self.quantize else None,
                    min_chips=self.min_chips,
                    snap_slices=self.snap_slices,
                    w=w,
                    init_state=est_kw["init_state"],
                    base_class_state=base,
                )
            else:
                rule = mc.class_rule(
                    self.policy_name,
                    n_servers=float(self.n_chips),
                    n_chips=self.n_chips if self.quantize else None,
                    min_chips=self.min_chips,
                    snap_slices=self.snap_slices,
                    dtype=dtype,
                    w=w,
                )
        else:
            pol = make_policy(self.policy_name, n_servers=float(self.n_chips))
            if self.use_estimator:
                from repro.core import estimation as est

                # Physics: each job's true exponent; the rule allocates
                # with the blended p-hat it carries through the scan.
                p_arg = jnp.asarray([j.p for j in act], dtype)
                p = self.effective_p()  # event-log annotation (initial)
                rule = est.estimating_rule(
                    pol,
                    float(self.n_chips),
                    dtype=dtype,
                    n_chips=self.n_chips if self.quantize else None,
                    min_chips=self.min_chips,
                    snap_slices=self.snap_slices,
                    **est_kw,
                )
            elif self.policy_name.lower() == "knee":
                # KNEE refits its alpha from the active set at every epoch;
                # the engine rule recomputes the same masked median inside
                # the scan (``core.engine.knee_rule``), which retired the
                # last Python-only policy path.
                p_arg = p = self.effective_p()
                rule = _engine.knee_rule(
                    float(self.n_chips),
                    n_chips=self.n_chips if self.quantize else None,
                    min_chips=self.min_chips,
                    snap_slices=self.snap_slices,
                    dtype=dtype,
                )
            elif self.quantize:
                p_arg = p = self.effective_p()
                rule = _engine.quantized_rule(
                    pol, self.n_chips, min_chips=self.min_chips, dtype=dtype,
                    snap_slices=self.snap_slices,
                )
            else:
                p_arg = p = self.effective_p()
                rule = _engine.continuous_rule(
                    pol, float(self.n_chips), dtype=dtype
                )
        res = _engine.run(
            x0,
            jnp.zeros(len(act), dtype),
            p_arg,
            rule,
            pre_arrived=True,
            horizon=len(act),
            rel_tol=self.rel_tol,
            t0=self.time,
            record=True,
        )
        times = np.asarray(res.completion_times, dtype=np.float64)
        if not np.all(np.isfinite(times)):
            raise RuntimeError("scheduler failed to converge (engine)")
        # Replay the trajectory into the event log / job table the Python
        # path would have produced (engine trace order == `act` order here:
        # every job is pre-arrived, so the engine's arrival sort is the
        # identity permutation).
        alloc = np.asarray(res.trace.alloc)
        sizes = np.asarray(res.trace.sizes)
        t_ev = np.asarray(res.trace.times)
        last_chips: dict[str, float] = {}
        for e in range(alloc.shape[0]):
            live = sizes[e] > 0
            if not live.any():
                break
            # Continuous mode records theta in the trace; the event log keeps
            # the Python path's unit (fractional *chips*, i.e. theta * N).
            chips = {
                ids[i]: (int(alloc[e, i]) if self.quantize
                         else float(alloc[e, i]) * self.n_chips)
                for i in range(len(ids))
                if live[i]
            }
            last_chips.update(chips)
            self.events.append(
                {"t": float(t_ev[e]), "event": "allocate", "chips": chips, "p": p}
            )
        for i, j in enumerate(act):
            j.remaining = 0.0
            j.chips = last_chips.get(j.job_id, 0)
            j.completion_time = float(times[i])
        for t, jid in sorted((float(times[i]), ids[i]) for i in range(len(ids))):
            self.events.append({"t": t, "event": "depart", "job": jid})
        self.time = float(np.max(times))
        return self._summary()

    def _summary(self) -> dict:
        times = {j.job_id: j.completion_time for j in self.jobs.values()}
        flows = {
            jid: t - self.jobs[jid].arrival_time for jid, t in times.items()
        }
        return {
            "completion_times": times,
            "total_flow_time": float(sum(flows.values())),
            "mean_flow_time": float(np.mean(list(flows.values()))),
            "makespan": float(max(times.values())),
        }

    def run_fluid_to_completion(self, *, use_engine: bool = True) -> dict:
        """Run the current job table to completion in the fluid model.

        Delegates to the scan engine when eligible (one jit'd device call);
        ``use_engine=False`` forces the per-event Python epoch loop
        (allocate -> advance to next departure -> repeat), which is the
        oracle the engine path is tested against event-for-event.
        """
        if use_engine and self.active_jobs() and self._engine_eligible():
            return self._run_fluid_engine()
        guard = 0
        while self.active_jobs():
            self.allocations()
            self.advance_fluid(until_departure=True)
            guard += 1
            if guard > 10 * len(self.jobs) + 100:
                raise RuntimeError("scheduler failed to converge")
        return self._summary()
