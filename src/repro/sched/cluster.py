"""ClusterScheduler: the paper's policies driving a real chip pool.

The scheduler owns the job table (remaining work, fitted p-hat) and, at every
*decision epoch* (job departure, arrival, failure — Thm 3 says allocations
only need to change at departures; arrivals/failures are the production
extensions, flagged as the paper's §4.3 heuristic), recomputes:

    theta = policy(remaining_sizes, p)        # heSRPT / heLRPT / SRPT / ...
    chips = quantize(theta, N)                # largest-remainder (+ slices)

``run_fluid_to_completion`` delegates the whole fluid trajectory to the
scan-based allocation engine (``core/engine.py``) whenever the instance fits
the engine's pure-function model — one jit'd device call instead of one
Python epoch at a time, with the same integer-chips quantization
(``core.engine.quantize_allocation_jax``, property-tested against the NumPy
``sched/quantize.py`` oracle used by the per-event path).  The per-event
Python path (``allocations`` / ``advance_fluid``) remains both the oracle
the engine is cross-checked against and the fallback for stateful features
(speedup estimators, slice snapping, per-job p, per-epoch KNEE alpha);
``sched/elastic.py`` uses it to drive real training jobs through
``report_progress``.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.policies import make_policy
from repro.sched.estimator import SpeedupEstimator, blended_p
from repro.sched.quantize import quantize_allocation, snap_to_slices


@dataclass
class Job:
    job_id: str
    size: float  # total work units (e.g. training steps x step cost)
    p: float = 0.7  # prior speedup exponent
    remaining: float = -1.0
    arrival_time: float = 0.0
    chips: float = 0  # whole chips normally; fractional when quantize=False
    completion_time: float | None = None
    estimator: SpeedupEstimator = field(default_factory=SpeedupEstimator)

    def __post_init__(self):
        if self.remaining < 0:
            self.remaining = self.size
        self.estimator.prior_p = self.p


class ClusterScheduler:
    def __init__(
        self,
        n_chips: int,
        *,
        policy: str = "hesrpt",
        min_chips: int = 1,
        snap_slices: bool = False,
        use_estimator: bool = False,
        quantize: bool = True,
        rel_tol: float = 1e-9,
    ):
        self.n_chips = n_chips
        self.policy_name = policy
        self.min_chips = min_chips
        self.snap_slices = snap_slices
        self.use_estimator = use_estimator
        # quantize=False keeps the paper's continuously-divisible allocation
        # (fractional chips) — the fluid reference that core/arrivals.py is
        # cross-checked against.
        self.quantize = quantize
        # Same role as the engine's rel_tol: a departure must not be kept
        # alive by float residue (~eps * size) from the linear advance.
        self.rel_tol = rel_tol
        self.jobs: dict[str, Job] = {}
        self.time = 0.0
        self.events: list[dict] = []

    # ------------------------------------------------------------- job table
    def add_job(self, job: Job) -> None:
        job.arrival_time = self.time
        self.jobs[job.job_id] = job
        self.events.append({"t": self.time, "event": "arrival", "job": job.job_id})

    def active_jobs(self) -> list[Job]:
        return [j for j in self.jobs.values() if j.remaining > 0]

    def effective_p(self) -> float:
        act = self.active_jobs()
        if not act:
            return 0.7
        if self.use_estimator:
            return blended_p([j.estimator for j in act], [j.remaining for j in act])
        return float(np.mean([j.p for j in act]))

    # ------------------------------------------------------ decision epochs
    def allocations(self) -> dict[str, float]:
        """Recompute theta -> chips for the current active set (int-valued
        when quantizing, fractional chips when ``quantize=False``)."""
        import jax.numpy as jnp

        act = self.active_jobs()
        if not act:
            return {}
        p = self.effective_p()
        x = jnp.asarray([j.remaining for j in act])
        pol = make_policy(
            self.policy_name,
            n_servers=float(self.n_chips),
            alpha=float(np.median([j.remaining for j in act]) * p / self.n_chips),
        )
        theta = np.asarray(pol(x, p), dtype=np.float64)
        if self.quantize:
            chips = quantize_allocation(theta, self.n_chips, min_chips=self.min_chips)
            if self.snap_slices:
                chips = snap_to_slices(chips, self.n_chips)
            chips = [int(c) for c in chips]
        else:
            chips = [float(c) for c in theta * self.n_chips]
        out = {}
        for j, c in zip(act, chips, strict=True):
            j.chips = c
            out[j.job_id] = c
        self.events.append(
            {"t": self.time, "event": "allocate", "chips": dict(out), "p": p}
        )
        return out

    # --------------------------------------------------------- progress I/O
    def report_progress(self, job_id: str, work_done: float,
                        wall_dt: float = 0.0) -> None:
        job = self.jobs[job_id]
        job.remaining = max(job.remaining - work_done, 0.0)
        if wall_dt > 0:
            self.time += 0.0  # wall time tracked by the driver
            job.estimator.observe(job.chips, work_done / wall_dt)
        if job.remaining == 0 and job.completion_time is None:
            job.completion_time = self.time
            self.events.append({"t": self.time, "event": "depart", "job": job_id})

    # --------------------------------------------------------- fluid model
    def advance_fluid(self, *, until_departure: bool = True, dt: float = 0.0):
        """Advance the fluid simulation: each job progresses at s(chips) =
        chips^p.  Used by benchmarks and the arrival-stream experiments."""
        act = self.active_jobs()
        if not act:
            return 0.0
        p = self.effective_p()
        rates = np.array([max(j.chips, 0) ** p for j in act])
        if until_departure:
            with np.errstate(divide="ignore"):
                tt = np.where(rates > 0, [j.remaining for j in act] / rates, np.inf)
            step = float(np.min(tt))
        else:
            step = dt
        if not np.isfinite(step):
            raise RuntimeError("no job can make progress (all rates zero)")
        # Float residue (rem - (rem/rate)*rate can land ~eps above zero)
        # must not keep the departing job alive for a micro-epoch — same
        # relative-tolerance clamp as the engine scan.
        tol = self.rel_tol * max(j.size for j in self.jobs.values())
        self.time += step
        for j, r in zip(act, rates, strict=True):
            j.remaining = max(j.remaining - step * r, 0.0)
            if j.remaining <= tol:
                j.remaining = 0.0
            if j.remaining == 0 and j.completion_time is None:
                j.completion_time = self.time
                self.events.append({"t": self.time, "event": "depart", "job": j.job_id})
        return step

    def _engine_eligible(self) -> bool:
        """The engine models a pure (x, p) -> allocation rule: uniform p,
        no online estimator state, no slice snapping, no per-epoch KNEE
        alpha refitting.  It also needs float64 JAX (else the trajectory
        would silently drop to f32 and near-tie chip decisions could flip
        vs the f64 NumPy oracle path) — callers without ``jax_enable_x64``
        get the Python loop."""
        import jax

        act = self.active_jobs()
        return (
            jax.config.jax_enable_x64
            and not self.use_estimator
            and not self.snap_slices
            and self.policy_name.lower() != "knee"
            and len({j.p for j in act}) <= 1
        )

    def _run_fluid_engine(self) -> dict:
        """One device call for the whole trajectory: delegate the epoch loop
        (allocate -> advance -> repeat) to ``core.engine.run`` with the
        quantized (or continuous) allocation rule."""
        import jax.numpy as jnp

        from repro.core import engine as _engine

        act = self.active_jobs()
        ids = [j.job_id for j in act]
        x0 = jnp.asarray([j.remaining for j in act])
        dtype = jnp.result_type(x0.dtype, jnp.float32)
        p = self.effective_p()
        pol = make_policy(self.policy_name, n_servers=float(self.n_chips))
        if self.quantize:
            rule = _engine.quantized_rule(
                pol, self.n_chips, min_chips=self.min_chips, dtype=dtype
            )
        else:
            rule = _engine.continuous_rule(pol, float(self.n_chips), dtype=dtype)
        res = _engine.run(
            x0,
            jnp.zeros(len(act), dtype),
            p,
            rule,
            pre_arrived=True,
            horizon=len(act),
            rel_tol=self.rel_tol,
            t0=self.time,
            record=True,
        )
        times = np.asarray(res.completion_times, dtype=np.float64)
        if not np.all(np.isfinite(times)):
            raise RuntimeError("scheduler failed to converge (engine)")
        # Replay the trajectory into the event log / job table the Python
        # path would have produced (engine trace order == `act` order here:
        # every job is pre-arrived, so the engine's arrival sort is the
        # identity permutation).
        alloc = np.asarray(res.trace.alloc)
        sizes = np.asarray(res.trace.sizes)
        t_ev = np.asarray(res.trace.times)
        last_chips: dict[str, float] = {}
        for e in range(alloc.shape[0]):
            live = sizes[e] > 0
            if not live.any():
                break
            # Continuous mode records theta in the trace; the event log keeps
            # the Python path's unit (fractional *chips*, i.e. theta * N).
            chips = {
                ids[i]: (int(alloc[e, i]) if self.quantize
                         else float(alloc[e, i]) * self.n_chips)
                for i in range(len(ids))
                if live[i]
            }
            last_chips.update(chips)
            self.events.append(
                {"t": float(t_ev[e]), "event": "allocate", "chips": chips, "p": p}
            )
        for i, j in enumerate(act):
            j.remaining = 0.0
            j.chips = last_chips.get(j.job_id, 0)
            j.completion_time = float(times[i])
        for t, jid in sorted((float(times[i]), ids[i]) for i in range(len(ids))):
            self.events.append({"t": t, "event": "depart", "job": jid})
        self.time = float(np.max(times))
        return self._summary()

    def _summary(self) -> dict:
        times = {j.job_id: j.completion_time for j in self.jobs.values()}
        flows = {
            jid: t - self.jobs[jid].arrival_time for jid, t in times.items()
        }
        return {
            "completion_times": times,
            "total_flow_time": float(sum(flows.values())),
            "mean_flow_time": float(np.mean(list(flows.values()))),
            "makespan": float(max(times.values())),
        }

    def run_fluid_to_completion(self, *, use_engine: bool = True) -> dict:
        """Run the current job table to completion in the fluid model.

        Delegates to the scan engine when eligible (one jit'd device call);
        ``use_engine=False`` forces the per-event Python epoch loop
        (allocate -> advance to next departure -> repeat), which is the
        oracle the engine path is tested against event-for-event.
        """
        if use_engine and self.active_jobs() and self._engine_eligible():
            return self._run_fluid_engine()
        guard = 0
        while self.active_jobs():
            self.allocations()
            self.advance_fluid(until_departure=True)
            guard += 1
            if guard > 10 * len(self.jobs) + 100:
                raise RuntimeError("scheduler failed to converge")
        return self._summary()
