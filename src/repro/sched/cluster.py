"""ClusterScheduler: the paper's policies driving a real chip pool.

The scheduler owns the job table (remaining work, fitted p-hat) and, at every
*decision epoch* (job departure, arrival, failure — Thm 3 says allocations
only need to change at departures; arrivals/failures are the production
extensions, flagged as the paper's §4.3 heuristic), recomputes:

    theta = policy(remaining_sizes, p)        # heSRPT / heLRPT / SRPT / ...
    chips = quantize(theta, N)                # largest-remainder (+ slices)

``advance_fluid`` runs the fluid model for simulation/benchmarks;
``sched/elastic.py`` instead drives real training jobs and reports progress
back through ``report_progress``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.policies import make_policy
from repro.sched.estimator import SpeedupEstimator, blended_p
from repro.sched.quantize import quantize_allocation, snap_to_slices


@dataclass
class Job:
    job_id: str
    size: float  # total work units (e.g. training steps x step cost)
    p: float = 0.7  # prior speedup exponent
    remaining: float = -1.0
    arrival_time: float = 0.0
    chips: float = 0  # whole chips normally; fractional when quantize=False
    completion_time: Optional[float] = None
    estimator: SpeedupEstimator = field(default_factory=SpeedupEstimator)

    def __post_init__(self):
        if self.remaining < 0:
            self.remaining = self.size
        self.estimator.prior_p = self.p


class ClusterScheduler:
    def __init__(
        self,
        n_chips: int,
        *,
        policy: str = "hesrpt",
        min_chips: int = 1,
        snap_slices: bool = False,
        use_estimator: bool = False,
        quantize: bool = True,
    ):
        self.n_chips = n_chips
        self.policy_name = policy
        self.min_chips = min_chips
        self.snap_slices = snap_slices
        self.use_estimator = use_estimator
        # quantize=False keeps the paper's continuously-divisible allocation
        # (fractional chips) — the fluid reference that core/arrivals.py is
        # cross-checked against.
        self.quantize = quantize
        self.jobs: Dict[str, Job] = {}
        self.time = 0.0
        self.events: List[dict] = []

    # ------------------------------------------------------------- job table
    def add_job(self, job: Job) -> None:
        job.arrival_time = self.time
        self.jobs[job.job_id] = job
        self.events.append({"t": self.time, "event": "arrival", "job": job.job_id})

    def active_jobs(self) -> List[Job]:
        return [j for j in self.jobs.values() if j.remaining > 0]

    def effective_p(self) -> float:
        act = self.active_jobs()
        if not act:
            return 0.7
        if self.use_estimator:
            return blended_p([j.estimator for j in act], [j.remaining for j in act])
        return float(np.mean([j.p for j in act]))

    # ------------------------------------------------------ decision epochs
    def allocations(self) -> Dict[str, float]:
        """Recompute theta -> chips for the current active set (int-valued
        when quantizing, fractional chips when ``quantize=False``)."""
        import jax.numpy as jnp

        act = self.active_jobs()
        if not act:
            return {}
        p = self.effective_p()
        x = jnp.asarray([j.remaining for j in act])
        pol = make_policy(
            self.policy_name,
            n_servers=float(self.n_chips),
            alpha=float(np.median([j.remaining for j in act]) * p / self.n_chips),
        )
        theta = np.asarray(pol(x, p), dtype=np.float64)
        if self.quantize:
            chips = quantize_allocation(theta, self.n_chips, min_chips=self.min_chips)
            if self.snap_slices:
                chips = snap_to_slices(chips, self.n_chips)
            chips = [int(c) for c in chips]
        else:
            chips = [float(c) for c in theta * self.n_chips]
        out = {}
        for j, c in zip(act, chips):
            j.chips = c
            out[j.job_id] = c
        self.events.append(
            {"t": self.time, "event": "allocate", "chips": dict(out), "p": p}
        )
        return out

    # --------------------------------------------------------- progress I/O
    def report_progress(self, job_id: str, work_done: float,
                        wall_dt: float = 0.0) -> None:
        job = self.jobs[job_id]
        job.remaining = max(job.remaining - work_done, 0.0)
        if wall_dt > 0:
            self.time += 0.0  # wall time tracked by the driver
            job.estimator.observe(job.chips, work_done / wall_dt)
        if job.remaining == 0 and job.completion_time is None:
            job.completion_time = self.time
            self.events.append({"t": self.time, "event": "depart", "job": job_id})

    # --------------------------------------------------------- fluid model
    def advance_fluid(self, *, until_departure: bool = True, dt: float = 0.0):
        """Advance the fluid simulation: each job progresses at s(chips) =
        chips^p.  Used by benchmarks and the arrival-stream experiments."""
        act = self.active_jobs()
        if not act:
            return 0.0
        p = self.effective_p()
        rates = np.array([max(j.chips, 0) ** p for j in act])
        if until_departure:
            with np.errstate(divide="ignore"):
                tt = np.where(rates > 0, [j.remaining for j in act] / rates, np.inf)
            step = float(np.min(tt))
        else:
            step = dt
        if not np.isfinite(step):
            raise RuntimeError("no job can make progress (all rates zero)")
        self.time += step
        for j, r in zip(act, rates):
            j.remaining = max(j.remaining - step * r, 0.0)
            if j.remaining == 0 and j.completion_time is None:
                j.completion_time = self.time
                self.events.append({"t": self.time, "event": "depart", "job": j.job_id})
        return step

    def run_fluid_to_completion(self) -> dict:
        """Epoch loop: allocate -> advance to next departure -> repeat."""
        guard = 0
        while self.active_jobs():
            self.allocations()
            self.advance_fluid(until_departure=True)
            guard += 1
            if guard > 10 * len(self.jobs) + 100:
                raise RuntimeError("scheduler failed to converge")
        times = {j.job_id: j.completion_time for j in self.jobs.values()}
        flows = {
            jid: t - self.jobs[jid].arrival_time for jid, t in times.items()
        }
        return {
            "completion_times": times,
            "total_flow_time": float(sum(flows.values())),
            "mean_flow_time": float(np.mean(list(flows.values()))),
            "makespan": float(max(times.values())),
        }
