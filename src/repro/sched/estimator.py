"""Online estimation of the speedup exponent p from observed step throughput.

The paper assumes p is known a priori; in production we fit it.  With
``s(k) = c * k^p``, observed throughput T(k) at allocation k satisfies
``log T = log c + p log k`` — ordinary least squares over the (k, T) history,
optionally exponentially discounted so p tracks regime changes (e.g. a job
entering a communication-bound phase has its *effective* p drop).

``blended_p`` work-weights the per-job estimates into the single p heSRPT
uses (the paper's single-speedup assumption; documented approximation for
heterogeneous jobs, DESIGN.md §9).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class SpeedupEstimator:
    """Per-job (or per-job-class) p-hat from (chips, throughput) samples."""

    prior_p: float = 0.7
    prior_weight: float = 1.0
    discount: float = 1.0  # 1.0 = no forgetting
    history: list[tuple[float, float, float]] = field(default_factory=list)
    # entries: (log k, log T, weight)

    def observe(self, chips: float, throughput: float) -> None:
        if chips <= 0 or throughput <= 0:
            return
        for i, (lk, lt, w) in enumerate(self.history):
            self.history[i] = (lk, lt, w * self.discount)
        self.history.append((np.log(chips), np.log(throughput), 1.0))

    def p_hat(self) -> float:
        """OLS slope with a ridge-style pull toward the prior."""
        if len(self.history) < 2:
            return self.prior_p
        lk = np.array([h[0] for h in self.history])
        lt = np.array([h[1] for h in self.history])
        w = np.array([h[2] for h in self.history])
        wsum = w.sum()
        mk, mt = (w * lk).sum() / wsum, (w * lt).sum() / wsum
        var = (w * (lk - mk) ** 2).sum()
        cov = (w * (lk - mk) * (lt - mt)).sum()
        if var < 1e-12:
            return self.prior_p  # all samples at one allocation: unidentifiable
        slope = (cov + self.prior_weight * 0.0) / (var + self.prior_weight * 0.0 + 1e-12)
        # blend with prior by effective sample size
        alpha = var / (var + self.prior_weight)
        p = alpha * slope + (1 - alpha) * self.prior_p
        return float(np.clip(p, 0.01, 0.999))

    def rate_at(self, chips: float) -> float:
        """Predicted throughput c * k^p (c fit given p_hat)."""
        if not self.history:
            return chips ** self.p_hat()
        p = self.p_hat()
        lk = np.array([h[0] for h in self.history])
        lt = np.array([h[1] for h in self.history])
        w = np.array([h[2] for h in self.history])
        logc = ((lt - p * lk) * w).sum() / w.sum()
        return float(np.exp(logc) * chips ** p)


def blended_p(estimators, remaining_work) -> float:
    """Work-weighted mean p-hat across jobs (heSRPT needs one p)."""
    ps = np.array([e.p_hat() for e in estimators])
    w = np.asarray(remaining_work, dtype=np.float64)
    if w.sum() <= 0:
        return float(ps.mean()) if len(ps) else 0.7
    return float((ps * w).sum() / w.sum())
