"""Online estimation of the speedup exponent p from observed step throughput.

The paper assumes p is known a priori; in production we fit it.  With
``s(k) = c * k^p``, observed throughput T(k) at allocation k satisfies
``log T = log c + p log k`` — weighted least squares over the (k, T)
history, ridge-blended toward a prior and optionally exponentially
discounted so p tracks regime changes (e.g. a job entering a
communication-bound phase has its *effective* p drop).

``blended_p`` work-weights the per-job estimates into the single p heSRPT
uses (the paper assumes one speedup exponent; the blend is the documented
approximation for heterogeneous jobs — see the README architecture
section).  ``pooled_p_hat`` is the per-class variant: jobs of one class
share one true exponent, so the right fit is the WLS over their
concatenated histories.

This NumPy implementation is the per-event oracle; the jit-safe
recursive-WLS port that runs *inside* the allocation engine's scan lives
in ``repro/core/estimation.py`` (same ridge formula over sufficient
statistics, regression-tested to agree to float precision).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

# Single source of truth for the clip bounds (p=0 and p=1 are both
# degenerate for the Thm-7 brackets): the NumPy/JAX fp-agreement contract
# breaks silently if the two implementations clip differently.
from repro.core.estimation import P_CLIP


def _ridge_p_hat(lk, lt, w, prior_p: float, prior_weight: float) -> float:
    """Ridge-regularized WLS slope of ``lt`` on ``lk``: pulled toward
    ``prior_p`` with strength ``prior_weight``, i.e. ``alpha * OLS +
    (1 - alpha) * prior`` with ``alpha = var / (var + prior_weight)`` —
    the blend by effective sample size.  Falls back to the prior when the
    design is unidentifiable (all samples at one allocation)."""
    wsum = w.sum()
    mk, mt = (w * lk).sum() / wsum, (w * lt).sum() / wsum
    var = (w * (lk - mk) ** 2).sum()
    cov = (w * (lk - mk) * (lt - mt)).sum()
    if var < 1e-12:
        return prior_p  # all samples at one allocation: unidentifiable
    slope = (cov + prior_weight * prior_p) / (var + prior_weight + 1e-12)
    return float(np.clip(slope, *P_CLIP))


@dataclass
class SpeedupEstimator:
    """Per-job (or per-job-class) p-hat from (chips, throughput) samples."""

    prior_p: float = 0.7
    prior_weight: float = 1.0
    discount: float = 1.0  # 1.0 = no forgetting
    history: list[tuple[float, float, float]] = field(default_factory=list)
    # entries: (log k, log T, weight)

    def observe(self, chips: float, throughput: float) -> None:
        if chips <= 0 or throughput <= 0:
            return
        for i, (lk, lt, w) in enumerate(self.history):
            self.history[i] = (lk, lt, w * self.discount)
        self.history.append((np.log(chips), np.log(throughput), 1.0))

    def p_hat(self) -> float:
        """Ridge-blended WLS slope (see :func:`_ridge_p_hat`)."""
        if len(self.history) < 2:
            return self.prior_p
        lk = np.array([h[0] for h in self.history])
        lt = np.array([h[1] for h in self.history])
        w = np.array([h[2] for h in self.history])
        return _ridge_p_hat(lk, lt, w, self.prior_p, self.prior_weight)

    def rate_at(self, chips: float) -> float:
        """Predicted throughput c * k^p (c fit given p_hat)."""
        if not self.history:
            return chips ** self.p_hat()
        p = self.p_hat()
        lk = np.array([h[0] for h in self.history])
        lt = np.array([h[1] for h in self.history])
        w = np.array([h[2] for h in self.history])
        logc = ((lt - p * lk) * w).sum() / w.sum()
        return float(np.exp(logc) * chips ** p)


def blended_p(estimators, remaining_work) -> float:
    """Work-weighted mean p-hat across jobs (heSRPT needs one p)."""
    ps = np.array([e.p_hat() for e in estimators])
    w = np.asarray(remaining_work, dtype=np.float64)
    if w.sum() <= 0:
        return float(ps.mean()) if len(ps) else 0.7
    return float((ps * w).sum() / w.sum())


def pooled_p_hat(
    estimators, prior_p: float, prior_weight: float = 1.0
) -> float:
    """One p-hat from the *pooled* histories of several estimators.

    The per-class fit: every job of a class shares one true exponent, so
    the WLS over the concatenated (discounted) histories — equivalently
    the summed sufficient statistics, which is what the jit-safe twin
    ``repro.core.estimation.p_hat_classes`` accumulates — beats averaging
    per-job fits.  Falls back to ``prior_p`` below 2 pooled samples.
    """
    hist = [h for e in estimators for h in e.history]
    if len(hist) < 2:
        return prior_p
    lk = np.array([h[0] for h in hist])
    lt = np.array([h[1] for h in hist])
    w = np.array([h[2] for h in hist])
    return _ridge_p_hat(lk, lt, w, prior_p, prior_weight)
