"""Elastic training jobs under the heSRPT cluster scheduler.

Each ``ElasticJob`` is a real JAX training job (model, optimizer, data
stream) that can be RESIZED between scheduler epochs: its state is
checkpointed to disk, a new mesh is built over the newly-assigned device
subset, and the state is restored with the new mesh's shardings
(``train/checkpoint.py`` is deliberately mesh-agnostic).  Data parallelism
inside a job is an explicit ``shard_map`` (params replicated, batch sharded,
gradient ``psum``), which is also where gradient compression (int8 / top-k
with error feedback) intercepts the collective.

``ElasticClusterDriver`` couples the jobs to ``ClusterScheduler``: at every
departure epoch it asks the policy (heSRPT by default) for chip counts,
reassigns devices, resizes jobs, and advances the fluid clock while the jobs
do real training work.  Flow time accounting matches the paper's model:
job i on k chips progresses at rate s(k) = k^p work-units per unit time, and
allocations change only at departures (Thm 3).
"""

from __future__ import annotations

import os
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.data.pipeline import DataConfig, ShardedSyntheticStream
from repro.models import ModelOptions, build_model
from repro.models.common import shard_map
from repro.sched.cluster import ClusterScheduler, Job
from repro.sched.stragglers import StragglerDetector
from repro.train import checkpoint
from repro.train.compression import init_error_state, make_grad_reducer
from repro.train.optimizer import OptimizerConfig, apply_updates, init_opt_state


@dataclass
class ElasticJobConfig:
    job_id: str
    model_cfg: object  # ModelConfig (smoke-scale)
    total_steps: int
    seq_len: int = 32
    batch_per_chip: int = 2
    p: float = 0.7  # speedup exponent handed to the scheduler
    lr: float = 1e-3
    compression: str | None = None  # None | int8 | topk
    seed: int = 0


class ElasticJob:
    def __init__(self, cfg: ElasticJobConfig, ckpt_root: str):
        self.cfg = cfg
        self.ckpt_dir = os.path.join(ckpt_root, cfg.job_id)
        self.model = build_model(
            cfg.model_cfg, ModelOptions(activation_dtype="float32", remat="none")
        )
        self.opt_cfg = OptimizerConfig(
            lr=cfg.lr, warmup_steps=5, total_steps=cfg.total_steps, clip_norm=1.0
        )
        params = self.model.init(jax.random.PRNGKey(cfg.seed))
        self.state = {
            "params": params,
            "opt": init_opt_state(params),
            "err": init_error_state(params),
        }
        self.steps_done = 0
        self.losses: list[float] = []
        self.resizes = 0
        self.mesh: Mesh | None = None
        self.devices: tuple = ()
        self._step_fn = None

    # ------------------------------------------------------------- resizing
    def ensure_devices(self, devices) -> None:
        devices = tuple(devices)
        if devices == self.devices and self._step_fn is not None:
            return
        if self.mesh is not None:
            # REAL resize path: state -> disk -> restore under the new mesh.
            checkpoint.save(self.ckpt_dir, self.state, step=self.steps_done)
            self.resizes += 1
        self.devices = devices
        self.mesh = Mesh(np.array(devices), ("data",))
        rep = NamedSharding(self.mesh, P())
        shardings = jax.tree.map(lambda _: rep, self.state)
        if checkpoint.exists(self.ckpt_dir) and self.resizes > 0:
            self.state = checkpoint.restore(self.ckpt_dir, self.state, shardings)
        else:
            self.state = jax.device_put(self.state, rep)
        self._step_fn = self._build_step()

    def _build_step(self):
        model, opt_cfg = self.model, self.opt_cfg
        reducer = make_grad_reducer(self.cfg.compression, "data")

        def local_step(params, opt, err, batch):
            (loss, _), grads = jax.value_and_grad(model.loss_fn, has_aux=True)(
                params, batch
            )
            grads, err = reducer(grads, err)
            params, opt, _ = apply_updates(params, grads, opt, opt_cfg)
            return params, opt, err, jax.lax.pmean(loss, "data")

        shmapped = shard_map(
            local_step,
            mesh=self.mesh,
            in_specs=(P(), P(), P(), P("data")),
            out_specs=(P(), P(), P(), P()),
            check_vma=False,
        )
        return jax.jit(shmapped)

    # ------------------------------------------------------------- training
    def run_steps(self, n: int) -> int:
        n = min(n, self.cfg.total_steps - self.steps_done)
        if n <= 0 or self._step_fn is None:
            return 0
        gb = len(self.devices) * self.cfg.batch_per_chip
        stream = ShardedSyntheticStream(
            DataConfig(
                self.cfg.model_cfg.vocab_size, self.cfg.seq_len, gb,
                seed=self.cfg.seed,
            ),
            family=self.cfg.model_cfg.family,
            model_cfg=self.cfg.model_cfg,
        )
        for _ in range(n):
            batch = {
                k: jnp.asarray(v) for k, v in stream.batch(self.steps_done).items()
            }
            p, o, e, loss = self._step_fn(
                self.state["params"], self.state["opt"], self.state["err"], batch
            )
            self.state = {"params": p, "opt": o, "err": e}
            self.losses.append(float(loss))
            self.steps_done += 1
        return n

    @property
    def done(self) -> bool:
        return self.steps_done >= self.cfg.total_steps


class ElasticClusterDriver:
    """Couples ClusterScheduler epochs to real elastic training jobs."""

    def __init__(
        self,
        job_cfgs: list[ElasticJobConfig],
        devices,
        *,
        policy: str = "hesrpt",
        ckpt_root: str = "/tmp/repro_elastic",
        straggler_detector: StragglerDetector | None = None,
    ):
        self.devices = list(devices)
        self.scheduler = ClusterScheduler(len(self.devices), policy=policy)
        self.jobs: dict[str, ElasticJob] = {}
        for jc in job_cfgs:
            self.jobs[jc.job_id] = ElasticJob(jc, ckpt_root)
            self.scheduler.add_job(
                Job(jc.job_id, size=float(jc.total_steps), p=jc.p)
            )
        self.detector = straggler_detector
        self.allocation_log: list[dict] = []

    def run(self, max_epochs: int = 100) -> dict:
        sched = self.scheduler
        for _ in range(max_epochs):
            act = sched.active_jobs()
            if not act:
                break
            alloc = sched.allocations()
            # contiguous device assignment, largest allocation first
            cursor = 0
            order = sorted(alloc, key=lambda j: -alloc[j])
            for jid in order:
                k = alloc[jid]
                if k <= 0:
                    continue
                devs = self.devices[cursor : cursor + k]
                cursor += k
                self.jobs[jid].ensure_devices(devs)
            self.allocation_log.append({"t": sched.time, "alloc": dict(alloc)})

            # fluid epoch: until the fastest-finishing job departs
            p = sched.effective_p()
            rates = {j.job_id: max(j.chips, 0) ** p for j in act}
            dt = min(
                j.remaining / rates[j.job_id] for j in act if rates[j.job_id] > 0
            )
            for j in act:
                steps = int(round(rates[j.job_id] * dt))
                steps = min(steps, int(round(j.remaining)))
                if j.remaining - steps < 0.5:  # finish the departing job exactly
                    steps = int(round(j.remaining))
                done = self.jobs[j.job_id].run_steps(steps)
                sched.time += 0.0
                sched.report_progress(j.job_id, float(done))
            sched.time += dt
            for j in act:
                if j.remaining <= 0 and j.completion_time is None:
                    j.completion_time = sched.time
        flows = {
            jid: (j.completion_time or sched.time) - j.arrival_time
            for jid, j in sched.jobs.items()
        }
        return {
            "total_flow_time": float(sum(flows.values())),
            "mean_flow_time": float(np.mean(list(flows.values()))),
            "makespan": float(max(flows.values())),
            "losses": {jid: job.losses for jid, job in self.jobs.items()},
            "resizes": {jid: job.resizes for jid, job in self.jobs.items()},
            "allocations": self.allocation_log,
        }
