"""train_step / serve_step factories.

``make_train_step(model, tc)`` returns a pure
``(params, opt_state, batch) -> (params, opt_state, metrics)`` suitable for
``jax.jit`` with in/out shardings.  Gradient accumulation over
``tc.microbatches`` runs as a ``lax.scan`` so the peak live activation set is
one microbatch (the standard way a 4k x 256 global batch fits HBM).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.train.optimizer import OptimizerConfig, apply_updates, init_opt_state


@dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    # Cast >=2D fp32 params to bf16 BEFORE use (i.e. before GSPMD's FSDP
    # all-gathers): halves weight-gather collective + HBM traffic.  Grads
    # flow through the cast, so masters/moments stay fp32.
    cast_params_bf16: bool = False


def _split_micro(batch: dict, n: int) -> dict:
    def r(x):
        b = x.shape[0]
        assert b % n == 0, f"global batch {b} not divisible by {n} microbatches"
        return x.reshape(n, b // n, *x.shape[1:])

    return {k: r(v) for k, v in batch.items()}


def make_train_step(model, tc: TrainConfig):
    n_micro = tc.microbatches

    def loss_with_cast(params, mb):
        if tc.cast_params_bf16:
            params = jax.tree.map(
                lambda x: x.astype(jnp.bfloat16)
                if (x.dtype == jnp.float32 and x.ndim >= 2)
                else x,
                params,
            )
        return model.loss_fn(params, mb)

    def train_step(params, opt_state, batch):
        if n_micro == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_with_cast, has_aux=True)(
                params, batch
            )
        else:
            micro = _split_micro(batch, n_micro)

            def acc_fn(carry, mb):
                g_acc, l_acc = carry
                (loss, _), g = jax.value_and_grad(loss_with_cast, has_aux=True)(
                    params, mb
                )
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g
                )
                return (g_acc, l_acc + loss), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, loss_sum), _ = jax.lax.scan(
                acc_fn, (g0, jnp.zeros((), jnp.float32)), micro
            )
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = loss_sum / n_micro
            metrics = {}

        params, opt_state, opt_metrics = apply_updates(
            params, grads, opt_state, tc.optimizer
        )
        metrics = {"loss": loss, **metrics, **opt_metrics}
        return params, opt_state, metrics

    return train_step


def make_init_fn(model, tc: TrainConfig):
    """(rng) -> (params, opt_state): jit-able so the dry-run can shard init."""

    def init_fn(rng):
        params = model.init(rng)
        return params, init_opt_state(params)

    return init_fn


def make_prefill_step(model):
    def prefill_step(params, batch):
        return model.prefill_fn(params, batch)

    return prefill_step


def make_decode_step(model):
    """One new token against an existing KV cache (the grid's decode cells)."""

    def decode_step(params, tokens, caches, cache_length):
        return model.decode_fn(params, tokens, caches, cache_length)

    return decode_step
