"""Serving steps (documented layout alias): prefill + single-token decode.

The factories live in ``train_step.py`` next to the train step so the three
step constructors share TrainConfig/microbatch plumbing; this module is the
stable import path used by serving code.
"""

from repro.train.train_step import make_decode_step, make_prefill_step

__all__ = ["make_decode_step", "make_prefill_step"]
