"""Fault tolerance: heartbeats, failure detection, checkpoint-restart loop.

On a real multi-pod deployment every host runs a ``Heartbeat`` reporter and
the coordinator runs ``FailureDetector``; in this single-process container
the same code paths are exercised with *injected* failures (tests flip a
worker's heartbeat off and assert the training loop restores from the last
checkpoint and converges anyway).

``run_with_recovery`` is the generic loop: it steps a training function,
checkpoints every ``ckpt_every`` steps, and on (injected or real) failure
restores params/opt_state from the last checkpoint and replays.  Straggler
detection lives in ``sched/stragglers.py`` (it needs the speedup model).
"""

from __future__ import annotations

import time
from collections.abc import Callable
from dataclasses import dataclass, field

import jax

from repro.train import checkpoint


@dataclass
class Heartbeat:
    """Last-seen timestamps per worker id."""

    timeout_s: float = 30.0
    last_seen: dict[int, float] = field(default_factory=dict)

    def beat(self, worker: int, now: float | None = None) -> None:
        self.last_seen[worker] = time.monotonic() if now is None else now

    def dead_workers(self, now: float | None = None) -> list:
        now = time.monotonic() if now is None else now
        return [w for w, t in self.last_seen.items() if now - t > self.timeout_s]


class FailureInjector:
    """Deterministic failure schedule for tests: fail at given step numbers."""

    def __init__(self, fail_at_steps=()):
        self.fail_at = set(fail_at_steps)
        self.injected = []

    def check(self, step: int) -> bool:
        if step in self.fail_at:
            self.fail_at.remove(step)
            self.injected.append(step)
            return True
        return False


def run_with_recovery(
    step_fn: Callable,  # (params, opt_state, batch) -> (params, opt_state, metrics)
    batches: Callable,  # (step) -> batch
    params,
    opt_state,
    *,
    n_steps: int,
    ckpt_dir: str,
    ckpt_every: int = 10,
    injector: FailureInjector | None = None,
    shardings=None,
    on_metrics: Callable | None = None,
):
    """Train for ``n_steps`` surviving failures.  Returns (params, opt_state,
    history) where history records losses and recovery events."""
    history = {"loss": [], "recoveries": []}
    state = {"params": params, "opt_state": opt_state}
    checkpoint.save(ckpt_dir, state, step=0)

    step = 0
    while step < n_steps:
        if injector is not None and injector.check(step):
            # Simulated node failure: wipe live state, restore from disk.
            manifest = checkpoint.load_manifest(ckpt_dir)
            state = checkpoint.restore(ckpt_dir, state, shardings)
            history["recoveries"].append(
                {"failed_at": step, "resumed_from": manifest["step"]}
            )
            step = manifest["step"]
            continue

        params, opt_state, metrics = step_fn(
            state["params"], state["opt_state"], batches(step)
        )
        state = {"params": params, "opt_state": opt_state}
        history["loss"].append(float(jax.device_get(metrics["loss"])))
        if on_metrics is not None:
            on_metrics(step, metrics)
        step += 1
        if step % ckpt_every == 0:
            checkpoint.save(ckpt_dir, state, step=step)

    checkpoint.save(ckpt_dir, state, step=n_steps)
    return state["params"], state["opt_state"], history
