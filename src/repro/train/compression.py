"""Gradient compression for the data-parallel all-reduce, with error feedback.

Two schemes:
- ``int8``: per-leaf symmetric quantization.  The psum runs on int32
  accumulators of int8 payloads — 4x less link traffic than fp32 (8x vs the
  naive fp32 tree at the wire level when links carry the int8 payload;
  we model the accumulate-at-int32 TPU collective).
- ``topk``: keep the largest ``k_frac`` fraction of entries per leaf (by
  magnitude), psum the sparse values densified (value-only traffic reduction
  is realized on hardware via gather-based collectives; under GSPMD we model
  it as a masked dense psum and account the traffic analytically).

Both keep per-shard ERROR FEEDBACK: the quantization/sparsification residual
is added back into the next step's gradient, which is what keeps SGD/Adam
convergence intact (Karimireddy et al., 2019).

Used by the elastic data-parallel trainer (``sched/elastic.py``), where the
gradient all-reduce is an explicit ``psum`` inside ``shard_map`` — the only
place compression can actually intercept the collective.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quant_int8(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_psum_int8(grads, err, axis_name: str):
    """Per-leaf int8 quantize (+error feedback) -> psum(int32) -> dequant.
    Returns (mean_grads, new_err).  Runs inside shard_map/pmap."""
    n = jax.lax.psum(1, axis_name)

    def leaf(g, e):
        g = g.astype(jnp.float32) + e
        q, scale = _quant_int8(g)
        local = _dequant_int8(q, scale)
        new_e = g - local
        tot = jax.lax.psum(q.astype(jnp.int32), axis_name).astype(jnp.float32)
        # every shard has its own scale; psum the scaled payloads' mean scale
        mean_scale = jax.lax.psum(scale, axis_name) / n
        return tot * mean_scale / n, new_e

    flat, treedef = jax.tree.flatten(grads)
    eflat = treedef.flatten_up_to(err)
    out = [leaf(g, e) for g, e in zip(flat, eflat, strict=True)]
    return treedef.unflatten([o[0] for o in out]), treedef.unflatten(
        [o[1] for o in out]
    )


def compress_psum_topk(grads, err, axis_name: str, k_frac: float = 0.1):
    """Magnitude top-k sparsification (+error feedback) -> psum.
    Traffic model: only k_frac of values cross the link (accounted
    analytically in the roofline); numerically we psum the masked tree."""
    n = jax.lax.psum(1, axis_name)

    def leaf(g, e):
        g = g.astype(jnp.float32) + e
        flat = g.reshape(-1)
        k = max(1, int(flat.shape[0] * k_frac))
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        mask = (jnp.abs(g) >= thresh).astype(jnp.float32)
        kept = g * mask
        new_e = g - kept
        tot = jax.lax.psum(kept, axis_name)
        return tot / n, new_e

    flat, treedef = jax.tree.flatten(grads)
    eflat = treedef.flatten_up_to(err)
    out = [leaf(g, e) for g, e in zip(flat, eflat, strict=True)]
    return treedef.unflatten([o[0] for o in out]), treedef.unflatten(
        [o[1] for o in out]
    )


def plain_psum(grads, axis_name: str):
    n = jax.lax.psum(1, axis_name)
    return jax.tree.map(lambda g: jax.lax.psum(g, axis_name) / n, grads)


def make_grad_reducer(scheme: str | None, axis_name: str, k_frac: float = 0.1):
    """Returns reduce(grads, err) -> (mean_grads, new_err)."""
    if scheme is None or scheme == "none":
        return lambda g, e: (plain_psum(g, axis_name), e)
    if scheme == "int8":
        return lambda g, e: compress_psum_int8(g, e, axis_name)
    if scheme == "topk":
        return lambda g, e: compress_psum_topk(g, e, axis_name, k_frac)
    raise ValueError(f"unknown compression scheme {scheme!r}")
