"""Mesh-agnostic numpy-tree checkpoints — the mechanism heSRPT's elasticity
rides on.

``save`` pulls every leaf to host and writes one ``.npz`` plus a JSON
manifest of flattened tree paths.  ``restore`` rebuilds the tree and
``device_put``s each leaf with the *target* sharding — which may belong to a
completely different mesh shape than the checkpoint was written from.  A
resize (checkpoint on 8 chips -> restore on 2) is therefore exactly
save + restore.  Writes are atomic (tmp + rename) so a crash mid-save never
corrupts the latest checkpoint.
"""

from __future__ import annotations

import json
import os
import tempfile

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out


def save(path: str, tree, *, step: int = 0, extra: dict | None = None) -> None:
    os.makedirs(path, exist_ok=True)
    arrays = _flatten(tree)
    manifest = {
        "step": step,
        "keys": sorted(arrays),
        "extra": extra or {},
    }
    fd, tmp = tempfile.mkstemp(dir=path, suffix=".tmp.npz")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, os.path.join(path, "arrays.npz"))
    fd, tmp = tempfile.mkstemp(dir=path, suffix=".json.tmp")
    with os.fdopen(fd, "w") as f:
        json.dump(manifest, f)
    os.replace(tmp, os.path.join(path, "manifest.json"))


def load_manifest(path: str) -> dict:
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)


def restore(path: str, target_tree, shardings=None):
    """Rebuild ``target_tree``'s structure from disk.  ``target_tree`` may be
    arrays or ShapeDtypeStructs (only structure/shape/dtype are used).
    ``shardings``: matching pytree of Sharding (or None -> default device)."""
    with np.load(os.path.join(path, "arrays.npz")) as data:
        arrays = {k: data[k] for k in data.files}

    flat, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    shard_flat = (
        treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(flat)
    )
    leaves = []
    for (path_keys, leaf), sh in zip(flat, shard_flat, strict=True):
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path_keys
        )
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs target {leaf.shape}"
            )
        arr = arr.astype(leaf.dtype)
        leaves.append(jax.device_put(arr, sh) if sh is not None else jax.device_put(arr))
    return treedef.unflatten(leaves)


def exists(path: str) -> bool:
    return os.path.exists(os.path.join(path, "manifest.json")) and os.path.exists(
        os.path.join(path, "arrays.npz")
    )
