"""Training substrate: optimizer, train/serve steps, checkpointing,
gradient compression, fault tolerance."""

from repro.train.optimizer import OptimizerConfig, apply_updates, init_opt_state
from repro.train.train_step import (
    TrainConfig,
    make_decode_step,
    make_init_fn,
    make_prefill_step,
    make_train_step,
)

__all__ = [
    "OptimizerConfig",
    "TrainConfig",
    "apply_updates",
    "init_opt_state",
    "make_decode_step",
    "make_init_fn",
    "make_prefill_step",
    "make_train_step",
]
