"""AdamW with warmup-cosine schedule and global-norm clipping.  No optax —
the optimizer is part of the substrate we own.

Parameters are fp32 masters (model code casts to the activation dtype at use
sites, so grads arrive fp32).  Moments are fp32 and shaped like the params,
hence they shard with the same PartitionSpecs (FSDP applies to optimizer
state for free).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 200
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(step: jax.Array, cfg: OptimizerConfig) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_frac * lr."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def init_opt_state(params, *, keep_master: bool = False):
    """``keep_master=True`` is the mixed-precision layout: params are stored
    bf16 (so every FSDP gather / HBM read moves half the bytes) and the fp32
    master copy lives here, updated by AdamW and re-cast to the param dtype
    each step."""
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    out = {
        "m": zeros,
        "v": jax.tree.map(jnp.copy, zeros),
        "step": jnp.zeros((), jnp.int32),
    }
    if keep_master:
        out["master"] = jax.tree.map(
            lambda p: jnp.asarray(p, jnp.float32), params
        )
    return out


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), norm


def apply_updates(params, grads, opt_state, cfg: OptimizerConfig):
    """One AdamW step.  Returns (params, opt_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.clip_norm > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)

    step = opt_state["step"] + 1
    lr = schedule(step, cfg)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, pm, g, m, v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * pm
        new_master = pm - lr * delta
        return new_master.astype(p.dtype), m, v, new_master

    masters = opt_state.get("master")
    flat_p, treedef = jax.tree.flatten(params)
    flat_pm = (
        treedef.flatten_up_to(masters)
        if masters is not None
        else [p.astype(jnp.float32) for p in flat_p]
    )
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [
        upd(p, pm.astype(jnp.float32), g, m, v)
        for p, pm, g, m, v in zip(flat_p, flat_pm, flat_g, flat_m, flat_v, strict=True)
    ]
    new_params = treedef.unflatten([o[0] for o in out])
    new_opt = {
        "m": treedef.unflatten([o[1] for o in out]),
        "v": treedef.unflatten([o[2] for o in out]),
        "step": step,
    }
    if masters is not None:
        new_opt["master"] = treedef.unflatten([o[3] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_opt, metrics
