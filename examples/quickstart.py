"""Quickstart: the paper in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

1. Computes the optimal heSRPT allocation for a job set (Theorem 7).
2. Simulates it and checks the closed-form total flow time (Theorem 8).
3. Shows the makespan-optimal heLRPT allocation (Theorem 2).
4. Runs the cluster scheduler with quantized (whole-chip) allocations.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import (  # noqa: E402
    helrpt,
    hesrpt,
    hesrpt_total_flowtime,
    optimal_makespan,
    simulate,
)
from repro.sched import ClusterScheduler, Job  # noqa: E402


def main():
    # --- 1. the paper's §1 example: 2 unit jobs, p=.5, the 75/25 split ----
    x = jnp.asarray([1.0, 1.0])
    print("two unit jobs, p=0.5  ->  theta* =", np.asarray(hesrpt(x, 0.5)))

    # --- 2. a bigger job set ---------------------------------------------
    sizes = jnp.asarray([8.0, 5.0, 3.0, 2.0, 1.0])
    p, n = 0.5, 100.0
    theta = hesrpt(sizes, p)
    print("\n5 jobs (descending size), theta* =", np.round(np.asarray(theta), 4))
    res = simulate(sizes, p, n, hesrpt)
    closed = hesrpt_total_flowtime(sizes, p, n)
    print(f"total flow time: simulated={float(res.total_flowtime):.6f} "
          f"closed-form={float(closed):.6f}")

    # --- 3. makespan instead? heLRPT finishes everyone simultaneously ----
    gamma = helrpt(sizes, p)
    mk = simulate(sizes, p, n, helrpt)
    print(f"\nheLRPT gamma* = {np.round(np.asarray(gamma), 4)}")
    print(f"makespan: simulated={float(mk.makespan):.6f} "
          f"closed-form={float(optimal_makespan(sizes, p, n)):.6f}")
    print("completion times:", np.round(np.asarray(mk.completion_times), 6))

    # --- 4. whole-chip cluster scheduling --------------------------------
    sched = ClusterScheduler(64, policy="hesrpt")
    for i, s in enumerate(np.asarray(sizes)):
        sched.add_job(Job(f"job{i}", size=float(s), p=p))
    alloc = sched.allocations()
    print("\n64-chip cluster, quantized heSRPT allocation:", alloc)
    out = sched.run_fluid_to_completion()
    print(f"cluster total flow time: {out['total_flow_time']:.4f} "
          f"(fluid optimum {float(hesrpt_total_flowtime(sizes, p, 64.0)):.4f})")


if __name__ == "__main__":
    main()
