"""The paper end-to-end: heSRPT schedules elastic training jobs on a chip
pool, resizing them at every departure epoch.

    python examples/train_cluster_elastic.py            # 8 fake devices
    python examples/train_cluster_elastic.py --policy equi   # compare

Four real training jobs with known sizes (total steps) share 8 devices.
The heSRPT allocation gives the smallest job the largest share (Theorem 7's
counter-intuitive split), departures trigger checkpoint -> remesh -> restore
resizes, and the achieved total flow time is compared against the paper's
fluid-optimum closed form and against EQUI/SRPT run the same way.
"""

import os
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse  # noqa: E402
import tempfile  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import smoke_config  # noqa: E402
from repro.core import hesrpt_total_flowtime  # noqa: E402
from repro.sched import ElasticClusterDriver, ElasticJobConfig  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="hesrpt",
                    choices=["hesrpt", "equi", "srpt", "helrpt"])
    ap.add_argument("--p", type=float, default=0.5)
    ap.add_argument("--sizes", type=int, nargs="*", default=[32, 16, 8, 4])
    args = ap.parse_args()

    cfg = smoke_config("phi4-mini-3.8b")
    jobs = [
        ElasticJobConfig(f"job{i}", cfg, total_steps=s, p=args.p, seed=i)
        for i, s in enumerate(args.sizes)
    ]
    driver = ElasticClusterDriver(
        jobs, jax.devices(), policy=args.policy, ckpt_root=tempfile.mkdtemp()
    )
    res = driver.run()

    x = jnp.asarray(sorted(map(float, args.sizes), reverse=True))
    opt = float(hesrpt_total_flowtime(x, args.p, float(len(jax.devices()))))
    print(f"\npolicy={args.policy}  p={args.p}  devices={len(jax.devices())}")
    print(f"achieved total flow time : {res['total_flow_time']:.3f}")
    print(f"heSRPT fluid optimum     : {opt:.3f}")
    print(f"resizes (ckpt->remesh->restore): {res['resizes']}")
    for jid, losses in res["losses"].items():
        print(f"  {jid}: loss {losses[0]:.3f} -> {losses[-1]:.3f} ({len(losses)} steps)")
    print("allocation trace:")
    for a in res["allocations"]:
        print(f"  t={a['t']:6.2f}  {a['alloc']}")


if __name__ == "__main__":
    main()
