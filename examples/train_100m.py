"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

    PYTHONPATH=src python examples/train_100m.py [--steps 300]

Builds a 12-layer, d_model=512 phi4-family decoder (~100M params with its
200k vocab), streams the deterministic synthetic pipeline, runs the
microbatched AdamW train step with checkpointing + fault-tolerant restart,
and reports the loss curve.  Several hundred steps take a few minutes on
this CPU container; on real hardware the same script scales via the mesh
flags in launch/train.py (this example keeps everything single-host).
"""

import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config  # noqa: E402
from repro.data.pipeline import make_stream_for  # noqa: E402
from repro.models import ModelOptions, build_model  # noqa: E402
from repro.train import TrainConfig, make_train_step  # noqa: E402
from repro.train.ft import run_with_recovery  # noqa: E402
from repro.train.optimizer import OptimizerConfig, init_opt_state  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m")
    args = ap.parse_args()

    # ~100M params: phi4 family scaled to 12 x 512 with a 32k vocab.
    cfg = get_config("phi4-mini-3.8b").scaled(
        n_layers=12, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
        d_ff=1536, vocab_size=32768,
    )
    model = build_model(cfg, ModelOptions(activation_dtype="float32", remat="none"))
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"model: {cfg.name} scaled -> {n_params/1e6:.1f}M params")

    tc = TrainConfig(
        microbatches=2,
        optimizer=OptimizerConfig(lr=6e-4, warmup_steps=20, total_steps=args.steps),
    )
    step = jax.jit(make_train_step(model, tc))
    opt = init_opt_state(params)
    stream = make_stream_for(cfg, args.seq_len, args.global_batch)

    t0 = time.time()

    def on_metrics(s, m):
        if s % 20 == 0:
            tps = args.global_batch * args.seq_len * (s + 1) / (time.time() - t0)
            print(f"step {s:4d} loss {float(m['loss']):.4f} tok/s {tps:,.0f}",
                  flush=True)

    params, opt, hist = run_with_recovery(
        step, lambda s: {k: jnp.asarray(v) for k, v in stream.batch(s).items()},
        params, opt, n_steps=args.steps, ckpt_dir=args.ckpt_dir, ckpt_every=100,
        on_metrics=on_metrics,
    )
    print(f"\nloss: {hist['loss'][0]:.4f} -> {hist['loss'][-1]:.4f} "
          f"over {len(hist['loss'])} steps "
          f"({time.time()-t0:.0f}s)")


if __name__ == "__main__":
    main()
