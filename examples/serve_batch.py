"""Batched serving with KV caches: prefill a batch of prompts, then decode.

    PYTHONPATH=src python examples/serve_batch.py --arch mixtral-8x7b

Uses the smoke-scale config of the chosen architecture (any of the 10
assigned archs works — SSM/hybrid archs carry state caches instead of KV).
Demonstrates the ring-buffer sliding-window cache: for mixtral the cache
capacity is the SWA window, not the sequence length.
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import ARCH_IDS, smoke_config  # noqa: E402
from repro.launch.serve import generate  # noqa: E402
from repro.models import ModelOptions, build_model  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b", choices=list(ARCH_IDS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen-len", type=int, default=24)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    model = build_model(cfg, ModelOptions(activation_dtype="float32", remat="none"))
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.n_patches, cfg.d_model)),
            jnp.float32) * 0.02
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.encoder_seq, cfg.d_model)),
            jnp.float32) * 0.02

    t0 = time.time()
    ids = generate(model, params, batch, gen_len=args.gen_len)
    dt = time.time() - t0
    print(f"arch={args.arch} ({cfg.family})  batch={args.batch}")
    print(f"prefill {args.prompt_len} + decode {args.gen_len}: {dt:.2f}s "
          f"({args.batch*args.gen_len/dt:.1f} tok/s on CPU)")
    if cfg.window:
        _, caches = model.prefill_fn(params, batch,
                                     max_len=args.prompt_len + args.gen_len)
        k = jax.tree.leaves(caches)[0]
        print(f"sliding-window ring cache: capacity {k.shape} "
              f"(window={cfg.window}, not seq)")
    print("first sequence:", np.asarray(ids[0])[:16], "...")


if __name__ == "__main__":
    main()
