"""Closed forms (Thm 2, Thm 8) vs the event-driven simulator — exact math."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    hesrpt,
    hesrpt_completion_times,
    hesrpt_sd_mean_slowdown,
    hesrpt_total_flowtime,
    helrpt,
    make_policy,
    omega_star,
    omega_weighted,
    optimal_makespan,
    simulate,
    speedup,
    weighted_hesrpt,
    weighted_total_flowtime,
)


@pytest.mark.parametrize("p", [0.05, 0.3, 0.5, 0.9, 0.99])
def test_theorem8_matches_simulation(p):
    rng = np.random.default_rng(0)
    x = np.sort(rng.pareto(1.5, size=50) + 1.0)[::-1].copy()  # descending
    n = 1e6
    closed = hesrpt_total_flowtime(jnp.asarray(x), p, n)
    sim = simulate(jnp.asarray(x), p, n, hesrpt)
    np.testing.assert_allclose(closed, sim.total_flowtime, rtol=1e-8)


@pytest.mark.parametrize("p", [0.3, 0.5, 0.9])
def test_completion_times_closed_form_matches_sim(p):
    rng = np.random.default_rng(1)
    x = np.sort(rng.pareto(1.5, size=20) + 1.0)[::-1].copy()
    n = 1000.0
    times = hesrpt_completion_times(jnp.asarray(x), p, n)
    sim = simulate(jnp.asarray(x), p, n, hesrpt)
    np.testing.assert_allclose(times, sim.completion_times, rtol=1e-8)


@pytest.mark.parametrize("p", [0.05, 0.5, 0.99])
def test_theorem2_makespan_matches_helrpt_sim(p):
    rng = np.random.default_rng(2)
    x = rng.pareto(1.5, size=30) + 1.0
    n = 1e4
    closed = optimal_makespan(jnp.asarray(x), p, n)
    sim = simulate(jnp.asarray(x), p, n, helrpt)
    np.testing.assert_allclose(closed, sim.makespan, rtol=1e-8)
    # Thm 1: ALL jobs complete at the same time under heLRPT.
    np.testing.assert_allclose(
        sim.completion_times, np.full(30, float(closed)), rtol=1e-8
    )


def test_omega_star_increasing():
    om = omega_star(100, 0.5)
    assert om[0] == 0
    assert np.all(np.diff(np.asarray(om)[1:]) > 0)  # Lemma 3


@pytest.mark.parametrize("p", [0.1, 0.5, 0.9])
def test_hesrpt_beats_makespan_equality_on_flowtime(p):
    """heSRPT total flow <= heLRPT total flow (heLRPT optimizes makespan)."""
    rng = np.random.default_rng(3)
    x = rng.pareto(1.5, size=25) + 1.0
    n = 1e4
    f_srpt = simulate(jnp.asarray(x), p, n, hesrpt).total_flowtime
    f_lrpt = simulate(jnp.asarray(x), p, n, helrpt).total_flowtime
    assert float(f_srpt) <= float(f_lrpt) * (1 + 1e-9)


@pytest.mark.parametrize("p", [0.1, 0.5, 0.9])
def test_helrpt_beats_hesrpt_on_makespan(p):
    rng = np.random.default_rng(4)
    x = rng.pareto(1.5, size=25) + 1.0
    n = 1e4
    m_lrpt = simulate(jnp.asarray(x), p, n, helrpt).makespan
    m_srpt = simulate(jnp.asarray(x), p, n, hesrpt).makespan
    assert float(m_lrpt) <= float(m_srpt) * (1 + 1e-9)


@pytest.mark.parametrize("name", ["srpt", "equi", "hell", "knee"])
@pytest.mark.parametrize("p", [0.05, 0.3, 0.5, 0.9, 0.99])
def test_hesrpt_is_optimal_vs_competitors(name, p):
    """The paper's headline claim: heSRPT minimizes total flow time."""
    rng = np.random.default_rng(5)
    x = rng.pareto(1.5, size=40) + 1.0
    n = 1e6
    pol = make_policy(name, n_servers=n, alpha=np.sqrt(p * np.median(x) / n))
    f_opt = simulate(jnp.asarray(x), p, n, hesrpt).total_flowtime
    f_other = simulate(jnp.asarray(x), p, n, pol).total_flowtime
    assert float(f_opt) <= float(f_other) * (1 + 1e-9), (
        f"heSRPT={float(f_opt)} vs {name}={float(f_other)} at p={p}"
    )


# ------------------------------------- Berg-2020 slowdown (weighted Thm 8)
def test_weighted_flowtime_reduces_to_theorem8_with_uniform_weights():
    """W_k = k collapses the weighted closed form onto Theorem 8 exactly
    (the coefficient identity (k^c - (k-1)^c)^(1-p) == k s(1+w_k) -
    (k-1) s(w_k))."""
    rng = np.random.default_rng(7)
    x = np.sort(rng.pareto(1.5, 40) + 1.0)[::-1].copy()
    for p in (0.05, 0.3, 0.5, 0.9, 0.99):
        a = float(weighted_total_flowtime(jnp.asarray(x), jnp.ones(40), p, 512.0))
        b = float(hesrpt_total_flowtime(jnp.asarray(x), p, 512.0))
        np.testing.assert_allclose(a, b, rtol=1e-12)


def test_omega_weighted_reduces_to_omega_star():
    om_w = omega_weighted(jnp.ones(50), 0.37)
    om = omega_star(50, 0.37)
    np.testing.assert_allclose(np.asarray(om_w), np.asarray(om), rtol=1e-12)


@pytest.mark.parametrize("p", [0.05, 0.3, 0.5, 0.9, 0.99])
def test_weighted_closed_form_matches_weighted_sim(p):
    """The weighted bracket policy's achieved sum w_k T_k equals the
    weighted Thm-8 analogue for size-monotone weights (w = 1/x here, the
    Berg-2020 slowdown weights)."""
    rng = np.random.default_rng(8)
    x = np.sort(rng.pareto(1.5, 30) + 1.0)[::-1].copy()
    xj = jnp.asarray(x)
    w = 1.0 / xj
    closed = float(weighted_total_flowtime(xj, w, p, 1e4))
    res = simulate(xj, p, 1e4, lambda xs, ps: weighted_hesrpt(xs, ps, w))
    sim = float(jnp.sum(w * res.completion_times))
    np.testing.assert_allclose(sim, closed, rtol=1e-9)


@pytest.mark.parametrize("p", [0.3, 0.5, 0.9])
def test_hesrpt_sd_mean_slowdown_closed_form(p):
    """hesrpt_sd's batch mean slowdown == the closed-form oracle, and it
    beats unweighted heSRPT on the slowdown objective (that's what the
    1/x weighting buys)."""
    rng = np.random.default_rng(9)
    x = np.sort(rng.pareto(1.5, 25) + 1.0)[::-1].copy()
    xj = jnp.asarray(x)
    n = 1e4
    closed = float(hesrpt_sd_mean_slowdown(xj, p, n))
    w = 1.0 / xj
    res = simulate(xj, p, n, lambda xs, ps: weighted_hesrpt(xs, ps, w))
    sn = float(speedup(jnp.asarray(n), p))
    sim = float(jnp.mean(res.completion_times * sn / xj))
    np.testing.assert_allclose(sim, closed, rtol=1e-9)
    res_he = simulate(xj, p, n, hesrpt)
    sd_he = float(jnp.mean(res_he.completion_times * sn / xj))
    assert closed <= sd_he * (1 + 1e-9)


def test_simulation_is_jittable_and_vmappable():
    xs = jnp.asarray(np.random.default_rng(6).pareto(1.5, (4, 16)) + 1.0)
    f = jax.jit(jax.vmap(lambda x: simulate(x, 0.5, 100.0, hesrpt).total_flowtime))
    out = f(xs)
    assert out.shape == (4,)
    assert np.all(np.isfinite(np.asarray(out)))
