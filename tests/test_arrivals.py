"""Online (arrival-stream) simulator: batch limit, cluster cross-check,
policy dominance under load, jit/vmap, trace-driven arrivals."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_policy, simulate, speedup
from repro.core.arrivals import (
    deterministic_arrivals,
    load_sweep,
    pareto_sizes,
    poisson_arrivals,
    simulate_online,
    simulate_online_ranked,
)
from repro.core.policies import make_rank_policy

ONLINE_POLICIES = ("hesrpt", "equi", "srpt")


@pytest.mark.parametrize("name", ONLINE_POLICIES)
@pytest.mark.parametrize("p", [0.3, 0.9])
def test_batch_limit_matches_offline_simulator(name, p):
    """All arrivals at t=0 — the online scan must reproduce the batch-only
    simulator job-for-job (same epochs, same fp ops)."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.pareto(1.5, 24) + 1.0)
    pol = make_policy(name, n_servers=1e3)
    off = simulate(x, p, 1e3, pol)
    on = simulate_online(x, jnp.zeros(24), p, 1e3, pol)
    np.testing.assert_allclose(on.completion_times, off.completion_times,
                               rtol=1e-9)
    np.testing.assert_allclose(on.total_flowtime, off.total_flowtime,
                               rtol=1e-9)
    np.testing.assert_allclose(on.makespan, off.makespan, rtol=1e-9)


@pytest.mark.parametrize("name", ONLINE_POLICIES)
def test_crosscheck_cluster_fluid_poisson_trace(name):
    """Per-job flow times agree with the ClusterScheduler per-event Python
    loop (continuous allocation, no quantization) on a 10-job Poisson trace."""
    from benchmarks.arrivals import run_stream_reference, stream_trace

    arrivals, sizes = stream_trace(10, rate=1.0, seed=3)
    ref = run_stream_reference(name, arrivals, sizes, p=0.5, n_chips=64,
                               quantize=False)
    res = simulate_online(jnp.asarray(sizes), jnp.asarray(arrivals), 0.5,
                          64.0, make_policy(name, n_servers=64.0))
    np.testing.assert_allclose(res.flow_times, ref, rtol=1e-6)


@pytest.mark.parametrize("name", ONLINE_POLICIES)
def test_ranked_fast_path_matches_generic(name):
    """The sort-free incremental-rank scan must agree with the generic
    sort-per-event path on Poisson traces (continuous sizes, no ties)."""
    rng = np.random.default_rng(11)
    for _ in range(2):
        x = jnp.asarray(rng.pareto(1.5, 40) + 1.0)
        arr = jnp.asarray(np.cumsum(rng.exponential(0.3, 40)))
        gen = simulate_online(x, arr, 0.5, 128.0,
                              make_policy(name, n_servers=128.0))
        fast = simulate_online_ranked(x, arr, 0.5, 128.0,
                                      make_rank_policy(name))
        np.testing.assert_allclose(fast.completion_times,
                                   gen.completion_times, rtol=1e-9)


@pytest.mark.parametrize("name", ONLINE_POLICIES)
def test_ranked_fast_path_ties_exchange_invariant(name):
    """Exact size ties: per-job order may permute within the tied group
    (documented SRPT tie-break difference) but the completion-time multiset
    and totals are exchange-invariant."""
    x = jnp.asarray([2.0, 2.0, 2.0, 1.0])
    arr = jnp.asarray([0.0, 0.0, 1.0, 1.0])
    gen = simulate_online(x, arr, 0.5, 64.0, make_policy(name, n_servers=64.0))
    fast = simulate_online_ranked(x, arr, 0.5, 64.0, make_rank_policy(name))
    np.testing.assert_allclose(np.sort(np.asarray(fast.completion_times)),
                               np.sort(np.asarray(gen.completion_times)),
                               rtol=1e-12)
    np.testing.assert_allclose(fast.total_flowtime, gen.total_flowtime,
                               rtol=1e-12)


def test_online_hesrpt_dominates_every_load():
    """heSRPT-online beats EQUI and SRPT at every tested load for p=0.5
    (paired seeds, 2% tolerance as in the seed arrival-stream test)."""
    res = load_sweep(ONLINE_POLICIES, (0.5, 2.0, 8.0), n_jobs=60, n_seeds=16,
                     p=0.5, n_servers=256.0, seed=0)
    for rate, row in res.items():
        best_other = min(row["equi"], row["srpt"])
        assert row["hesrpt"] <= best_other * 1.02, (rate, row)


def test_isolated_arrivals_have_unit_slowdown():
    """Arrivals spaced far apart -> every job runs alone on all N servers ->
    flow time x/s(N) exactly, slowdown 1."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.pareto(1.5, 12) + 1.0)
    arr = deterministic_arrivals(12, rate=1e-3)  # 1000 time units apart
    res = simulate_online(x, arr, 0.5, 256.0, make_policy("hesrpt"))
    np.testing.assert_allclose(res.slowdowns, 1.0, rtol=1e-8)
    np.testing.assert_allclose(res.flow_times, x / speedup(256.0, 0.5),
                               rtol=1e-8)


def test_simultaneous_and_unsorted_arrivals():
    """Ties and out-of-order arrival vectors are handled; results come back
    in input order."""
    x = jnp.asarray([4.0, 1.0, 2.0, 1.5])
    arr = jnp.asarray([3.0, 0.0, 3.0, 0.0])  # two pairs of ties, unsorted
    res = simulate_online(x, arr, 0.5, 64.0, make_policy("hesrpt"))
    assert np.all(np.isfinite(np.asarray(res.completion_times)))
    # completion after arrival, for every job, in input order
    assert np.all(np.asarray(res.flow_times) > 0)
    # permuting the jobs permutes the outputs identically
    perm = jnp.asarray([2, 0, 3, 1])
    res_p = simulate_online(x[perm], arr[perm], 0.5, 64.0,
                            make_policy("hesrpt"))
    np.testing.assert_allclose(res_p.completion_times,
                               res.completion_times[perm], rtol=1e-12)


def test_online_simulator_jit_and_vmap_over_seeds():
    def one(key):
        k1, k2 = jax.random.split(key)
        arr = poisson_arrivals(k1, 30, 2.0)
        x0 = pareto_sizes(k2, 30)
        return simulate_online(x0, arr, 0.5, 128.0,
                               make_policy("hesrpt")).mean_flowtime

    keys = jax.random.split(jax.random.PRNGKey(0), 8)
    out = jax.jit(jax.vmap(one))(keys)
    assert out.shape == (8,)
    assert np.all(np.isfinite(np.asarray(out)))
    assert np.all(np.asarray(out) > 0)


def test_load_sweep_raw_shapes_and_metric_validation():
    from repro.core.arrivals import load_sweep_raw

    raw = load_sweep_raw(("equi",), (1.0, 4.0), n_jobs=20, n_seeds=5)
    assert raw["equi"].shape == (2, 5)
    with pytest.raises(ValueError):
        load_sweep_raw(("equi",), (1.0,), n_jobs=4, n_seeds=2, metric="nope")
