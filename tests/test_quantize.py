"""Property tests for chip quantization: the NumPy oracle's invariants and
exact agreement between ``core.engine.quantize_allocation_jax`` (the
vectorized scan-friendly port) and ``sched.quantize.quantize_allocation``
(the oracle) across random theta / n_chips / min_chips.

Exactness strategy: the main largest-remainder path is purely elementwise
(identical fp ops in NumPy and jnp), so random float thetas agree exactly.
The oversubscribed branch renormalizes by an internal *sum*, whose
summation order could differ between backends — the dyadic strategy below
draws theta as ``w / 2**k`` (exactly representable, exactly summable), so
even that branch admits no rounding slack and ties are exercised on
purpose (equal weights), pinning the stable tie-break order.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import (
    DEFAULT_SLICES,
    quantize_allocation_jax,
    snap_to_slices_jax,
)
from repro.sched.quantize import quantize_allocation, snap_to_slices

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (pip install -e '.[dev]')"
)
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

# A no-hypothesis seeded-fuzz fallback of the exact-agreement property lives
# in tests/test_engine.py (this module is skipped wholesale without
# hypothesis, matching tests/test_properties.py).


@st.composite
def float_thetas(draw):
    m = draw(st.integers(1, 24))
    w = np.array(draw(st.lists(
        st.floats(0.0, 1e3, allow_nan=False, allow_infinity=False),
        min_size=m, max_size=m,
    )))
    zero = np.array(draw(st.lists(st.booleans(), min_size=m, max_size=m)))
    w = np.where(zero, 0.0, w)
    s = w.sum()
    return w / s if s > 0 else w


@st.composite
def dyadic_thetas(draw):
    """theta = w / 2**k: exactly representable and exactly summable, with
    deliberate ties (repeated weights)."""
    m = draw(st.integers(1, 16))
    w = np.array(draw(st.lists(st.integers(0, 64), min_size=m, max_size=m)),
                 dtype=np.float64)
    tot = int(w.sum())
    if tot == 0:
        return w
    scale = 1 << (tot - 1).bit_length()  # next power of two >= sum
    return w / scale


chip_counts = st.integers(1, 300)
min_chip_counts = st.integers(1, 5)


@settings(max_examples=150, deadline=None)
@given(theta=float_thetas(), n_chips=chip_counts, min_chips=min_chip_counts)
def test_jax_quantizer_matches_numpy_oracle_floats(theta, n_chips, min_chips):
    ref = quantize_allocation(theta, n_chips, min_chips=min_chips)
    got = np.asarray(
        quantize_allocation_jax(jnp.asarray(theta), n_chips, min_chips=min_chips)
    )
    np.testing.assert_array_equal(got.astype(np.int64), ref)


@settings(max_examples=150, deadline=None)
@given(theta=dyadic_thetas(), n_chips=st.integers(1, 64),
       min_chips=st.integers(1, 4))
def test_jax_quantizer_matches_numpy_oracle_dyadic_ties(
    theta, n_chips, min_chips
):
    ref = quantize_allocation(theta, n_chips, min_chips=min_chips)
    got = np.asarray(
        quantize_allocation_jax(jnp.asarray(theta), n_chips, min_chips=min_chips)
    )
    np.testing.assert_array_equal(got.astype(np.int64), ref)


@settings(max_examples=150, deadline=None)
@given(theta=float_thetas(), n_chips=chip_counts, min_chips=min_chip_counts)
def test_conservation(theta, n_chips, min_chips):
    """sum(chips) == n_chips whenever any job is active and the floor is
    satisfiable for at least one job; never more than n_chips."""
    chips = quantize_allocation(theta, n_chips, min_chips=min_chips)
    n_active = int((theta > 0).sum())
    assert chips.sum() <= n_chips
    if n_active == 0 or n_chips < min_chips:
        assert chips.sum() == 0
    else:
        assert chips.sum() == n_chips


@settings(max_examples=150, deadline=None)
@given(theta=float_thetas(), n_chips=chip_counts, min_chips=min_chip_counts)
def test_min_chips_floor(theta, n_chips, min_chips):
    """Served jobs get >= min_chips; inactive jobs get nothing; when
    capacity allows (no oversubscription) *every* active job is served."""
    chips = quantize_allocation(theta, n_chips, min_chips=min_chips)
    active = theta > 0
    assert np.all(chips[~active] == 0)
    served = chips > 0
    assert np.all(chips[served] >= min_chips)
    if int(active.sum()) * min_chips <= n_chips:
        assert np.all(served[active])


@settings(max_examples=200, deadline=None)
@given(theta=float_thetas(), n_chips=chip_counts, min_chips=min_chip_counts)
def test_within_one_of_raw_when_floor_does_not_bind(theta, n_chips, min_chips):
    """Largest-remainder property: |chips - theta * n_chips| <= 1 for every
    job the min-chips floor did not touch, provided the floor forced no
    overflow trim and the pool was not oversubscribed."""
    active = theta > 0
    n_active = int(active.sum())
    if n_active == 0 or n_active * min_chips > n_chips:
        return  # oversubscribed: within-1 is vacuous (jobs are queued at 0)
    raw = theta * n_chips
    base0 = np.where(active, np.maximum(np.floor(raw), min_chips), 0)
    if base0.sum() > n_chips:
        return  # floor bound -> trim may move a job far from raw (documented)
    chips = quantize_allocation(theta, n_chips, min_chips=min_chips)
    unfloored = active & (np.floor(raw) >= min_chips)
    assert np.all(np.abs(chips[unfloored] - raw[unfloored]) <= 1.0)
    assert np.all(chips[active & ~unfloored] == min_chips)


@settings(max_examples=100, deadline=None)
@given(theta=float_thetas(), min_chips=st.integers(1, 5))
def test_oversubscription_queues_smallest_theta(theta, min_chips):
    """More active jobs than the floor can hold: exactly floor(N/min) jobs
    are served (the largest thetas), the rest queue at 0 chips."""
    active = theta > 0
    n_active = int(active.sum())
    if n_active < 2:
        return
    n_chips = min_chips * (n_active - 1)  # can't serve everyone
    chips = quantize_allocation(theta, n_chips, min_chips=min_chips)
    served = chips > 0
    assert served.sum() <= n_chips // min_chips
    assert chips.sum() == n_chips
    # every served job's theta >= every queued active job's theta
    if served.any() and (active & ~served).any():
        assert theta[served].min() >= theta[active & ~served].max() - 1e-12


# ------------------------------------------------------------ slice snapping
@st.composite
def chip_vectors(draw):
    """A plausible post-quantization allocation plus the pool it came from:
    ``n_chips >= sum(chips)`` (with slack so upgrades are reachable)."""
    m = draw(st.integers(1, 16))
    chips = np.array(
        draw(st.lists(st.integers(0, 300), min_size=m, max_size=m)),
        dtype=np.int64,
    )
    slack = draw(st.integers(0, 64))
    return chips, max(int(chips.sum()) + slack, 1)


@settings(max_examples=200, deadline=None)
@given(cv=chip_vectors())
def test_snap_jax_matches_numpy_oracle(cv):
    """Exact agreement of the while_loop jnp port with the greedy NumPy
    oracle, including its `>=` (last-index-wins) tie-break."""
    chips, n_chips = cv
    ref = snap_to_slices(chips, n_chips)
    got = np.asarray(snap_to_slices_jax(jnp.asarray(chips), n_chips))
    np.testing.assert_array_equal(got.astype(np.int64), ref)


@settings(max_examples=200, deadline=None)
@given(cv=chip_vectors())
def test_snap_postconditions(cv):
    """Power-of-two membership, conservation, and no chips conjured for
    jobs that held none."""
    chips, n_chips = cv
    snapped = snap_to_slices(chips, n_chips)
    assert set(np.unique(snapped)) <= set(DEFAULT_SLICES) | {0}
    assert snapped.sum() <= n_chips
    assert np.all(snapped[chips == 0] == 0)
    # snap-down is a lower bound before upgrades: never below the largest
    # slice <= chips unless an upgrade moved it *up*.
    down = np.array([max([s for s in DEFAULT_SLICES if s <= c], default=0)
                     for c in chips])
    assert np.all(snapped >= down)


@pytest.mark.parametrize("n_chips,min_chips", [(0, 1), (4, 5)])
def test_degenerate_pools(n_chips, min_chips):
    theta = np.array([0.5, 0.5])
    np.testing.assert_array_equal(
        quantize_allocation(theta, n_chips, min_chips=min_chips), [0, 0]
    )
    np.testing.assert_array_equal(
        np.asarray(quantize_allocation_jax(jnp.asarray(theta), n_chips,
                                           min_chips=min_chips)),
        [0, 0],
    )
