"""In-scan telemetry probes (core/telemetry.py) and their sweep threading.

The contract under test has three legs:

- **neutrality** — attaching a probe never changes the trajectory: the
  completion times with ``telemetry=None``, a series probe, and a stream
  probe must be bit-for-bit identical, across the continuous, quantized
  and fused rule paths (the golden pins in test_sweeps.py already enforce
  the ``telemetry=None`` program is the pre-telemetry one);
- **stream == series** — the O(1) streaming aggregates must reproduce the
  full series reduced host-side (``analysis.time_weighted_stats``), and
  the time-weighted histogram mass must account for the whole span;
- **sweep threading** — ``Sweep.create(telemetry=)`` appends ``tel_*``
  columns without perturbing the base metrics, validates its inputs, and
  stamps provenance into every benchmark record.
"""

import jax
import numpy as np
import pytest

from repro.core import engine, make_policy, make_scenario
from repro.core.analysis import time_weighted_stats
from repro.core.sweeps import SCHEMA_VERSION, Sweep, provenance, run_sweep
from repro.core.telemetry import (
    DEFAULT_METRICS,
    default_hist_ranges,
    make_probe,
    p_hat_error_metric,
    scalar_columns,
    scalar_values,
)

N_JOBS = 40


def _stream(seed=0, rate=2.0, n_jobs=N_JOBS, p=0.5):
    scn = make_scenario("poisson", p=p)(jax.random.key(seed), n_jobs, rate)
    return scn.x0, scn.arrival_times


def _rule(kind, dtype):
    pol = make_policy("hesrpt")
    if kind == "continuous":
        return engine.continuous_rule(pol, 1.0, dtype=dtype), 1.0, False
    if kind == "quantized":
        return engine.quantized_rule(pol, 64, dtype=dtype), 64.0, False
    assert kind == "fused"
    return engine.quantized_rule(pol, 64, dtype=dtype), 64.0, True


# ----------------------------------------------------------------- neutrality
@pytest.mark.parametrize("kind", ["continuous", "quantized", "fused"])
def test_probe_never_changes_the_trajectory(kind):
    x0, arr = _stream()
    dtype = x0.dtype
    rule, unit, fused = _rule(kind, dtype)
    base = engine.run(x0, arr, 0.5, rule, fused=fused)
    assert base.telemetry is None
    for mode in ("series", "stream"):
        probe = make_probe(
            DEFAULT_METRICS, mode=mode, alloc_unit=unit, n_jobs=N_JOBS,
            dtype=dtype,
        )
        res = engine.run(x0, arr, 0.5, rule, fused=fused, telemetry=probe)
        np.testing.assert_array_equal(
            np.asarray(base.completion_times),
            np.asarray(res.completion_times),
        )
        assert res.telemetry is not None


def test_probe_neutral_under_jit_and_with_record():
    x0, arr = _stream(seed=3)
    rule, unit, _ = _rule("continuous", x0.dtype)
    probe = make_probe(("efficiency", "queue"), mode="stream",
                       alloc_unit=unit, n_jobs=N_JOBS, dtype=x0.dtype)

    @jax.jit
    def with_probe(x, a):
        return engine.run(x, a, 0.5, rule, record=True, telemetry=probe)

    res = with_probe(x0, arr)
    base = engine.run(x0, arr, 0.5, rule)
    np.testing.assert_array_equal(np.asarray(base.completion_times),
                                  np.asarray(res.completion_times))
    assert res.trace is not None  # record and telemetry compose
    assert float(res.telemetry.aggregates["queue_max"]) >= 1.0


# ------------------------------------------------------------ stream == series
@pytest.mark.parametrize("kind", ["continuous", "quantized"])
def test_stream_aggregates_match_series_reduction(kind):
    x0, arr = _stream(seed=1)
    dtype = x0.dtype
    rule, unit, fused = _rule(kind, dtype)
    tel = {}
    for mode in ("series", "stream"):
        probe = make_probe(DEFAULT_METRICS, mode=mode, alloc_unit=unit,
                           n_jobs=N_JOBS, dtype=dtype)
        tel[mode] = engine.run(
            x0, arr, 0.5, rule, fused=fused, telemetry=probe
        ).telemetry
    series = {k: np.asarray(v) for k, v in tel["series"].series.items()}
    agg = tel["stream"].aggregates
    for m in DEFAULT_METRICS:
        ref = time_weighted_stats(series[m], series["dt"])
        assert float(agg[f"{m}_mean"]) == pytest.approx(ref["mean"], abs=1e-12)
        assert float(agg[f"{m}_max"]) == pytest.approx(ref["max"], abs=1e-12)
    assert float(agg["time"]) == pytest.approx(
        float(series["dt"].sum()), abs=1e-12
    )


def test_histogram_mass_accounts_for_the_whole_span():
    x0, arr = _stream(seed=2)
    rule, unit, _ = _rule("continuous", x0.dtype)
    probe = make_probe(DEFAULT_METRICS, mode="stream", alloc_unit=unit,
                       n_jobs=N_JOBS, hist_bins=16, dtype=x0.dtype)
    tel = engine.run(x0, arr, 0.5, rule, telemetry=probe).telemetry
    total = float(tel.aggregates["time"])
    for m in DEFAULT_METRICS:
        hist = np.asarray(tel.aggregates[f"{m}_hist"])
        edges = np.asarray(tel.hist_edges[m])
        assert hist.shape == (16,) and edges.shape == (17,)
        assert np.all(hist >= 0)
        assert float(hist.sum()) == pytest.approx(total, rel=1e-12)
        lo, hi = default_hist_ranges(N_JOBS)[m]
        assert edges[0] == pytest.approx(lo) and edges[-1] == pytest.approx(hi)


def test_series_values_respect_structural_bounds():
    x0, arr = _stream(seed=4)
    probe = make_probe(DEFAULT_METRICS, mode="series", dtype=x0.dtype)
    rule, _, _ = _rule("continuous", x0.dtype)
    tel = engine.run(x0, arr, 0.5, rule, telemetry=probe).telemetry
    s = {k: np.asarray(v) for k, v in tel.series.items()}
    live = s["dt"] > 0
    assert np.all(np.diff(s["t"]) >= 0)  # event starts are ordered
    assert np.all(s["utilization"] <= 1.0 + 1e-12)  # Σθ <= 1 (continuous)
    q = s["queue"]
    assert np.all((q >= 0) & (q <= N_JOBS)) and np.all(q == np.round(q))
    with np.errstate(divide="ignore"):
        cap = np.where(q > 0, np.log(np.maximum(q, 1.0)), 0.0)
    assert np.all(s["entropy"][live] <= cap[live] + 1e-12)
    # efficiency Σ θ^p is bounded by m(t)^{1-p} (Cauchy-Schwarz at p=1/2)
    assert np.all(s["efficiency"][live] <= np.sqrt(np.maximum(q[live], 1.0)) + 1e-12)


def test_p_hat_err_probe_tracks_the_estimator():
    from repro.core.estimation import estimating_rule

    x0, arr = _stream(seed=5, p=0.5)
    dtype = x0.dtype
    prior = 0.9  # wrong prior: the fit must pull the error down
    rule = estimating_rule(make_policy("hesrpt"), 1.0, prior_p=prior,
                           dtype=dtype, n_jobs=N_JOBS)
    reader = p_hat_error_metric(prior)
    tel = {}
    for mode in ("series", "stream"):
        probe = make_probe(("p_hat_err", "queue"), mode=mode, n_jobs=N_JOBS,
                           p_hat_reader=reader, dtype=dtype)
        tel[mode] = engine.run(x0, arr, 0.5, rule, telemetry=probe).telemetry
    s = {k: np.asarray(v) for k, v in tel["series"].series.items()}
    err, busy = s["p_hat_err"], (s["dt"] > 0) & (s["queue"] > 0)
    assert np.all((err >= 0) & (err <= 1.0))
    # the first busy epoch sees the raw prior; the fit must improve on it
    first = err[busy][0]
    assert first == pytest.approx(abs(prior - 0.5), abs=1e-12)
    assert err[busy][-1] < first
    ref = time_weighted_stats(err, s["dt"])
    mean = float(tel["stream"].aggregates["p_hat_err_mean"])
    assert mean == pytest.approx(ref["mean"], abs=1e-12)
    assert 0.0 < mean < abs(prior - 0.5) + 0.11  # idle epochs read err=|0-p|


# ------------------------------------------------------------------ validation
def test_make_probe_validation():
    with pytest.raises(ValueError, match="mode"):
        make_probe(mode="rolling")
    with pytest.raises(ValueError, match="unknown telemetry metric"):
        make_probe(("throughput",), mode="series")
    with pytest.raises(ValueError, match="p_hat_reader"):
        make_probe(("p_hat_err",), mode="series")
    with pytest.raises(ValueError, match="n_jobs"):
        make_probe(mode="stream")
    with pytest.raises(ValueError, match="stream-mode"):
        probe = make_probe(("queue",), mode="series")
        x0, arr = _stream(seed=6, n_jobs=10)
        rule, _, _ = _rule("continuous", x0.dtype)
        tel = engine.run(x0, arr, 0.5, rule, telemetry=probe).telemetry
        scalar_values(tel, ("queue",))


def test_sweep_telemetry_validation():
    with pytest.raises(ValueError, match="unknown telemetry"):
        Sweep.create(["hesrpt"], [1.0], telemetry=("nope",))
    with pytest.raises(ValueError, match="single-class"):
        Sweep.create(["hesrpt"], [1.0], scenario="multiclass_poisson",
                     classes=((0.3, 1.0), (0.7, 1.0)), telemetry=True)
    with pytest.raises(ValueError, match="estimator"):
        Sweep.create(["hesrpt"], [1.0], telemetry=("p_hat_err",))


# -------------------------------------------------------------- sweep threading
def test_sweep_telemetry_columns_ride_along_without_perturbing_metrics():
    base = Sweep.create(["hesrpt", "srpt"], [0.5, 4.0], n_jobs=30, n_seeds=3)
    tele = base._replace(telemetry=DEFAULT_METRICS)
    r0 = run_sweep(base, log=False)
    r1 = run_sweep(tele, log=False)
    for pol in ("hesrpt", "srpt"):
        for metric in base.metrics:
            np.testing.assert_array_equal(r0.stats[pol][metric],
                                          r1.stats[pol][metric])
        for col in scalar_columns(DEFAULT_METRICS):
            assert r1.stats[pol][col].shape == (2, 3)
            assert np.all(np.isfinite(r1.stats[pol][col]))
        util = r1.stats[pol]["tel_utilization_max"]
        assert np.all((util > 0) & (util <= 1.0 + 1e-12))


def test_sweep_telemetry_quantized_and_estimator_arms():
    q = Sweep.create(["hesrpt"], [2.0], n_jobs=24, n_seeds=2, n_chips=64,
                     telemetry=("utilization", "queue"))
    rq = run_sweep(q, log=False)
    util = rq.stats["hesrpt"]["tel_utilization_max"]
    assert np.all(util <= 1.0 + 1e-12)  # chips normalized by n_chips
    est = Sweep.create(["hesrpt"], [2.0], scenario="drift_poisson",
                       scenario_kw={"p0": 0.7, "p1": 0.3}, n_jobs=30,
                       n_seeds=2, arm="estimator",
                       telemetry=("queue", "p_hat_err"))
    re_ = run_sweep(est, log=False)
    err = re_.stats["hesrpt"]["tel_p_hat_err_mean"]
    assert np.all((err >= 0) & (err <= 1.0))


def test_record_carries_provenance_and_round_trips():
    spec = Sweep.create(["hesrpt"], [1.0], n_jobs=16, n_seeds=2,
                        telemetry=("queue",))
    res = run_sweep(spec, log=False)
    rec = res.record()
    prov = rec["provenance"]
    assert prov["schema_version"] == SCHEMA_VERSION
    assert prov["jax_version"] == jax.__version__
    assert "created_utc" in prov
    assert set(prov) == set(provenance())
    assert "tel_queue_mean" in rec["cells"]["hesrpt"]
    rt = type(res).from_json(res.to_json())
    assert rt.spec.telemetry == ("queue",)
    np.testing.assert_array_equal(rt.stats["hesrpt"]["tel_queue_max"],
                                  res.stats["hesrpt"]["tel_queue_max"])
