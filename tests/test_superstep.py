"""Closed-form superstep path (core/superstep.py) vs the generic engine.

The superstep scan advances one *arrival* (or drift boundary) per step and
resolves every departure in between analytically, so its completion times
must agree with the generic per-event scan wherever the closed form is
valid: continuous allocation, uniform scalar ``p`` per regime, the rank
family (heSRPT / EQUI / SRPT).  The contract under test:

- every registered single-class scenario x policy agrees <= 1e-10;
- the batch closed form is *exact* against Theorem 3 / Theorem 8 (and the
  weighted Thm-8 analogue) in float64;
- tie semantics match the generic scan (heSRPT/EQUI exactly; SRPT up to a
  permutation within the tied group, so sorted times agree);
- every unsupported configuration raises at trace time with a message
  pointing back at the generic scan.

Hypothesis twins live in tests/test_superstep_properties.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine as eng
from repro.core.arrivals import simulate_online_superstep, simulate_scenario
from repro.core.flowtime import (
    hesrpt_completion_times,
    hesrpt_total_flowtime,
    weighted_total_flowtime,
)
from repro.core.policies import make_policy, weighted_hesrpt
from repro.core.scenarios import make_scenario
from repro.core.simulator import simulate
from repro.core.superstep import (
    SUPERSTEP_POLICIES,
    batch_result_closed_form,
    run_superstep,
)
from repro.core.sweeps import Sweep, run_sweep

pytestmark = pytest.mark.usefixtures("fresh_compile_cache")

SCENARIO_NAMES = (
    "batch", "poisson", "deterministic", "bursty",
    "drift_poisson", "drift_bursty",
)
POLICIES = ("hesrpt", "equi", "srpt")


def _generic(x0, arr, p, n, pol, **kw):
    rule = eng.continuous_rule(
        make_policy(pol), n_servers=n, dtype=jnp.float64
    )
    return eng.run(x0, arr, p, rule, **kw)


def _assert_times_match(pol, got, want, tol=1e-10):
    got, want = np.asarray(got), np.asarray(want)
    if pol == "srpt":
        # SRPT breaks remaining-size ties arbitrarily (generic argmin vs
        # superstep rank order); totals are exchange-invariant within the
        # tied group, so compare the sorted spectra.
        got, want = np.sort(got), np.sort(want)
    np.testing.assert_allclose(got, want, rtol=0, atol=tol)


@pytest.mark.parametrize("pol", POLICIES)
@pytest.mark.parametrize("scenario", SCENARIO_NAMES)
def test_matches_generic_on_registry(scenario, pol):
    """Superstep == generic scan on every registered continuous scenario."""
    sampler = make_scenario(scenario)
    for seed in (0, 1):
        scn = sampler(jax.random.PRNGKey(seed), 40, 1.2)
        gen = _generic(
            scn.x0, scn.arrival_times, 0.5, 8, pol, p_drift=scn.p_drift
        )
        ss = run_superstep(
            scn.x0, scn.arrival_times, 0.5, 8, pol, p_drift=scn.p_drift
        )
        _assert_times_match(pol, ss.completion_times, gen.completion_times)


@pytest.mark.parametrize("p", [0.25, 0.5, 0.9])
def test_batch_closed_form_thm3_exact(p):
    """Batch completion times == Theorem 3, same floats (both closed form)."""
    x = jnp.sort(
        jax.random.uniform(
            jax.random.PRNGKey(2), (64,), dtype=jnp.float64,
            minval=0.05, maxval=5.0,
        )
    )[::-1]
    bc = batch_result_closed_form(x, p, "hesrpt", n_servers=16)
    thm3 = hesrpt_completion_times(x, p, 16)
    np.testing.assert_array_equal(
        np.asarray(bc.completion_times), np.asarray(thm3)
    )
    # Theorem 8: the sum is the optimal total flow time.
    np.testing.assert_allclose(
        float(jnp.sum(bc.completion_times)),
        float(hesrpt_total_flowtime(x, p, 16)),
        rtol=1e-13,
    )


def test_batch_closed_form_weighted_thm8():
    """Weighted batch times reproduce the weighted Thm-8 total and the
    event-driven simulator, for Berg-style slowdown weights (w = 1/x —
    the non-increasing-in-size envelope where the closed form is valid)."""
    x = jnp.sort(
        jax.random.uniform(
            jax.random.PRNGKey(3), (40,), dtype=jnp.float64,
            minval=0.1, maxval=3.0,
        )
    )[::-1]
    w = 1.0 / x
    bc = batch_result_closed_form(
        x, 0.5, "weighted_hesrpt", n_servers=8, weights=w
    )
    np.testing.assert_allclose(
        float(jnp.sum(w * bc.completion_times)),
        float(weighted_total_flowtime(x, w, 0.5, 8)),
        rtol=1e-13,
    )
    res = simulate(x, 0.5, 8, lambda xs, ps: weighted_hesrpt(xs, ps, w))
    np.testing.assert_allclose(
        np.asarray(bc.completion_times),
        np.asarray(res.completion_times),
        rtol=0, atol=1e-10,
    )


def test_batch_trajectory_sizes_at():
    """x_i(t): exact at t=0, zero past the makespan, non-increasing, and
    self-consistent — restarting the batch from a snapshot at time t
    reproduces the original completion times shifted by t."""
    x = jnp.sort(
        jax.random.uniform(
            jax.random.PRNGKey(4), (20,), dtype=jnp.float64,
            minval=0.2, maxval=4.0,
        )
    )[::-1]
    p, n = 0.5, 8.0
    bc = batch_result_closed_form(x, p, "hesrpt", n_servers=n)
    t_mid = 0.4 * float(jnp.max(bc.completion_times))
    ev = jnp.array([0.0, t_mid, 2.0 * float(jnp.max(bc.completion_times))])
    bct = batch_result_closed_form(x, p, "hesrpt", n_servers=n, eval_times=ev)
    np.testing.assert_array_equal(np.asarray(bct.sizes_at[0]), np.asarray(x))
    assert float(jnp.max(bct.sizes_at[2])) == 0.0
    assert bool(jnp.all(bct.sizes_at[1] <= bct.sizes_at[0] + 1e-12))
    # Memorylessness of the allocation: survivors at t_mid, restarted as a
    # fresh batch, finish at (T_i - t_mid).
    x_mid = bct.sizes_at[1]
    bc2 = batch_result_closed_form(x_mid, p, "hesrpt", n_servers=n)
    alive = np.asarray(x_mid) > 0
    np.testing.assert_allclose(
        np.asarray(bc2.completion_times)[alive],
        np.asarray(bc.completion_times)[alive] - t_mid,
        rtol=0, atol=1e-10,
    )


def test_batch_t0_offset_and_zero_sizes():
    """t0 shifts all finite times; zero-size jobs stay at 0.0 (the generic
    engine never activates them)."""
    x = jnp.array([3.0, 2.0, 0.0, 1.0, 0.0], dtype=jnp.float64)
    bc = batch_result_closed_form(x, 0.5, "hesrpt", n_servers=4, t0=7.0)
    t = np.asarray(bc.completion_times)
    assert t[2] == 0.0 and t[4] == 0.0
    assert np.all(t[[0, 1, 3]] > 7.0)
    bc0 = batch_result_closed_form(x, 0.5, "hesrpt", n_servers=4)
    np.testing.assert_allclose(
        t[[0, 1, 3]], np.asarray(bc0.completion_times)[[0, 1, 3]] + 7.0,
        rtol=0, atol=1e-12,
    )


@pytest.mark.parametrize("pol", POLICIES)
def test_exact_size_ties(pol):
    """Exact remaining-size ties: heSRPT/EQUI agree job-for-job with the
    generic scan; SRPT agrees up to permutation within the tied group."""
    x = jnp.array(
        [2.0, 2.0, 2.0, 1.0, 1.0, 3.0, 0.5, 0.5], dtype=jnp.float64
    )
    arr = jnp.array(
        [0.0, 0.0, 0.3, 0.3, 0.7, 0.7, 1.1, 1.1], dtype=jnp.float64
    )
    gen = _generic(x, arr, 0.5, 4, pol)
    ss = run_superstep(x, arr, 0.5, 4, pol)
    _assert_times_match(pol, ss.completion_times, gen.completion_times)


@pytest.mark.parametrize("pol", POLICIES)
def test_simultaneous_arrival_and_departure(pol):
    """An arrival landing exactly on another job's departure instant: both
    scans fire the departure at the arrival time."""
    from repro.core.flowtime import speedup

    n, p = 4.0, 0.5
    # Lone job of size 1 departs at exactly 1/s(N); schedule the second
    # arrival there.
    t_dep = float(1.0 / speedup(jnp.asarray(n), p))
    x = jnp.array([1.0, 2.0], dtype=jnp.float64)
    arr = jnp.array([0.0, t_dep], dtype=jnp.float64)
    gen = _generic(x, arr, p, n, pol)
    ss = run_superstep(x, arr, p, n, pol)
    _assert_times_match(pol, ss.completion_times, gen.completion_times)
    np.testing.assert_allclose(
        float(ss.completion_times[0]), t_dep, rtol=0, atol=1e-12
    )


@pytest.mark.parametrize("pol", POLICIES)
def test_pre_arrived_scanless_path(pol):
    """pre_arrived=True without drift takes the zero-scan batch closed form
    and still matches the generic engine."""
    x = jax.random.uniform(
        jax.random.PRNGKey(5), (30,), dtype=jnp.float64,
        minval=0.1, maxval=2.0,
    )
    arr = jnp.zeros_like(x)
    gen = _generic(x, arr, 0.5, 8, pol, pre_arrived=True)
    ss = run_superstep(x, arr, 0.5, 8, pol, pre_arrived=True)
    _assert_times_match(pol, ss.completion_times, gen.completion_times)


def test_engine_run_superstep_dispatch():
    """engine.run(superstep=True) routes to run_superstep and agrees with
    the same call on the generic path."""
    x = jax.random.uniform(
        jax.random.PRNGKey(6), (25,), dtype=jnp.float64,
        minval=0.1, maxval=2.0,
    )
    arr = jnp.sort(
        jax.random.uniform(jax.random.PRNGKey(7), (25,), dtype=jnp.float64)
        * 4.0
    )
    rule = eng.continuous_rule(
        make_policy("hesrpt"), n_servers=8, dtype=jnp.float64
    )
    gen = eng.run(x, arr, 0.5, rule)
    ss = eng.run(x, arr, 0.5, rule, superstep=True)
    np.testing.assert_allclose(
        np.asarray(ss.completion_times),
        np.asarray(gen.completion_times),
        rtol=0, atol=1e-10,
    )


def test_simulate_online_superstep_metrics():
    """The arrivals-layer wrapper reproduces simulate_scenario's metrics."""
    sampler = make_scenario("poisson")
    scn = sampler(jax.random.PRNGKey(8), 40, 1.0)
    base = simulate_scenario(scn, 0.5, 8, make_policy("hesrpt"))
    ss = simulate_online_superstep(
        scn.x0, scn.arrival_times, 0.5, 8, "hesrpt"
    )
    np.testing.assert_allclose(
        float(ss.mean_flowtime), float(base.mean_flowtime), rtol=1e-10
    )


def test_sweep_superstep_equivalence_and_roundtrip():
    """Sweep.create(superstep=True) matches the plain sweep cell-for-cell
    and survives the JSON round-trip."""
    kw = dict(
        scenario="poisson", policies=("hesrpt", "srpt"), rates=(0.8,),
        n_jobs=30, n_seeds=2, p=0.5, n_servers=8,
    )
    plain = run_sweep(Sweep.create(**kw))
    ss = run_sweep(Sweep.create(**kw, superstep=True))
    for pol in kw["policies"]:
        for m, v in plain.stats[pol].items():
            np.testing.assert_allclose(
                np.asarray(ss.stats[pol][m]), np.asarray(v), rtol=1e-9
            )
    rt = type(ss).from_json(ss.to_json())
    assert rt.spec.superstep is True
    assert type(plain).from_json(plain.to_json()).spec.superstep is False


# ---------------------------------------------------------------------------
# Trace-time rejection: every documented fallback raises before compiling.
# ---------------------------------------------------------------------------

def _x_arr(m=6):
    x = jnp.linspace(1.0, 2.0, m, dtype=jnp.float64)
    return x, jnp.zeros_like(x)


def test_raises_quantized_rule():
    x, arr = _x_arr()
    rule = eng.quantized_rule(
        make_policy("hesrpt", n_servers=4), n_chips=4, dtype=jnp.float64
    )
    with pytest.raises(ValueError, match="generic per-event scan"):
        eng.run(x, arr, 0.5, rule, superstep=True)


def test_raises_record_and_telemetry():
    x, arr = _x_arr()
    rule = eng.continuous_rule(
        make_policy("hesrpt"), n_servers=4, dtype=jnp.float64
    )
    with pytest.raises(ValueError, match="generic per-event scan"):
        eng.run(x, arr, 0.5, rule, superstep=True, record=True)


def test_raises_per_job_p():
    x, arr = _x_arr()
    rule = eng.continuous_rule(
        make_policy("hesrpt"), n_servers=4, dtype=jnp.float64
    )
    with pytest.raises(ValueError, match="scalar p"):
        eng.run(x, arr, jnp.full(x.shape, 0.5), rule, superstep=True)


def test_raises_estimating_rule():
    x, arr = _x_arr()
    rule = eng.continuous_rule(
        make_policy("hesrpt"), n_servers=4, dtype=jnp.float64,
        p_hat=jnp.asarray(0.4),
    )
    with pytest.raises(ValueError, match="generic per-event scan"):
        eng.run(x, arr, 0.5, rule, superstep=True)


def test_raises_unknown_policy_and_missing_weights():
    x, arr = _x_arr()
    with pytest.raises(ValueError, match="superstep path supports"):
        run_superstep(x, arr, 0.5, 4, "knee")
    with pytest.raises(ValueError, match="weights"):
        run_superstep(x, arr, 0.5, 4, "weighted_hesrpt")
    assert set(SUPERSTEP_POLICIES) == {
        "hesrpt", "equi", "srpt", "weighted_hesrpt"
    }


def test_sweep_create_rejects_unsupported():
    kw = dict(
        scenario="poisson", policies=("hesrpt",), rates=(0.8,),
        n_jobs=10, n_seeds=1, p=0.5, n_servers=8,
    )
    with pytest.raises(ValueError, match="continuous closed-form"):
        Sweep.create(**kw, superstep=True, n_chips=8)
    with pytest.raises(ValueError, match="heSRPT/EQUI/SRPT"):
        Sweep.create(**dict(kw, policies=("knee",)), superstep=True)
    with pytest.raises(ValueError, match="noise-free"):
        Sweep.create(
            **kw, superstep=True, scenario_kw={"sigma_size": 0.1}
        )
    with pytest.raises(ValueError, match="single-class"):
        Sweep.create(
            **dict(kw, scenario="multiclass_poisson"), superstep=True
        )


# ---------------------------------------------------------------------------
# Seeded fuzz (non-hypothesis twin of test_superstep_properties.py).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(4))
def test_fuzz_random_instances(seed):
    """Random sizes/arrivals (with deliberate duplicates) across all three
    policies and two exponents."""
    key = jax.random.PRNGKey(100 + seed)
    kx, ka, kd = jax.random.split(key, 3)
    m = 24
    x = jax.random.uniform(kx, (m,), dtype=jnp.float64, minval=0.05,
                           maxval=4.0)
    # Force duplicate sizes and coincident arrivals half the time.
    x = x.at[1].set(x[0]).at[5].set(x[4])
    arr = jnp.sort(
        jnp.round(
            jax.random.uniform(ka, (m,), dtype=jnp.float64) * 6.0, 1
        )
    )
    for pol in POLICIES:
        for p in (0.3, 0.7):
            gen = _generic(x, arr, p, 8, pol)
            ss = run_superstep(x, arr, p, 8, pol)
            _assert_times_match(pol, ss.completion_times,
                                gen.completion_times)
