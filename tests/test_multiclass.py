"""Multi-class subsystem invariants and oracle cross-checks.

Property tests (hypothesis where available) for the class-aware allocation
policies and the multi-class engine path:

- allocation conservation across classes (theta sums to 1 over active jobs,
  zero on inactive, non-negative);
- per-class monotonicity: within a class (same exponent/weight), a job with
  smaller remaining size never gets a smaller share;
- class-blind reduction: K classes with identical ``p_k`` reproduce the
  single-class engine **bit-for-bit** on f64 (continuous and quantized);
- engine vs the per-event ``ClusterScheduler(class_aware=True)`` NumPy
  oracle: exact chips event-for-event (quantized), <=1e-10 flow times
  (continuous);
- scenario samplers, per-class estimation noise, per-class aggregation
  helpers, and the one-jit+vmap sweep shape.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ClassSpec,
    class_theta,
    make_policy,
    make_scenario,
    per_class_count,
    per_class_mean,
    per_class_summary,
    simulate_multiclass,
    simulate_online,
    simulate_online_quantized,
)
from repro.core.multiclass import _class_counts, as_specs, uniform_p
from repro.sched import ClusterScheduler, Job

TWO_CLASSES = (
    ClassSpec(p=0.3, mix=0.5, size_alpha=1.5),
    ClassSpec(p=0.8, mix=0.5, size_alpha=2.5, size_scale=2.0),
)


def _draw(key, n=24, rate=2.0, classes=TWO_CLASSES):
    return make_scenario("multiclass_poisson", classes=classes)(key, n, rate)


# ------------------------------------------------------------- conservation
CLASS_POLICIES = ("hesrpt_pc", "waterfill", "hesrpt_sd", "hesrpt_blind")


def _theta(name, x, p, x0):
    from repro.core import policy_weights

    w = policy_weights(name, x0=x0)
    return class_theta(name, x, p, n_servers=64.0, w=w)


@pytest.mark.parametrize("name", CLASS_POLICIES)
def test_conservation_seeded_fuzz(name):
    """sum(theta) == 1 over active jobs, 0 on inactive, all >= 0 — across
    random sizes, random per-job exponents, random inactive subsets."""
    rng = np.random.default_rng(7)
    for _ in range(40):
        m = int(rng.integers(1, 20))
        x0 = rng.pareto(1.3, m) + 0.05
        x = x0 * rng.uniform(0.05, 1.0, m)
        x[rng.random(m) < 0.3] = 0.0
        p = rng.uniform(0.1, 0.9, m)
        th = np.asarray(
            _theta(name, jnp.asarray(x), jnp.asarray(p), jnp.asarray(x0))
        )
        assert np.all(th >= 0)
        assert np.all(th[x <= 0] == 0)
        if (x > 0).any():
            np.testing.assert_allclose(th.sum(), 1.0, rtol=1e-9)
        else:
            assert th.sum() == 0


@pytest.mark.parametrize("name", ("hesrpt_pc", "waterfill"))
def test_per_class_monotone_in_remaining_size(name):
    """Within one class (same exponent, same weight), the job with smaller
    remaining size gets at least as large a share — SRPT-like bias holds
    class-wise for the unweighted class-aware policies."""
    rng = np.random.default_rng(3)
    for _ in range(30):
        m = int(rng.integers(2, 16))
        cls = rng.integers(0, 2, m)
        p = np.where(cls == 0, 0.35, 0.75)
        x = rng.pareto(1.5, m) + 0.1
        th = np.asarray(
            _theta(name, jnp.asarray(x), jnp.asarray(p), jnp.asarray(x))
        )
        for k in (0, 1):
            xs, ts = x[cls == k], th[cls == k]
            order = np.argsort(xs)
            assert np.all(np.diff(ts[order]) <= 1e-9), (name, xs, ts)


# -------------------------------------------------- class-blind reduction
@pytest.mark.parametrize("k", [2, 3])
@pytest.mark.parametrize("policy", ["hesrpt_pc", "hesrpt_blind"])
def test_equal_p_classes_reduce_to_single_class_bitforbit(k, policy):
    """K classes sharing one exponent: the multi-class path must reproduce
    the single-class engine exactly (not approximately) on f64."""
    classes = tuple(
        ClassSpec(p=0.55, mix=1.0 / k, size_alpha=1.4 + 0.3 * i,
                  size_scale=1.0 + i)
        for i in range(k)
    )
    assert uniform_p(classes) == 0.55
    scn = _draw(jax.random.PRNGKey(k), n=30, classes=classes)
    got = simulate_multiclass(scn, classes=classes, policy=policy,
                              n_servers=128.0)
    ref = simulate_online(scn.x0, scn.arrival_times, 0.55, 128.0,
                          make_policy("hesrpt", n_servers=128.0))
    np.testing.assert_array_equal(np.asarray(got.completion_times),
                                  np.asarray(ref.completion_times))
    np.testing.assert_array_equal(np.asarray(got.slowdowns),
                                  np.asarray(ref.slowdowns))


def test_equal_p_classes_reduce_quantized_bitforbit():
    classes = (ClassSpec(p=0.5, mix=0.4), ClassSpec(p=0.5, mix=0.6,
                                                    size_scale=3.0))
    scn = _draw(jax.random.PRNGKey(5), n=20, classes=classes)
    got = simulate_multiclass(scn, classes=classes, policy="hesrpt_pc",
                              n_chips=32)
    ref = simulate_online_quantized(scn.x0, scn.arrival_times, 0.5, 32,
                                    make_policy("hesrpt", n_servers=32.0))
    np.testing.assert_array_equal(np.asarray(got.completion_times),
                                  np.asarray(ref.completion_times))


# ------------------------------------------------------- oracle cross-checks
def test_engine_matches_cluster_oracle_event_for_event():
    """The acceptance bar: exact integer chips at every decision epoch for
    the quantized rule, <=1e-10 per-job flow times for the continuous rule,
    across all three class-aware policies."""
    from benchmarks.multiclass import cross_check

    cc = cross_check(n_jobs=14, rate=1.5, n_chips=32, seed=11)
    assert cc["chips_exact"], cc
    assert cc["n_events"] > 3 * 14  # re-allocated at arrivals AND departures
    assert cc["worst_continuous_flow_rel"] < 1e-10, cc
    assert cc["worst_quantized_flow_rel"] < 1e-9, cc


def test_engine_matches_cluster_oracle_with_slice_snap():
    from benchmarks.multiclass import cross_check

    cc = cross_check(("hesrpt_pc",), n_jobs=10, rate=1.0, n_chips=64, seed=2,
                     snap_slices=True)
    assert cc["chips_exact"], cc
    assert cc["worst_quantized_flow_rel"] < 1e-9, cc


def test_cluster_engine_delegation_class_aware_batch():
    """Batch case: ``run_fluid_to_completion(use_engine=True)`` must equal
    the per-event Python loop event-for-event for a class-aware instance
    (heterogeneous p), including with slice snapping."""
    rng = np.random.default_rng(9)
    for snap in (False, True):
        a = ClusterScheduler(48, policy="hesrpt_pc", class_aware=True,
                             snap_slices=snap)
        b = ClusterScheduler(48, policy="hesrpt_pc", class_aware=True,
                             snap_slices=snap)
        for i, s in enumerate(rng.pareto(1.5, 10) + 1.0):
            for sched in (a, b):
                sched.add_job(Job(f"j{i}", size=float(s),
                                  p=0.3 if i % 2 else 0.8, class_id=i % 2))
        assert a._engine_eligible()
        ra = a.run_fluid_to_completion(use_engine=True)
        rb = b.run_fluid_to_completion(use_engine=False)
        ea = [e["chips"] for e in a.events if e["event"] == "allocate"]
        eb = [e["chips"] for e in b.events if e["event"] == "allocate"]
        assert ea == eb, f"snap={snap}"
        np.testing.assert_allclose(ra["total_flow_time"],
                                   rb["total_flow_time"], rtol=1e-9)


def test_single_class_snap_slices_now_engine_eligible():
    """PR2 excluded snap_slices from engine delegation; the snap is
    engine-native now and must match the Python loop event-for-event."""
    rng = np.random.default_rng(13)
    a = ClusterScheduler(64, policy="hesrpt", snap_slices=True)
    b = ClusterScheduler(64, policy="hesrpt", snap_slices=True)
    for i, s in enumerate(rng.pareto(1.5, 9) + 1.0):
        a.add_job(Job(f"j{i}", size=float(s), p=0.5))
        b.add_job(Job(f"j{i}", size=float(s), p=0.5))
    assert a._engine_eligible()
    ra = a.run_fluid_to_completion(use_engine=True)
    rb = b.run_fluid_to_completion(use_engine=False)
    ea = [e["chips"] for e in a.events if e["event"] == "allocate"]
    eb = [e["chips"] for e in b.events if e["event"] == "allocate"]
    assert ea == eb
    np.testing.assert_allclose(ra["makespan"], rb["makespan"], rtol=1e-9)


def test_seeded_fuzz_snap_matches_oracle():
    """Seeded-fuzz twin of tests/test_quantize.py's hypothesis slice-snap
    property (that module is skipped wholesale without hypothesis): exact
    jnp == NumPy-oracle agreement plus the power-of-two postcondition."""
    from repro.core import DEFAULT_SLICES, snap_to_slices_jax
    from repro.sched.quantize import snap_to_slices

    rng = np.random.default_rng(21)
    for _ in range(150):
        m = int(rng.integers(1, 12))
        chips = rng.integers(0, 280, m)
        n_chips = int(chips.sum() + rng.integers(0, 40))
        ref = snap_to_slices(chips, max(n_chips, 1))
        got = np.asarray(snap_to_slices_jax(jnp.asarray(chips), max(n_chips, 1)))
        np.testing.assert_array_equal(got.astype(np.int64), ref)
        assert set(np.unique(ref)) <= set(DEFAULT_SLICES) | {0}
        assert ref.sum() <= max(n_chips, 1)


# ----------------------------------------------------- scenarios and noise
def test_multiclass_poisson_sampler_fields():
    scn = _draw(jax.random.PRNGKey(0), n=40)
    assert scn.class_ids is not None and scn.p_job is not None
    cls = np.asarray(scn.class_ids)
    assert set(np.unique(cls)) <= {0, 1}
    ps = np.asarray(scn.p_job)
    np.testing.assert_array_equal(ps, np.where(cls == 0, 0.3, 0.8))
    assert np.all(np.asarray(scn.x0) > 0)


def test_multiclass_bursty_counts_follow_mix():
    classes = (ClassSpec(p=0.4, mix=0.25), ClassSpec(p=0.6, mix=0.75))
    scn = make_scenario("multiclass_bursty", classes=classes)(
        jax.random.PRNGKey(1), 40, 2.0
    )
    counts = np.bincount(np.asarray(scn.class_ids), minlength=2)
    np.testing.assert_array_equal(counts, [10, 30])
    assert _class_counts(as_specs(classes), 41) in ([10, 31], [11, 30])
    res = simulate_multiclass(scn, classes=classes, policy="waterfill",
                              n_servers=64.0)
    assert np.all(np.isfinite(np.asarray(res.completion_times)))


def test_bursty_noise_streams_do_not_collide_with_workload():
    """Regression: the per-class bursty streams must live in an RNG domain
    disjoint from _with_noise's fold_in(key, 1)/fold_in(key, 2) — a
    collision makes the 'estimation error' a near-deterministic function
    of the job's own true size."""
    classes = (ClassSpec(p=0.3, mix=0.5), ClassSpec(p=0.8, mix=0.5))
    scn = make_scenario("multiclass_bursty", classes=classes,
                        sigma_size=0.3)(jax.random.PRNGKey(0), 1200, 4.0)
    cls = np.asarray(scn.class_ids)
    lx = np.log(np.asarray(scn.x0))
    lf = np.log(np.asarray(scn.size_factors))
    for k in (0, 1):
        c = np.corrcoef(lx[cls == k], lf[cls == k])[0, 1]
        assert abs(c) < 0.15, f"class {k} noise correlated with sizes: {c}"


def test_per_class_noise_perturbs_policy_view_only():
    """Per-class sigma sequences: class 1 gets noise, class 0 does not; the
    p_hat vector is per-job, clipped, centered on each class's true p."""
    sampler = make_scenario(
        "multiclass_poisson", classes=TWO_CLASSES,
        sigma_size=(0.0, 0.8), sigma_p=(0.0, 10.0),
    )
    scn = sampler(jax.random.PRNGKey(4), 30, 2.0)
    cls = np.asarray(scn.class_ids)
    factors = np.asarray(scn.size_factors)
    np.testing.assert_array_equal(factors[cls == 0], 1.0)
    assert np.any(factors[cls == 1] != 1.0)
    p_hat = np.asarray(scn.p_hat)
    assert p_hat.shape == cls.shape
    np.testing.assert_array_equal(p_hat[cls == 0], 0.3)
    assert np.all((p_hat >= 0.05) & (p_hat <= 0.95))
    res = simulate_multiclass(scn, classes=TWO_CLASSES, policy="hesrpt_pc",
                              n_servers=64.0)
    assert np.all(np.isfinite(np.asarray(res.completion_times)))


def test_load_sweep_multiclass_with_per_class_noise():
    """Regression: per-class sigma sequences through load_sweep must not
    crash the noisy-check, and the blind policy must see ONE p_hat (a
    per-job vector would break the rank brackets' sum-to-1 telescoping)."""
    from repro.core import load_sweep_raw, make_scenario, simulate_scenario

    raw = load_sweep_raw(
        ("hesrpt",), (1.0,), n_jobs=25, n_seeds=3, p=0.5, n_servers=32.0,
        scenario="multiclass_poisson",
        scenario_kw={"classes": TWO_CLASSES, "sigma_size": (0.0, 0.5),
                     "sigma_p": (0.2, 0.2)},
    )
    assert np.all(np.isfinite(np.asarray(raw["hesrpt"])))
    # the blind wrapper collapses a per-job p_hat to its mean: theta from
    # the policy must still conserve (sum to 1 over active jobs)
    scn = make_scenario("multiclass_poisson", classes=TWO_CLASSES,
                        sigma_p=(0.3, 0.3))(jax.random.PRNGKey(2), 20, 1.0)
    assert np.asarray(scn.p_hat).shape == (20,)
    res = simulate_scenario(scn, 0.5, 32.0, make_policy("hesrpt"))
    assert np.all(np.isfinite(np.asarray(res.completion_times)))


def test_load_sweep_multiclass_scenario_falls_back_to_generic():
    """The rank fast path must not be used for multi-class scenarios (rates
    are not monotone in size); the sweep still runs and is finite, with
    per-job class physics (this is the class-blind baseline path)."""
    from repro.core import load_sweep_raw

    raw = load_sweep_raw(
        ("hesrpt",), (0.5, 2.0), n_jobs=30, n_seeds=4, p=0.5,
        n_servers=32.0, scenario="multiclass_poisson",
        scenario_kw={"classes": TWO_CLASSES},
    )
    assert raw["hesrpt"].shape == (2, 4)
    assert np.all(np.isfinite(np.asarray(raw["hesrpt"])))


# -------------------------------------------------- per-class aggregation
def test_per_class_mean_and_count():
    vals = jnp.asarray([1.0, 2.0, 3.0, 5.0])
    ids = jnp.asarray([0, 1, 1, 0])
    np.testing.assert_allclose(np.asarray(per_class_mean(vals, ids, 3)),
                               [3.0, 2.5, np.nan])
    np.testing.assert_array_equal(np.asarray(per_class_count(ids, 3)),
                                  [2, 2, 0])


def test_per_class_summary_completion_order():
    flow = jnp.asarray([4.0, 1.0, 2.0, 3.0])
    slow = jnp.asarray([2.0, 1.0, 1.5, 1.25])
    times = jnp.asarray([4.0, 1.0, 2.0, 3.0])
    ids = jnp.asarray([1, 0, 0, 1])
    s = per_class_summary(flow, slow, times, ids, 2)
    # class 0 departs 1st and 2nd (orders 0, 1); class 1 departs 3rd, 4th
    np.testing.assert_allclose(np.asarray(s["mean_completion_order"]),
                               [0.5, 2.5])
    np.testing.assert_allclose(np.asarray(s["mean_flowtime"]), [1.5, 3.5])
    np.testing.assert_array_equal(np.asarray(s["count"]), [2, 2])


def test_multiclass_sweep_single_call_shapes():
    from repro.core import multiclass_sweep

    out = multiclass_sweep(
        ("hesrpt_pc", "hesrpt_blind"), (0.5, 2.0), classes=TWO_CLASSES,
        n_jobs=30, n_seeds=3, n_servers=32.0,
    )
    for name in ("hesrpt_pc", "hesrpt_blind"):
        assert out[name]["mean_flowtime"].shape == (2, 3)
        assert out[name]["class_flowtime"].shape == (2, 3, 2)
        assert np.all(np.isfinite(np.asarray(out[name]["mean_slowdown"])))


# ----------------------------------------------- per-class time-varying drift
def test_drift_multiclass_two_piece_closed_form_exact():
    """Single-job draws from the registered ``drift_multiclass`` sampler:
    the (random) job runs alone, so its completion has a two-piece closed
    form under its class's ``p -> p1[k]`` regime change — the engine must
    hit it exactly, whichever class was drawn and wherever the drift lands
    relative to the arrival."""
    classes = (ClassSpec(p=0.8, mix=0.5), ClassSpec(p=0.6, mix=0.5))
    sampler = make_scenario("drift_multiclass", classes=classes,
                            p1=(0.3, 0.2), drift_frac=0.5)
    n_servers = 64.0
    for seed in range(8):
        scn = sampler(jax.random.PRNGKey(seed), 1, 1.0)
        res = simulate_multiclass(scn, classes=classes, policy="hesrpt_pc",
                                  n_servers=n_servers)
        a1 = float(scn.arrival_times[0])
        x = float(scn.x0[0])
        p0v = float(scn.p_drift.values[0][0])
        p1v = float(scn.p_drift.values[1][0])
        t_d = float(scn.p_drift.times[0])
        r0, r1 = n_servers ** p0v, n_servers ** p1v
        if t_d <= a1:  # drift before the job even arrives
            expect = a1 + x / r1
        elif a1 + x / r0 <= t_d:  # finishes inside the first regime
            expect = a1 + x / r0
        else:  # the genuine two-piece case
            expect = t_d + (x - (t_d - a1) * r0) / r1
        np.testing.assert_allclose(float(res.completion_times[0]), expect,
                                   rtol=1e-12)


def test_drift_multiclass_sampler_structure():
    """The sampler fills the per-job-rows PDrift form: ``values[0]`` is
    the drawn pre-drift ``p_job`` (the stale scheduler's belief) and
    ``values[1]`` each job's class's post-drift exponent; a drift placed
    after the horizon reproduces the undrifted trajectory bit-for-bit."""
    classes = (ClassSpec(p=0.7, mix=0.6), ClassSpec(p=0.4, mix=0.4))
    sampler = make_scenario("drift_multiclass", classes=classes,
                            p1=(0.2, 0.9), drift_frac=0.5)
    scn = sampler(jax.random.PRNGKey(2), 40, 2.0)
    assert scn.p_drift is not None
    assert scn.p_drift.values.shape == (2, 40)
    np.testing.assert_array_equal(np.asarray(scn.p_drift.values[0]),
                                  np.asarray(scn.p_job))
    p1 = np.asarray([0.2, 0.9])[np.asarray(scn.class_ids)]
    np.testing.assert_array_equal(np.asarray(scn.p_drift.values[1]), p1)
    # drift far beyond the horizon: identical to dropping it entirely
    late = sampler(jax.random.PRNGKey(2), 40, 2.0)
    late = late._replace(
        p_drift=late.p_drift._replace(times=jnp.asarray([1e9]))
    )
    res_late = simulate_multiclass(late, classes=classes, policy="waterfill",
                                   n_servers=64.0)
    res_none = simulate_multiclass(scn._replace(p_drift=None),
                                   classes=classes, policy="waterfill",
                                   n_servers=64.0)
    np.testing.assert_array_equal(np.asarray(res_late.completion_times),
                                  np.asarray(res_none.completion_times))


def test_drift_multiclass_p1_length_validation():
    with pytest.raises(ValueError, match="post-drift exponent per class"):
        make_scenario("drift_multiclass", classes=TWO_CLASSES,
                      p1=(0.3,))(jax.random.PRNGKey(0), 8, 1.0)


def test_drift_multiclass_through_sweep_engine():
    """The new scenario composes with the sweep subsystem (per-class
    metrics finite at every load; physics follow each class's schedule)."""
    from repro.core import multiclass_sweep

    out = multiclass_sweep(
        ("hesrpt_pc",), (0.5, 2.0), classes=TWO_CLASSES, n_jobs=30,
        n_seeds=3, n_servers=32.0, scenario="drift_multiclass",
        scenario_kw={"p1": (0.15, 0.25)},
    )
    assert out["hesrpt_pc"]["mean_flowtime"].shape == (2, 3)
    assert np.all(np.isfinite(out["hesrpt_pc"]["class_slowdown"]))


# The hypothesis property twins (wider random ranges) live in
# tests/test_multiclass_properties.py, which — like tests/test_quantize.py
# — is skipped wholesale when hypothesis is absent; this module keeps the
# seeded-fuzz fallbacks above so bare environments still cover the
# invariants.
