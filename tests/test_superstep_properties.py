"""Hypothesis property tests for the closed-form superstep path.

Wider-random twins of the seeded-fuzz checks in tests/test_superstep.py:
superstep == generic per-event scan across arbitrary size/arrival draws —
including exact size ties, coincident arrivals, and arrivals landing
exactly on a departure instant — plus batch closed-form exactness against
Theorem 3 / Theorem 8.  Skipped wholesale when hypothesis is absent (same
convention as tests/test_quantize.py).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine as eng
from repro.core.flowtime import (
    hesrpt_completion_times,
    hesrpt_total_flowtime,
    speedup,
)
from repro.core.policies import make_policy
from repro.core.superstep import batch_result_closed_form, run_superstep

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (pip install -e '.[dev]')"
)
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

pytestmark = pytest.mark.usefixtures("fresh_compile_cache")

POLICIES = ("hesrpt", "equi", "srpt")


def _generic(x0, arr, p, n, pol):
    rule = eng.continuous_rule(
        make_policy(pol), n_servers=n, dtype=jnp.float64
    )
    return eng.run(x0, arr, p, rule)


def _assert_match(pol, got, want, tol=1e-10):
    got, want = np.asarray(got), np.asarray(want)
    if pol == "srpt":
        got, want = np.sort(got), np.sort(want)
    np.testing.assert_allclose(got, want, rtol=0, atol=tol)


@st.composite
def online_instances(draw):
    """Random online instance with deliberate tie mass.

    Sizes come from a coarse grid half the time (forcing exact remaining-
    size ties) and arrivals are rounded to a 0.25 grid (forcing coincident
    arrivals and arrival-on-departure events).
    """
    m = draw(st.integers(2, 16))
    gridded = draw(st.booleans())
    if gridded:
        xs = draw(st.lists(
            st.sampled_from([0.5, 1.0, 1.0, 2.0, 2.0, 4.0]),
            min_size=m, max_size=m,
        ))
    else:
        xs = draw(st.lists(
            st.floats(1e-2, 1e2, allow_nan=False, allow_infinity=False),
            min_size=m, max_size=m,
        ))
    raw = draw(st.lists(st.floats(0.0, 8.0), min_size=m, max_size=m))
    arr = np.sort(np.round(np.asarray(raw) / 0.25) * 0.25)
    p = draw(st.sampled_from([0.1, 0.3, 0.5, 0.7, 0.9]))
    n = draw(st.sampled_from([1.0, 4.0, 16.0]))
    return np.asarray(xs), arr, p, n


@settings(max_examples=60, deadline=None)
@given(inst=online_instances(), pol=st.sampled_from(POLICIES))
def test_superstep_matches_generic(inst, pol):
    """Superstep == generic scan on arbitrary draws (ties included)."""
    xs, arr, p, n = inst
    x = jnp.asarray(xs, jnp.float64)
    a = jnp.asarray(arr, jnp.float64)
    gen = _generic(x, a, p, n, pol)
    ss = run_superstep(x, a, p, n, pol)
    _assert_match(pol, ss.completion_times, gen.completion_times)


@settings(max_examples=40, deadline=None)
@given(inst=online_instances(), pol=st.sampled_from(POLICIES))
def test_arrival_on_departure_instant(inst, pol):
    """Append one arrival exactly at the first job's solo departure time —
    the superstep must fire the departure at that instant, like the
    generic scan's simultaneous admit+departure events."""
    xs, arr, p, n = inst
    x0 = float(xs[0])
    t_dep = float(arr[0]) + x0 / float(speedup(jnp.asarray(n), p))
    x = jnp.asarray(np.concatenate([xs, [1.0]]), jnp.float64)
    a = jnp.asarray(np.sort(np.concatenate([arr, [t_dep]])), jnp.float64)
    gen = _generic(x, a, p, n, pol)
    ss = run_superstep(x, a, p, n, pol)
    _assert_match(pol, ss.completion_times, gen.completion_times)


@settings(max_examples=60, deadline=None)
@given(
    xs=st.lists(
        st.floats(1e-2, 1e3, allow_nan=False, allow_infinity=False),
        min_size=1, max_size=32,
    ),
    p=st.floats(0.05, 0.95),
    n=st.sampled_from([1.0, 8.0, 64.0]),
)
def test_batch_closed_form_is_thm3(xs, p, n):
    """batch_result_closed_form == Theorem 3 floats, and its sum is the
    Theorem 8 optimal total flow time, in f64."""
    x = jnp.sort(jnp.asarray(xs, jnp.float64))[::-1]
    bc = batch_result_closed_form(x, p, "hesrpt", n_servers=n)
    np.testing.assert_array_equal(
        np.asarray(bc.completion_times),
        np.asarray(hesrpt_completion_times(x, p, n)),
    )
    np.testing.assert_allclose(
        float(jnp.sum(bc.completion_times)),
        float(hesrpt_total_flowtime(x, p, n)),
        rtol=1e-12,
    )
