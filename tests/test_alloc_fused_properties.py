"""Hypothesis twins of the fused-kernel invariants (tests/test_alloc_fused.py
carries the seeded-fuzz fallback that runs without hypothesis).

All examples share ONE static shape (M=16, n_chips/min_chips from tiny
sampled sets): interpret-mode Pallas recompiles per static configuration,
so varying shapes across hypothesis examples would turn a property test
into a compile benchmark.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine
from repro.core.policies import hesrpt
from repro.kernels.alloc import hesrpt_alloc_fused
from tests.test_alloc_fused import PS, _invariants

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (pip install -e '.[dev]')"
)
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402


@st.composite
def padded_jobs(draw):
    """Sizes padded to a FIXED M=16 (see module docstring)."""
    m = 16
    k = draw(st.integers(0, m))
    vals = draw(st.lists(
        st.floats(0.01, 1e3, allow_nan=False, allow_infinity=False),
        min_size=k, max_size=k,
    ))
    x = np.zeros(m)
    x[:k] = vals
    return jnp.asarray(x)


@settings(max_examples=60, deadline=None)
@given(x=padded_jobs(), p=st.sampled_from(PS),
       n_chips=st.sampled_from((8, 64)), min_chips=st.sampled_from((1, 3)))
def test_property_fused_kernel_invariants_interpret(x, p, n_chips, min_chips):
    """Conservation, min-chips floor, and within-1 hold for the Pallas
    kernel in interpret mode."""
    _invariants(x, p, n_chips, min_chips, "interpret")


@settings(max_examples=60, deadline=None)
@given(x=padded_jobs(), p=st.sampled_from(PS),
       n_chips=st.sampled_from((8, 64)), min_chips=st.sampled_from((1, 3)))
def test_property_fused_matches_unfused_interpret(x, p, n_chips, min_chips):
    """Exactness twin: fused (interpret) == the unfused policy+quantizer
    pipeline, theta bit-for-bit and chips exactly."""
    theta_ref = hesrpt(x, p)
    chips_ref = engine.quantize_allocation_jax(
        theta_ref, n_chips, min_chips=min_chips
    )
    theta, chips = hesrpt_alloc_fused(
        x, p, n_chips, min_chips=min_chips, impl="interpret"
    )
    np.testing.assert_array_equal(np.asarray(theta), np.asarray(theta_ref))
    np.testing.assert_array_equal(np.asarray(chips), np.asarray(chips_ref))
