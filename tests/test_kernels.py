"""Per-kernel shape/dtype sweeps: pallas(interpret=True) vs the jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.ssd_scan import ssd_scan

RNG = np.random.default_rng(42)


def _mk(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5), jnp.bfloat16: dict(rtol=5e-2, atol=5e-2)}


# ----------------------------------------------------------- flash attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,hq,hkv,sq,skv,d",
    [
        (1, 4, 4, 128, 128, 64),  # MHA, block-aligned
        (2, 4, 2, 256, 256, 64),  # GQA 2:1
        (1, 8, 1, 128, 128, 32),  # MQA
        (2, 4, 2, 130, 190, 64),  # ragged (padding paths)
        (1, 2, 2, 64, 64, 128),   # small seq < block
    ],
)
@pytest.mark.slow
def test_flash_attention_causal(dtype, b, hq, hkv, sq, skv, d):
    q, k, v = _mk((b, hq, sq, d), dtype), _mk((b, hkv, skv, d), dtype), _mk(
        (b, hkv, skv, d), dtype
    )
    off = max(skv - sq, 0)
    out = flash_attention(q, k, v, causal=True, q_offset=off, interpret=True)
    expect = ref.attention(q, k, v, causal=True, q_offset=off)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), **TOL[dtype]
    )


@pytest.mark.slow
@pytest.mark.parametrize("window", [16, 64, 100])
def test_flash_attention_sliding_window(window):
    q, k, v = _mk((1, 4, 256, 64)), _mk((1, 2, 256, 64)), _mk((1, 2, 256, 64))
    out = flash_attention(q, k, v, causal=True, window=window, interpret=True)
    expect = ref.attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=2e-5, atol=2e-5)


def test_flash_attention_non_causal():
    q, k, v = _mk((2, 2, 128, 64)), _mk((2, 2, 192, 64)), _mk((2, 2, 192, 64))
    out = flash_attention(q, k, v, causal=False, interpret=True)
    expect = ref.attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=2e-5, atol=2e-5)


def test_flash_attention_block_shape_independence():
    q, k, v = _mk((1, 2, 256, 64)), _mk((1, 2, 256, 64)), _mk((1, 2, 256, 64))
    a = flash_attention(q, k, v, causal=True, block_q=64, block_k=64, interpret=True)
    b = flash_attention(q, k, v, causal=True, block_q=128, block_k=256, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------- SSD
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,h,p,n,blk",
    [
        (1, 128, 2, 32, 16, 64),
        (2, 200, 3, 32, 16, 64),  # ragged
        (1, 64, 1, 64, 128, 32),
        (2, 96, 4, 16, 8, 128),  # block > seq
    ],
)
@pytest.mark.slow
def test_ssd_matches_recurrence(dtype, b, s, h, p, n, blk):
    x = _mk((b, s, h, p), dtype)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (b, s, h)), dtype)
    a = -jnp.asarray(RNG.uniform(0.5, 2.0, (h,)), jnp.float32)
    bm = _mk((b, s, n), dtype)
    cm = _mk((b, s, n), dtype)
    d = _mk((h,), jnp.float32)
    y, st = ssd_scan(x, dt, a, bm, cm, d, block_q=blk, interpret=True,
                     return_state=True)
    y_ref, st_ref = ref.ssd(x, dt, a, bm, cm, d, return_state=True)
    tol = TOL[dtype]
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y_ref, np.float32), **tol
    )
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref), rtol=1e-3, atol=1e-3)


def test_ssd_state_carries_decode():
    """Final prefill state must continue the recurrence exactly."""
    b, s, h, p, n = 1, 96, 2, 16, 8
    x = _mk((b, s + 1, h, p))
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (b, s + 1, h)), jnp.float32)
    a = -jnp.asarray(RNG.uniform(0.5, 2.0, (h,)), jnp.float32)
    bm, cm, d = _mk((b, s + 1, n)), _mk((b, s + 1, n)), _mk((h,))
    _, st = ssd_scan(x[:, :s], dt[:, :s], a, bm[:, :s], cm[:, :s], d,
                     block_q=32, interpret=True, return_state=True)
    y_step, _ = ref.ssd(x[:, s:], dt[:, s:], a, bm[:, s:], cm[:, s:], d,
                        h0=st, return_state=True)
    y_full = ref.ssd(x, dt, a, bm, cm, d)
    np.testing.assert_allclose(
        np.asarray(y_step[:, 0]), np.asarray(y_full[:, s]), rtol=1e-4, atol=1e-4
    )


# ------------------------------------------------------------------- RG-LRU
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,w,bt,bw",
    [(2, 100, 48, 256, 512), (1, 256, 64, 64, 32), (2, 64, 128, 17, 40)],
)
@pytest.mark.slow
def test_rglru_matches_scan(dtype, b, s, w, bt, bw):
    x = _mk((b, s, w), dtype)
    gx, ga = _mk((b, s, w), dtype), _mk((b, s, w), dtype)
    ap = _mk((w,), jnp.float32)
    rf = jax.nn.sigmoid(ga.astype(jnp.float32))
    log_a = -8.0 * jax.nn.softplus(ap)[None, None, :] * rf
    a_t = jnp.exp(log_a).astype(dtype)
    g = (jax.nn.sigmoid(gx.astype(jnp.float32)) * x.astype(jnp.float32)
         * jnp.sqrt(-jnp.expm1(2 * log_a))).astype(dtype)
    out = rglru_scan(a_t, g, block_t=bt, block_w=bw, interpret=True)
    expect = ref.rglru(x, gx, ga, ap)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), **TOL[dtype]
    )


def test_ops_dispatch():
    """impl='interpret' (kernel) and impl='ref' (oracle) agree through ops."""
    from repro.kernels import ops

    q, k, v = _mk((1, 4, 128, 64)), _mk((1, 2, 128, 64)), _mk((1, 2, 128, 64))
    np.testing.assert_allclose(
        np.asarray(ops.attention(q, k, v, impl="interpret")),
        np.asarray(ops.attention(q, k, v, impl="ref")),
        rtol=2e-5, atol=2e-5,
    )
    with pytest.raises(ValueError):
        ops.attention(q, k, v, impl="nope")


# ------------------------------------------------- chunked XLA implementations
@pytest.mark.parametrize(
    "kw",
    [dict(causal=True), dict(causal=True, window=70), dict(causal=False),
     dict(causal=True, q_offset=120)],
)
def test_chunked_attention_matches_ref(kw):
    from repro.kernels import chunked

    q = _mk((2, 4, 300, 32))
    k = _mk((2, 2, 420, 32))
    v = _mk((2, 2, 420, 32))
    got = chunked.attention(q, k, v, block_q=128, block_k=128, **kw)
    expect = ref.attention(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


def test_chunked_ssd_matches_ref():
    from repro.kernels import chunked

    b, s, h, p, n = 2, 200, 3, 32, 16
    x = _mk((b, s, h, p))
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (b, s, h)), jnp.float32)
    a = -jnp.asarray(RNG.uniform(0.5, 2.0, (h,)), jnp.float32)
    bm, cm, d = _mk((b, s, n)), _mk((b, s, n)), _mk((h,))
    h0 = _mk((b, h, p, n), scale=0.1)
    y1, s1 = chunked.ssd(x, dt, a, bm, cm, d, block=64, h0=h0, return_state=True)
    y2, s2 = ref.ssd(x, dt, a, bm, cm, d, h0=h0, return_state=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4, atol=1e-4)


def test_chunked_rglru_matches_ref():
    from repro.kernels import chunked

    b, s, w = 2, 150, 48
    x, gx, ga = _mk((b, s, w)), _mk((b, s, w)), _mk((b, s, w))
    ap = _mk((w,))
    h0 = _mk((b, w), scale=0.3)
    y1, f1 = chunked.rglru(x, gx, ga, ap, h0=h0, return_state=True)
    y2, f2 = ref.rglru(x, gx, ga, ap, h0=h0, return_state=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), rtol=1e-5, atol=1e-5)


@pytest.mark.slow
def test_model_forward_identical_across_impls():
    """A full model forward agrees between ref and chunked lowering paths."""
    from repro.configs import smoke_config
    from repro.models import ModelOptions, build_model

    for arch in ("mamba2-130m", "recurrentgemma-9b", "qwen2.5-14b"):
        cfg = smoke_config(arch)
        params = None
        outs = {}
        batch = {
            "tokens": jnp.asarray(RNG.integers(0, cfg.vocab_size, (2, 24)),
                                  jnp.int32),
            "labels": jnp.asarray(RNG.integers(0, cfg.vocab_size, (2, 24)),
                                  jnp.int32),
        }
        for impl in ("ref", "chunked"):
            m = build_model(cfg, ModelOptions(activation_dtype="float32",
                                              remat="none", attn_impl=impl,
                                              mixer_impl=impl))
            if params is None:
                params = m.init(jax.random.PRNGKey(0))
            outs[impl], _ = m.loss_fn(params, batch)
        np.testing.assert_allclose(float(outs["ref"]), float(outs["chunked"]),
                                   rtol=1e-5)
