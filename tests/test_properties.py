"""Property-based tests (hypothesis) for the paper's structural claims and
the scheduler's invariants."""

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (pip install -e '.[dev]')"
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (  # noqa: E402
    equi,
    helrpt,
    hesrpt,
    hesrpt_total_flowtime,
    omega_star,
    simulate,
    srpt,
)
from repro.sched.quantize import quantize_allocation, snap_to_slices  # noqa: E402

sizes_strategy = st.lists(
    st.floats(min_value=0.05, max_value=100.0, allow_nan=False),
    min_size=2,
    max_size=12,
)
p_strategy = st.floats(min_value=0.05, max_value=0.95)


@settings(max_examples=30, deadline=None)
@given(sizes_strategy, p_strategy)
def test_hesrpt_allocations_form_distribution(xs, p):
    theta = np.asarray(hesrpt(jnp.asarray(xs), p))
    assert np.all(theta >= -1e-12)
    np.testing.assert_allclose(theta.sum(), 1.0, rtol=1e-9)


@settings(max_examples=30, deadline=None)
@given(sizes_strategy, p_strategy)
def test_hesrpt_smaller_jobs_get_more(xs, p):
    """theta increases as remaining size decreases (ties excluded)."""
    xs = sorted(set(round(x, 6) for x in xs), reverse=True)
    if len(xs) < 2:
        return
    theta = np.asarray(hesrpt(jnp.asarray(xs), p))
    assert np.all(np.diff(theta) > -1e-12)


@settings(max_examples=20, deadline=None)
@given(sizes_strategy, p_strategy)
def test_hesrpt_beats_competitors(xs, p):
    """Optimality (Thm 7/8): no competitor achieves lower total flow time."""
    x = jnp.asarray(sorted(xs, reverse=True))
    n = 1000.0
    opt = float(simulate(x, p, n, hesrpt).total_flowtime)
    for pol in (equi, srpt, helrpt):
        other = float(simulate(x, p, n, pol).total_flowtime)
        assert opt <= other * (1 + 1e-6), (pol.__name__, opt, other)


@settings(max_examples=20, deadline=None)
@given(sizes_strategy, p_strategy)
def test_theorem8_closed_form_equals_simulation(xs, p):
    x = jnp.asarray(sorted(xs, reverse=True))
    closed = float(hesrpt_total_flowtime(x, p, 1000.0))
    sim = float(simulate(x, p, 1000.0, hesrpt).total_flowtime)
    np.testing.assert_allclose(closed, sim, rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(sizes_strategy, p_strategy)
def test_scale_free_property_along_trajectory(xs, p):
    """Thm 4: during job i's lifetime, sum_{j<i} theta_j / theta_i is the
    constant omega_i.  Verified on the simulated heSRPT trajectory."""
    x = jnp.asarray(sorted(xs, reverse=True))
    m = x.shape[0]
    res = simulate(x, p, 100.0, hesrpt)
    om = np.asarray(omega_star(m, p))
    theta_tr = np.asarray(res.theta_trace)  # [E, M]
    sizes_tr = np.asarray(res.sizes_trace)
    for e in range(theta_tr.shape[0]):
        active = sizes_tr[e] > 1e-12
        th = theta_tr[e]
        if active.sum() < 2:
            continue
        # jobs sorted descending by x0: rank i = index among active
        idx = np.where(active)[0]
        for r, j in enumerate(idx):
            if th[j] <= 1e-12:
                continue
            omega_hat = th[idx[:r]].sum() / th[j]
            np.testing.assert_allclose(omega_hat, om[r], rtol=1e-4, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(sizes_strategy, p_strategy)
def test_size_invariance(xs, p):
    """Thm 6: theta depends only on the number of active jobs."""
    a = np.asarray(hesrpt(jnp.asarray(sorted(xs, reverse=True)), p))
    b = np.asarray(
        hesrpt(jnp.asarray(sorted([x * 7.3 + 1 for x in xs], reverse=True)), p)
    )
    np.testing.assert_allclose(a, b, rtol=1e-9)


def test_hesrpt_limits():
    """p -> 1: heSRPT -> SRPT; p -> 0: heSRPT -> EQUI."""
    x = jnp.asarray([5.0, 3.0, 1.0])
    near_srpt = np.asarray(hesrpt(x, 0.999))
    assert near_srpt[2] > 0.99  # smallest job takes (almost) everything
    near_equi = np.asarray(hesrpt(x, 1e-4))
    np.testing.assert_allclose(near_equi, [1 / 3] * 3, atol=1e-3)


# ------------------------------------------------------------- quantization
chips_strategy = st.integers(min_value=1, max_value=512)


@settings(max_examples=50, deadline=None)
@given(sizes_strategy, p_strategy, chips_strategy)
def test_quantizer_conservation_and_proximity(xs, p, n_chips):
    theta = np.asarray(hesrpt(jnp.asarray(sorted(xs, reverse=True)), p))
    chips = quantize_allocation(theta, n_chips, min_chips=1)
    assert chips.sum() <= n_chips
    m = (theta > 0).sum()
    if m <= n_chips:  # every job servable
        assert chips.sum() == n_chips
        assert np.all(chips[theta > 0] >= 1)
        # within 1 chip of fractional share unless pushed by the min floor
        raw = theta * n_chips
        slack = np.maximum(np.abs(chips - raw), 0)
        assert np.all((slack <= m) | (chips == 1))
    else:  # oversubscribed: largest-theta jobs served
        assert np.all(chips[theta == 0] == 0)


@settings(max_examples=30, deadline=None)
@given(sizes_strategy, p_strategy, st.integers(min_value=8, max_value=256))
def test_slice_snapping_stays_within_budget(xs, p, n_chips):
    theta = np.asarray(hesrpt(jnp.asarray(sorted(xs, reverse=True)), p))
    chips = quantize_allocation(theta, n_chips, min_chips=1)
    snapped = snap_to_slices(chips, n_chips)
    assert snapped.sum() <= n_chips
    allowed = {1, 2, 4, 8, 16, 32, 64, 128, 256, 0}
    assert set(int(c) for c in snapped) <= allowed


# --------------------------------------------------------------- estimator
@settings(max_examples=20, deadline=None)
@given(p_strategy)
def test_estimator_recovers_p(p):
    from repro.sched.estimator import SpeedupEstimator

    est = SpeedupEstimator(prior_p=0.5, prior_weight=1e-6)
    for k in [1, 2, 4, 8, 16, 32]:
        est.observe(k, 3.7 * k ** p)
    assert abs(est.p_hat() - p) < 0.02, (est.p_hat(), p)
