"""The bounded-slot streaming engine: reduction, recycling, windows.

Three layers of proof, mirroring the engine's own contract:

- **Reduction (golden pin)**: with ``n_slots >= n_jobs`` the slot pool
  never recycles, so ``run_stream`` / ``run_stream_ranked`` must
  reproduce ``run`` / ``run_ranked`` *bit-for-bit* on the same tape —
  continuous, quantized, fused and stateful rules alike.  Any drift
  means the refactor changed the physics, not just the memory layout.
- **Recycling**: with ``n_slots`` far below the job count the engine
  defers admissions instead of dropping them; completion order, blocked
  accounting and the windowed aggregates must match the per-event Python
  ``ClusterScheduler`` oracle on the same tape.
- **Slot invariance**: telemetry's time-weighted aggregates and the
  windowed flow/slowdown sums are functions of the *active set*, never
  of which slot a job happens to sit in — so any two pools wide enough
  to avoid blocking must agree exactly (hypothesis property + seeded
  regression twin).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, make_policy, make_rank_policy, make_scenario
from repro.core.scenarios import stream_tape
from repro.core.telemetry import make_probe, scalar_values

pytestmark = pytest.mark.usefixtures("fresh_compile_cache")

N_JOBS = 40


def _tape(seed=0, n_jobs=N_JOBS, rate=2.0, p=0.5):
    scn = make_scenario("poisson", p=p)(jax.random.key(seed), n_jobs, rate)
    return scn.x0, scn.arrival_times


def _rule(kind, dtype, n_chips=16):
    pol = make_policy("hesrpt")
    if kind == "continuous":
        return engine.continuous_rule(pol, 1.0, dtype=dtype), False
    if kind == "quantized":
        return engine.quantized_rule(pol, n_chips, dtype=dtype), False
    if kind == "fused":
        return engine.quantized_rule(pol, n_chips, dtype=dtype), True
    if kind == "knee":
        knee = make_policy("knee", n_servers=1.0)
        return engine.continuous_rule(knee, 1.0, dtype=dtype), False
    raise AssertionError(kind)


# ------------------------------------------------------- reduction golden pin
@pytest.mark.parametrize("kind", ["continuous", "quantized", "fused", "knee"])
@pytest.mark.parametrize("seed", [0, 3])
def test_run_stream_reduces_to_run_bitforbit(kind, seed):
    x0, arr = _tape(seed)
    rule, fused = _rule(kind, x0.dtype)
    ref = engine.run(x0, arr, 0.5, rule, fused=fused)
    res = engine.run_stream(
        x0, arr, 0.5, rule, n_slots=N_JOBS, record_times=True, fused=fused,
    )
    np.testing.assert_array_equal(
        np.asarray(res.completion_times), np.asarray(ref.completion_times)
    )
    assert int(res.n_admitted) == N_JOBS
    assert int(res.n_completed) == N_JOBS
    assert int(res.blocked_steps) == 0
    assert not np.any(np.asarray(res.x_final))


@pytest.mark.parametrize("name", ["hesrpt", "srpt", "equi"])
def test_run_stream_ranked_reduces_to_run_ranked_bitforbit(name):
    x0, arr = _tape(seed=1)
    ref = engine.run_ranked(x0, arr, 0.5, 1.0, make_rank_policy(name))
    res = engine.run_stream_ranked(
        x0, arr, 0.5, 1.0, make_rank_policy(name), n_slots=N_JOBS,
        record_times=True,
    )
    np.testing.assert_array_equal(np.asarray(res.completion_times),
                                  np.asarray(ref))


def test_ranked_and_generic_streams_agree_under_recycling():
    x0, arr = _tape(seed=2, n_jobs=80)
    rule, _ = _rule("continuous", x0.dtype)
    span = float(arr[-1])
    window = (0.1 * span, 0.9 * span)
    a = engine.run_stream(x0, arr, 0.5, rule, n_slots=12, window=window)
    b = engine.run_stream_ranked(
        x0, arr, 0.5, 1.0, make_rank_policy("hesrpt"), n_slots=12,
        window=window,
    )
    np.testing.assert_allclose(float(a.mean_flow), float(b.mean_flow),
                               rtol=1e-9)
    assert int(a.n_window) == int(b.n_window)
    assert int(a.blocked_steps) == int(b.blocked_steps)
    assert int(a.occupancy_max) == int(b.occupancy_max)


# ------------------------------------------------------ recycling vs oracle
def test_recycled_stream_matches_python_oracle_window():
    from benchmarks.arrivals import run_stream_reference, stream_trace

    arr_np, x_np = stream_trace(100, rate=2.0, seed=5)
    span = float(arr_np[-1])
    window = (0.1 * span, 0.9 * span)
    in_w = (arr_np >= window[0]) & (arr_np < window[1])
    dtype = jnp.result_type(float)
    pol = make_policy("hesrpt", n_servers=64)
    for quantize in (False, True):
        rule = (
            engine.quantized_rule(pol, 64, dtype=dtype) if quantize
            else engine.continuous_rule(pol, 64, dtype=dtype)
        )
        res = engine.run_stream(
            jnp.asarray(x_np, dtype), jnp.asarray(arr_np, dtype), 0.5, rule,
            n_slots=16, window=window, n_alone=64,
        )
        flows = run_stream_reference("hesrpt", arr_np, x_np, p=0.5,
                                     n_chips=64, quantize=quantize)
        assert int(res.n_window) == int(in_w.sum())
        np.testing.assert_allclose(
            float(res.mean_flow), float(np.mean(flows[in_w])), rtol=1e-9,
        )


def test_blocked_arrival_defers_not_drops():
    # One slot, two unit jobs: the second arrives at t=0.1 into a full
    # pool, waits for the slot, and its flow time counts the wait.
    x0 = jnp.asarray([1.0, 1.0])
    arr = jnp.asarray([0.0, 0.1])
    rule, _ = _rule("continuous", x0.dtype)
    res = engine.run_stream(x0, arr, 0.5, rule, n_slots=1, horizon=8,
                            record_times=True)
    np.testing.assert_allclose(np.asarray(res.completion_times), [1.0, 2.0],
                               rtol=1e-12)
    assert int(res.n_admitted) == 2 and int(res.n_completed) == 2
    assert int(res.blocked_steps) >= 1
    assert int(res.occupancy_max) == 1
    # windowed flow counts from TRUE arrival: job 2 waited 0.9 in the queue
    assert float(res.flow_sum) == pytest.approx(1.0 + 1.9, rel=1e-12)


def test_poisson_source_runs_unbounded():
    dtype = jnp.result_type(float)
    rule, _ = _rule("continuous", dtype)
    src = engine.poisson_source(jax.random.key(0), 1.5, dtype=dtype)
    res = engine.run_stream_source(src, 0.5, rule, n_slots=8, n_events=400)
    assert int(res.n_completed) > 50
    assert int(res.occupancy_max) <= 8
    assert int(res.n_admitted) >= int(res.n_completed)
    assert float(res.t_final) > 0


# ------------------------------------------------- slot-placement invariance
def _invariance_pair(x0, arr, window, wide, narrow):
    """Run the same tape through two non-blocking pool widths with a
    telemetry probe; aggregates must not see the slot layout."""
    rule, _ = _rule("continuous", x0.dtype)
    out = []
    for n_slots in (wide, narrow):
        probe = make_probe(("utilization", "queue"), mode="stream",
                           n_jobs=n_slots, window=window, dtype=x0.dtype)
        res = engine.run_stream(x0, arr, 0.5, rule, n_slots=n_slots,
                                window=window, telemetry=probe)
        assert int(res.blocked_steps) == 0, "pool too narrow for the pin"
        out.append(res)
    return out


def _assert_invariant(a, b):
    np.testing.assert_allclose(float(a.mean_flow), float(b.mean_flow),
                               rtol=1e-12)
    np.testing.assert_allclose(float(a.mean_slowdown), float(b.mean_slowdown),
                               rtol=1e-12)
    assert int(a.n_window) == int(b.n_window)
    assert int(a.n_arrived_window) == int(b.n_arrived_window)
    for m in ("utilization", "queue"):
        np.testing.assert_allclose(
            float(a.telemetry.aggregates[f"{m}_mean"]),
            float(b.telemetry.aggregates[f"{m}_mean"]), rtol=1e-12,
        )
        np.testing.assert_allclose(
            float(a.telemetry.aggregates[f"{m}_max"]),
            float(b.telemetry.aggregates[f"{m}_max"]), rtol=1e-12,
        )
        # histograms are time-weighted masses over the same trajectory;
        # the queue support is sized by n_jobs=n_slots, so compare the
        # slot-size-independent utilization one bin-for-bin
        if m == "utilization":
            np.testing.assert_allclose(
                np.asarray(a.telemetry.aggregates[f"{m}_hist"]),
                np.asarray(b.telemetry.aggregates[f"{m}_hist"]), atol=1e-12,
            )


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_telemetry_invariant_to_slot_placement_seeded(seed):
    x0, arr = _tape(seed=seed, n_jobs=30, rate=1.0)
    span = float(arr[-1])
    window = (0.1 * span, 0.9 * span)
    probe_res = engine.run_stream(
        x0, arr, 0.5, _rule("continuous", x0.dtype)[0], n_slots=30,
    )
    narrow = max(int(probe_res.occupancy_max), 2)
    a, b = _invariance_pair(x0, arr, window, 30, narrow)
    _assert_invariant(a, b)


def test_telemetry_invariant_to_slot_placement_property():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(seed=st.integers(0, 2**16), rate=st.floats(0.5, 3.0))
    @hyp.settings(max_examples=15, deadline=None)
    def check(seed, rate):
        x0, arr = _tape(seed=seed, n_jobs=16, rate=rate)
        span = float(arr[-1])
        window = (0.2 * span, 0.8 * span)
        first = engine.run_stream(
            x0, arr, 0.5, _rule("continuous", x0.dtype)[0], n_slots=16,
        )
        narrow = max(int(first.occupancy_max), 2)
        a, b = _invariance_pair(x0, arr, window, 16, narrow)
        _assert_invariant(a, b)

    check()


def test_windowed_probe_counts_only_window_time():
    # One job, size 4, rate 1: active over [0, 4); the window [1, 3)
    # must contribute exactly 2.0 of time mass regardless of the tail.
    x0 = jnp.asarray([4.0])
    arr = jnp.asarray([0.0])
    rule, _ = _rule("continuous", x0.dtype)
    probe = make_probe(("utilization",), mode="stream", n_jobs=1,
                       window=(1.0, 3.0), dtype=x0.dtype)
    res = engine.run_stream(x0, arr, 0.5, rule, n_slots=1, telemetry=probe)
    assert float(res.telemetry.aggregates["time"]) == pytest.approx(2.0)
    un = make_probe(("utilization",), mode="stream", n_jobs=1, dtype=x0.dtype)
    res2 = engine.run_stream(x0, arr, 0.5, rule, n_slots=1, telemetry=un)
    assert float(res2.telemetry.aggregates["time"]) == pytest.approx(4.0)


def test_stream_telemetry_is_neutral():
    x0, arr = _tape(seed=4)
    rule, _ = _rule("continuous", x0.dtype)
    plain = engine.run_stream(x0, arr, 0.5, rule, n_slots=10)
    probe = make_probe(("utilization",), mode="stream", n_jobs=10,
                       dtype=x0.dtype)
    with_tel = engine.run_stream(x0, arr, 0.5, rule, n_slots=10,
                                 telemetry=probe)
    np.testing.assert_array_equal(np.asarray(plain.x_final),
                                  np.asarray(with_tel.x_final))
    assert float(plain.mean_flow) == float(with_tel.mean_flow)
    vals = scalar_values(with_tel.telemetry, ("utilization",))
    assert all(np.isfinite(float(v)) for v in vals)


# ----------------------------------------------------- sweep-layer threading
def test_streaming_sweep_end_to_end_and_roundtrip():
    from repro.core.sweeps import (
        STREAM_METRICS, Sweep, SweepResult, run_sweep,
    )

    spec = Sweep.create(
        ["hesrpt", "helrpt"], [1.0, 4.0], n_jobs=60, n_seeds=2,
        stream={"n_slots": 12},
        metrics=tuple(STREAM_METRICS),
    )
    res = run_sweep(spec, log=False)
    for name in spec.policies:
        for m in spec.metrics:
            assert res.stats[name][m].shape == (2, 2)
        assert np.all(res.stats[name]["stream_flow"] > 0)
        assert np.all(res.stats[name]["stream_occupancy"] <= 12)
    back = SweepResult.from_json(res.to_json())
    assert back.spec == spec
    rec = res.record()
    assert dict(rec["spec"]["stream"])["n_slots"] == 12


def test_simulate_stream_quantized_plumbing():
    from repro.core.arrivals import simulate_stream

    scn = make_scenario("poisson", p=0.5)(jax.random.key(0), 50, 2.0)
    res = simulate_stream(scn, 0.5, 1.0, make_policy("hesrpt", n_servers=32),
                          n_slots=10, n_chips=32)
    assert int(res.n_completed) > 0
    assert int(res.occupancy_max) <= 10


# ------------------------------------------------------------- validation
def test_stream_rejects_per_job_p():
    x0, arr = _tape(seed=0, n_jobs=8)
    rule, _ = _rule("continuous", x0.dtype)
    p_job = jnp.full(8, 0.5)
    with pytest.raises(ValueError, match="scalar p"):
        engine.run_stream(x0, arr, p_job, rule, n_slots=8)
    with pytest.raises(ValueError, match="scalar p"):
        engine.run_stream_ranked(x0, arr, p_job, 1.0,
                                 make_rank_policy("hesrpt"), n_slots=8)


def test_stream_tape_rejects_non_slot_state():
    scn = make_scenario("poisson", p=0.5)(jax.random.key(0), 8, 1.0)
    x0, arr = stream_tape(scn)
    assert x0.shape == (8,) and arr.shape == (8,)
    noisy = scn._replace(size_factors=jnp.ones(8))
    with pytest.raises(ValueError, match="estimation noise"):
        stream_tape(noisy)
    classed = scn._replace(p_job=jnp.full(8, 0.5))
    with pytest.raises(ValueError, match="per-job class"):
        stream_tape(classed)


def test_window_is_stream_mode_only():
    with pytest.raises(ValueError, match="stream-mode only"):
        make_probe(("utilization",), mode="series", window=(0.0, 1.0))
