"""Hypothesis property tests for the sweep subsystem.

Wider-random twins of the seeded chunk checks in tests/test_sweeps.py:
chunk-boundary invariance (any chunk size, any seed count, divisor or not,
reproduces the unchunked vmap bit-for-bit) and the jobs-in-flight budget
arithmetic.  Skipped wholesale when hypothesis is absent (same convention
as tests/test_quantize.py).
"""

import numpy as np
import pytest

from repro.core.sweeps import Sweep, resolve_chunk, run_sweep

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (pip install -e '.[dev]')"
)
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

# One fixed small grid per seed count: the property varies HOW it is
# chunked, not WHAT is simulated, so the reference runs once per n_seeds.
_REFS: dict[int, np.ndarray] = {}


def _ref(n_seeds: int):
    spec = Sweep.create(("equi",), (1.0, 4.0), n_jobs=12, n_seeds=n_seeds,
                        p=0.5, n_servers=32.0, seed=0)
    if n_seeds not in _REFS:
        _REFS[n_seeds] = run_sweep(spec, log=False).stats["equi"][
            "mean_flowtime"]
    return spec, _REFS[n_seeds]


@settings(max_examples=12, deadline=None)
@given(n_seeds=st.integers(2, 7), chunk=st.integers(1, 9))
def test_chunk_boundary_invariance(n_seeds, chunk):
    """Any (n_seeds, chunk) pair — divisor, non-divisor, chunk > n_seeds —
    is bit-for-bit the unchunked vmap."""
    spec, ref = _ref(n_seeds)
    got = run_sweep(spec, chunk_seeds=chunk, log=False)
    np.testing.assert_array_equal(got.stats["equi"]["mean_flowtime"], ref)


@settings(max_examples=25, deadline=None)
@given(budget=st.integers(1, 5000), n_jobs=st.integers(1, 100),
       n_rates=st.integers(1, 5))
def test_jobs_in_flight_budget_arithmetic(budget, n_jobs, n_rates):
    """The resolved chunk never exceeds the budget (except the one-seed
    floor) and never wastes it by more than one seed's worth."""
    spec = Sweep.create(("equi",), tuple(float(r + 1) for r in range(n_rates)),
                        n_jobs=n_jobs, n_seeds=8)
    chunk = resolve_chunk(spec, None, budget)
    per_seed = spec.jobs_per_seed()
    assert chunk >= 1
    if chunk > 1:
        assert chunk * per_seed <= budget
    assert (chunk + 1) * per_seed > budget
