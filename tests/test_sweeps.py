"""The sweep subsystem (core/sweeps.py): golden pins, scale layers,
artifacts.

- **Golden pins**: the declarative engine must reproduce the pre-refactor
  sweep outputs BIT-FOR-BIT on f64 — the hardcoded arrays below were
  computed with the historical ``load_sweep_raw`` / ``multiclass_sweep`` /
  ``benchmarks.estimation.sweep`` implementations (per-experiment jit+vmap
  closures) immediately before the refactor.  Any drift here means the
  consolidation changed the numbers, not just the plumbing.
- **Chunked execution**: ``lax.map`` over seed-chunks must equal the
  unchunked vmap exactly, for any chunk size (boundary invariance), and
  the ``max_jobs_in_flight`` budget must bound the chunk.
- **Device sharding**: ``shard_map`` over the seed axis must equal the
  single-device run exactly (forced multi-device via ``XLA_FLAGS`` in a
  subprocess — the main process must stay single-device for other tests).
- **Artifacts**: ``SweepResult`` JSON round-trips exactly; ``run_sweep``
  appends records that ``write_bench_json`` flushes to ``BENCH_sweeps.json``.

Hypothesis twins (wider random chunk/grid shapes) live in
tests/test_sweeps_properties.py.
"""

import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core.multiclass import ClassSpec
from repro.core.sweeps import (
    RUN_LOG,
    Sweep,
    SweepResult,
    resolve_chunk,
    run_sweep,
    write_bench_json,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

TWO_CLASSES = (
    ClassSpec(p=0.35, mix=0.5, size_alpha=1.5),
    ClassSpec(p=0.75, mix=0.5, size_alpha=2.2, size_scale=2.0),
)

# ---------------------------------------------------------------- golden pins
# Captured from the pre-refactor implementations (f64, CPU) — see module
# docstring.  Shapes are [n_rates, n_seeds] (plus [K] for per-class).
GOLDEN_SINGLE_HESRPT = np.array([
    [0.23153625482726803, 0.3703338944968655, 0.2662982809188139],
    [0.3450925220814107, 0.6507978461174639, 0.3897807287222366],
])
GOLDEN_SINGLE_EQUI = np.array([
    [0.23223165577890212, 0.37297075197246493, 0.2677361073535773],
    [0.34854334340932225, 0.6512371185412188, 0.388522691289554],
])
GOLDEN_QUANTIZED = np.array([
    [0.7648913378555785, 0.6046536432011128, 0.6815494191735356],
])
GOLDEN_NOISY = np.array([[0.3708841996040246, 0.24538231893316642]])
GOLDEN_MC_FLOW = np.array([
    [0.4264753807970431, 0.4248864547066305, 0.5173592524092415],
    [0.5871012240009411, 0.6798427826155753, 0.8929526188533408],
])
GOLDEN_MC_CLASS_SLOWDOWN = np.array([
    [[1.226092645204169, 1.0633959020215102],
     [1.070026184700001, 1.1180905917924688],
     [1.1364921388941647, 1.0408943509583977]],
    [[1.4996845733857311, 1.350360953887995],
     [1.6575531256010392, 1.3325386750629662],
     [1.6071727881423732, 1.3914540718450603]],
])
GOLDEN_ARMS = {
    "oracle": {0.5: 0.28600679084453096, 4.0: 0.393446439817357},
    "stale": {0.5: 0.2940916760924689, 4.0: 0.46252966839098775},
    "estimator": {0.5: 0.2924068540805797, 4.0: 0.41391450905303173},
}


def test_golden_pin_single_class_load_sweep():
    from repro.core import load_sweep_raw

    raw = load_sweep_raw(("hesrpt", "equi"), (0.5, 4.0), n_jobs=40,
                         n_seeds=3, p=0.5, n_servers=64.0, seed=0)
    np.testing.assert_array_equal(np.asarray(raw["hesrpt"]),
                                  GOLDEN_SINGLE_HESRPT)
    np.testing.assert_array_equal(np.asarray(raw["equi"]),
                                  GOLDEN_SINGLE_EQUI)


def test_golden_pin_quantized_and_noisy_paths():
    from repro.core import load_sweep_raw

    rq = load_sweep_raw(("hesrpt",), (2.0,), n_jobs=30, n_seeds=3, p=0.5,
                        n_servers=32.0, seed=1, n_chips=32)
    np.testing.assert_array_equal(np.asarray(rq["hesrpt"]), GOLDEN_QUANTIZED)
    rn = load_sweep_raw(("hesrpt",), (1.0,), n_jobs=25, n_seeds=2, p=0.5,
                        n_servers=64.0, seed=2,
                        scenario_kw={"sigma_size": 0.3})
    np.testing.assert_array_equal(np.asarray(rn["hesrpt"]), GOLDEN_NOISY)


def test_golden_pin_multiclass_sweep():
    from repro.core import multiclass_sweep

    out = multiclass_sweep(("hesrpt_pc", "waterfill"), (1.0, 4.0),
                           classes=TWO_CLASSES, n_jobs=30, n_seeds=3,
                           n_servers=64.0, seed=3)
    np.testing.assert_array_equal(
        np.asarray(out["hesrpt_pc"]["mean_flowtime"]), GOLDEN_MC_FLOW)
    np.testing.assert_array_equal(
        np.asarray(out["waterfill"]["class_slowdown"]),
        GOLDEN_MC_CLASS_SLOWDOWN)


def test_golden_pin_estimation_arms():
    from benchmarks.estimation import sweep

    got = sweep(("oracle", "stale", "estimator"), (0.5, 4.0), n_jobs=40,
                n_seeds=3, p0=0.8, p1=0.3, drift_frac=0.5, n_servers=64.0,
                seed=0, discount=0.9, prior_weight=1.0)
    assert got == GOLDEN_ARMS  # exact float equality, not allclose


# --------------------------------------------------------- chunked execution
def _small_spec(**kw):
    base = dict(policies=("hesrpt",), rates=(0.5, 4.0), n_jobs=25, n_seeds=5,
                p=0.5, n_servers=64.0, seed=0)
    base.update(kw)
    pols = base.pop("policies")
    rates = base.pop("rates")
    return Sweep.create(pols, rates, **base)


@pytest.fixture(scope="module")
def small_sweep_ref():
    """One shared unchunked reference run of ``_small_spec()``.

    Several tests below need "the plain vmap answer for the small spec" as
    their comparison baseline; computing it per-test recompiled (and
    re-ran) the same executor under slightly different n_seeds shapes.
    Hoisting it means one compile + one run for the whole module — tests
    that only need *a* reference (not a specific shape) use this spec.
    """
    spec = _small_spec()
    return spec, run_sweep(spec, log=False)


def test_chunked_equals_unchunked_every_chunk_size(small_sweep_ref):
    """Seeded twin of the hypothesis boundary-invariance property: every
    chunk size (including non-divisors of n_seeds, which exercise the pad
    + slice path, and chunk > n_seeds) reproduces the vmap bit-for-bit."""
    spec, res = small_sweep_ref
    ref = res.stats["hesrpt"]["mean_flowtime"]
    for chunk in (1, 2, 3, 4, 5, 7):
        got = run_sweep(spec, chunk_seeds=chunk, log=False)
        np.testing.assert_array_equal(
            got.stats["hesrpt"]["mean_flowtime"], ref)


def test_chunked_equals_unchunked_multiclass_metrics():
    """Per-class metrics carry a trailing [K] axis through the chunk
    reshape/moveaxis; they must survive chunking bit-for-bit too."""
    spec = Sweep.create(("hesrpt_pc",), (1.0, 4.0),
                        scenario="multiclass_poisson", classes=TWO_CLASSES,
                        n_jobs=20, n_seeds=5, n_servers=32.0, seed=1)
    ref = run_sweep(spec, log=False)
    got = run_sweep(spec, chunk_seeds=2, log=False)
    for m in spec.metrics:
        np.testing.assert_array_equal(got.stats["hesrpt_pc"][m],
                                      ref.stats["hesrpt_pc"][m])


def test_max_jobs_in_flight_budget_bounds_chunk(small_sweep_ref):
    spec, ref = small_sweep_ref  # jobs_per_seed = 2 rates * 25 jobs = 50
    assert resolve_chunk(spec, None, 200) == 4  # 200 // 50
    assert resolve_chunk(spec, None, 10) == 1  # floor: one seed per chunk
    assert resolve_chunk(spec, 3, None) == 3
    assert resolve_chunk(spec, None, None) is None
    with pytest.raises(ValueError):
        resolve_chunk(spec, 2, 100)
    res = run_sweep(spec, max_jobs_in_flight=200, log=False)
    assert res.chunk_seeds == 4
    assert res.chunk_seeds * spec.jobs_per_seed() <= 200
    np.testing.assert_array_equal(
        res.stats["hesrpt"]["mean_flowtime"],
        ref.stats["hesrpt"]["mean_flowtime"])


def test_load_sweep_chunk_passthrough_identical():
    """The historical entry point exposes the memory budget and yields the
    same numbers through it."""
    from repro.core import load_sweep_raw

    a = load_sweep_raw(("equi",), (1.0, 4.0), n_jobs=20, n_seeds=5)
    b = load_sweep_raw(("equi",), (1.0, 4.0), n_jobs=20, n_seeds=5,
                       max_jobs_in_flight=80)
    np.testing.assert_array_equal(np.asarray(a["equi"]), np.asarray(b["equi"]))


# ----------------------------------------------------------- device sharding
def test_sharded_equals_single_device_forced_multidevice():
    """shard_map over the seed axis == the single-device run, under 4 fake
    CPU devices.  XLA pins the device count at first init, so the forced
    multi-device world lives in a subprocess (same pattern as
    tests/test_distribution.py)."""
    body = textwrap.dedent(
        f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys
        sys.path.insert(0, {os.path.join(REPO, "src")!r})
        import jax
        jax.config.update("jax_enable_x64", True)
        assert jax.device_count() == 4
        import numpy as np
        from repro.core.sweeps import Sweep, run_sweep

        spec = Sweep.create(("hesrpt", "equi"), (0.5, 4.0), n_jobs=25,
                            n_seeds=6, p=0.5, n_servers=64.0, seed=0)
        ref = run_sweep(spec, log=False)
        for kw in ({{}}, {{"chunk_seeds": 1}}):
            got = run_sweep(spec, shard=True, **kw, log=False)
            assert got.device_count == 4 and got.sharded
            for name in spec.policies:
                assert np.array_equal(
                    got.stats[name]["mean_flowtime"],
                    ref.stats[name]["mean_flowtime"]), (name, kw)
        print("SHARDED_OK")
        """
    )
    proc = subprocess.run([sys.executable, "-c", body], capture_output=True,
                          text=True, timeout=600)
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "SHARDED_OK" in proc.stdout


def test_sharded_on_single_device_is_noop_equal(small_sweep_ref):
    """shard=True must also be safe (and exact) on a 1-device host."""
    spec, ref = small_sweep_ref
    got = run_sweep(spec, shard=True, log=False)
    np.testing.assert_array_equal(got.stats["hesrpt"]["mean_flowtime"],
                                  ref.stats["hesrpt"]["mean_flowtime"])


def test_rate_axis_sharded_equals_single_device_forced_multidevice():
    """shard_axis="rates" (the accelerator-lane shape: wide rate grid, few
    seeds) == the single-device run under 4 fake CPU devices, including a
    rate grid that does not divide the device count (5 -> padded to 8)."""
    body = textwrap.dedent(
        f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys
        sys.path.insert(0, {os.path.join(REPO, "src")!r})
        import jax
        jax.config.update("jax_enable_x64", True)
        assert jax.device_count() == 4
        import numpy as np
        from repro.core.sweeps import Sweep, run_sweep

        for rates, fused in (((0.5, 1.0, 2.0, 4.0, 8.0), False),
                             ((0.5, 1.0, 2.0, 4.0), True)):
            spec = Sweep.create(("hesrpt",), rates, n_jobs=20, n_seeds=2,
                                p=0.5, n_servers=32.0, seed=0, n_chips=32,
                                fused=fused)
            ref = run_sweep(spec, log=False)
            got = run_sweep(spec, shard=True, shard_axis="rates", log=False)
            assert got.sharded and got.device_count == 4
            assert np.array_equal(got.stats["hesrpt"]["mean_flowtime"],
                                  ref.stats["hesrpt"]["mean_flowtime"]), (
                rates, fused)
        print("RATE_SHARDED_OK")
        """
    )
    proc = subprocess.run([sys.executable, "-c", body], capture_output=True,
                          text=True, timeout=600)
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "RATE_SHARDED_OK" in proc.stdout


def test_rate_axis_shard_validation_and_single_device_noop(small_sweep_ref):
    spec, ref = small_sweep_ref
    with pytest.raises(ValueError, match="shard_axis"):
        run_sweep(spec, shard_axis="policies", log=False)
    got = run_sweep(spec, shard=True, shard_axis="rates", log=False)
    np.testing.assert_array_equal(got.stats["hesrpt"]["mean_flowtime"],
                                  ref.stats["hesrpt"]["mean_flowtime"])


# ------------------------------------------------------- structured artifacts
def test_sweep_result_json_round_trip_exact():
    spec = Sweep.create(("hesrpt_pc",), (1.0,), scenario="multiclass_poisson",
                        classes=TWO_CLASSES, n_jobs=15, n_seeds=2,
                        n_servers=32.0, seed=4)
    res = run_sweep(spec, chunk_seeds=1, log=False)
    back = SweepResult.from_json(res.to_json())
    assert back.spec == res.spec  # classes/scenario_kw re-normalize exactly
    assert back.chunk_seeds == res.chunk_seeds
    assert back.backend == res.backend
    for name in res.stats:
        for m in res.stats[name]:
            np.testing.assert_array_equal(back.stats[name][m],
                                          res.stats[name][m])


def test_sweep_result_record_and_cell_means(small_sweep_ref):
    _, res = small_sweep_ref
    rec = res.record()
    json.dumps(rec)  # JSON-able as-is
    assert rec["kind"] == "sweep"
    assert rec["total_jobs"] == 2 * 25 * 5  # rates * jobs * seeds (1 policy)
    means = rec["cells"]["hesrpt"]["mean_flowtime"]["mean"]
    np.testing.assert_allclose(
        means, np.mean(res.stats["hesrpt"]["mean_flowtime"], axis=1))
    cm = res.cell_means()
    assert set(cm) == {0.5, 4.0}
    np.testing.assert_allclose(cm[0.5]["hesrpt"], means[0])


def test_run_log_accumulates_and_writes_bench_json(tmp_path):
    n0 = len(RUN_LOG)
    run_sweep(_small_spec(n_seeds=2, seed=11))  # log=True default
    assert len(RUN_LOG) == n0 + 1
    path = write_bench_json(str(tmp_path / "BENCH_sweeps.json"))
    data = json.loads(open(path).read())
    assert len(data["records"]) == len(RUN_LOG)
    assert data["records"][-1]["spec"]["seed"] == 11
    assert data["records"][-1]["wall_s"] >= 0.0


def test_spec_validation_errors():
    with pytest.raises(ValueError, match="unknown metric"):
        Sweep.create(("equi",), (1.0,), metrics=("nope",))
    with pytest.raises(ValueError, match="multi-class"):
        Sweep.create(("equi",), (1.0,), metrics=("class_flowtime",))
    with pytest.raises(ValueError, match="unknown arm"):
        Sweep.create(("equi",), (1.0,), arm="psychic")
    with pytest.raises(ValueError, match="p0"):
        # without an explicit p0 the stale arm would silently anchor to
        # the generic default p, not the drift sampler's own p0
        Sweep.create(("equi",), (1.0,), scenario="drift_poisson",
                     arm="stale")
    with pytest.raises(ValueError, match="continuous-only"):
        # the arm cells run the continuous simulators; a quantized arm
        # spec would record n_chips its physics never used
        Sweep.create(("equi",), (1.0,), scenario="drift_poisson",
                     scenario_kw={"p0": 0.8}, arm="stale", n_chips=64)
    with pytest.raises(ValueError, match="snap_slices"):
        Sweep.create(("equi",), (1.0,), snap_slices=True)


def test_executor_cache_reuses_compilation():
    spec = _small_spec(n_seeds=2, seed=21)
    first = run_sweep(spec, log=False)
    again = run_sweep(spec, log=False)
    assert first.compile_s > 0.0
    assert again.compile_s == 0.0  # cache hit: no re-lower/re-compile
    np.testing.assert_array_equal(again.stats["hesrpt"]["mean_flowtime"],
                                  first.stats["hesrpt"]["mean_flowtime"])


def test_sched_scale_reports_through_sweep_result():
    """The decision-epoch timing benchmark reports through the same
    artifact container (dict spec, M-indexed rows) and its record is
    JSON-able for the trajectory file."""
    from benchmarks.sched_scale import run as sched_run

    res = sched_run(ms=(50, 120), repeats=2, n_chips=64, log=False)
    assert isinstance(res, SweepResult)
    assert res.stats["hesrpt"]["theta_us"].shape == (2, 2)
    assert res.stats["hesrpt"]["chips_sum"][1, 0] == 64
    rec = res.record()
    json.dumps(rec)
    assert rec["kind"] == "sched_scale"
    assert rec["total_jobs"] is None  # not a seeds-x-rates sweep
    assert rec["spec"]["ms"] == [50, 120]
    back = SweepResult.from_json(res.to_json())  # dict-spec round-trip
    assert back.spec == res.spec
    np.testing.assert_array_equal(back.stats["hesrpt"]["theta_us"],
                                  res.stats["hesrpt"]["theta_us"])


# ------------------------------------------------------------ scale (nightly)
@pytest.mark.slow
def test_two_million_job_chunked_sweep_on_cpu():
    """The acceptance-criterion scale: 2,000 jobs x 200 seeds x 5 loads
    (2M simulated jobs) through the chunked executor under a 200k
    jobs-in-flight budget — must complete on CPU without OOM."""
    spec = Sweep.create(("hesrpt",), (0.5, 1.0, 2.0, 4.0, 8.0), n_jobs=2000,
                        n_seeds=200, p=0.5, n_servers=256.0, seed=0)
    assert spec.total_jobs() == 2_000_000
    res = run_sweep(spec, max_jobs_in_flight=200_000, log=False)
    assert res.chunk_seeds == 20  # 200_000 // (5 * 2000)
    a = res.stats["hesrpt"]["mean_flowtime"]
    assert a.shape == (5, 200)
    assert np.all(np.isfinite(a))
    assert np.all(a > 0)


def test_jax_single_device_invariant():
    """Guard: no test in this module may leak a forced multi-device world
    into the main process (sharding tests run in subprocesses)."""
    assert jax.device_count() >= 1
