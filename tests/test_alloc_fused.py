"""The fused heSRPT allocation kernel (kernels/alloc.py) and its engine wiring.

- **Exactness vs the unfused pipeline**: ``hesrpt_alloc_fused`` (ref and
  Pallas-interpret) must return theta bit-for-bit ``policies.hesrpt`` and
  chips exactly ``engine.quantize_allocation_jax`` over seeded random
  cases, including oversubscribed regimes (static shape combos are fixed
  so interpret-mode Pallas compiles once per combo, not per case).
- **Event-for-event engine agreement**: ``engine.run(..., fused=True)``
  must reproduce the unfused run's full recorded trajectory — every
  epoch's integer chips, event times, and completion times — bit-for-bit,
  with and without slice snapping, and for the continuous regime.
- **Golden pin**: the fused sweep reproduces the pre-refactor quantized
  sweep output (the same array tests/test_sweeps.py pins for the unfused
  path) — the fused engine changes the op schedule, never the numbers.
- **Sort counts**: the optimization's whole point, measured from compiled
  HLO via ``launch.hlo_analysis.op_histogram`` — 1 sort for the policy,
  3 for the unfused allocate, 2 fused, 0 for the Pallas kernel, and the
  engine's scan body pays exactly one fewer sort per event when fused.

Hypothesis twins of the quantizer invariants (conservation, min-chips
floor, within-1) run against the *interpret-mode Pallas kernel* when
hypothesis is installed; the seeded-fuzz fallback below keeps the same
invariants exercised in tier-1 without it.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, make_policy
from repro.core.policies import hesrpt
from repro.kernels.alloc import hesrpt_alloc_fused, hesrpt_theta_fused

# (M, n_chips, min_chips): fixed static combos — one interpret-mode compile
# each — spanning plenty-of-chips, tight, floored, and oversubscribed.
COMBOS = (
    (6, 16, 1),
    (12, 64, 1),
    (16, 32, 3),   # floor binds: trims exercised
    (16, 8, 1),    # oversubscribed: 16 active > 8 chips
    (9, 8, 2),     # oversubscribed with min_chips > 1
)
PS = (0.2, 0.5, 0.8)


def _sizes(rng, m, zero_frac=0.3):
    x = rng.pareto(1.5, m) + 0.01
    x[rng.random(m) < zero_frac] = 0.0
    return jnp.asarray(x)


# ------------------------------------------------------ exactness vs unfused
@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_fused_matches_unfused_pipeline_exactly(impl):
    """theta bit-for-bit vs policies.hesrpt, chips exact vs
    quantize_allocation_jax, across all static combos x seeded draws."""
    rng = np.random.default_rng(7)
    for m, n_chips, min_chips in COMBOS:
        for trial in range(10):
            x = _sizes(rng, m)
            p = PS[trial % len(PS)]
            theta_ref = hesrpt(x, p)
            chips_ref = engine.quantize_allocation_jax(
                theta_ref, n_chips, min_chips=min_chips
            )
            theta, chips = hesrpt_alloc_fused(
                x, p, n_chips, min_chips=min_chips, impl=impl
            )
            msg = f"{impl} m={m} chips={n_chips}/{min_chips} trial={trial}"
            np.testing.assert_array_equal(
                np.asarray(theta), np.asarray(theta_ref), err_msg=msg
            )
            np.testing.assert_array_equal(
                np.asarray(chips), np.asarray(chips_ref), err_msg=msg
            )


@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_fused_theta_only_matches_policy(impl):
    rng = np.random.default_rng(3)
    x = _sizes(rng, 16)
    np.testing.assert_array_equal(
        np.asarray(hesrpt_theta_fused(x, 0.5, impl=impl)),
        np.asarray(hesrpt(x, 0.5)),
    )


def test_fused_zero_and_degenerate_cases():
    for impl in ("ref", "interpret"):
        theta, chips = hesrpt_alloc_fused(
            jnp.zeros(8), 0.5, 16, impl=impl
        )
        assert np.all(np.asarray(chips) == 0)
        assert np.all(np.asarray(theta) == 0)
    # n_chips=0 static early-out (the theta-only path)
    _theta, chips = hesrpt_alloc_fused(
        jnp.asarray([2.0, 1.0]), 0.5, 0, impl="ref"
    )
    assert np.all(np.asarray(chips) == 0)


# --------------------------------------------------- engine: event-for-event
def _stream(m, seed, rate=2.0):
    rng = np.random.default_rng(seed)
    sizes = jnp.asarray(rng.pareto(1.5, m) + 0.5)
    arrivals = jnp.asarray(np.cumsum(rng.exponential(1.0 / rate, m)))
    return sizes, arrivals


@pytest.mark.parametrize("snap", [False, True])
def test_engine_fused_quantized_trace_bit_for_bit(snap):
    """fused=True reproduces the unfused engine's recorded trajectory —
    chips at every event, event times, completions — exactly."""
    x0, arr = _stream(40, seed=11)
    rule = engine.quantized_rule(
        hesrpt, 32, min_chips=1, snap_slices=snap, dtype=jnp.float64
    )
    ref = engine.run(x0, arr, 0.5, rule, record=True)
    got = engine.run(x0, arr, 0.5, rule, record=True, fused=True)
    np.testing.assert_array_equal(
        np.asarray(got.trace.alloc), np.asarray(ref.trace.alloc)
    )
    np.testing.assert_array_equal(
        np.asarray(got.trace.times), np.asarray(ref.trace.times)
    )
    np.testing.assert_array_equal(
        np.asarray(got.completion_times), np.asarray(ref.completion_times)
    )


def test_engine_fused_continuous_bit_for_bit():
    """The continuous fused path IS the policy (no sorts to collapse) —
    outputs must be identical, not merely close."""
    x0, arr = _stream(30, seed=5)
    rule = engine.continuous_rule(hesrpt, 64.0, dtype=jnp.float64)
    ref = engine.run(x0, arr, 0.5, rule)
    got = engine.run(x0, arr, 0.5, rule, fused=True)
    np.testing.assert_array_equal(
        np.asarray(got.completion_times), np.asarray(ref.completion_times)
    )


def test_engine_fused_rejects_rules_without_variant():
    x0, arr = _stream(10, seed=0)
    rule = engine.quantized_rule(
        make_policy("equi", n_servers=32.0), 32, dtype=jnp.float64
    )
    with pytest.raises(ValueError, match="fused_variant"):
        engine.run(x0, arr, 0.5, rule, fused=True)


# ----------------------------------------------------------------- golden pin
# The pre-refactor quantized sweep output pinned in tests/test_sweeps.py
# (GOLDEN_QUANTIZED there): the fused engine must reproduce it bit-for-bit.
GOLDEN_QUANTIZED_FUSED = np.array([
    [0.7648913378555785, 0.6046536432011128, 0.6815494191735356],
])


def test_fused_sweep_reproduces_golden_pin():
    from repro.core.sweeps import Sweep, run_sweep

    spec = Sweep.create(("hesrpt",), (2.0,), n_jobs=30, n_seeds=3, p=0.5,
                        n_servers=32.0, seed=1, n_chips=32, fused=True)
    res = run_sweep(spec, log=False)
    np.testing.assert_array_equal(
        res.stats["hesrpt"]["mean_flowtime"], GOLDEN_QUANTIZED_FUSED
    )


def test_sweep_fused_requires_hesrpt_quantized():
    from repro.core.sweeps import Sweep

    with pytest.raises(ValueError):
        Sweep.create(("hesrpt", "equi"), (1.0,), n_jobs=10, n_seeds=2,
                     p=0.5, n_servers=32.0, seed=0, n_chips=32, fused=True)
    with pytest.raises(ValueError):  # continuous regime has no fused rule
        Sweep.create(("hesrpt",), (1.0,), n_jobs=10, n_seeds=2, p=0.5,
                     n_servers=32.0, seed=0, fused=True)


# ---------------------------------------------------------------- sort counts
def _sorts(f, *args) -> float:
    from repro.launch.hlo_analysis import op_histogram

    hlo = jax.jit(f).lower(*args).compile().as_text()
    return op_histogram(hlo).get("sort", 0.0)


def test_sort_counts_measured_from_hlo():
    """The collapse, in compiled-HLO sort ops: policy 1, unfused allocate 3,
    fused ref 2, Pallas kernel 0."""
    from repro.kernels.alloc import hesrpt_alloc_fused_ref

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.pareto(1.5, 64) + 1.0)
    assert _sorts(hesrpt, x, 0.5) == 1
    assert _sorts(
        lambda xv, pv: engine.quantize_allocation_jax(hesrpt(xv, pv), 16),
        x, 0.5,
    ) == 3
    assert _sorts(
        lambda xv, pv: hesrpt_alloc_fused_ref(xv, pv, 16)[1], x, 0.5
    ) == 2
    assert _sorts(
        lambda xv, pv: hesrpt_alloc_fused(xv, pv, 16, impl="interpret")[1],
        x, 0.5,
    ) == 0


def test_engine_scan_pays_one_fewer_sort_per_event_fused():
    """Trip-count-aware histogram of the compiled scan: 3 sorts/event
    unfused vs 2 fused (+1 one-time arrival-order sort outside the loop)."""
    m = 16
    x0, arr = _stream(m, seed=2)
    rule = engine.quantized_rule(hesrpt, 16, dtype=jnp.float64)

    def scan_sorts(fused):
        def f(x0v, arrv):
            return engine.run(
                x0v, arrv, 0.5, rule, pre_arrived=True, fused=fused
            ).completion_times

        return _sorts(f, x0, arr)

    assert scan_sorts(False) == 1 + 3 * m
    assert scan_sorts(True) == 1 + 2 * m


# ------------------------------------- quantizer invariants, fused kernel
def _invariants(x, p, n_chips, min_chips, impl):
    theta, chips = hesrpt_alloc_fused(
        x, p, n_chips, min_chips=min_chips, impl=impl
    )
    theta = np.asarray(theta)
    chips = np.asarray(chips)
    active = theta > 0
    n_active = int(active.sum())
    # conservation
    assert chips.sum() <= n_chips
    if n_active == 0 or n_chips < min_chips:
        assert chips.sum() == 0
    else:
        assert chips.sum() == n_chips
    # min-chips floor
    assert np.all(chips[~active] == 0)
    assert np.all(chips[chips > 0] >= min_chips)
    if n_active * min_chips <= n_chips:
        assert np.all(chips[active] > 0)
    # within-1 of raw when the floor does not bind (largest-remainder)
    if 0 < n_active * min_chips <= n_chips:
        raw = theta * n_chips
        base0 = np.where(active, np.maximum(np.floor(raw), min_chips), 0)
        if base0.sum() <= n_chips:
            unfloored = active & (np.floor(raw) >= min_chips)
            assert np.all(np.abs(chips[unfloored] - raw[unfloored]) <= 1.0)
            # Floored jobs sit at the floor, +1 at most: a floored job can
            # still win a leftover chip on a large fractional part.
            floored = chips[active & ~unfloored]
            assert np.all((floored >= min_chips) & (floored <= min_chips + 1))


def test_seeded_fuzz_fused_kernel_invariants():
    """No-hypothesis fallback of the property twins
    (tests/test_alloc_fused_properties.py): the interpret Pallas kernel
    (and ref) satisfy conservation / floor / within-1 over seeded draws on
    the fixed static combos."""
    rng = np.random.default_rng(19)
    for m, n_chips, min_chips in COMBOS:
        for trial in range(8):
            x = _sizes(rng, m)
            p = PS[trial % len(PS)]
            for impl in ("ref", "interpret"):
                _invariants(x, p, n_chips, min_chips, impl)
