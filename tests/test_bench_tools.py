"""The perf gate and the accelerator lane: tools/bench_diff.py,
benchmarks/backend_lane.py, and the profiler's reconstructed baseline.

bench_diff is what CI runs between the committed ``BENCH_sweeps.json`` and
the freshly regenerated one, so its matching and failure semantics are
pinned here on synthetic records: spec-hash matching must survive falsy
field additions (a baseline written before ``fused`` existed still matches
a new record carrying ``fused: false``), wall regressions only fail above
the noise floor, and any metric-mean drift on a sweep record fails.
"""

import json

import jax.numpy as jnp
import numpy as np
import pytest

from tools.bench_diff import diff, main as bench_diff_main, spec_key


def _rec(wall=1.0, mean=(1.0, 2.0), backend="cpu", kind="sweep", **spec):
    base_spec = dict(policies=["hesrpt"], rates=[0.5, 2.0],
                     scenario="poisson", n_jobs=40, n_seeds=3, seed=0)
    base_spec.update(spec)
    return {
        "kind": kind,
        "spec": base_spec,
        "cells": {"hesrpt": {"mean_flowtime": {"mean": list(mean),
                                               "std": [0.0, 0.0]}}},
        "wall_s": wall,
        "backend": backend,
    }


# -------------------------------------------------------------- spec matching
def test_spec_key_ignores_falsy_field_additions():
    old = _rec()
    new = _rec(fused=False, snap_slices=False, classes=None)
    assert spec_key(old) == spec_key(new)
    assert spec_key(_rec(fused=True)) != spec_key(old)
    assert spec_key(_rec(backend="gpu")) != spec_key(old)
    assert spec_key(_rec(n_jobs=80)) != spec_key(old)


def test_self_diff_passes():
    recs = [_rec(), _rec(n_jobs=80, wall=2.0)]
    failures, _notes = diff(recs, recs)
    assert failures == []


# ------------------------------------------------------------------ the gates
def test_metric_mean_drift_fails():
    failures, _ = diff([_rec()], [_rec(mean=(1.0, 2.0000001))], rtol=1e-9)
    assert len(failures) == 1 and "drift" in failures[0]
    failures, _ = diff([_rec()], [_rec(mean=(1.0, 2.0000001))], rtol=1e-3)
    assert failures == []


def test_wall_regression_fails_only_above_noise_floor():
    failures, _ = diff([_rec(wall=1.0)], [_rec(wall=1.5)])
    assert len(failures) == 1 and "wall-time" in failures[0]
    # below the min-wall floor: smoke-cell timer noise, not a regression
    failures, _ = diff([_rec(wall=0.1)], [_rec(wall=0.4)])
    assert failures == []
    # 30% threshold is a ratio, not absolute
    failures, _ = diff([_rec(wall=1.0)], [_rec(wall=1.25)])
    assert failures == []


def test_lost_coverage_notes_but_passes():
    failures, notes = diff([_rec(), _rec(n_jobs=80)], [_rec()])
    assert failures == []
    assert any("coverage lost" in n for n in notes)


def test_non_sweep_records_skip_metric_gate():
    base = _rec(kind="profile_engine", mean=(1.0, 2.0))
    new = _rec(kind="profile_engine", mean=(5.0, 6.0))
    failures, _ = diff([base], [new])
    assert failures == []  # timings drift freely; only wall/ratio gates apply


def test_cli_parses_options_and_exit_codes(tmp_path):
    base = tmp_path / "base.json"
    new = tmp_path / "new.json"
    base.write_text(json.dumps({"records": [_rec(wall=1.0)]}))
    new.write_text(json.dumps({"records": [_rec(wall=1.4)]}))
    assert bench_diff_main([str(base), str(new)]) == 1
    assert bench_diff_main([str(base), str(new),
                            "--max-time-ratio", "2.0"]) == 0
    assert bench_diff_main([str(base), str(new), "--min-wall", "1.5"]) == 0
    assert bench_diff_main([str(base)]) == 2  # usage


# ------------------------------------------------------------ backend lane
def test_backend_lane_specs_and_records(tmp_path):
    from benchmarks import backend_lane

    specs = backend_lane.lane_specs(smoke=True)
    labels = [label for label, _ in specs]
    assert labels == ["quantized", "quantized-fused", "continuous"]
    by = dict(specs)
    assert by["quantized-fused"].fused and not by["quantized"].fused
    assert by["quantized"]._replace(fused=True) == by["quantized-fused"]
    assert by["continuous"].n_chips is None

    text, records = backend_lane.main(smoke=True)
    assert "bit-for-bit): True" in text
    kinds = [r["kind"] for r in records]
    assert kinds == ["sweep", "sweep", "sweep", "backend_lane"]
    assert [r.get("lane") for r in records[:3]] == labels
    summary = records[-1]
    assert summary["fused_speedup_wall"] > 0
    assert set(summary["lanes"]) == set(labels)
    json.dumps(records)  # artifact-ready as-is

    # append_records merges into an existing artifact and creates one fresh
    path = tmp_path / "BENCH_sweeps.json"
    backend_lane.append_records(records[:1], str(path))
    backend_lane.append_records(records[1:], str(path))
    data = json.loads(path.read_text())
    assert [r["kind"] for r in data["records"]] == kinds


# ------------------------------------------------- profiler's seed baseline
def test_profiler_seed_quantizer_matches_collapsed():
    """The reconstructed 3-sort seed quantizer and the shipped collapsed
    2-sort quantizer are the same function — the mutual-exclusivity proof
    the collapse rests on, checked end to end."""
    from benchmarks.profile_engine import _seed_quantize
    from repro.core.engine import quantize_allocation_jax

    rng = np.random.default_rng(23)
    for n_chips, min_chips in ((16, 1), (64, 3), (8, 2)):
        for _ in range(10):
            m = 12
            w = rng.pareto(1.2, m) + 0.01
            w[rng.random(m) < 0.3] = 0.0
            s = w.sum()
            theta = jnp.asarray(w / s if s > 0 else w)
            np.testing.assert_array_equal(
                np.asarray(_seed_quantize(theta, n_chips,
                                          min_chips=min_chips)),
                np.asarray(quantize_allocation_jax(theta, n_chips,
                                                   min_chips=min_chips)),
            )


def test_profiler_sort_count_helper():
    from benchmarks.profile_engine import _sort_count
    from repro.core.policies import hesrpt

    x = jnp.asarray(np.random.default_rng(0).pareto(1.5, 32) + 1.0)
    assert _sort_count(hesrpt, x, 0.5) == 1
    assert _sort_count(lambda v: v * 2.0, x) == 0
