"""The unified allocation engine: wrapper equivalences, quantized
trajectories vs the ClusterScheduler oracle, scenario registry.

The batch/online wrappers were refactored onto ``core/engine.py`` with a
bit-for-bit guarantee (verified against the pre-refactor implementations
when the refactor landed); these tests keep that contract enforceable:

- batch ``simulate`` and online ``simulate_online`` at t=0 are the *same*
  scan and must agree exactly (not approximately);
- a golden f64 trajectory pins the online wrapper against silent drift
  (tolerance 1e-13: elementwise ops are deterministic, but libm pow may
  differ in the last ulp across platforms);
- the quantized engine must reproduce ``ClusterScheduler(quantize=True)``
  event-for-event: exact integer chips at every decision epoch, epoch
  times and flows to float tolerance, batch and arrival-stream cases.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    engine,
    make_policy,
    make_scenario,
    simulate,
    simulate_online,
    simulate_online_quantized,
    simulate_scenario,
    trace_scenario,
)
from repro.sched import ClusterScheduler, Job

POLICIES = ("hesrpt", "equi", "srpt")


# ------------------------------------------------------ wrapper equivalences
@pytest.mark.parametrize("name", POLICIES + ("helrpt",))
def test_batch_wrapper_is_online_wrapper_at_t0_exactly(name):
    """One engine: the batch scan is the online scan with every job
    pre-arrived, so at t=0 the two wrappers must agree bit-for-bit."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.pareto(1.5, 20) + 1.0)
    pol = make_policy(name, n_servers=256.0)
    batch = simulate(x, 0.5, 256.0, pol)
    online = simulate_online(x, jnp.zeros(20), 0.5, 256.0, pol)
    np.testing.assert_array_equal(np.asarray(batch.completion_times),
                                  np.asarray(online.completion_times))
    np.testing.assert_array_equal(np.asarray(batch.makespan),
                                  np.asarray(online.makespan))


def test_online_wrapper_golden_trajectory_f64():
    """Regression pin: completion times of a fixed 10-job heSRPT stream,
    recorded from the pre-refactor ``simulate_online`` (f64)."""
    x = jnp.asarray([1.488817, 1.081145, 1.182775, 1.227906, 1.063113,
                     4.795832, 17.443706, 1.10859, 1.393492, 1.734739])
    arr = jnp.asarray([0.355747, 0.501643, 1.153774, 1.341068, 1.644977,
                       1.968636, 2.445131, 2.503631, 2.705213, 2.81598])
    golden = np.array([
        0.5480690341836435, 0.6599991420918218, 1.301620875, 1.49455625,
        1.7778661249999999, 2.6006604927609605, 4.982769206018355,
        2.695985347983885, 2.9209760889648533, 3.1013667034775536,
    ])
    res = simulate_online(x, arr, 0.5, 64.0,
                          make_policy("hesrpt", n_servers=64.0))
    np.testing.assert_allclose(np.asarray(res.completion_times), golden,
                               rtol=1e-13)


def test_engine_trace_matches_simresult_fields():
    """The batch wrapper repackages the engine trace unchanged."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.pareto(1.5, 8) + 1.0)
    pol = make_policy("hesrpt", n_servers=64.0)
    res = simulate(x, 0.5, 64.0, pol)
    eng = engine.run(
        x, jnp.zeros(8), 0.5,
        engine.continuous_rule(pol, 64.0, dtype=x.dtype),
        pre_arrived=True, horizon=8, record=True,
    )
    np.testing.assert_array_equal(np.asarray(res.theta_trace),
                                  np.asarray(eng.trace.alloc))
    np.testing.assert_array_equal(np.asarray(res.epoch_times),
                                  np.asarray(eng.trace.times))
    np.testing.assert_array_equal(np.asarray(res.sizes_trace),
                                  np.asarray(eng.trace.sizes))


def test_seeded_fuzz_quantizer_matches_oracle():
    """Seeded-fuzz twin of tests/test_quantize.py's hypothesis property
    (which is skipped when hypothesis is absent): exact jnp == NumPy-oracle
    agreement, including oversubscription and min-chips trims."""
    from repro.sched.quantize import quantize_allocation

    rng = np.random.default_rng(42)
    # Small static (m, n_chips, min_chips) grids keep eager-mode lax
    # compilation cached; the hypothesis twin sweeps the full ranges in CI.
    for _ in range(120):
        m = int(rng.choice([1, 2, 3, 5, 9, 14]))
        n_chips = int(rng.choice([1, 7, 16, 64, 250]))
        min_chips = int(rng.choice([1, 2, 4]))
        w = rng.pareto(1.2, m) + 0.01
        w[rng.random(m) < 0.3] = 0.0
        s = w.sum()
        theta = w / s if s > 0 else w
        ref = quantize_allocation(theta, n_chips, min_chips=min_chips)
        got = np.asarray(engine.quantize_allocation_jax(
            jnp.asarray(theta), n_chips, min_chips=min_chips))
        np.testing.assert_array_equal(got.astype(np.int64), ref,
                                      err_msg=f"{theta} {n_chips} {min_chips}")


# ------------------------------------------- quantized engine vs the cluster
@pytest.mark.parametrize("name", POLICIES)
def test_quantized_batch_matches_cluster_event_for_event(name):
    """Engine-delegated ``run_fluid_to_completion`` == the per-event Python
    epoch loop: identical integer chips at every allocate event, epoch
    times and completion times to float tolerance."""
    rng = np.random.default_rng(11)
    for _ in range(3):
        sizes = rng.pareto(1.5, 12) + 1.0
        a = ClusterScheduler(48, policy=name)
        b = ClusterScheduler(48, policy=name)
        for i, s in enumerate(sizes):
            a.add_job(Job(f"j{i}", size=float(s), p=0.5))
            b.add_job(Job(f"j{i}", size=float(s), p=0.5))
        ra = a.run_fluid_to_completion(use_engine=True)
        rb = b.run_fluid_to_completion(use_engine=False)
        ea = [e["chips"] for e in a.events if e["event"] == "allocate"]
        eb = [e["chips"] for e in b.events if e["event"] == "allocate"]
        assert ea == eb
        np.testing.assert_allclose(
            [e["t"] for e in a.events if e["event"] == "allocate"],
            [e["t"] for e in b.events if e["event"] == "allocate"],
            rtol=1e-9, atol=1e-12,
        )
        np.testing.assert_allclose(ra["total_flow_time"],
                                   rb["total_flow_time"], rtol=1e-9)
        np.testing.assert_allclose(ra["makespan"], rb["makespan"], rtol=1e-9)


def test_quantized_online_matches_cluster_event_for_event():
    """Arrival-stream case on <=16-job instances: the engine's quantized
    trajectory must reproduce the ClusterScheduler loop's chips exactly."""
    from benchmarks.quantized import cross_check

    cc = cross_check(POLICIES, n_jobs=14, rate=1.5, p=0.5, n_chips=32, seed=5)
    assert cc["chips_exact"], cc
    assert cc["n_events"] > 3 * 14  # re-allocated at arrivals AND departures
    assert cc["worst_epoch_time_rel"] < 1e-9, cc
    assert cc["worst_flow_rel"] < 1e-9, cc


def test_quantized_oversubscription_queues_and_completes():
    """More jobs than chips: the engine must queue (0 chips) yet finish."""
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.pareto(1.5, 12) + 1.0)
    res, eng = simulate_online_quantized(
        x, jnp.zeros(12), 0.5, 4, make_policy("hesrpt", n_servers=4.0),
        record=True)
    assert np.all(np.isfinite(np.asarray(res.completion_times)))
    chips = np.asarray(eng.trace.alloc)
    assert chips.max() <= 4
    assert np.all(chips.sum(axis=1) <= 4)
    # at least one event had a queued active job
    sizes = np.asarray(eng.trace.sizes)
    assert np.any((sizes > 0) & (chips == 0))


def test_quantized_sweep_jit_vmap_single_call():
    """The acceptance-criterion shape: seeds x loads in ONE jitted vmap of
    the quantized engine (scaled down for test runtime)."""
    from repro.core import load_sweep_raw

    raw = load_sweep_raw(("hesrpt",), (0.5, 2.0, 8.0), n_jobs=40, n_seeds=6,
                         p=0.5, n_servers=16.0, n_chips=16)
    assert raw["hesrpt"].shape == (3, 6)
    assert np.all(np.isfinite(np.asarray(raw["hesrpt"])))


# ----------------------------------------------------------------- scenarios
def test_scenario_registry_names_and_errors():
    with pytest.raises(ValueError, match="unknown scenario"):
        make_scenario("nope")
    key = jax.random.PRNGKey(0)
    for name in ("batch", "poisson", "deterministic", "bursty"):
        scn = make_scenario(name)(key, 16, 2.0)
        assert scn.x0.shape == (16,)
        assert scn.arrival_times.shape == (16,)
        assert scn.size_factors is None and scn.p_hat is None
    assert np.all(np.asarray(make_scenario("batch")(key, 16, 2.0)
                             .arrival_times) == 0)


def test_poisson_scenario_matches_legacy_draw_exactly():
    """The registry's poisson sampler must reproduce the historical
    load_sweep key discipline bit-for-bit (paired-seed continuity)."""
    from repro.core import pareto_sizes, poisson_arrivals

    key = jax.random.PRNGKey(7)
    scn = make_scenario("poisson", size_alpha=1.5)(key, 32, 3.0)
    k1, k2 = jax.random.split(key)
    np.testing.assert_array_equal(np.asarray(scn.arrival_times),
                                  np.asarray(poisson_arrivals(k1, 32, 3.0)))
    np.testing.assert_array_equal(np.asarray(scn.x0),
                                  np.asarray(pareto_sizes(k2, 32, 1.5)))


def test_noise_reaches_policy_not_physics():
    """sigma_size perturbs only what the policy sees: with a *rank-preserving*
    noise draw the trajectory would be identical; generically it degrades
    heSRPT toward mis-ranked allocations but never changes total work."""
    key = jax.random.PRNGKey(3)
    sampler = make_scenario("poisson", sigma_size=1.0)
    scn = sampler(key, 24, 2.0)
    assert scn.size_factors is not None
    clean = scn._replace(size_factors=None, p_hat=None)
    pol = make_policy("hesrpt", n_servers=64.0)
    res_noisy = simulate_scenario(scn, 0.5, 64.0, pol)
    res_clean = simulate_scenario(clean, 0.5, 64.0, pol)
    assert np.all(np.isfinite(np.asarray(res_noisy.completion_times)))
    # same jobs, same physics: identical work, different (worse) schedule
    assert float(res_noisy.mean_flowtime) >= float(res_clean.mean_flowtime)


def test_p_hat_noise_clips_and_runs():
    key = jax.random.PRNGKey(9)
    scn = make_scenario("poisson", sigma_p=10.0, p=0.5)(key, 12, 1.0)
    assert 0.05 <= float(scn.p_hat) <= 0.95
    res = simulate_scenario(scn, 0.5, 32.0, make_policy("hesrpt"))
    assert np.all(np.isfinite(np.asarray(res.completion_times)))


def test_trace_scenario_replay():
    arr = jnp.asarray([0.0, 1.0, 2.0])
    x = jnp.asarray([3.0, 2.0, 1.0])
    scn = trace_scenario(arr, x)(jax.random.PRNGKey(0), 3, 99.0)
    res = simulate_scenario(scn, 0.5, 8.0, make_policy("hesrpt"))
    ref = simulate_online(x, arr, 0.5, 8.0, make_policy("hesrpt"))
    np.testing.assert_array_equal(np.asarray(res.completion_times),
                                  np.asarray(ref.completion_times))
    with pytest.raises(ValueError, match="trace has"):
        trace_scenario(arr, x)(jax.random.PRNGKey(0), 5, 1.0)


def test_bursty_arrivals_are_bursty():
    """MAP on-off gaps must show positive autocorrelation vs an exponential
    stream of the same mean (that's the point of the scenario)."""
    from repro.core import bursty_arrivals

    key = jax.random.PRNGKey(0)
    arr = np.asarray(bursty_arrivals(key, 4000, 8.0, 0.5, p_stay=0.97))
    gaps = np.diff(arr)
    g = (gaps - gaps.mean()) / gaps.std()
    lag1 = float(np.mean(g[:-1] * g[1:]))
    assert lag1 > 0.1, lag1  # strongly correlated; iid exp would be ~0
    assert np.all(gaps > 0)


def test_cluster_engine_fallbacks_preserved():
    """Heterogeneous-p instances must take the Python path (the plain
    single-class engine rule models one uniform p) and still complete."""
    sched = ClusterScheduler(16, policy="hesrpt")
    sched.add_job(Job("a", size=4.0, p=0.3))
    sched.add_job(Job("b", size=2.0, p=0.7))  # heterogeneous p
    assert not sched._engine_eligible()
    res = sched.run_fluid_to_completion()
    assert res["makespan"] > 0


def test_cluster_knee_delegates_to_engine():
    """KNEE's per-epoch alpha refit (median of the active remaining sizes)
    now runs inside the scan (``engine.knee_rule``): the delegated
    trajectory must match the per-event Python oracle — chips exactly at
    every decision epoch in the quantized regime, flows to float tolerance
    in both regimes."""
    rng = np.random.default_rng(5)
    sizes = rng.pareto(1.5, 13) + 1.0
    for quantize in (True, False):
        def mk(quantize=quantize):
            s = ClusterScheduler(48, policy="knee", quantize=quantize)
            for i, sz in enumerate(sizes):
                s.add_job(Job(f"j{i}", size=float(sz), p=0.45))
            return s

        a, b = mk(), mk()
        assert a._engine_eligible(), "knee must delegate now"
        ra = a.run_fluid_to_completion(use_engine=True)
        rb = b.run_fluid_to_completion(use_engine=False)
        ta = np.array(sorted(ra["completion_times"].values()))
        tb = np.array(sorted(rb["completion_times"].values()))
        np.testing.assert_allclose(ta, tb, rtol=1e-10)
        if quantize:
            ea = [e["chips"] for e in a.events if e["event"] == "allocate"]
            eb = [e["chips"] for e in b.events if e["event"] == "allocate"]
            assert ea == eb  # integer chips exact, event-for-event
