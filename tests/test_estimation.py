"""Online p-hat estimation: the JAX recursive-WLS port vs the NumPy
estimator, the stateful-rule engine API, p-drift scenarios, and the
``ClusterScheduler(use_estimator=True)`` engine delegation.

The exactness contracts:

- the fixed ridge blend in ``sched/estimator.py`` and the
  sufficient-statistics fit in ``core/estimation.py`` are the same
  regression — same histories must give the same p-hat to float
  precision (the ``prior_weight * 0.0`` dead-ridge regression);
- a plain allocation rule and its :func:`~repro.core.engine.as_stateful`
  wrapper are the SAME scan — trajectories must agree bit-for-bit;
- ``use_estimator=True`` cluster runs delegate to the engine and must
  reproduce the per-event Python oracle (identical observation schedules)
  to <= 1e-8 on flows.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, estimation, make_policy, make_scenario
from repro.core.arrivals import simulate_online
from repro.sched import ClusterScheduler, Job
from repro.sched.estimator import SpeedupEstimator, blended_p, pooled_p_hat


def _observe_seq(rng, n_obs, p, c=2.0, noise=0.0):
    """A (chips, throughput) sample path from the s(k) = c k^p family."""
    ks = rng.uniform(1.0, 64.0, n_obs)
    ts = c * ks ** p * np.exp(noise * rng.standard_normal(n_obs))
    return ks, ts


# ------------------------------------------------- NumPy <-> JAX agreement
@pytest.mark.parametrize("discount", [1.0, 0.9, 0.5])
def test_jax_rls_matches_numpy_estimator(discount):
    """Regression test for the dead-ridge fix: recursive sufficient
    statistics and the NumPy history fit give the same ridge-blended
    p-hat, including exponential forgetting and the prior fallbacks."""
    rng = np.random.default_rng(0)
    M = 7
    prior_p = rng.uniform(0.2, 0.8, M)
    prior_w = rng.uniform(0.1, 3.0, M)
    ests = [
        SpeedupEstimator(prior_p=float(prior_p[j]), prior_weight=float(prior_w[j]),
                         discount=discount)
        for j in range(M)
    ]
    state = estimation.init_est_state(M, jnp.float64)
    n_rounds = 12
    for _ in range(n_rounds):
        chips = rng.uniform(0.0, 32.0, M)
        chips[rng.random(M) < 0.25] = 0.0  # queued jobs learn nothing
        rate = 1.7 * chips ** 0.6
        for j in range(M):
            ests[j].observe(chips[j], rate[j])
        obs = engine.Observation(
            alloc=jnp.asarray(chips), rate=jnp.asarray(rate),
            dt=jnp.asarray(0.5), active=jnp.ones(M, bool),
        )
        state = estimation.observe_throughput(state, obs, discount=discount)
    got = np.asarray(estimation.p_hat_jobs(
        state, jnp.asarray(prior_p), prior_weight=jnp.asarray(prior_w)))
    want = np.array([e.p_hat() for e in ests])
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-12)
    # blended read-out == sched.estimator.blended_p on the same work
    x_rem = jnp.asarray(rng.uniform(0.5, 5.0, M))
    got_b = float(estimation.blended_p_hat(
        state, x_rem, jnp.asarray(prior_p), prior_weight=jnp.asarray(prior_w)))
    want_b = blended_p(ests, np.asarray(x_rem))
    np.testing.assert_allclose(got_b, want_b, rtol=1e-9)


def test_recursive_wls_equals_batch_ols():
    """Seeded-fuzz twin of the hypothesis property: folding observations
    one at a time through the sufficient statistics equals the one-shot
    weighted OLS slope on the full (discount-weighted) history."""
    rng = np.random.default_rng(1)
    for trial in range(25):
        n_obs = int(rng.integers(2, 40))
        discount = float(rng.uniform(0.5, 1.0))
        ks, ts = _observe_seq(rng, n_obs, rng.uniform(0.1, 0.9), noise=0.3)
        state = estimation.init_est_state(1, jnp.float64)
        for k, t in zip(ks, ts, strict=True):
            obs = engine.Observation(
                alloc=jnp.asarray([k]), rate=jnp.asarray([t]),
                dt=jnp.asarray(1.0), active=jnp.ones(1, bool),
            )
            state = estimation.observe_throughput(state, obs, discount=discount)
        got = float(estimation.p_hat_jobs(state, 0.5, prior_weight=1e-12)[0])
        # batch WLS with the same exponential weights
        w = discount ** np.arange(n_obs - 1, -1, -1, dtype=np.float64)
        lk, lt = np.log(ks), np.log(ts)
        mk = (w * lk).sum() / w.sum()
        mt = (w * lt).sum() / w.sum()
        slope = (w * (lk - mk) * (lt - mt)).sum() / (w * (lk - mk) ** 2).sum()
        np.testing.assert_allclose(got, np.clip(slope, 0.01, 0.999),
                                   rtol=1e-8, atol=1e-10)


def test_p_hat_prior_fallback_and_clip_bounds():
    """<2 samples or an unidentifiable design -> the prior; otherwise the
    fit is clipped into the open (0, 1) exponent range."""
    state = estimation.init_est_state(1, jnp.float64)
    assert float(estimation.p_hat_jobs(state, 0.42)[0]) == 0.42
    # two samples at the SAME allocation: var == 0 -> prior
    for _ in range(2):
        obs = engine.Observation(
            alloc=jnp.asarray([8.0]), rate=jnp.asarray([3.0]),
            dt=jnp.asarray(1.0), active=jnp.ones(1, bool))
        state = estimation.observe_throughput(state, obs)
    assert float(estimation.p_hat_jobs(state, 0.42)[0]) == 0.42
    # wildly super-linear data clips at the upper bound, never escapes (0,1)
    state = estimation.init_est_state(1, jnp.float64)
    for k in (2.0, 64.0):
        obs = engine.Observation(
            alloc=jnp.asarray([k]), rate=jnp.asarray([k ** 4]),
            dt=jnp.asarray(1.0), active=jnp.ones(1, bool))
        state = estimation.observe_throughput(state, obs, discount=1.0)
    p = float(estimation.p_hat_jobs(state, 0.5, prior_weight=1e-9)[0])
    assert p == estimation.P_CLIP[1]
    # NumPy estimator agrees on both edge behaviours
    e = SpeedupEstimator(prior_p=0.5, prior_weight=1e-9)
    e.observe(2.0, 2.0 ** 4)
    e.observe(64.0, 64.0 ** 4)
    assert e.p_hat() == estimation.P_CLIP[1]


def test_estimator_recovers_true_p_seeded():
    """Seeded twin of the hypothesis property in test_properties.py."""
    for p in (0.15, 0.5, 0.85):
        est = SpeedupEstimator(prior_p=0.5, prior_weight=1e-6)
        for k in (1, 2, 4, 8, 16, 32):
            est.observe(k, 3.7 * k ** p)
        assert abs(est.p_hat() - p) < 0.02


def test_pooled_p_hat_beats_per_job_on_shared_exponent():
    """Two jobs of one class, each with a 2-point history: pooling the
    sufficient statistics fits the shared exponent from all 4 samples."""
    p_true = 0.63
    a = SpeedupEstimator(prior_p=0.3, prior_weight=1e-9)
    b = SpeedupEstimator(prior_p=0.3, prior_weight=1e-9)
    for k in (2.0, 8.0):
        a.observe(k, 1.0 * k ** p_true)
    for k in (16.0, 64.0):
        b.observe(k, 1.0 * k ** p_true)
    pooled = pooled_p_hat([a, b], 0.3, 1e-9)
    np.testing.assert_allclose(pooled, p_true, rtol=1e-9)
    # jit-safe twin on the same observations, pooled by class id
    state = estimation.init_est_state(2, jnp.float64)
    for ka, kb in ((2.0, 16.0), (8.0, 64.0)):
        obs = engine.Observation(
            alloc=jnp.asarray([ka, kb]),
            rate=jnp.asarray([ka ** p_true, kb ** p_true]),
            dt=jnp.asarray(1.0), active=jnp.ones(2, bool))
        state = estimation.observe_throughput(state, obs)
    p_k = estimation.p_hat_classes(
        state, jnp.zeros(2, jnp.int32), 1, 0.3, prior_weight=1e-9)
    np.testing.assert_allclose(float(p_k[0]), pooled, rtol=1e-9)


# ------------------------------------------------------ stateful-rule engine
def test_stateless_rule_and_as_stateful_are_bit_for_bit():
    """The tentpole's backward-compatibility contract: wrapping a plain
    rule in the trivial StatefulRule changes nothing, bit for bit."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.pareto(1.5, 24) + 1.0)
    arr = jnp.asarray(np.cumsum(rng.exponential(0.5, 24)))
    pol = make_policy("hesrpt", n_servers=64.0)
    plain = engine.continuous_rule(pol, 64.0, dtype=x.dtype)
    wrapped = engine.as_stateful(plain)
    explicit = engine.StatefulRule(
        init=lambda: (), observe=lambda st, obs: st,
        allocate=lambda st, x_act, p: plain(x_act, p),
    )
    a = engine.run(x, arr, 0.5, plain, record=True)
    b = engine.run(x, arr, 0.5, wrapped, record=True)
    c = engine.run(x, arr, 0.5, explicit, record=True)
    for other in (b, c):
        np.testing.assert_array_equal(np.asarray(a.completion_times),
                                      np.asarray(other.completion_times))
        np.testing.assert_array_equal(np.asarray(a.trace.alloc),
                                      np.asarray(other.trace.alloc))
        np.testing.assert_array_equal(np.asarray(a.trace.times),
                                      np.asarray(other.trace.times))
    # idempotent: as_stateful of a StatefulRule is the same object
    assert engine.as_stateful(wrapped) is wrapped


def test_estimating_rule_converges_and_conserves():
    """Batch run with a wrong prior: the blended p-hat the rule carries
    converges toward the true exponent, allocations stay a distribution,
    and the estimator run can't beat the known-p run."""
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.pareto(1.5, 30) + 1.0)
    arr = jnp.zeros(30)
    p_true = 0.7
    pol = make_policy("hesrpt", n_servers=128.0)
    rule = estimation.estimating_rule(
        pol, 128.0, prior_p=0.3, prior_weight=1.0, discount=1.0,
        dtype=x.dtype, n_jobs=30)
    res = engine.run(x, arr, p_true, rule, pre_arrived=True, horizon=30,
                     record=True)
    assert np.all(np.isfinite(np.asarray(res.completion_times)))
    theta = np.asarray(res.trace.alloc)
    live = np.asarray(res.trace.sizes) > 0
    sums = theta.sum(axis=1)
    assert np.all(sums[live.any(axis=1)] <= 1 + 1e-9)
    assert np.all(theta >= -1e-12)
    # oracle run on the same jobs is at least as good
    oracle = simulate_online(x, arr, p_true, 128.0, pol)
    est_total = float(np.sum(np.asarray(res.completion_times)))
    assert float(oracle.total_flowtime) <= est_total * (1 + 1e-9)
    # and the final per-job estimates are near the truth for jobs that
    # observed at several distinct allocations (here: all of them)
    # -> rerun the observation fold to read the state out
    state = rule.init()
    for e in range(theta.shape[0]):
        obs = engine.Observation(
            alloc=jnp.asarray(theta[e]) * 128.0,
            rate=jnp.asarray(theta[e] * 128.0) ** p_true,
            dt=jnp.asarray(1.0), active=jnp.asarray(live[e]))
        state = estimation.observe_throughput(state, obs)
    p_hats = np.asarray(estimation.p_hat_jobs(state, 0.3, prior_weight=1e-6))
    seen = np.asarray(state.n) >= 3
    assert np.all(np.abs(p_hats[seen] - p_true) < 0.05)


def test_drift_single_job_exact():
    """One job, theta == 1: completion under a p0 -> p1 drift has a
    two-piece closed form; the engine must hit it exactly."""
    pol = make_policy("hesrpt", n_servers=16.0)
    rule = engine.continuous_rule(pol, 16.0, dtype=jnp.float64)
    x = jnp.asarray([10.0])
    t_d, p0, p1 = 0.75, 0.8, 0.2
    drift = engine.PDrift(times=jnp.asarray([t_d]),
                          values=jnp.asarray([p0, p1]))
    res = engine.run(x, jnp.zeros(1), p0, rule, pre_arrived=True,
                     p_drift=drift)
    expect = t_d + (10.0 - t_d * 16 ** p0) / 16 ** p1
    np.testing.assert_allclose(float(res.completion_times[0]), expect,
                               rtol=1e-12)
    # drift after the job would finish: no effect at all
    late = engine.PDrift(times=jnp.asarray([1e6]),
                         values=jnp.asarray([p0, p1]))
    res_late = engine.run(x, jnp.zeros(1), p0, rule, pre_arrived=True,
                          p_drift=late)
    np.testing.assert_allclose(float(res_late.completion_times[0]),
                               10.0 / 16 ** p0, rtol=1e-12)


def test_drift_scenario_estimator_between_oracle_and_stale():
    """On a p-drift stream the three arms order as they must: oracle <=
    estimator (has to learn) and estimator <= stale (never learns)."""
    from repro.core import simulate_scenario, simulate_scenario_estimated

    key = jax.random.PRNGKey(2)
    sampler = make_scenario("drift_poisson", p0=0.8, p1=0.3, drift_frac=0.4)
    scn = sampler(key, 80, 4.0)
    assert scn.p_drift is not None
    pol = make_policy("hesrpt", n_servers=128.0)
    oracle = simulate_scenario(scn, 0.8, 128.0, pol)
    stale = simulate_scenario(scn._replace(p_hat=jnp.asarray(0.8)), 0.8,
                              128.0, pol)
    est = simulate_scenario_estimated(scn, 0.8, 128.0, pol, prior_p=0.8,
                                      discount=0.9)
    f_o = float(oracle.mean_flowtime)
    f_s = float(stale.mean_flowtime)
    f_e = float(est.mean_flowtime)
    assert f_o <= f_e * (1 + 1e-9)
    assert f_e < f_s  # tracking the drift must pay on this stream


def test_estimation_sweep_jit_vmap_single_call():
    """The acceptance-criterion shape: estimator-in-the-loop seeds x loads
    through one jitted vmap (scaled down for test runtime)."""
    from benchmarks.estimation import sweep

    out = sweep(("oracle", "stale", "estimator"), (0.5, 2.0),
                n_jobs=30, n_seeds=4, n_servers=64.0)
    for arm in ("oracle", "stale", "estimator"):
        assert set(out[arm]) == {0.5, 2.0}
        assert all(np.isfinite(v) for v in out[arm].values())


# --------------------------------------------- cluster delegation oracle
def _mk_sched(sizes, ps, priors, **kw):
    s = ClusterScheduler(48, policy="hesrpt", use_estimator=True, **kw)
    for i, (sz, p, pr) in enumerate(zip(sizes, ps, priors, strict=True)):
        s.add_job(Job(f"j{i}", size=float(sz), p=float(p), prior_p=float(pr)))
    return s


@pytest.mark.parametrize("quantize", [False, True])
def test_cluster_estimator_delegates_and_matches_oracle(quantize):
    """use_estimator=True now runs on the engine; the per-event Python
    loop is the oracle it must reproduce to <= 1e-8 on flows (identical
    observation schedules), heterogeneous true p included."""
    rng = np.random.default_rng(11)
    for _ in range(2):
        sizes = rng.pareto(1.5, 10) + 1.0
        ps = rng.uniform(0.3, 0.8, 10)
        a = _mk_sched(sizes, ps, np.full(10, 0.5), quantize=quantize,
                      est_discount=0.9)
        b = _mk_sched(sizes, ps, np.full(10, 0.5), quantize=quantize,
                      est_discount=0.9)
        assert a._engine_eligible()
        ra = a.run_fluid_to_completion(use_engine=True)
        rb = b.run_fluid_to_completion(use_engine=False)
        ta = np.array([ra["completion_times"][f"j{i}"] for i in range(10)])
        tb = np.array([rb["completion_times"][f"j{i}"] for i in range(10)])
        np.testing.assert_allclose(ta, tb, rtol=1e-8)
        if quantize:  # integer chips agree event-for-event in practice
            ea = [e["chips"] for e in a.events if e["event"] == "allocate"]
            eb = [e["chips"] for e in b.events if e["event"] == "allocate"]
            assert ea == eb


def test_cluster_class_aware_estimator_matches_oracle():
    """Class-aware + estimator: the engine's per-class pooled p-hat
    (segment-summed sufficient statistics) vs the oracle's pooled
    histories."""
    rng = np.random.default_rng(12)
    pk = {0: 0.35, 1: 0.6, 2: 0.8}
    sizes = rng.pareto(1.5, 12) + 1.0
    cls = rng.integers(0, 3, 12)

    def mk():
        s = ClusterScheduler(48, policy="hesrpt_pc", use_estimator=True,
                             class_aware=True)
        for i, sz in enumerate(sizes):
            s.add_job(Job(f"j{i}", size=float(sz), p=pk[int(cls[i])],
                          class_id=int(cls[i]), prior_p=0.5))
        return s

    a, b = mk(), mk()
    assert a._engine_eligible()
    ra = a.run_fluid_to_completion(use_engine=True)
    rb = b.run_fluid_to_completion(use_engine=False)
    ta = np.array([ra["completion_times"][f"j{i}"] for i in range(12)])
    tb = np.array([rb["completion_times"][f"j{i}"] for i in range(12)])
    np.testing.assert_allclose(ta, tb, rtol=1e-8)


def test_cluster_estimator_seeds_engine_from_history():
    """Jobs that already observed throughput (report_progress) delegate
    with their history folded into the engine's sufficient statistics —
    the two paths must stay in agreement mid-flight too."""

    def mk():
        s = _mk_sched([4.0, 3.0, 2.0], [0.6, 0.6, 0.6], [0.4, 0.4, 0.4])
        s.allocations()
        for jid in ("j0", "j1"):
            s.report_progress(jid, 0.5, wall_dt=0.25)
        return s

    a, b = mk(), mk()
    assert a.jobs["j0"].estimator.history  # the seed is non-trivial
    ra = a.run_fluid_to_completion(use_engine=True)
    rb = b.run_fluid_to_completion(use_engine=False)
    ta = np.array(sorted(ra["completion_times"].values()))
    tb = np.array(sorted(rb["completion_times"].values()))
    np.testing.assert_allclose(ta, tb, rtol=1e-8)


def test_cluster_class_estimator_reuse_keeps_departed_observations():
    """Regression: a second run on the same scheduler must pool the
    FIRST run's (departed) observations into the class p-hat on the
    engine path too, exactly as the per-event oracle does."""
    pk = {0: 0.35, 1: 0.75}

    def mk():
        s = ClusterScheduler(32, policy="hesrpt_pc", use_estimator=True,
                             class_aware=True)
        for i, sz in enumerate([5.0, 3.0, 2.0, 4.0]):
            s.add_job(Job(f"a{i}", size=sz, p=pk[i % 2], class_id=i % 2,
                          prior_p=0.5))
        s.run_fluid_to_completion(use_engine=False)  # builds real histories
        for i, sz in enumerate([4.0, 2.5, 1.5, 3.5]):
            s.add_job(Job(f"b{i}", size=sz, p=pk[i % 2], class_id=i % 2,
                          prior_p=0.5))
        return s

    a, b = mk(), mk()
    ra = a.run_fluid_to_completion(use_engine=True)
    rb = b.run_fluid_to_completion(use_engine=False)
    ta = np.array([ra["completion_times"][f"b{i}"] for i in range(4)])
    tb = np.array([rb["completion_times"][f"b{i}"] for i in range(4)])
    np.testing.assert_allclose(ta, tb, rtol=1e-8)


def test_simulate_multiclass_with_estimated_class_exponents():
    """core/multiclass.py accepts online-estimated per-class p-hat_k:
    the estimating rule runs inside the same engine scan and cannot beat
    the truth-fed class-aware run."""
    from repro.core import ClassSpec, simulate_multiclass

    classes = (ClassSpec(p=0.35, mix=1.0), ClassSpec(p=0.75, mix=1.0))
    key = jax.random.PRNGKey(5)
    scn = make_scenario("multiclass_poisson", classes=classes)(key, 40, 3.0)
    truth = simulate_multiclass(scn, classes=classes, policy="hesrpt_pc",
                                n_servers=64.0)
    est = simulate_multiclass(
        scn, classes=classes, policy="hesrpt_pc", n_servers=64.0,
        estimator_kw=dict(prior_p=jnp.asarray([0.5, 0.5]), discount=0.95),
    )
    assert np.all(np.isfinite(np.asarray(est.completion_times)))
    assert float(truth.mean_flowtime) <= float(est.mean_flowtime) * 1.05


def test_knee_estimator_still_falls_back_to_python_loop():
    """KNEE alone delegates now (``engine.knee_rule``); the one remaining
    Python-only combination is KNEE *under the estimator* — its alpha
    refit is not threaded through ``estimating_rule``'s static policy."""
    s = ClusterScheduler(16, policy="knee", use_estimator=True)
    s.add_job(Job("a", size=4.0, p=0.5))
    assert not s._engine_eligible()
    assert s.run_fluid_to_completion()["makespan"] > 0
