"""Per-arch smoke tests (reduced configs): forward/train-step shapes + no
NaNs on CPU, and prefill+decode consistency against full-prefix prefill."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.models import ModelOptions, build_model
from repro.train import TrainConfig, make_train_step
from repro.train.optimizer import OptimizerConfig, init_opt_state

OPTS = ModelOptions(activation_dtype="float32", remat="none")
RNG = jax.random.PRNGKey(0)


def make_batch(cfg, b=2, s=16, with_labels=True):
    rng = np.random.default_rng(1)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    }
    if with_labels:
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32
        )
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_patches, cfg.d_model)), jnp.float32
        ) * 0.05
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.encoder_seq, cfg.d_model)), jnp.float32
        ) * 0.05
    return batch


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg, OPTS)
    params = model.init(RNG)
    batch = make_batch(cfg)
    loss, metrics = model.loss_fn(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"

    tc = TrainConfig(microbatches=1, optimizer=OptimizerConfig(lr=1e-3))
    step = jax.jit(make_train_step(model, tc))
    p2, o2, m2 = step(params, init_opt_state(params), batch)
    assert bool(jnp.isfinite(m2["loss"]))
    # params actually changed
    delta = sum(
        float(jnp.sum(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2), strict=True)
    )
    assert delta > 0, f"{arch}: train step did not update params"


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode_consistency(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg, OPTS)
    params = model.init(RNG)
    S = 20
    batch = make_batch(cfg, b=2, s=S, with_labels=False)
    toks = batch["tokens"]

    prefix = dict(batch)
    prefix["tokens"] = toks[:, : S - 3]
    logits, caches = model.prefill_fn(params, prefix, max_len=S)
    outs = [logits]
    for t in range(S - 3, S):
        lg, caches = model.decode_fn(
            params, toks[:, t : t + 1], caches, jnp.asarray(t, jnp.int32)
        )
        outs.append(lg[:, 0])

    for i, t in enumerate(range(S - 4, S)):
        rb = dict(batch)
        rb["tokens"] = toks[:, : t + 1]
        ref_lg, _ = model.prefill_fn(params, rb)
        np.testing.assert_allclose(
            np.asarray(outs[i]), np.asarray(ref_lg), rtol=2e-4, atol=2e-4,
        )


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_exactness(arch):
    """The registered full config matches the published numbers (sanity on
    the fields the grid spec pins)."""
    cfg = get_config(arch)
    expected = {
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064),
        "stablelm-12b": (40, 5120, 32, 8, 13824, 100352),
        "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
        "mamba2-130m": (24, 768, 0, 0, 0, 50280),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab_size)
    assert got == expected, f"{arch}: {got} != {expected}"


def test_param_counts_match_published_sizes():
    """Analytic param counts land near the published totals."""
    targets = {  # (billions, tolerance fraction)
        "qwen2.5-14b": (14.8, 0.05),
        "qwen1.5-110b": (111.0, 0.05),
        "mixtral-8x7b": (46.7, 0.05),
        "qwen3-moe-235b-a22b": (235.0, 0.05),
        "mamba2-130m": (0.13, 0.10),
        "whisper-base": (0.074, 0.15),
    }
    for arch, (tgt, tol) in targets.items():
        n = get_config(arch).param_count() / 1e9
        assert abs(n - tgt) / tgt < tol, f"{arch}: {n:.2f}B vs {tgt}B"


def test_moe_active_params():
    cfg = get_config("qwen3-moe-235b-a22b")
    active = cfg.active_param_count() / 1e9
    assert 20.0 < active < 24.5, active  # A22B


def test_moe_ragged_local_matches_dense():
    cfg = smoke_config("mixtral-8x7b")
    from repro.models.moe import moe_apply, moe_init

    p = moe_init(jax.random.PRNGKey(3), cfg, jnp.float32)
    x = jnp.asarray(np.random.default_rng(4).standard_normal((2, 8, cfg.d_model)),
                    jnp.float32)
    y_dense, aux_d = moe_apply(p, x, cfg, impl="dense")
    y_ragged, aux_r = moe_apply(p, x, cfg, impl="ragged_local")
    np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_ragged),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(aux_d), float(aux_r), rtol=1e-5)


@pytest.mark.slow
def test_sliding_window_ring_cache_drops_old_tokens():
    """With a ring cache of size window, decode must match a model that can
    only see the last `window` positions."""
    cfg = smoke_config("mixtral-8x7b")  # window = 16
    model = build_model(cfg, OPTS)
    params = model.init(RNG)
    S = 40  # much longer than the window
    rng = np.random.default_rng(7)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, S)), jnp.int32)
    _, caches = model.prefill_fn(params, {"tokens": toks[:, : S - 1]}, max_len=S)
    lg, _ = model.decode_fn(params, toks[:, S - 1 :], caches,
                            jnp.asarray(S - 1, jnp.int32))
    ref_lg, _ = model.prefill_fn(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(ref_lg),
                               rtol=2e-4, atol=2e-4)
    # ring capacity is the window, not the sequence
    k_leaf = jax.tree.leaves(caches)[0]
    assert cfg.window in k_leaf.shape
