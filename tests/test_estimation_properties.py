"""Property-based tests (hypothesis) for the estimation subsystem and the
stateful-rule engine contract — the fuzzed twins of the seeded tests in
tests/test_estimation.py."""

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (pip install -e '.[dev]')"
)
from hypothesis import assume, given, settings, strategies as st  # noqa: E402

from repro.core import engine, estimation, make_policy  # noqa: E402
from repro.sched.estimator import SpeedupEstimator  # noqa: E402


def _design_var(samples, discount):
    """The history's weighted design variance — both implementations gate
    identifiability on it at 1e-12, so properties asserting exact
    agreement must stay clear of that boundary (their fp paths differ by
    ~1 ulp and could land on opposite sides)."""
    n = len(samples)
    w = np.array([discount ** (n - 1 - i) for i in range(n)])
    lk = np.log([k for k, _ in samples])
    mk = (w * lk).sum() / w.sum()
    return float((w * (lk - mk) ** 2).sum())

obs_strategy = st.lists(
    st.tuples(
        st.floats(min_value=0.5, max_value=256.0),  # chips
        st.floats(min_value=0.01, max_value=1e3),  # throughput
    ),
    min_size=2,
    max_size=25,
)
prior_strategy = st.floats(min_value=0.05, max_value=0.95)
discount_strategy = st.floats(min_value=0.3, max_value=1.0)


def _fold(samples, discount):
    """Recursive JAX state from a (chips, throughput) sample path."""
    state = estimation.init_est_state(1, jnp.float64)
    for k, t in samples:
        obs = engine.Observation(
            alloc=jnp.asarray([k]), rate=jnp.asarray([t]),
            dt=jnp.asarray(1.0), active=jnp.ones(1, bool),
        )
        state = estimation.observe_throughput(state, obs, discount=discount)
    return state


@settings(max_examples=30, deadline=None)
@given(obs_strategy, prior_strategy, discount_strategy,
       st.floats(min_value=1e-6, max_value=10.0))
def test_recursive_wls_equals_batch_ols(samples, prior, discount, prior_w):
    """Folding observations through the sufficient statistics == the
    one-shot ridge-blended WLS on the full discounted history (what the
    NumPy estimator computes)."""
    assume(not 1e-13 < _design_var(samples, discount) < 1e-11)
    est = SpeedupEstimator(prior_p=prior, prior_weight=prior_w,
                           discount=discount)
    for k, t in samples:
        est.observe(k, t)
    state = _fold(samples, discount)
    got = float(estimation.p_hat_jobs(state, prior, prior_weight=prior_w)[0])
    np.testing.assert_allclose(got, est.p_hat(), rtol=1e-8, atol=1e-9)


@settings(max_examples=30, deadline=None)
@given(obs_strategy, prior_strategy, discount_strategy)
def test_p_hat_respects_clip_and_prior_bounds(samples, prior, discount):
    """p-hat always lands in [min(clip_lo, prior), max(clip_hi, prior)]
    and exactly on the prior for degenerate histories."""
    state = _fold(samples, discount)
    p = float(estimation.p_hat_jobs(state, prior)[0])
    lo, hi = estimation.P_CLIP
    assert min(lo, prior) - 1e-12 <= p <= max(hi, prior) + 1e-12
    empty = estimation.init_est_state(1, jnp.float64)
    assert float(estimation.p_hat_jobs(empty, prior)[0]) == prior
    # one repeated allocation: unidentifiable -> prior, any history length
    same = _fold([(8.0, t) for _, t in samples], discount)
    assert float(estimation.p_hat_jobs(same, prior)[0]) == prior


@settings(max_examples=30, deadline=None)
@given(obs_strategy, discount_strategy)
def test_pooled_stats_equal_concatenated_history(samples, discount):
    """Per-class pooling of per-job sufficient statistics == the WLS on
    the concatenated histories (the NumPy ``pooled_p_hat``)."""
    from repro.sched.estimator import pooled_p_hat

    half = len(samples) // 2
    a = SpeedupEstimator(prior_p=0.5, discount=discount)
    b = SpeedupEstimator(prior_p=0.5, discount=discount)
    for k, t in samples[:half]:
        a.observe(k, t)
    for k, t in samples[half:]:
        b.observe(k, t)
    hist = a.history + b.history
    w = np.array([h[2] for h in hist])
    lk = np.array([h[0] for h in hist])
    mk = (w * lk).sum() / w.sum()
    pooled_var = float((w * (lk - mk) ** 2).sum())
    assume(not 1e-13 < pooled_var < 1e-11)
    state = estimation.init_est_state(2, jnp.float64)
    for i in range(max(half, len(samples) - half)):
        row = [
            samples[i] if i < half else (0.0, 0.0),
            samples[half + i] if half + i < len(samples) else (0.0, 0.0),
        ]
        obs = engine.Observation(
            alloc=jnp.asarray([r[0] for r in row]),
            rate=jnp.asarray([r[1] for r in row]),
            dt=jnp.asarray(1.0), active=jnp.ones(2, bool),
        )
        state = estimation.observe_throughput(state, obs, discount=discount)
    got = float(estimation.p_hat_classes(
        state, jnp.zeros(2, jnp.int32), 1, 0.5)[0])
    want = pooled_p_hat([a, b], 0.5, 1.0)
    np.testing.assert_allclose(got, want, rtol=1e-8, atol=1e-9)


sizes12 = st.lists(
    st.floats(min_value=0.05, max_value=50.0), min_size=12, max_size=12
)


@settings(max_examples=15, deadline=None)
@given(sizes12, st.floats(min_value=0.1, max_value=0.9))
def test_stateless_rule_wrapping_is_bit_for_bit(xs, p):
    """The tentpole contract, fuzzed: a plain rule and its as_stateful
    wrapper produce identical trajectories, bit for bit (fixed shape so
    every example hits the same compiled scan)."""
    x = jnp.asarray(xs)
    arr = jnp.linspace(0.0, 1.0, 12)
    pol = make_policy("hesrpt", n_servers=64.0)
    plain = engine.continuous_rule(pol, 64.0, dtype=x.dtype)
    a = engine.run(x, arr, p, plain)
    b = engine.run(x, arr, p, engine.as_stateful(plain))
    np.testing.assert_array_equal(np.asarray(a.completion_times),
                                  np.asarray(b.completion_times))
    np.testing.assert_array_equal(np.asarray(a.x_final),
                                  np.asarray(b.x_final))
