"""Scheduler-layer unit tests: cluster epochs, arrivals, stragglers,
compression math, data pipeline determinism."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hesrpt_total_flowtime, optimal_makespan
from repro.data.pipeline import DataConfig, ShardedSyntheticStream
from repro.sched import ClusterScheduler, Job, StragglerDetector
from repro.sched.estimator import SpeedupEstimator, blended_p
from repro.train.compression import (
    compress_psum_int8,
    compress_psum_topk,
    init_error_state,
)


@pytest.mark.slow
def test_cluster_fluid_matches_closed_form():
    rng = np.random.default_rng(0)
    x = np.sort(rng.pareto(1.5, 16) + 1.0)[::-1]
    n = 256
    sched = ClusterScheduler(n, policy="hesrpt")
    for i, xi in enumerate(x):
        sched.add_job(Job(f"j{i}", size=float(xi), p=0.5))
    res = sched.run_fluid_to_completion()
    closed = float(hesrpt_total_flowtime(jnp.asarray(x), 0.5, float(n)))
    assert res["total_flow_time"] <= closed * 1.02  # quantization gap < 2%


def test_cluster_helrpt_equalizes_completions():
    sched = ClusterScheduler(64, policy="helrpt")
    sizes = [9.0, 5.0, 2.0]
    for i, s in enumerate(sizes):
        sched.add_job(Job(f"j{i}", size=s, p=0.5))
    res = sched.run_fluid_to_completion()
    times = list(res["completion_times"].values())
    assert max(times) - min(times) < 0.25 * max(times)  # near-simultaneous
    closed = float(optimal_makespan(jnp.asarray(sizes), 0.5, 64.0))
    assert res["makespan"] <= closed * 1.10


def test_cluster_arrival_reschedules():
    """The paper's §4.3 heuristic: re-run heSRPT on the active set when a
    job arrives mid-run."""
    sched = ClusterScheduler(16, policy="hesrpt")
    sched.add_job(Job("a", size=8.0, p=0.5))
    sched.add_job(Job("b", size=4.0, p=0.5))
    sched.allocations()
    sched.advance_fluid(until_departure=False, dt=0.2)
    sched.add_job(Job("late", size=1.0, p=0.5))
    alloc = sched.allocations()
    assert alloc["late"] > 0
    # smallest remaining job gets the largest share under heSRPT
    act = sched.active_jobs()
    smallest = min(act, key=lambda j: j.remaining).job_id
    assert alloc[smallest] == max(alloc.values())
    res = sched.run_fluid_to_completion()
    assert res["makespan"] > 0


def test_straggler_detector_flags_slow_job():
    det = StragglerDetector(threshold=0.7, patience=2)
    assert not det.report("j", observed_rate=1.0, expected_rate=1.0)
    assert not det.report("j", observed_rate=0.5, expected_rate=1.0)
    assert det.report("j", observed_rate=0.5, expected_rate=1.0)
    assert det.events and det.events[0]["action"] == "evict"
    # healthy reports reset the counter
    assert not det.report("k", 0.5, 1.0)
    assert not det.report("k", 1.0, 1.0)
    assert not det.report("k", 0.5, 1.0)


def test_blended_p_work_weighted():
    a, b = SpeedupEstimator(prior_p=0.2), SpeedupEstimator(prior_p=0.8)
    assert abs(blended_p([a, b], [3.0, 1.0]) - (0.2 * 3 + 0.8) / 4) < 1e-9


# ------------------------------------------------------------- compression
def test_int8_compression_error_feedback_converges():
    """With error feedback, the time-averaged compressed gradient converges
    to the true gradient (single 'device': psum over trivial axis)."""
    import jax

    g_true = {"w": jnp.asarray(np.random.default_rng(0).standard_normal(64),
                               jnp.float32)}
    err = init_error_state(g_true)

    def one(err):
        return compress_psum_int8(g_true, err, "i")

    f = jax.jit(lambda e: jax.vmap(lambda _, e: one(e), in_axes=(0, None),
                                   axis_name="i")(jnp.arange(1), e))
    acc = jnp.zeros(64)
    for _ in range(50):
        out, err = f(err)
        out = jax.tree.map(lambda x: x[0], out)
        err = jax.tree.map(lambda x: x[0], err)
        acc = acc + out["w"]
    np.testing.assert_allclose(np.asarray(acc / 50), np.asarray(g_true["w"]),
                               atol=1e-3)


def test_topk_compression_keeps_largest():
    import jax

    g = {"w": jnp.asarray([0.1, -5.0, 0.2, 4.0, 0.0, 0.05], jnp.float32)}
    err = init_error_state(g)

    def run(g, e):
        return compress_psum_topk(g, e, "i", k_frac=0.34)

    out, new_err = jax.vmap(lambda _: run(g, jax.tree.map(lambda x: x, err)),
                            axis_name="i")(jnp.arange(1))
    w = np.asarray(out["w"][0])
    assert w[1] != 0 and w[3] != 0  # two largest kept
    assert np.count_nonzero(w) == 2
    # error feedback holds the dropped mass
    np.testing.assert_allclose(np.asarray(new_err["w"][0]),
                               np.asarray(g["w"]) - w, atol=1e-6)


# ---------------------------------------------------------------- pipeline
def test_pipeline_deterministic_and_host_sharded():
    cfg = DataConfig(vocab_size=97, seq_len=16, global_batch=8, seed=3)
    a = ShardedSyntheticStream(cfg, host_id=0, n_hosts=2).batch(5)
    b = ShardedSyntheticStream(cfg, host_id=0, n_hosts=2).batch(5)
    c = ShardedSyntheticStream(cfg, host_id=1, n_hosts=2).batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])  # deterministic
    assert not np.array_equal(a["tokens"], c["tokens"])  # host-sharded
    assert a["tokens"].shape == (4, 16)
    # labels are next-token shifted along the affine chain
    np.testing.assert_array_equal(
        a["labels"][:, :-1], a["tokens"][:, 1:]
    )
    np.testing.assert_array_equal(
        a["labels"], (31 * a["tokens"].astype(np.int64) + 7) % 97
    )


def test_arrival_stream_hesrpt_dominates():
    """Paper §4.3 heuristic: online heSRPT (recompute at arrivals) beats
    SRPT and matches-or-beats EQUI on a small Poisson stream."""
    from benchmarks.arrivals import run_stream

    kw = dict(n_jobs=20, rate=2.0, p=0.5, n_chips=64, seed=1)
    f_he = run_stream("hesrpt", **kw)
    f_srpt = run_stream("srpt", **kw)
    f_equi = run_stream("equi", **kw)
    assert f_he <= f_srpt * 1.02
    assert f_he <= f_equi * 1.02


def test_straggler_detection_triggers_resize_decision():
    """Integration: a degraded job (observed rate below the speedup-model
    expectation) is flagged and the scheduler can re-quantize without it."""
    from repro.sched import ClusterScheduler, Job, StragglerDetector

    sched = ClusterScheduler(32, policy="hesrpt")
    for i, s in enumerate([8.0, 4.0, 2.0]):
        sched.add_job(Job(f"j{i}", size=s, p=0.5))
    alloc = sched.allocations()
    det = StragglerDetector(threshold=0.7, patience=2)
    victim = "j1"
    expected = alloc[victim] ** 0.5  # s(k) = k^p model
    flagged = False
    for _ in range(3):
        flagged = det.report(victim, observed_rate=0.3 * expected,
                             expected_rate=expected)
        if flagged:
            break
    assert flagged
    # driver response: evict one chip from the straggler and re-quantize
    sched.n_chips -= 1
    new_alloc = sched.allocations()
    assert sum(new_alloc.values()) <= 31
