"""Distribution tests: run in subprocesses with 8 fake CPU devices (XLA
locks the device count at first init, so the main test process — which other
tests need at 1 device — can never host these)."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_fake_devices(body: str, n_devices: int = 8, timeout: int = 600) -> str:
    """Run `body` in a fresh python with n fake devices; returns stdout."""
    prelude = textwrap.dedent(
        f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_devices}"
        import sys
        sys.path.insert(0, {os.path.join(REPO, "src")!r})
        import jax
        assert len(jax.devices()) == {n_devices}
        """
    )
    proc = subprocess.run(
        [sys.executable, "-c", prelude + textwrap.dedent(body)],
        capture_output=True, text=True, timeout=timeout,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout


@pytest.mark.slow
def test_mesh_build_and_sharded_train_step():
    out = run_with_fake_devices(
        """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.configs import smoke_config
        from repro.models import build_model, ModelOptions, ParallelConfig
        from repro.launch import sharding as sh
        from repro.train import TrainConfig, make_train_step
        from repro.train.optimizer import init_opt_state

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = smoke_config("qwen2.5-14b").scaled(d_model=64, d_ff=128, n_heads=4,
                                                 n_kv_heads=2, head_dim=16)
        par = ParallelConfig(mesh, ("data",), "model")
        model = build_model(cfg, ModelOptions(activation_dtype="float32",
                                              remat="full", parallel=par))
        params = model.init(jax.random.PRNGKey(0))
        pspecs = sh.param_specs(params, mesh, cfg)
        ospecs = {"m": pspecs, "v": pspecs, "step": P()}
        step = make_train_step(model, TrainConfig(microbatches=2))
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, 256, (8, 32)), jnp.int32),
                 "labels": jnp.asarray(rng.integers(0, 256, (8, 32)), jnp.int32)}
        bspecs = sh.batch_specs(batch, mesh)
        jstep = jax.jit(step, in_shardings=(sh.named(pspecs, mesh),
                                            sh.named(ospecs, mesh),
                                            sh.named(bspecs, mesh)))
        params = jax.device_put(params, sh.named(pspecs, mesh))
        opt = jax.device_put(init_opt_state(params), sh.named(ospecs, mesh))
        batch = jax.device_put(batch, sh.named(bspecs, mesh))
        p2, o2, m = jstep(params, opt, batch)
        loss = float(m["loss"])
        assert np.isfinite(loss)
        # weights actually sharded: a d_ff leaf should occupy 1/2 per device
        leaf = p2["stack"]["blocks"]["sub0"]["mlp"]["gate"]
        assert len(leaf.sharding.device_set) == 8
        print("LOSS", loss)
        """
    )
    assert "LOSS" in out


def test_checkpoint_restore_across_mesh_shapes():
    """Elasticity mechanism: save on a (4,2) mesh, restore on (2,1)."""
    out = run_with_fake_devices(
        """
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.train import checkpoint

        tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                "b": jnp.ones((4,), jnp.float32)}
        mesh_a = jax.make_mesh((4, 2), ("data", "model"))
        sh_a = {"w": NamedSharding(mesh_a, P("data", "model")),
                "b": NamedSharding(mesh_a, P())}
        tree_a = jax.device_put(tree, sh_a)
        d = tempfile.mkdtemp()
        checkpoint.save(d, tree_a, step=7)

        mesh_b = Mesh(np.array(jax.devices()[:2]).reshape(2, 1), ("data", "model"))
        sh_b = {"w": NamedSharding(mesh_b, P("model", "data")),
                "b": NamedSharding(mesh_b, P())}
        tree_b = checkpoint.restore(d, tree, sh_b)
        np.testing.assert_array_equal(np.asarray(tree_b["w"]), np.asarray(tree["w"]))
        assert len(tree_b["w"].sharding.device_set) == 2
        assert checkpoint.load_manifest(d)["step"] == 7
        print("RESTORED")
        """
    )
    assert "RESTORED" in out


@pytest.mark.slow
def test_moe_ragged_shard_map_matches_dense():
    out = run_with_fake_devices(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import smoke_config
        from repro.models.common import ParallelConfig, use_mesh
        from repro.models.moe import moe_apply, moe_init

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = smoke_config("qwen3-moe-235b-a22b")
        par = ParallelConfig(mesh, ("data",), "model")
        p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jnp.asarray(np.random.default_rng(1).standard_normal((8, 16, cfg.d_model)),
                        jnp.float32)
        with use_mesh(mesh):
            y_r, aux_r = jax.jit(lambda p, x: moe_apply(p, x, cfg, impl="ragged",
                                                        parallel=par))(p, x)
        y_d, aux_d = moe_apply(p, x, cfg, impl="dense")
        np.testing.assert_allclose(np.asarray(y_r), np.asarray(y_d), rtol=2e-4, atol=2e-4)
        print("MOE_OK", float(aux_r), float(aux_d))
        """
    )
    assert "MOE_OK" in out


@pytest.mark.slow
def test_elastic_cluster_end_to_end():
    """heSRPT-scheduled multi-job elastic training: losses drop, resizes
    happen, flow time tracks the fluid optimum."""
    out = run_with_fake_devices(
        """
        import jax, jax.numpy as jnp, tempfile
        from repro.configs import smoke_config
        from repro.core import hesrpt_total_flowtime
        from repro.sched import ElasticClusterDriver, ElasticJobConfig

        cfg = smoke_config("phi4-mini-3.8b")
        sizes = [24, 12, 6]
        jobs = [ElasticJobConfig(f"j{i}", cfg, total_steps=s, p=0.5, seed=i,
                                 compression="int8" if i == 1 else None)
                for i, s in enumerate(sizes)]
        driver = ElasticClusterDriver(jobs, jax.devices(), policy="hesrpt",
                                      ckpt_root=tempfile.mkdtemp())
        res = driver.run()
        closed = float(hesrpt_total_flowtime(jnp.asarray(sorted(map(float, sizes),
                                                                reverse=True)),
                                             0.5, 8.0))
        gap = res["total_flow_time"] / closed - 1
        assert gap < 0.35, (res["total_flow_time"], closed)
        assert sum(res["resizes"].values()) >= 2
        for jid, losses in res["losses"].items():
            assert losses[-1] < losses[0], jid
        print("E2E_OK gap", gap)
        """,
        timeout=900,
    )
    assert "E2E_OK" in out


@pytest.mark.slow
def test_miniature_dryrun():
    """Tiny production-mesh analogue: lower+compile a reduced arch on a
    (2,2,2) pod/data/model mesh and check the roofline terms come out."""
    out = run_with_fake_devices(
        """
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.configs import smoke_config
        from repro.launch import sharding as sh
        from repro.launch.hlo_analysis import analyze_hlo
        from repro.models import build_model, ModelOptions, ParallelConfig
        from repro.train import TrainConfig, make_train_step
        from repro.train.optimizer import init_opt_state

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = smoke_config("mixtral-8x7b")
        par = ParallelConfig(mesh, ("pod", "data"), "model")
        model = build_model(cfg, ModelOptions(activation_dtype="bfloat16",
                                              remat="full", parallel=par))
        params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        pspecs = sh.param_specs(params_sds, mesh, cfg)
        opt_sds = jax.eval_shape(init_opt_state, params_sds)
        ospecs = {"m": pspecs, "v": pspecs, "step": P()}
        batch_sds = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
                     "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
        bspecs = sh.batch_specs(batch_sds, mesh)
        step = make_train_step(model, TrainConfig(microbatches=2))
        jitted = jax.jit(step, in_shardings=(sh.named(pspecs, mesh),
                                             sh.named(ospecs, mesh),
                                             sh.named(bspecs, mesh)))
        lowered = jitted.lower(params_sds, opt_sds, batch_sds)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        h = analyze_hlo(compiled.as_text())
        assert h["flops"] > 0 and h["bytes"] > 0
        assert sum(h["collective_bytes"].values()) > 0  # pod axis really shards
        print("DRYRUN_OK", h["flops"] > 0, int(mem.temp_size_in_bytes))
        """
    )
    assert "DRYRUN_OK" in out


@pytest.mark.slow
def test_fault_tolerant_recovery_loop():
    out = run_with_fake_devices(
        """
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from repro.configs import smoke_config
        from repro.data.pipeline import make_stream_for
        from repro.models import build_model, ModelOptions
        from repro.train import TrainConfig, make_train_step
        from repro.train.ft import FailureInjector, run_with_recovery
        from repro.train.optimizer import init_opt_state

        cfg = smoke_config("mamba2-130m")
        model = build_model(cfg, ModelOptions(activation_dtype="float32",
                                              remat="none"))
        params = model.init(jax.random.PRNGKey(0))
        opt = init_opt_state(params)
        step = jax.jit(make_train_step(model, TrainConfig()))
        stream = make_stream_for(cfg, 32, 4)
        def batches(s):
            return {k: jnp.asarray(v) for k, v in stream.batch(s).items()}
        inj = FailureInjector(fail_at_steps=[7, 13])
        p, o, hist = run_with_recovery(step, batches, params, opt, n_steps=20,
                                       ckpt_dir=tempfile.mkdtemp(), ckpt_every=5,
                                       injector=inj)
        assert len(hist["recoveries"]) == 2
        assert hist["loss"][-1] < hist["loss"][0]
        print("FT_OK", hist["recoveries"])
        """,
        n_devices=1,
    )
    assert "FT_OK" in out
