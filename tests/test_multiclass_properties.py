"""Hypothesis property tests for the multi-class subsystem.

Wider-random twins of the seeded-fuzz checks in tests/test_multiclass.py:
allocation conservation across classes, per-class monotonicity in
remaining size, and the class-blind reduction (K classes with one shared
exponent == the single-class engine bit-for-bit).  Skipped wholesale when
hypothesis is absent (same convention as tests/test_quantize.py).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ClassSpec,
    class_theta,
    make_policy,
    make_scenario,
    policy_weights,
    simulate_multiclass,
    simulate_online,
)

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (pip install -e '.[dev]')"
)
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

CLASS_POLICIES = ("hesrpt_pc", "waterfill", "hesrpt_sd", "hesrpt_blind")


def _theta(name, x, p, x0):
    w = policy_weights(name, x0=x0)
    return class_theta(name, x, p, n_servers=64.0, w=w)


@st.composite
def class_instances(draw):
    m = draw(st.integers(1, 14))
    x = np.array(draw(st.lists(
        st.floats(1e-3, 1e4, allow_nan=False, allow_infinity=False),
        min_size=m, max_size=m,
    )))
    dead = np.array(draw(st.lists(st.booleans(), min_size=m, max_size=m)))
    x = np.where(dead, 0.0, x)
    p = np.array(draw(st.lists(st.floats(0.05, 0.95), min_size=m, max_size=m)))
    return x, p


@settings(max_examples=120, deadline=None)
@given(inst=class_instances(), name=st.sampled_from(CLASS_POLICIES))
def test_conservation_across_classes(inst, name):
    """sum(theta) == 1 over active jobs, 0 on inactive, all >= 0."""
    x, p = inst
    x0 = np.where(x > 0, x, 1.0)
    th = np.asarray(
        _theta(name, jnp.asarray(x), jnp.asarray(p), jnp.asarray(x0))
    )
    assert np.all(th >= 0)
    assert np.all(th[x <= 0] == 0)
    if (x > 0).any():
        np.testing.assert_allclose(th.sum(), 1.0, rtol=1e-9)
    else:
        assert th.sum() == 0


@st.composite
def two_class_instances(draw):
    m = draw(st.integers(2, 14))
    x = np.array(draw(st.lists(
        st.floats(1e-2, 1e3, allow_nan=False, allow_infinity=False),
        min_size=m, max_size=m,
    )))
    cls = np.array(draw(st.lists(st.integers(0, 1), min_size=m, max_size=m)))
    p0 = draw(st.floats(0.1, 0.9))
    p1 = draw(st.floats(0.1, 0.9))
    return x, cls, np.where(cls == 0, p0, p1)


@settings(max_examples=120, deadline=None)
@given(inst=two_class_instances(), name=st.sampled_from(("hesrpt_pc",
                                                         "waterfill")))
def test_per_class_monotonicity(inst, name):
    """Within a class, smaller remaining size never means a smaller share."""
    x, cls, p = inst
    th = np.asarray(_theta(name, jnp.asarray(x), jnp.asarray(p),
                           jnp.asarray(x)))
    for k in (0, 1):
        xs, ts = x[cls == k], th[cls == k]
        order = np.argsort(xs, kind="stable")
        assert np.all(np.diff(ts[order]) <= 1e-9), (xs, ts)


@settings(max_examples=25, deadline=None)
@given(
    k=st.integers(2, 4),
    p=st.floats(0.2, 0.8),
    seed=st.integers(0, 2**16),
    policy=st.sampled_from(("hesrpt_pc", "hesrpt_blind")),
)
def test_class_blind_reduction_bitforbit(k, p, seed, policy):
    """K equal-p classes (different size distributions) must reproduce the
    single-class engine exactly on f64."""
    classes = tuple(
        ClassSpec(p=p, mix=1.0 / k, size_alpha=1.3 + 0.4 * i,
                  size_scale=1.0 + 0.5 * i)
        for i in range(k)
    )
    scn = make_scenario("multiclass_poisson", classes=classes)(
        jax.random.PRNGKey(seed), 16, 2.0
    )
    got = simulate_multiclass(scn, classes=classes, policy=policy,
                              n_servers=64.0)
    ref = simulate_online(scn.x0, scn.arrival_times, p, 64.0,
                          make_policy("hesrpt", n_servers=64.0))
    np.testing.assert_array_equal(np.asarray(got.completion_times),
                                  np.asarray(ref.completion_times))
