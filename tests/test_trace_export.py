"""The recorded-trace path (engine ``record=True``) and its Perfetto export.

Two layers:

- the ``EngineTrace`` itself must be physically sane across the
  continuous, quantized and fused rule paths — allocations non-negative
  and within budget at every event, remaining sizes non-increasing per
  job, event times ordered, and each job's last positive-size epoch
  consistent with its reported completion time;
- ``launch/trace_export.py`` must turn that trace into *valid* Chrome
  trace-event JSON (the committed sample artifact included): slices only
  while a job holds an allocation, one completion marker per finished job
  at exactly its completion time, counter tracks present, and the schema
  validator catching each way the format can be malformed.
"""

import json
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.core import engine, make_policy, make_scenario
from repro.core.telemetry import DEFAULT_METRICS, make_probe
from repro.launch import trace_export

N_JOBS = 24
SAMPLE = Path(__file__).parent.parent / "examples" / "sample_schedule_trace.json"


@pytest.fixture(scope="module", autouse=True)
def _fresh_compile_cache(fresh_compile_cache):
    # This file runs near the end of the suite and compiles large recorded
    # scans — see the shared ``fresh_compile_cache`` fixture in conftest.py
    # for the jaxlib 0.4.x CPU-backend rationale; autouse it here.
    pass


def _recorded(kind, seed=0, rate=2.0, n_jobs=N_JOBS, p=0.5):
    scn = make_scenario("poisson", p=p)(jax.random.key(seed), n_jobs, rate)
    dtype = scn.x0.dtype
    pol = make_policy("hesrpt")
    if kind == "continuous":
        rule, unit, fused = engine.continuous_rule(pol, 1.0, dtype=dtype), 1.0, False
    elif kind == "quantized":
        rule, unit, fused = engine.quantized_rule(pol, 64, dtype=dtype), 64.0, False
    else:
        rule, unit, fused = engine.quantized_rule(pol, 64, dtype=dtype), 64.0, True
    res = engine.run(scn.x0, scn.arrival_times, p, rule, record=True,
                     fused=fused)
    return res, unit


# ------------------------------------------------------- trace-path invariants
@pytest.mark.parametrize("kind", ["continuous", "quantized", "fused"])
def test_recorded_trace_is_physically_sane(kind):
    res, unit = _recorded(kind)
    alloc = np.asarray(res.trace.alloc)
    times = np.asarray(res.trace.times)
    sizes = np.asarray(res.trace.sizes)
    assert np.all(alloc >= 0)
    assert np.all(alloc.sum(axis=1) <= unit * (1 + 1e-12))  # never oversubscribed
    if unit != 1.0:  # quantized paths allocate whole chips
        assert np.all(alloc == np.round(alloc))
    assert np.all(np.diff(times) >= 0)
    assert np.all(np.diff(sizes, axis=0) <= 1e-12)  # work only ever completes
    # completion times (input order) match the trace: a departed job's
    # size hits zero by the first event at/after its completion time
    done = np.asarray(res.completion_times)[np.asarray(res.order)]
    assert np.all(np.isfinite(done))
    for j in range(sizes.shape[1]):
        after = times >= done[j] + 1e-9
        assert np.all(sizes[after, j] == 0.0)
        assert np.all(alloc[after, j] == 0.0)


def test_recorded_trace_composes_with_telemetry_bitforbit():
    scn = make_scenario("poisson", p=0.5)(jax.random.key(7), N_JOBS, 2.0)
    rule = engine.continuous_rule(make_policy("hesrpt"), 1.0, dtype=scn.x0.dtype)
    probe = make_probe(DEFAULT_METRICS, mode="series", dtype=scn.x0.dtype)
    plain = engine.run(scn.x0, scn.arrival_times, 0.5, rule, record=True)
    probed = engine.run(scn.x0, scn.arrival_times, 0.5, rule, record=True,
                        telemetry=probe)
    for a, b in zip(plain.trace, probed.trace, strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the probe saw the same epochs the trace recorded
    np.testing.assert_array_equal(np.asarray(probed.trace.times),
                                  np.asarray(probed.telemetry.series["t"]))


# ------------------------------------------------------------------- exporter
@pytest.mark.parametrize("kind", ["continuous", "quantized"])
def test_schedule_to_events_is_valid_and_complete(kind):
    res, unit = _recorded(kind)
    events = trace_export.schedule_to_events(res, alloc_unit=unit, p=0.5)
    trace_export.validate_trace_events(events)  # schema-valid as built
    done = np.asarray(res.completion_times)
    markers = [e for e in events if e["ph"] == "i"]
    assert len(markers) == int(np.sum(np.isfinite(done)))
    # marker timestamps are exactly the completion times (default 1e6 scale)
    got = sorted(e["ts"] for e in markers)
    want = sorted(float(t) * 1e6 for t in done[np.isfinite(done)])
    np.testing.assert_allclose(got, want, rtol=0, atol=1e-6)
    slices = [e for e in events if e["ph"] == "X"]
    assert slices and all(e["dur"] > 0 for e in slices)
    order = np.asarray(res.order)
    for e in slices:  # no slice outlives its job
        j = e["tid"]
        assert e["ts"] + e["dur"] <= float(done[order[j]]) * 1e6 + 1e-3
    counters = {e["name"] for e in events if e["ph"] == "C"}
    assert {"efficiency", "utilization", "queue"} <= counters


def test_exporter_prefers_telemetry_series_counters():
    scn = make_scenario("poisson", p=0.5)(jax.random.key(1), N_JOBS, 2.0)
    rule = engine.continuous_rule(make_policy("hesrpt"), 1.0, dtype=scn.x0.dtype)
    probe = make_probe(DEFAULT_METRICS, mode="series", dtype=scn.x0.dtype)
    res = engine.run(scn.x0, scn.arrival_times, 0.5, rule, record=True,
                     telemetry=probe)
    events = trace_export.schedule_to_events(
        res, telemetry_series=res.telemetry.series
    )
    trace_export.validate_trace_events(events)
    counters = {e["name"] for e in events if e["ph"] == "C"}
    assert "entropy" in counters  # only the probe computes entropy
    series = {k: np.asarray(v) for k, v in res.telemetry.series.items()}
    live = series["dt"] > 0
    eff = [e for e in events if e["ph"] == "C" and e["name"] == "efficiency"]
    got = np.array([e["args"]["efficiency"] for e in eff[:-1]])  # final flat-line
    np.testing.assert_allclose(got, series["efficiency"][live], atol=1e-12)


def test_export_requires_a_recorded_trace():
    scn = make_scenario("poisson", p=0.5)(jax.random.key(2), 8, 2.0)
    rule = engine.continuous_rule(make_policy("hesrpt"), 1.0, dtype=scn.x0.dtype)
    res = engine.run(scn.x0, scn.arrival_times, 0.5, rule)
    with pytest.raises(ValueError, match="record=True"):
        trace_export.schedule_to_events(res)


# ------------------------------------------------------------ schema validator
def test_validator_rejects_each_malformation():
    ok = {"ph": "X", "pid": 0, "tid": 1, "ts": 0.0, "dur": 1.0, "name": "s"}
    trace_export.validate_trace_events([ok])
    bad_cases = [
        [],  # empty
        [{**ok, "ph": "Q"}],  # unknown phase
        [{k: v for k, v in ok.items() if k != "dur"}],  # missing required key
        [{**ok, "ts": float("nan")}],  # non-finite timestamp
        [{**ok, "ts": -1.0}],  # negative timestamp
        [{**ok, "dur": float("nan")}],  # NaN duration
        [{"ph": "C", "pid": 0, "ts": 0.0, "name": "q", "args": {}}],  # empty counter
        [{"ph": "C", "pid": 0, "ts": 0.0, "name": "q", "args": {"q": "hi"}}],
        ["not a dict"],
    ]
    for events in bad_cases:
        with pytest.raises(ValueError):
            trace_export.validate_trace_events(events)


# ----------------------------------------------------- artifact + CLI round trip
def test_committed_sample_trace_is_valid():
    with open(SAMPLE) as f:
        doc = json.load(f)
    events = doc["traceEvents"]
    trace_export.validate_trace_events(events)
    phases = {e["ph"] for e in events}
    assert {"X", "i", "C", "M"} <= phases
    names = {e["name"] for e in events if e["ph"] == "M"}
    assert "process_name" in names and "thread_name" in names


def test_cli_writes_a_loadable_trace(tmp_path):
    out = tmp_path / "trace.json"
    trace_export.main([
        "--out", str(out), "--jobs", "6", "--rate", "2.0", "--seed", "1",
        "--n-chips", "16",
    ])
    with open(out) as f:
        doc = json.load(f)
    assert doc["displayTimeUnit"] == "ms"
    trace_export.validate_trace_events(doc["traceEvents"])
    assert sum(1 for e in doc["traceEvents"] if e["ph"] == "i") == 6
