"""Shared test configuration.

- float64 is enabled for the scheduler-math tests (closed-form vs simulator
  comparisons need it).  Model/kernel code specifies its dtypes explicitly,
  so this does not change model behaviour.
- NOTE: we deliberately do NOT set XLA_FLAGS here; distribution tests that
  need many fake devices spawn subprocesses with their own flags so ordinary
  tests see the real single-CPU device.
"""

import jax

jax.config.update("jax_enable_x64", True)
