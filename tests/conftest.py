"""Shared test configuration.

- float64 is enabled for the scheduler-math tests (closed-form vs simulator
  comparisons need it).  Model/kernel code specifies its dtypes explicitly,
  so this does not change model behaviour.
- NOTE: we deliberately do NOT set XLA_FLAGS here; distribution tests that
  need many fake devices spawn subprocesses with their own flags so ordinary
  tests see the real single-CPU device.
- Known seed-state failures (tests/KNOWN_FAILURES.md) are marked
  xfail(strict=False) at collection, so any run — tier-1 or full — enforces
  "no new failures" instead of tolerating a red suite.  Fix a test, delete
  its line from KNOWN_FAILURES.md, and a regression breaks CI again.
"""

import os
import re
from pathlib import Path

import jax
import pytest

jax.config.update("jax_enable_x64", True)

# Persistent compilation cache (shared with benchmarks/run.py): the suite
# compiles hundreds of distinct XLA programs; caching them on disk makes
# repeat local runs and CI (which restores the directory via actions/cache)
# skip recompilation.  JAX_COMPILATION_CACHE_DIR overrides the repo-local
# default; threshold 0 caches even sub-second test-size programs.
jax.config.update(
    "jax_compilation_cache_dir",
    os.environ.get(
        "JAX_COMPILATION_CACHE_DIR",
        str(Path(__file__).resolve().parent.parent / ".jax_cache"),
    ),
)
jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)

_KNOWN_FAILURES = Path(__file__).parent / "KNOWN_FAILURES.md"


def _known_failure_nodeids() -> frozenset[str]:
    if not _KNOWN_FAILURES.exists():
        return frozenset()
    ids = re.findall(r"^- `([^`]+)`", _KNOWN_FAILURES.read_text(), re.M)
    return frozenset(ids)


def pytest_collection_modifyitems(config, items):
    known = _known_failure_nodeids()
    for item in items:
        if item.nodeid in known:
            item.add_marker(pytest.mark.xfail(
                reason="known seed failure — tracked in tests/KNOWN_FAILURES.md",
                strict=False,
            ))


@pytest.fixture(scope="module")
def fresh_compile_cache():
    """Drop jax's executable cache before a compile-heavy module runs.

    Late in the suite, after a few hundred distinct XLA programs have been
    compiled in-process, jaxlib 0.4.x's CPU backend segfaults inside
    backend_compile on the next large scan (reproducibly, and only then —
    the same compile is fine standalone or after either half of the suite,
    with >100 GB free).  Dropping the executable cache releases the
    accumulated JIT state and keeps the compile below whatever threshold
    it trips.  Opt in per module with
    ``pytestmark = pytest.mark.usefixtures("fresh_compile_cache")`` (or an
    autouse wrapper) from any module that compiles large scans and can run
    late in the alphabetical order.
    """
    jax.clear_caches()
