"""Unit tests for the allocation policies (core/policies.py)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    equi,
    helrpt,
    hell,
    hesrpt,
    knee,
    size_ranks_desc,
    srpt,
)


def test_two_job_example():
    """Paper §1: N=10, two unit jobs, p=.5 -> optimal split is 75/25."""
    x = jnp.array([1.0, 1.0])
    theta = hesrpt(x, 0.5)
    # rank 1 (larger / completes last) gets (1/2)^2 = .25; rank 2 gets .75
    np.testing.assert_allclose(np.sort(np.asarray(theta)), [0.25, 0.75], rtol=1e-12)
    np.testing.assert_allclose(theta.sum(), 1.0, rtol=1e-12)


def test_hesrpt_closed_form_three_jobs():
    x = jnp.array([3.0, 2.0, 1.0])
    p = 0.5
    theta = hesrpt(x, p)
    c = 1.0 / (1.0 - p)
    expect = [
        (1 / 3) ** c - 0.0,
        (2 / 3) ** c - (1 / 3) ** c,
        (3 / 3) ** c - (2 / 3) ** c,
    ]
    np.testing.assert_allclose(theta, expect, rtol=1e-12)
    # increasing allocation with decreasing size (theta_1 < ... < theta_m)
    assert np.all(np.diff(np.asarray(theta)) > 0)


def test_size_ranks_desc_with_inactive():
    x = jnp.array([5.0, 0.0, 7.0, 1.0])
    ranks = size_ranks_desc(x)
    np.testing.assert_array_equal(ranks, [2, 0, 1, 3])


def test_hesrpt_ignores_departed_jobs():
    x = jnp.array([4.0, 0.0, 1.0])
    theta = hesrpt(x, 0.3)
    assert theta[1] == 0
    np.testing.assert_allclose(theta.sum(), 1.0, rtol=1e-12)


def test_helrpt_allocations():
    """Thm 2: gamma_i = x_i^(1/p) / sum x_j^(1/p); longer job gets more."""
    x = jnp.array([2.0, 1.0])
    p = 0.5
    gamma = helrpt(x, p)
    w = np.array([2.0, 1.0]) ** 2
    np.testing.assert_allclose(gamma, w / w.sum(), rtol=1e-12)
    assert gamma[0] > gamma[1]


def test_srpt_gives_everything_to_smallest():
    x = jnp.array([4.0, 2.0, 9.0])
    theta = srpt(x)
    np.testing.assert_array_equal(theta, [0.0, 1.0, 0.0])


def test_equi_splits_evenly_over_active():
    x = jnp.array([4.0, 0.0, 9.0])
    theta = equi(x)
    np.testing.assert_allclose(theta, [0.5, 0.0, 0.5], rtol=1e-12)


@pytest.mark.parametrize("p", [0.05, 0.3, 0.49])
def test_hell_waterfill_biases_short_jobs(p):
    x = jnp.array([8.0, 4.0, 2.0, 1.0])
    theta = hell(x, p, n_servers=1e6)
    assert np.all(np.diff(np.asarray(theta)) > 0)  # short jobs get more
    np.testing.assert_allclose(theta.sum(), 1.0, rtol=1e-12)


@pytest.mark.parametrize("p", [0.5, 0.9, 0.99])
def test_hell_is_srpt_for_high_p(p):
    x = jnp.array([8.0, 4.0, 2.0, 1.0])
    theta = hell(x, p, n_servers=1e6)
    np.testing.assert_array_equal(theta, [0, 0, 0, 1.0])


def test_knee_undersubscribed_proportional():
    x = jnp.array([4.0, 1.0])
    p = 0.5
    alpha = 1e3  # huge threshold -> tiny knees -> undersubscribed
    theta = knee(x, p, n_servers=1e6, alpha=alpha)
    kn = (p * np.array([4.0, 1.0]) / alpha) ** (1 / (1 + p))
    np.testing.assert_allclose(theta, kn / kn.sum(), rtol=1e-9)


def test_knee_oversubscribed_prefix():
    x = jnp.array([4.0, 1.0])
    p = 0.5
    n = 10.0
    alpha = 1e-6  # tiny threshold -> huge knees -> oversubscribed
    theta = knee(x, p, n_servers=n, alpha=alpha)
    kn_small = (p * 1.0 / alpha) ** (1 / (1 + p))
    assert kn_small > n  # even the small job's knee exceeds the system
    np.testing.assert_allclose(theta, [0.0, 1.0], atol=1e-12)


@pytest.mark.parametrize("policy", [hesrpt, helrpt, equi])
def test_allocations_are_distributions(policy):
    x = jnp.array([9.0, 5.0, 5.0, 0.5, 0.0])
    theta = policy(x, 0.37)
    assert np.all(np.asarray(theta) >= 0)
    np.testing.assert_allclose(np.asarray(theta).sum(), 1.0, rtol=1e-9)
    assert theta[-1] == 0  # departed job holds nothing
