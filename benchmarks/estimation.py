"""Beyond paper: online speedup-exponent estimation in the allocation loop.

The paper assumes the speedup exponent ``p`` is known; production fits it
from observed throughput (Li et al. 2025 study scheduling when the speedup
curve is only approximately known).  Since the stateful-rule refactor the
estimator runs *inside* the engine's event scan (``core/estimation.py``:
recursive WLS over sufficient statistics, exponentially discounted), so
the whole regime sweeps jit+vmap like everything else — the
``use_estimator=True`` path was the last simulator feature stuck on the
per-event Python loop.

Sections:

- three-arm sweep on p-drift scenarios (``core/scenarios.py``: the true
  exponent drops mid-stream, e.g. the workload turning
  communication-bound): **oracle-p** (policy always sees the current
  truth), **stale-p** (policy keeps the pre-drift exponent forever),
  **estimator** (policy allocates with the blended p-hat fit online).
  Seeds x loads x drift scenarios in one jit+vmap device call per arm.
  The estimator should recover most of the oracle-stale gap;
- forgetting: the same sweep at discount 1.0 (no forgetting) vs < 1
  (tracks the regime change) on one drift scenario;
- cross-check: ``ClusterScheduler(use_estimator=True)`` delegating to the
  engine vs the per-event Python loop — identical observation schedules,
  flows must agree to ~1e-10 (batch, heterogeneous p, class-aware pooled
  p-hat, and the arrival-stream loop).
"""

from __future__ import annotations

import time

import numpy as np

# Keep in sync with repro.core.sweeps.ARMS (duplicated so importing this
# benchmark module stays jax-free; Sweep.create validates arm names, so a
# drifted copy fails loudly rather than silently).
ARMS = ("oracle", "stale", "estimator")
RATES = (0.5, 2.0, 8.0)
DRIFT_SCENARIOS = ("drift_poisson", "drift_bursty")


def sweep(arms=ARMS, rates=RATES, *, policy="hesrpt", n_jobs=500, n_seeds=20,
          p0=0.8, p1=0.3, drift_frac=0.5, n_servers=256.0, seed=0,
          scenario="drift_poisson", discount=0.9, prior_weight=1.0) -> dict:
    """Seeds x loads for each arm, paired sample paths (shared keys).
    Returns ``{arm: {rate: mean-over-seeds mean flow time}}``.

    Each arm is a thin :class:`repro.core.sweeps.Sweep` spec (the ``arm``
    field selects oracle / stale / estimator semantics inside the engine),
    golden-pinned bit-for-bit against the historical per-arm jit+vmap.
    """
    import jax.numpy as jnp

    from repro.core.sweeps import Sweep, run_sweep

    out = {}
    for arm in arms:
        spec = Sweep.create(
            (policy,), rates, scenario=scenario,
            scenario_kw={"p0": p0, "p1": p1, "drift_frac": drift_frac},
            n_jobs=n_jobs, n_seeds=n_seeds, seed=seed, p=p0,
            n_servers=float(n_servers), arm=arm,
            arm_kw={"discount": discount, "prior_weight": prior_weight},
        )
        per_seed = run_sweep(spec).stats[policy]["mean_flowtime"]
        out[arm] = {
            float(r): float(jnp.mean(per_seed[i]))
            for i, r in enumerate(rates)
        }
    return out


def forgetting_rows(rates=RATES, *, n_jobs=300, n_seeds=10, p0=0.8, p1=0.3,
                    n_servers=256.0, seed=0) -> dict:
    """Discount ablation: without forgetting (discount=1) the estimator
    averages over both regimes; with forgetting it tracks the drift."""
    out = {}
    for label, disc in (("discount=1.0", 1.0), ("discount=0.9", 0.9)):
        res = sweep(("estimator",), rates, n_jobs=n_jobs, n_seeds=n_seeds,
                    p0=p0, p1=p1, n_servers=n_servers, seed=seed,
                    discount=disc)
        out[label] = res["estimator"]
    return out


def cross_check(*, n_jobs=10, n_chips=48, seed=0) -> dict:
    """Engine-delegated ``use_estimator=True`` vs the per-event Python
    oracle on identical observation schedules (one observation per active
    job per epoch, after the advance).  Covers the batch case with
    heterogeneous true p (continuous + quantized chips), the class-aware
    pooled-p-hat case, and the arrival-stream loop."""
    import jax.numpy as jnp

    from repro.core import make_policy, simulate_scenario_estimated, trace_scenario
    from repro.sched import ClusterScheduler, Job

    rng = np.random.default_rng(seed)
    worst = 0.0
    n_cases = 0

    def pair(mk):
        a, b = mk(), mk()
        assert a._engine_eligible(), "estimator instance must delegate"
        ra = a.run_fluid_to_completion(use_engine=True)
        rb = b.run_fluid_to_completion(use_engine=False)
        ta = np.array(sorted(ra["completion_times"].values()))
        tb = np.array(sorted(rb["completion_times"].values()))
        return float(np.max(np.abs(ta - tb) / tb))

    # batch, heterogeneous true p, wrong prior — continuous and quantized
    sizes = rng.pareto(1.5, n_jobs) + 1.0
    ps = rng.uniform(0.3, 0.8, n_jobs)
    for quantize in (False, True):

        def mk(quantize=quantize):
            s = ClusterScheduler(n_chips, policy="hesrpt", use_estimator=True,
                                 quantize=quantize, est_discount=0.9)
            for i, sz in enumerate(sizes):
                s.add_job(Job(f"j{i}", size=float(sz), p=float(ps[i]),
                              prior_p=0.5))
            return s

        worst = max(worst, pair(mk))
        n_cases += 1

    # class-aware: per-class pooled p-hat
    cls = rng.integers(0, 3, n_jobs)
    pk = {0: 0.3, 1: 0.55, 2: 0.8}

    def mk_class():
        s = ClusterScheduler(n_chips, policy="hesrpt_pc", use_estimator=True,
                             quantize=True, class_aware=True)
        for i, sz in enumerate(sizes):
            s.add_job(Job(f"j{i}", size=float(sz), p=pk[int(cls[i])],
                          class_id=int(cls[i]), prior_p=0.5))
        return s

    worst = max(worst, pair(mk_class))
    n_cases += 1

    # arrival stream: per-event reference loop vs the engine's stateful rule
    from benchmarks.arrivals import run_stream_reference, stream_trace

    arrivals, sz = stream_trace(n_jobs, 1.5, seed)
    flows_ref = run_stream_reference(
        "hesrpt", arrivals, sz, p=0.6, n_chips=n_chips, quantize=False,
        use_estimator=True, prior_p=0.4, est_discount=0.9)
    scn = trace_scenario(arrivals, sz)(None, n_jobs, 0.0)
    res = simulate_scenario_estimated(
        scn, 0.6, float(n_chips), make_policy("hesrpt", n_servers=n_chips),
        prior_p=0.4, discount=0.9)
    flows = np.asarray(res.flow_times)
    worst = max(worst, float(np.max(np.abs(flows - flows_ref) / flows_ref)))
    n_cases += 1
    ok = jnp.isfinite(res.completion_times).all()
    return {"worst_flow_rel": worst, "n_cases": n_cases, "finite": bool(ok)}


def main(quick: bool = False, smoke: bool = False):
    rates = RATES
    if smoke:
        n_jobs, n_seeds = 60, 4
    elif quick:
        n_jobs, n_seeds = 200, 10
    else:
        n_jobs, n_seeds = 500, 20

    t0 = time.perf_counter()
    tables = {
        scn: sweep(rates=rates, n_jobs=n_jobs, n_seeds=n_seeds, scenario=scn)
        for scn in DRIFT_SCENARIOS
    }
    sweep_s = time.perf_counter() - t0
    lines = [f"{n_jobs} jobs x {n_seeds} seeds x {len(rates)} loads x "
             f"{len(ARMS)} arms x {len(DRIFT_SCENARIOS)} drift scenarios, "
             f"p 0.8 -> 0.3 mid-stream (one jit+vmap lax.scan call per arm, "
             f"{sweep_s:.1f}s incl. compile)"]
    ok_order = True
    for scn, res in tables.items():
        lines.append(f"  {scn} (mean flow time)")
        lines.append(f"  {'arrival rate':>12s} " + " ".join(f"{a:>10s}"
                                                            for a in ARMS))
        for r in rates:
            lines.append(f"  {r:12.1f} " + " ".join(f"{res[a][r]:10.4f}"
                                                    for a in ARMS))
            # the estimator must not lose to never-updating its prior
            ok_order &= res["estimator"][r] <= res["stale"][r] * 1.02
    lines.append(f"estimator <= stale-p at every load/scenario: {ok_order}")

    fr = forgetting_rows(rates=rates, n_jobs=max(n_jobs // 2, 50),
                         n_seeds=max(n_seeds // 2, 4))
    lines.append("forgetting ablation (drift_poisson, estimator arm):")
    for label, row in fr.items():
        lines.append(f"  {label:>14s} " + " ".join(f"{v:10.4f}"
                                                   for v in row.values()))

    cc = cross_check()
    lines.append(
        f"engine vs per-event Python oracle (use_estimator=True, identical "
        f"observation schedules, {cc['n_cases']} cases incl. class-aware "
        f"pooled p-hat + arrival stream): worst flow rel err "
        f"{cc['worst_flow_rel']:.1e}")
    assert cc["worst_flow_rel"] < 1e-8, cc
    assert ok_order, "estimator arm lost to stale-p"
    return "\n".join(lines), {"tables": tables, "forgetting": fr,
                              "cross_check": cc}


if __name__ == "__main__":
    import jax

    # Same rationale as benchmarks/run.py: cross-checks against the f64
    # ClusterScheduler path need f64.
    jax.config.update("jax_enable_x64", True)
    print(main(quick=True)[0])
