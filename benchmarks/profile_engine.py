"""Profile the allocation scan body: where does a quantized heSRPT event go?

The engine's per-event hot path is sort-dominated.  This harness attributes
per-event cost to its pieces — the policy's size sort/rank, the
largest-remainder quantizer, and the assembled allocate — across the
optimization trajectory this repo shipped:

  ========  =========  =================================================
  variant   sorts/ev   what it is
  ========  =========  =================================================
  seed          4      policy sort + the first quantizer port (separate
                       trim and leftover argsorts), reconstructed here so
                       the win stays attributable after the code moved on
  unfused       3      policy sort + collapsed quantizer — what
                       ``engine.quantized_rule`` ships today
  fused         2      ``kernels/alloc.py`` ref pass sharing one sorted
                       order (rank-space oversubscription cut)
  pallas        0      the Pallas kernel: O(M^2) comparison counting, no
                       sort primitive at all (interpret mode on CPU, so
                       its wall time here is NOT representative — the
                       sort count and the TPU roofline are the story)
  ========  =========  =================================================

Wall times come from ``jax.block_until_ready`` over jitted calls; sort
counts are *measured from the compiled HLO* via
``launch.hlo_analysis.op_histogram`` (trip-count-aware, so the full
``engine.run`` scan reports sorts *per event*, not per program).  The
headline acceptance number is the fused-vs-seed per-event allocate
speedup on CPU (target >= 1.5x, driven by the sort-count reduction).

A second section profiles the *closed-form superstep* path
(``core/superstep.py``) against the per-event scans on two lanes — a
pre-arrived batch (zero scan steps: the Thm-3/8 closed form directly) and
a Poisson arrival stream (M+1 scan steps vs the generic/ranked 2M) — with
events-per-second and scan-trip-count columns, and logs one
``kind="profile_superstep"`` record per lane carrying the
``superstep_speedup_wall`` ratio (targets: >= 10x batch, >= 1.5x Poisson
vs the generic scan).

``python -m benchmarks.profile_engine [--smoke] [--json]``; also runs as a
section of ``benchmarks/run.py`` (including ``--smoke``), logging a
``kind="profile_engine"`` record into the ``BENCH_sweeps.json`` trajectory.
"""

from __future__ import annotations

import time

import numpy as np


# ------------------------------------------------- the seed's 3-sort quantizer
def _seed_quantize(theta, n_chips: int, *, min_chips: int = 1):
    """The first ``quantize_allocation_jax`` port: separate trim/leftover
    argsorts (3 sorts per call).  Kept verbatim here — not in core — purely
    so the profiler can measure the collapse against its true baseline.
    """
    import jax
    import jax.numpy as jnp

    from repro.core.ranking import inv_rank

    theta = jnp.asarray(theta)
    M = theta.shape[0]
    if n_chips <= 0 or min_chips <= 0 or M == 0:
        return jnp.zeros(M, jnp.int32)
    cap = n_chips // min_chips

    active0 = theta > 0
    n_active = jnp.sum(active0, dtype=jnp.int32)
    desc = inv_rank(jnp.argsort(jnp.where(active0, -theta, jnp.inf)))
    servable = active0 & (desc < cap)
    over = n_active * min_chips > n_chips
    sub = jnp.where(servable, theta, 0.0)
    tot = jnp.sum(sub)
    theta_eff = jnp.where(over, jnp.where(tot > 0, sub / tot, 0.0), theta)
    active = theta_eff > 0

    raw = theta_eff * n_chips
    fl = jnp.floor(raw)
    frac = raw - fl
    base = jnp.where(active, jnp.maximum(fl, min_chips), 0.0).astype(jnp.int32)

    K = jnp.maximum(jnp.sum(base) - n_chips, 0)
    capj = jnp.maximum(base - min_chips, 0) * (base > min_chips)

    def bisect(_, lohi):
        lo, hi = lohi
        mid = (lo + hi) // 2
        ge = jnp.sum(jnp.minimum(capj, mid)) >= K
        return jnp.where(ge, lo, mid + 1), jnp.where(ge, mid, hi)

    n_bits = (n_chips + 1).bit_length()
    lo, _hi = jax.lax.fori_loop(
        0, n_bits, bisect, (jnp.int32(0), jnp.int32(n_chips))
    )
    r_star = lo
    full = jnp.minimum(capj, jnp.maximum(r_star - 1, 0))
    extra_needed = K - jnp.sum(full)
    elig = capj >= jnp.maximum(r_star, 1)
    # The two argsorts the shipped quantizer collapses into one:
    erank = inv_rank(jnp.argsort(jnp.where(elig, frac, jnp.inf)))
    extra = (elig & (erank < extra_needed)).astype(jnp.int32)
    base = base - full - extra

    remainder = n_chips - jnp.sum(base)
    frank = inv_rank(jnp.argsort(jnp.where(active, -frac, jnp.inf)))
    return base + (active & (frank < remainder)).astype(jnp.int32)


# --------------------------------------------------------------- measurement
def _time(f, *args, repeats=5, inner=1):
    """Per-repeat wall times (us) of a compiled call, warm (post-compile).

    Each repeat times ``inner`` back-to-back calls and reports the per-call
    average — sub-millisecond calls are otherwise swamped by scheduler
    jitter on a shared machine.
    """
    import jax

    jax.block_until_ready(f(*args))  # compile + warm
    out = np.zeros(repeats)
    for r in range(repeats):
        t0 = time.perf_counter()
        for _ in range(inner):
            jax.block_until_ready(f(*args))
        out[r] = (time.perf_counter() - t0) * 1e6 / inner
    return out


def _sort_count(f, *args) -> float:
    """``sort`` ops in the compiled HLO (while bodies x trip count)."""
    import jax

    from repro.launch.hlo_analysis import op_histogram

    hlo = jax.jit(f).lower(*args).compile().as_text()
    return op_histogram(hlo).get("sort", 0.0)


def run(m: int = 4096, engine_m: int = 1024, p: float = 0.5,
        n_chips: int = 1024, min_chips: int = 1, repeats: int = 5,
        log: bool = True):
    """Profile components at job count ``m`` and the full scan at
    ``engine_m``; returns ``(rows, engine_rows, result)`` where ``rows`` is
    ``[(name, sorts_per_call, us_min, us_per_repeat)]``.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import engine
    from repro.core.flowtime import speedup
    from repro.core.policies import hesrpt
    from repro.core.sweeps import RUN_LOG, SweepResult
    from repro.kernels.alloc import hesrpt_alloc_fused, hesrpt_alloc_fused_ref

    t_start = time.perf_counter()
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.pareto(1.5, m) + 1.0)  # f64 under run.py's x64 flag
    pj = jnp.asarray(p, x.dtype)
    theta0 = hesrpt(x, p)

    rule = engine.quantized_rule(
        hesrpt, n_chips, min_chips=min_chips, dtype=x.dtype
    )
    fused_rule = getattr(rule, "fused_variant")  # noqa: B009

    def alloc_seed(x_act, pv):
        theta = hesrpt(x_act, pv).astype(x.dtype)
        chips = _seed_quantize(theta, n_chips, min_chips=min_chips)
        return chips, speedup(chips.astype(x.dtype), pv)

    def alloc_pallas(x_act, pv):
        _theta, chips = hesrpt_alloc_fused(
            x_act, pv, n_chips, min_chips=min_chips, impl="interpret"
        )
        return chips, speedup(chips.astype(x.dtype), pv)

    components = [
        ("policy_theta", lambda xv, pv: hesrpt(xv, pv), (x, pj)),
        ("quantize_seed",
         lambda th: _seed_quantize(th, n_chips, min_chips=min_chips),
         (theta0,)),
        ("quantize_collapsed",
         lambda th: engine.quantize_allocation_jax(
             th, n_chips, min_chips=min_chips),
         (theta0,)),
        ("alloc_seed", alloc_seed, (x, pj)),
        ("alloc_unfused", rule, (x, pj)),
        ("alloc_fused_ref", fused_rule, (x, pj)),
        ("alloc_pallas_interp", alloc_pallas, (x, pj)),
    ]
    # Ratios use the min over repeats: on a shared machine the mean is
    # contaminated by scheduler interference, while the min approaches the
    # true (uninterfered) cost of the compiled call.
    rows = []
    for name, f, args in components:
        jf = jax.jit(f)
        us = _time(jf, *args, repeats=repeats, inner=8)
        sorts = _sort_count(f, *args)
        rows.append((name, sorts, float(us.min()), us))

    # Full event scan, unfused vs fused: per-event wall time and — via the
    # trip-count-aware histogram — per-event sort count from the compiled
    # while loop (minus the one-time arrival-order sort outside the scan).
    xe = jnp.asarray(rng.pareto(1.5, engine_m) + 1.0)
    arr = jnp.zeros(engine_m, xe.dtype)
    n_events = engine_m  # pre_arrived horizon

    engine_rows = []
    for name, fused in (("engine_unfused", False), ("engine_fused", True)):
        def f_run(x0, at, *, _fused=fused):
            return engine.run(
                x0, at, p, rule, pre_arrived=True, fused=_fused
            ).completion_times

        us = _time(jax.jit(f_run), xe, arr, repeats=repeats)
        sorts_ev = (_sort_count(f_run, xe, arr) - 1.0) / n_events
        engine_rows.append(
            (name, sorts_ev, float(us.min()) / n_events, us / n_events)
        )

    by_name = {name: (sorts, best) for name, sorts, best, _ in rows}
    speedup_vs_seed = by_name["alloc_seed"][1] / by_name["alloc_fused_ref"][1]
    speedup_vs_unfused = (
        by_name["alloc_unfused"][1] / by_name["alloc_fused_ref"][1]
    )
    engine_speedup = engine_rows[0][2] / engine_rows[1][2]

    stats: dict[str, np.ndarray] = {}
    for name, sorts, _mean, us in rows:
        stats[f"{name}_us"] = us.reshape(1, -1)
        stats[f"{name}_sorts"] = np.array([[sorts]])
        stats[f"{name}_us_p50"] = np.array([[float(np.percentile(us, 50))]])
        stats[f"{name}_us_p95"] = np.array([[float(np.percentile(us, 95))]])
    for name, sorts_ev, _mean, us_ev in engine_rows:
        stats[f"{name}_us_per_event"] = us_ev.reshape(1, -1)
        stats[f"{name}_sorts_per_event"] = np.array([[sorts_ev]])
        stats[f"{name}_us_per_event_p50"] = np.array(
            [[float(np.percentile(us_ev, 50))]]
        )
        stats[f"{name}_us_per_event_p95"] = np.array(
            [[float(np.percentile(us_ev, 95))]]
        )
    stats["alloc_speedup_vs_seed"] = np.array([[speedup_vs_seed]])
    stats["alloc_speedup_vs_unfused"] = np.array([[speedup_vs_unfused]])
    stats["engine_speedup"] = np.array([[engine_speedup]])

    result = SweepResult(
        spec={
            "kind": "profile_engine",
            "m": m,
            "engine_m": engine_m,
            "p": p,
            "n_chips": n_chips,
            "min_chips": min_chips,
            "repeats": repeats,
            "policy": "hesrpt",
        },
        stats={"hesrpt": stats},
        wall_s=time.perf_counter() - t_start,
        compile_s=0.0,
        backend=jax.default_backend(),
        device_count=jax.device_count(),
        chunk_seeds=None,
        sharded=False,
    )
    if log:
        RUN_LOG.append(result.record())
    return rows, engine_rows, result


def run_superstep_lanes(m: int = 1000, p: float = 0.5,
                        n_servers: float = 64.0, rate: float = 1.0,
                        repeats: int = 5, log: bool = True):
    """Closed-form superstep vs the per-event scans, two lanes.

    - ``batch``: pre-arrived M jobs.  The generic scan walks M departure
      events; the superstep path is the zero-scan batch closed form
      (Thm 3/8 vectorized) — acceptance target >= 10x wall.
    - ``poisson``: M Poisson arrivals.  Generic and ranked scans walk
      2M events (admit + departure); the superstep scan walks M+1 steps
      (one per arrival, departures analytic) — target >= 1.5x end-to-end
      vs the generic scan (the ranked ratio is recorded for honesty: it
      already dodges the per-event sort, so the superstep's win there is
      the halved trip count and the transcendental-free body).

    Wall ratios land in ``BENCH_sweeps.json`` as ``superstep_speedup_wall``
    under ``kind="profile_superstep"`` records (one per lane).  Those ride
    tools/bench_diff.py's wall-time gate; the speedup *metrics* are
    machine-relative, deliberately outside the drift gate (same convention
    as the fused-allocate ratios above).
    """
    import jax
    import jax.numpy as jnp

    from repro.core import engine
    from repro.core.policies import make_policy, make_rank_policy
    from repro.core.scenarios import pareto_sizes, poisson_arrivals
    from repro.core.superstep import run_superstep
    from repro.core.sweeps import RUN_LOG, SweepResult

    key = jax.random.PRNGKey(0)
    kx, ka = jax.random.split(key)
    x = pareto_sizes(kx, m).astype(jnp.float64)
    rule = engine.continuous_rule(
        make_policy("hesrpt"), n_servers=n_servers, dtype=x.dtype
    )
    rank_pol = make_rank_policy("hesrpt")

    lanes = []
    for lane, arr, pre in (
        ("batch", jnp.zeros(m, x.dtype), True),
        ("poisson", poisson_arrivals(ka, m, rate).astype(x.dtype), False),
    ):
        t_start = time.perf_counter()
        n_events = m if pre else 2 * m  # generic scan horizon
        n_steps_ss = 0 if pre else m + 1  # superstep trips (+1 drain step)
        # run_ranked has no pre_arrived shortcut — its batch lane walks
        # the full 2M admit+departure horizon (recorded as its trip count).
        n_trips_ranked = 2 * m

        def f_generic(x0, at, *, _pre=pre):
            return engine.run(
                x0, at, p, rule, pre_arrived=_pre
            ).completion_times

        def f_ranked(x0, at):
            return engine.run_ranked(x0, at, p, n_servers, rank_pol)

        def f_superstep(x0, at, *, _pre=pre):
            return run_superstep(
                x0, at, p, n_servers, "hesrpt", pre_arrived=_pre
            ).completion_times

        variants = [
            ("generic", f_generic, n_events),
            ("ranked", f_ranked, n_trips_ranked),
            ("superstep", f_superstep, n_steps_ss),
        ]
        rows, stats = [], {}
        for name, f, trips in variants:
            import jax as _jax

            us = _time(_jax.jit(f), x, arr, repeats=repeats)
            best = float(us.min())
            ev_per_s = n_events / (best * 1e-6)  # events resolved, not trips
            rows.append((name, trips, best, ev_per_s, us))
            stats[f"{name}_us"] = us.reshape(1, -1)
            stats[f"{name}_scan_trips"] = np.array([[float(trips)]])
            stats[f"{name}_events_per_s"] = np.array([[ev_per_s]])
        by = {name: best for name, _t, best, _e, _u in rows}
        stats["superstep_speedup_wall"] = np.array(
            [[by["generic"] / by["superstep"]]]
        )
        stats["superstep_speedup_vs_ranked"] = np.array(
            [[by["ranked"] / by["superstep"]]]
        )
        result = SweepResult(
            spec={
                "kind": "profile_superstep",
                "lane": lane,
                "m": m,
                "p": p,
                "n_servers": n_servers,
                "rate": None if pre else rate,
                "repeats": repeats,
                "policy": "hesrpt",
            },
            stats={"hesrpt": stats},
            wall_s=time.perf_counter() - t_start,
            compile_s=0.0,
            backend=jax.default_backend(),
            device_count=jax.device_count(),
            chunk_seeds=None,
            sharded=False,
        )
        if log:
            RUN_LOG.append(result.record())
        lanes.append((lane, rows, result))
    return lanes


def main(smoke: bool = False):
    if smoke:
        rows, engine_rows, res = run(
            m=512, engine_m=256, repeats=5, n_chips=256
        )
        ss_lanes = run_superstep_lanes(m=1000, repeats=3)
    else:
        rows, engine_rows, res = run()
        ss_lanes = run_superstep_lanes()
    spec = res.spec
    lines = [
        f"components at M={spec['m']}, n_chips={spec['n_chips']}, "
        f"p={spec['p']} ({res.backend}, over {spec['repeats']} repeats):",
        f"{'component':>22s} {'sorts/call':>10s} {'us_min':>10s} "
        f"{'us_p50':>10s} {'us_p95':>10s}",
    ]
    for name, sorts, best, us in rows:
        p50, p95 = np.percentile(us, [50, 95])
        lines.append(
            f"{name:>22s} {sorts:10.0f} {best:10.1f} {p50:10.1f} {p95:10.1f}"
        )
    lines.append("")
    lines.append(f"full event scan at M={spec['engine_m']} (pre-arrived, "
                 f"{spec['engine_m']} events):")
    lines.append(f"{'variant':>22s} {'sorts/ev':>10s} {'us_min':>10s} "
                 f"{'us_p50':>10s} {'us_p95':>10s}")
    for name, sorts_ev, best_ev, us_ev in engine_rows:
        p50, p95 = np.percentile(us_ev, [50, 95])
        lines.append(
            f"{name:>22s} {sorts_ev:10.1f} {best_ev:10.1f} "
            f"{p50:10.1f} {p95:10.1f}"
        )
    st = res.stats["hesrpt"]
    vs_seed = float(st["alloc_speedup_vs_seed"][0, 0])
    vs_unfused = float(st["alloc_speedup_vs_unfused"][0, 0])
    eng = float(st["engine_speedup"][0, 0])
    lines.append("")
    lines.append(
        f"allocate speedup (fused ref vs seed 4-sort): {vs_seed:.2f}x "
        f"[target >= 1.5x: {'PASS' if vs_seed >= 1.5 else 'MISS'}]"
    )
    lines.append(
        f"allocate speedup (fused ref vs shipped unfused): "
        f"{vs_unfused:.2f}x"
    )
    lines.append(f"engine.run speedup (fused vs unfused): {eng:.2f}x")

    for lane, lrows, lres in ss_lanes:
        lst = lres.stats["hesrpt"]
        ss_m = lres.spec["m"]
        lines.append("")
        lines.append(
            f"superstep lane '{lane}' at M={ss_m} (continuous heSRPT, "
            f"N={lres.spec['n_servers']:.0f}):"
        )
        lines.append(
            f"{'variant':>22s} {'scan-trips':>10s} {'us_min':>10s} "
            f"{'events/s':>12s}"
        )
        for name, trips, best, ev_per_s, _us in lrows:
            lines.append(
                f"{name:>22s} {trips:10d} {best:10.1f} {ev_per_s:12.3g}"
            )
        wall = float(lst["superstep_speedup_wall"][0, 0])
        vs_ranked = float(lst["superstep_speedup_vs_ranked"][0, 0])
        target = 10.0 if lane == "batch" else 1.5
        lines.append(
            f"superstep speedup (vs generic scan): {wall:.2f}x "
            f"[target >= {target:.1f}x: "
            f"{'PASS' if wall >= target else 'MISS'}]"
        )
        lines.append(
            f"superstep speedup (vs ranked scan):  {vs_ranked:.2f}x"
        )
    return "\n".join(lines), res


if __name__ == "__main__":
    import json
    import sys

    import jax

    jax.config.update("jax_enable_x64", True)
    text, res = main(smoke="--smoke" in sys.argv)
    if "--json" in sys.argv:
        print(json.dumps(res.record(), indent=1))
    else:
        print(text)
