"""Beyond paper: the bounded-slot streaming engine at heavy traffic.

Three read-outs, each landing as a row in the ``BENCH_sweeps.json``
trajectory:

- **Horizon scaling** (:func:`horizon_scaling`): wall time and XLA
  temp-buffer footprint of :func:`repro.core.engine.run_stream_source`
  as the event budget grows at fixed ``n_slots``, next to the
  finite-tape :func:`repro.core.engine.run` on an equivalent tape.  The
  streaming engine's per-event cost and memory must stay flat in the
  horizon (the O(n_slots) claim); the tape engine's footprint grows with
  the job count.
- **load -> 1 ladder** (:func:`load_ladder`): a streaming ``Sweep`` over
  arrival rates climbing into saturation — windowed mean flow/slowdown
  per policy, the heavy-traffic regime the finite-tape sweeps cannot
  reach without O(horizon) memory.
- **Oracle cross-check** (:func:`oracle_check`): windowed engine
  aggregates under slot *recycling* (``n_slots`` far below the job
  count) against the per-event Python ``ClusterScheduler`` reference on
  the same tape, windowed identically host-side.

``python -m benchmarks.streaming [--quick|--smoke]``.
"""

from __future__ import annotations

import time

import numpy as np


def _temp_bytes(compiled) -> int:
    """XLA temp-buffer size of a compiled executable, or -1 if the
    backend does not expose a memory analysis (the scaling row then
    documents wall time only)."""
    try:
        return int(compiled.memory_analysis().temp_size_in_bytes)
    except Exception:
        return -1


def horizon_scaling(
    horizons=(1_000, 4_000, 16_000),
    *,
    n_slots: int = 32,
    rate: float = 4.0,
    p: float = 0.5,
    n_servers: float = 4.0,
    repeats: int = 3,
    log: bool = True,
):
    """Time + size the streaming scan per horizon; returns a SweepResult.

    ``stats["hesrpt"]`` rows are indexed by horizon (event budget):
    ``stream_us_per_event`` is ``[len(horizons), repeats]``;
    ``stream_temp_bytes``, ``tape_us_per_event``, ``tape_temp_bytes``
    and ``stream_completed`` are ``[len(horizons), 1]``.  The tape
    comparator runs :func:`repro.core.engine.run` on a ``horizon / 2``-job
    trace (a horizon of E events completes ~E/2 jobs), so the two
    columns face the same workload.
    """
    import jax
    import jax.numpy as jnp

    from benchmarks.arrivals import stream_trace
    from repro.core import engine
    from repro.core.policies import make_policy
    from repro.core.sweeps import RUN_LOG, SweepResult

    dtype = jnp.result_type(float)
    pol = make_policy("hesrpt")
    rule = engine.continuous_rule(pol, n_servers, dtype=dtype)

    rows = len(horizons)
    stream_us = np.zeros((rows, repeats))
    stream_bytes = np.zeros((rows, 1))
    tape_us = np.zeros((rows, repeats))
    tape_bytes = np.zeros((rows, 1))
    completed = np.zeros((rows, 1))
    t_start = time.perf_counter()
    compile_s = 0.0

    for hi, E in enumerate(horizons):
        def stream_fn(key, E=E):
            src = engine.poisson_source(key, rate, dtype=dtype)
            res = engine.run_stream_source(
                src, p, rule, n_slots=n_slots, n_events=E,
                n_alone=n_servers,
            )
            return res.n_completed, res.occupancy_max

        key = jax.random.PRNGKey(hi)
        t0 = time.perf_counter()
        c_stream = jax.jit(stream_fn).lower(key).compile()
        n_done, _ = jax.block_until_ready(c_stream(key))
        compile_s += time.perf_counter() - t0
        completed[hi, 0] = int(n_done)
        stream_bytes[hi, 0] = _temp_bytes(c_stream)
        for r in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(c_stream(key))
            stream_us[hi, r] = (time.perf_counter() - t0) * 1e6 / E

        # The finite-tape engine on the matching workload: E/2 jobs on a
        # materialized trace, horizon E — same event count, O(jobs) state.
        n_jobs = max(E // 2, 2)
        arr_np, x_np = stream_trace(n_jobs, rate, seed=hi)
        x0 = jnp.asarray(x_np, dtype)
        arr = jnp.asarray(arr_np, dtype)

        def tape_fn(x0, arr, E=E):
            return engine.run(x0, arr, p, rule, horizon=E).completion_times

        t0 = time.perf_counter()
        c_tape = jax.jit(tape_fn).lower(x0, arr).compile()
        jax.block_until_ready(c_tape(x0, arr))
        compile_s += time.perf_counter() - t0
        tape_bytes[hi, 0] = _temp_bytes(c_tape)
        for r in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(c_tape(x0, arr))
            tape_us[hi, r] = (time.perf_counter() - t0) * 1e6 / E

    result = SweepResult(
        spec={
            "kind": "streaming_horizon",
            "horizons": list(horizons),
            "n_slots": n_slots,
            "rate": rate,
            "p": p,
            "n_servers": n_servers,
            "repeats": repeats,
            "policy": "hesrpt",
        },
        stats={
            "hesrpt": {
                "stream_us_per_event": stream_us,
                "stream_temp_bytes": stream_bytes,
                "tape_us_per_event": tape_us,
                "tape_temp_bytes": tape_bytes,
                "stream_completed": completed,
            }
        },
        wall_s=time.perf_counter() - t_start,
        compile_s=compile_s,
        backend=jax.default_backend(),
        device_count=jax.device_count(),
        chunk_seeds=None,
        sharded=False,
    )
    if log:
        RUN_LOG.append(result.record())
    return result


def load_ladder(
    rates=(1.0, 2.0, 4.0, 8.0),
    *,
    policies=("hesrpt", "srpt", "equi"),
    n_jobs: int = 1000,
    n_seeds: int = 10,
    n_slots: int = 64,
    p: float = 0.5,
    log: bool = True,
):
    """Streaming sweep up the load ladder; windowed flow/slowdown rows."""
    from repro.core.sweeps import Sweep, run_sweep

    spec = Sweep.create(
        policies, rates, n_jobs=n_jobs, n_seeds=n_seeds, p=p,
        stream={"n_slots": n_slots},
        metrics=("stream_flow", "stream_slowdown", "stream_blocked",
                 "stream_occupancy"),
    )
    return run_sweep(spec, log=log)


def oracle_check(
    *,
    n_jobs: int = 120,
    n_slots: int = 24,
    rate: float = 2.0,
    p: float = 0.5,
    n_chips: int = 64,
    seed: int = 0,
) -> float:
    """Max relative windowed-mean-flow error, engine vs Python oracle.

    The engine recycles ``n_slots`` slots over an ``n_jobs``-deep tape;
    the :func:`benchmarks.arrivals.run_stream_reference` oracle replays
    the same tape per event on ``n_chips`` whole chips.  Both are
    windowed to the same stationary span host-side (jobs by arrival
    time), so the comparison covers admission deferral, recycling and
    the windowed accounting at once.  Also checks the continuous rule
    against the ``quantize=False`` oracle.
    """
    import jax.numpy as jnp

    from benchmarks.arrivals import run_stream_reference, stream_trace
    from repro.core import engine
    from repro.core.policies import make_policy

    arr_np, x_np = stream_trace(n_jobs, rate, seed)
    span = float(arr_np[-1])
    window = (0.1 * span, 0.9 * span)
    in_w = (arr_np >= window[0]) & (arr_np < window[1])
    dtype = jnp.result_type(float)
    pol = make_policy("hesrpt", n_servers=n_chips)
    worst = 0.0
    for quantize in (False, True):
        rule = (
            engine.quantized_rule(pol, n_chips, dtype=dtype)
            if quantize
            else engine.continuous_rule(pol, n_chips, dtype=dtype)
        )
        res = engine.run_stream(
            jnp.asarray(x_np, dtype), jnp.asarray(arr_np, dtype), p, rule,
            n_slots=n_slots, window=window, n_alone=n_chips,
        )
        flows = run_stream_reference(
            "hesrpt", arr_np, x_np, p=p, n_chips=n_chips, quantize=quantize,
        )
        ref = float(np.mean(flows[in_w]))
        got = float(res.mean_flow)
        worst = max(worst, abs(got - ref) / ref)
        assert int(res.n_window) == int(in_w.sum()), (
            "windowed completion count disagrees with the oracle tape"
        )
    return worst


def long_horizon(
    *,
    n_slots: int = 32,
    jobs_factor: int = 50,
    rate: float = 4.0,
    p: float = 0.5,
    n_servers: float = 4.0,
):
    """Slot-recycled run with >= ``jobs_factor`` x more jobs than slots.

    Returns ``(n_completed, occupancy_max, blocked_steps, temp_bytes)``
    from a single :func:`run_stream_source` scan whose event budget
    admits ``jobs_factor * n_slots`` jobs through the fixed pool — the
    acceptance run showing the engine is flat in the horizon.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import engine
    from repro.core.policies import make_policy

    dtype = jnp.result_type(float)
    rule = engine.continuous_rule(make_policy("hesrpt"), n_servers, dtype=dtype)
    n_events = int(2.4 * jobs_factor * n_slots)

    def fn(key):
        src = engine.poisson_source(key, rate, dtype=dtype)
        res = engine.run_stream_source(
            src, p, rule, n_slots=n_slots, n_events=n_events,
            n_alone=n_servers,
        )
        return res.n_completed, res.occupancy_max, res.blocked_steps

    key = jax.random.PRNGKey(7)
    compiled = jax.jit(fn).lower(key).compile()
    done, occ, blocked = jax.block_until_ready(compiled(key))
    assert int(done) >= jobs_factor * n_slots, (
        f"long-horizon run completed {int(done)} jobs, wanted "
        f">= {jobs_factor * n_slots}"
    )
    assert int(occ) <= n_slots, "occupancy exceeded the slot pool"
    return int(done), int(occ), int(blocked), _temp_bytes(compiled)


def main(quick: bool = False, smoke: bool = False):
    if smoke:
        horizons, repeats = (200, 800), 2
        rates, n_jobs, n_seeds, n_slots = (2.0, 8.0), 120, 2, 16
        oc_jobs, oc_slots = 60, 12
        lh_slots, lh_factor = 8, 50
    elif quick:
        horizons, repeats = (1_000, 4_000), 3
        rates, n_jobs, n_seeds, n_slots = (1.0, 4.0, 8.0), 400, 4, 32
        oc_jobs, oc_slots = 100, 20
        lh_slots, lh_factor = 16, 50
    else:
        horizons, repeats = (1_000, 4_000, 16_000, 64_000), 3
        rates, n_jobs, n_seeds, n_slots = (1.0, 2.0, 4.0, 8.0), 1000, 10, 64
        oc_jobs, oc_slots = 120, 24
        lh_slots, lh_factor = 32, 50

    lines = []
    hs = horizon_scaling(horizons, repeats=repeats)
    st = hs.stats["hesrpt"]
    lines.append(f"{'events':>8s} {'stream us/ev':>13s} {'tape us/ev':>11s} "
                 f"{'stream temp B':>13s} {'tape temp B':>12s} {'done':>6s}")
    for hi, E in enumerate(hs.spec["horizons"]):
        lines.append(
            f"{E:8d} {st['stream_us_per_event'][hi].mean():13.2f} "
            f"{st['tape_us_per_event'][hi].mean():11.2f} "
            f"{int(st['stream_temp_bytes'][hi, 0]):13d} "
            f"{int(st['tape_temp_bytes'][hi, 0]):12d} "
            f"{int(st['stream_completed'][hi, 0]):6d}"
        )

    ll = load_ladder(rates, n_jobs=n_jobs, n_seeds=n_seeds, n_slots=n_slots)
    lines.append(f"\nload ladder (n_slots={n_slots}, windowed means):")
    lines.append(f"{'rate':>6s} " + " ".join(
        f"{name:>10s}" for name in ll.spec.policies))
    for ri, rate in enumerate(ll.spec.rates):
        row = " ".join(
            f"{ll.stats[name]['stream_flow'][ri].mean():10.4f}"
            for name in ll.spec.policies
        )
        lines.append(f"{rate:6.2f} {row}")

    worst = oracle_check(n_jobs=oc_jobs, n_slots=oc_slots)
    lines.append(f"\noracle cross-check (slot-recycled, windowed): "
                 f"max rel err {worst:.2e}")
    assert worst < 1e-6, "streaming engine drifted from the per-event oracle"

    done, occ, blocked, temp_b = long_horizon(
        n_slots=lh_slots, jobs_factor=lh_factor)
    lines.append(
        f"long horizon: {done} jobs through {lh_slots} slots "
        f"({done // lh_slots}x recycle), peak occupancy {occ}, "
        f"{blocked} deferred admissions, temp {temp_b} B"
    )
    return "\n".join(lines), (hs, ll)


if __name__ == "__main__":
    import sys

    import jax

    jax.config.update("jax_enable_x64", True)
    text, _ = main(quick="--quick" in sys.argv, smoke="--smoke" in sys.argv)
    print(text)
