"""Beyond paper: heSRPT under an online arrival stream (the paper's §4.3
open question — it proves optimality only for all jobs present at t=0, and
suggests re-running heSRPT on the active set at each arrival; this benchmark
quantifies that heuristic in heavy traffic).

Jobs arrive Poisson(rate), sizes Pareto(1.5)+1.  At every arrival AND
departure epoch the policy recomputes allocations over the active set
(remaining sizes).  Mean flow time is compared across policies at several
system loads; each cell is the mean over seeds.

Two implementations:

- ``run_stream_reference`` / ``run_stream``: the original per-event Python
  loop over ``ClusterScheduler`` (one JAX dispatch per event).  Kept as the
  ground-truth reference for cross-checking and as the speedup baseline.
- ``repro.core.arrivals.simulate_online``: a single ``jax.lax.scan`` over
  the event horizon, jit + vmap over seeds × loads.  ``run``/``main`` use
  it to sweep 1000+ jobs × 100+ seeds × loads in one device call per
  policy — the heavy-traffic scale the Python loop cannot reach.
"""

from __future__ import annotations

import time

import numpy as np

POLICIES = ("hesrpt", "equi", "srpt")


def stream_trace(n_jobs: int, rate: float, seed: int, size_alpha: float = 1.5):
    """The benchmark's canonical random trace: Poisson arrivals, Pareto sizes."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n_jobs))
    sizes = rng.pareto(size_alpha, n_jobs) + 1.0
    return arrivals, sizes


def run_stream_reference(policy: str, arrivals, sizes, *, p=0.5, n_chips=256,
                         quantize=True, min_chips=1, return_events=False,
                         use_estimator=False, prior_p=None, est_discount=1.0,
                         est_prior_weight=1.0):
    """Per-event Python loop over ``ClusterScheduler``; returns per-job flow
    times.  ``quantize=False`` keeps fractional chips (the pure fluid model),
    which is what ``core/arrivals.py`` must reproduce to 1e-6; with
    ``quantize=True`` it is the whole-chips oracle the quantized engine is
    compared against event-for-event.  ``use_estimator=True`` runs the
    online-estimation regime (jobs start from ``prior_p`` and fit p from
    observed throughput; physics keep the true ``p``) — the per-event
    oracle ``benchmarks/estimation.py`` cross-checks the stateful engine
    rule against.  ``return_events=True`` additionally returns the
    allocation-event list ``[(t, {job_id: chips}), ...]``."""
    from repro.sched import ClusterScheduler, Job

    arrivals = np.asarray(arrivals, dtype=np.float64)
    sizes = np.asarray(sizes, dtype=np.float64)
    n_jobs = len(sizes)
    sched = ClusterScheduler(n_chips, policy=policy, quantize=quantize,
                             min_chips=min_chips, use_estimator=use_estimator,
                             est_discount=est_discount,
                             est_prior_weight=est_prior_weight)
    i = 0  # next arrival index
    guard = 0
    while i < n_jobs or sched.active_jobs():
        # admit everything that has arrived by now
        while i < n_jobs and arrivals[i] <= sched.time + 1e-12:
            sched.add_job(Job(f"j{i}", size=float(sizes[i]), p=p,
                              prior_p=prior_p))
            sched.jobs[f"j{i}"].arrival_time = float(arrivals[i])
            i += 1
        act = sched.active_jobs()
        if not act:
            sched.time = float(arrivals[i])  # idle until next arrival
            continue
        sched.allocations()
        # fluid-advance to the next departure, but clip at the next arrival
        # (job_rates: blended-p physics historically, per-job true p in the
        # estimator/class-aware regimes — identical values either way for
        # the uniform-p non-estimator case).
        r_arr = sched.job_rates(act)
        rates = {j.job_id: r for j, r in zip(act, r_arr, strict=True)}
        dts = [j.remaining / rates[j.job_id] for j in act if rates[j.job_id] > 0]
        dt = min(dts)
        if i < n_jobs:
            dt = min(dt, float(arrivals[i]) - sched.time)
        sched.advance_fluid(until_departure=False, dt=dt + 1e-15)
        guard += 1
        if guard > 50 * n_jobs:
            raise RuntimeError("arrival-stream sim did not converge")
    flows = np.array([
        j.completion_time - j.arrival_time for j in sched.jobs.values()
    ])
    if return_events:
        allocs = [(e["t"], e["chips"]) for e in sched.events
                  if e["event"] == "allocate"]
        return flows, allocs
    return flows


def run_stream(policy: str, *, n_jobs=60, rate=1.0, p=0.5, n_chips=256,
               seed=0, quantize=True):
    arrivals, sizes = stream_trace(n_jobs, rate, seed)
    flows = run_stream_reference(policy, arrivals, sizes, p=p,
                                 n_chips=n_chips, quantize=quantize)
    return float(np.mean(flows))


def cross_check(*, n_jobs=10, rate=1.0, p=0.5, n_chips=64, seed=0,
                policies=POLICIES) -> float:
    """Max relative per-job flow-time error: lax.scan simulator vs the
    Python ``ClusterScheduler`` fluid path (continuous allocation)."""
    import jax.numpy as jnp

    from repro.core import make_policy
    from repro.core.arrivals import simulate_online

    arrivals, sizes = stream_trace(n_jobs, rate, seed)
    worst = 0.0
    for name in policies:
        ref = run_stream_reference(name, arrivals, sizes, p=p,
                                   n_chips=n_chips, quantize=False)
        res = simulate_online(jnp.asarray(sizes), jnp.asarray(arrivals), p,
                              float(n_chips), make_policy(name, n_servers=n_chips))
        got = np.asarray(res.flow_times)
        worst = max(worst, float(np.max(np.abs(got - ref) / ref)))
    return worst


def run(rates=(0.5, 2.0, 8.0), policies=POLICIES, n_seeds=100, p=0.5,
        n_chips=256, n_jobs=1000, seed=0):
    """Heavy-traffic sweep on the JAX-native online simulator."""
    from repro.core.arrivals import load_sweep

    return load_sweep(policies, rates, n_jobs=n_jobs, n_seeds=n_seeds, p=p,
                      n_servers=float(n_chips), seed=seed)


def measure_speedup(*, n_jobs, n_seeds, rates, p=0.5, n_chips=256,
                    n_python_streams=1) -> dict:
    """Wall-clock: per-event Python loop vs the lax.scan sweep, per stream.

    The Python loop is timed on ``n_python_streams`` full-size streams of
    the same workload (same n_jobs / rate / policy) and normalized
    per-stream; running it on all streams would take hours, which is the
    point.  The JAX side is timed end-to-end on the whole sweep (compile
    excluded via a warmup at identical shapes).
    """
    rate_mid = rates[len(rates) // 2]
    t0 = time.perf_counter()
    for s in range(n_python_streams):
        # quantize=False: the same continuous fluid model the lax.scan
        # sweep simulates, so both sides do identical per-event work.
        run_stream("hesrpt", n_jobs=n_jobs, rate=rate_mid, p=p,
                   n_chips=n_chips, seed=s, quantize=False)
    t_py_stream = (time.perf_counter() - t0) / n_python_streams

    # warmup at identical shapes so the timed run excludes compilation
    run(rates=rates, n_seeds=n_seeds, p=p, n_chips=n_chips, n_jobs=n_jobs)
    t0 = time.perf_counter()
    run(rates=rates, n_seeds=n_seeds, p=p, n_chips=n_chips, n_jobs=n_jobs)
    t_jax_total = time.perf_counter() - t0

    n_streams = len(rates) * n_seeds * len(POLICIES)
    t_jax_stream = t_jax_total / n_streams
    return {
        "python_s_per_stream": t_py_stream,
        "jax_s_per_stream": t_jax_stream,
        "jax_total_s": t_jax_total,
        "n_streams": n_streams,
        "speedup": t_py_stream / t_jax_stream,
    }


def main(quick: bool = False, smoke: bool = False):
    rates = (0.5, 2.0, 8.0)
    n_jobs, n_seeds = (80, 8) if smoke else (200, 20) if quick else (1000, 100)

    t0 = time.perf_counter()
    res = run(rates=rates, n_seeds=n_seeds, n_jobs=n_jobs)
    sweep_s = time.perf_counter() - t0

    lines = [f"{n_jobs} jobs x {n_seeds} seeds x {len(rates)} loads x "
             f"{len(POLICIES)} policies (lax.scan online simulator, "
             f"{sweep_s:.1f}s incl. compile)"]
    lines.append(f"{'arrival rate':>12s} " + " ".join(f"{p:>10s}"
                                                      for p in POLICIES))
    ok = True
    for rate, row in res.items():
        lines.append(f"{rate:12.1f} " + " ".join(f"{row[p]:10.4f}"
                                                 for p in POLICIES))
        ok &= row["hesrpt"] <= min(row["equi"], row["srpt"]) * 1.02
    lines.append(f"heSRPT-heuristic <= best competitor at every load: {ok}")

    worst = cross_check()
    lines.append(f"cross-check vs ClusterScheduler fluid path (10-job "
                 f"Poisson, continuous): max rel err {worst:.2e}")
    assert worst < 1e-6, "online simulator diverged from ClusterScheduler"

    out = {"sweep": res, "cross_check": worst}
    if not smoke:  # the per-event Python baseline is minutes of wall clock
        sp = measure_speedup(n_jobs=n_jobs, n_seeds=n_seeds, rates=rates)
        out["speedup"] = sp
        lines.append(
            f"speedup vs per-event Python loop at equal workload: "
            f"{sp['speedup']:.0f}x  (python {sp['python_s_per_stream']:.2f}s/stream, "
            f"jax {sp['jax_s_per_stream'] * 1e3:.1f}ms/stream over "
            f"{sp['n_streams']} streams)")
    return "\n".join(lines), out


if __name__ == "__main__":
    import jax

    # Same rationale as benchmarks/run.py: scheduler math (cross-check vs
    # the f64 ClusterScheduler path) needs f64 to hit 1e-6 agreement.
    jax.config.update("jax_enable_x64", True)
    print(main()[0])
