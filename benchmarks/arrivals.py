"""Beyond paper: heSRPT as an online heuristic under a Poisson arrival
stream (the paper's §4.3 open question — it proves optimality only for all
jobs present at t=0, and suggests re-running heSRPT on the active set at
each arrival; this benchmark quantifies that heuristic).

Jobs arrive Poisson(rate), sizes Pareto(1.5)+1.  At every arrival AND
departure epoch the policy recomputes allocations over the active set
(remaining sizes).  Mean flow time is compared across policies at several
system loads; each cell is the mean over seeds.
"""

from __future__ import annotations

import numpy as np


def run_stream(policy: str, *, n_jobs=60, rate=1.0, p=0.5, n_chips=256,
               seed=0):
    from repro.sched import ClusterScheduler, Job

    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n_jobs))
    sizes = rng.pareto(1.5, n_jobs) + 1.0

    sched = ClusterScheduler(n_chips, policy=policy)
    i = 0  # next arrival index
    guard = 0
    while i < n_jobs or sched.active_jobs():
        # admit everything that has arrived by now
        while i < n_jobs and arrivals[i] <= sched.time + 1e-12:
            sched.add_job(Job(f"j{i}", size=float(sizes[i]), p=p))
            i += 1
        act = sched.active_jobs()
        if not act:
            sched.time = float(arrivals[i])  # idle until next arrival
            continue
        sched.allocations()
        # fluid-advance to the next departure, but clip at the next arrival
        pp = sched.effective_p()
        rates = {j.job_id: max(j.chips, 0) ** pp for j in act}
        dts = [j.remaining / rates[j.job_id] for j in act if rates[j.job_id] > 0]
        dt = min(dts)
        if i < n_jobs:
            dt = min(dt, float(arrivals[i]) - sched.time)
        sched.advance_fluid(until_departure=False, dt=dt + 1e-15)
        guard += 1
        if guard > 50 * n_jobs:
            raise RuntimeError("arrival-stream sim did not converge")
    flows = [
        j.completion_time - j.arrival_time for j in sched.jobs.values()
    ]
    return float(np.mean(flows))


def run(rates=(0.5, 2.0, 8.0), policies=("hesrpt", "equi", "srpt"),
        n_seeds=3, p=0.5, n_chips=256, n_jobs=60):
    out = {}
    for rate in rates:
        row = {}
        for pol in policies:
            vals = [
                run_stream(pol, n_jobs=n_jobs, rate=rate, p=p,
                           n_chips=n_chips, seed=s)
                for s in range(n_seeds)
            ]
            row[pol] = float(np.mean(vals))
        out[rate] = row
    return out


def main():
    res = run()
    lines = [f"{'arrival rate':>12s} " + " ".join(f"{p:>10s}" for p in
                                                  ("hesrpt", "equi", "srpt"))]
    ok = True
    for rate, row in res.items():
        lines.append(f"{rate:12.1f} " + " ".join(f"{row[p]:10.4f}" for p in
                                                 ("hesrpt", "equi", "srpt")))
        ok &= row["hesrpt"] <= min(row["equi"], row["srpt"]) * 1.02
    lines.append(f"heSRPT-heuristic <= best competitor at every load: {ok}")
    return "\n".join(lines), res


if __name__ == "__main__":
    print(main()[0])
