"""Accelerator benchmark lane: the same sweep specs on every backend.

Runs a fixed set of canonical sweep lanes on whatever backend jax sees —
CPU in CI, GPU/TPU when the container has one — and accumulates
backend-tagged throughput rows into ``BENCH_sweeps.json``, so the artifact
carries one comparable trajectory per backend instead of a CPU-only story.

Lanes (fixed specs; ``--smoke``/``--quick`` shrink sizes, not shapes):

- ``quantized`` — whole-chips heSRPT sweep on the unfused engine;
- ``quantized-fused`` — the identical spec through the ``kernels/alloc.py``
  fused allocate (``Sweep.create(..., fused=True)``), chip-exact, so the
  wall-clock delta is pure engine speed;
- ``continuous`` — the paper's divisible regime (no quantizer sorts to
  collapse; it rides along as the baseline lane).

The lane shape is a *wide rate grid with few seeds* — the accelerator
sweet spot — so multi-device hosts shard the rate axis
(``run_sweep(..., shard_axis="rates")``) where the CI smoke sweeps shard
seeds.  On CPU the fused lane's win is the measured sort collapse
(``benchmarks/profile_engine.py``); on an accelerator the recorded
``fused_speedup_wall`` row is the >=10x on-chip target's paper trail.

``python -m benchmarks.backend_lane [--smoke|--quick] [--no-append]
[--out BENCH_sweeps.json] [--json]``
"""

from __future__ import annotations

import json

import numpy as np

RATES_FULL = tuple(float(r) for r in np.geomspace(0.25, 16.0, 24).round(4))
RATES_QUICK = tuple(float(r) for r in np.geomspace(0.25, 16.0, 12).round(4))
RATES_SMOKE = (0.5, 1.0, 2.0, 4.0, 8.0)
N_CHIPS = 256


def lane_specs(smoke: bool = False, quick: bool = False):
    """The canonical lanes as ``(label, Sweep)`` pairs."""
    from repro.core.sweeps import Sweep

    if smoke:
        rates, n_jobs, n_seeds = RATES_SMOKE, 60, 2
    elif quick:
        rates, n_jobs, n_seeds = RATES_QUICK, 300, 4
    else:
        rates, n_jobs, n_seeds = RATES_FULL, 1000, 8
    common = dict(n_jobs=n_jobs, n_seeds=n_seeds, p=0.5,
                  n_servers=float(N_CHIPS), seed=0)
    return [
        ("quantized",
         Sweep.create(("hesrpt",), rates, n_chips=N_CHIPS, **common)),
        ("quantized-fused",
         Sweep.create(("hesrpt",), rates, n_chips=N_CHIPS, fused=True,
                      **common)),
        ("continuous", Sweep.create(("hesrpt",), rates, **common)),
    ]


def run_lanes(smoke: bool = False, quick: bool = False):
    """Run every lane on the current backend; returns ``[(label, result)]``.

    Multi-device hosts shard the rate axis; the results are identical to
    the single-device run (property-tested), only the wall clock moves.
    """
    import jax

    from repro.core.sweeps import run_sweep

    shard = jax.device_count() > 1
    out = []
    for label, spec in lane_specs(smoke=smoke, quick=quick):
        res = run_sweep(spec, shard=shard, shard_axis="rates", log=False)
        out.append((label, res))
    return out


def lane_records(lanes) -> list[dict]:
    """Backend-tagged rows for ``BENCH_sweeps.json``: one sweep record per
    lane (spec + cells + wall, ``lane`` added) plus one ``backend_lane``
    summary row carrying throughput and the fused/unfused wall ratio."""
    records = []
    by_label = {}
    for label, res in lanes:
        rec = res.record()
        rec["lane"] = label
        records.append(rec)
        by_label[label] = res
    q = by_label.get("quantized")
    qf = by_label.get("quantized-fused")
    summary = {
        "kind": "backend_lane",
        "backend": q.backend if q else "unknown",
        "device_count": q.device_count if q else 0,
        "lanes": {
            label: {
                "wall_s": res.wall_s,
                "compile_s": res.compile_s,
                "jobs_per_s": (
                    res.spec.total_jobs() * len(res.spec.policies)
                    / max(res.wall_s, 1e-9)
                ),
                "sharded": res.sharded,
            }
            for label, res in lanes
        },
        # The on-chip acceptance metric: fused/unfused wall ratio for the
        # identical quantized spec.  ~1.2-1.5x on CPU (sort collapse);
        # the accelerator target is >=10x (no host sorts at all).
        "fused_speedup_wall": (
            q.wall_s / max(qf.wall_s, 1e-9) if q and qf else None
        ),
        "fused_speedup_target": (
            10.0 if q and q.backend in ("gpu", "tpu") else None
        ),
    }
    records.append(summary)
    return records


def append_records(records: list[dict], path: str = "BENCH_sweeps.json") -> str:
    """Merge ``records`` into the artifact at ``path`` (create if absent)."""
    try:
        with open(path) as f:
            data = json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        data = {"records": []}
    data.setdefault("records", []).extend(records)
    with open(path, "w") as f:
        json.dump(data, f, indent=1)
    return path


def main(smoke: bool = False, quick: bool = False):
    lanes = run_lanes(smoke=smoke, quick=quick)
    records = lane_records(lanes)
    summary = records[-1]
    lines = [
        f"backend lane: {summary['backend']} x{summary['device_count']} "
        f"(rate-axis sharding: {lanes[0][1].sharded})",
        f"{'lane':>18s} {'rates':>6s} {'seeds':>6s} {'wall s':>8s} "
        f"{'compile s':>10s} {'jobs/s':>10s}",
    ]
    for label, res in lanes:
        row = summary["lanes"][label]
        lines.append(
            f"{label:>18s} {len(res.spec.rates):6d} {res.spec.n_seeds:6d} "
            f"{row['wall_s']:8.2f} {row['compile_s']:10.2f} "
            f"{row['jobs_per_s']:10.0f}"
        )
    fs = summary["fused_speedup_wall"]
    tgt = summary["fused_speedup_target"]
    lines.append(
        f"fused/unfused quantized wall ratio: {fs:.2f}x"
        + (f" (accelerator target >= {tgt:.0f}x)" if tgt else " (CPU lane)")
    )
    # Exactness across the lane: fused and unfused quantized sweeps must
    # agree bit-for-bit (same spec, same seeds, same chips).
    q = dict(lanes)["quantized"]
    qf = dict(lanes)["quantized-fused"]
    exact = all(
        np.array_equal(q.stats["hesrpt"][m], qf.stats["hesrpt"][m])
        for m in q.spec.metrics
    )
    lines.append(f"fused == unfused sweep outputs (bit-for-bit): {exact}")
    assert exact, "fused backend lane diverged from unfused sweep"
    return "\n".join(lines), records


if __name__ == "__main__":
    import sys

    import jax

    jax.config.update("jax_enable_x64", True)
    text, records = main(smoke="--smoke" in sys.argv,
                         quick="--quick" in sys.argv)
    if "--json" in sys.argv:
        print(json.dumps(records[-1], indent=1))
    else:
        print(text)
    if "--no-append" not in sys.argv:
        out = "BENCH_sweeps.json"
        if "--out" in sys.argv:
            out = sys.argv[sys.argv.index("--out") + 1]
        path = append_records(records, out)
        print(f"appended {len(records)} backend-tagged records to {path}")
