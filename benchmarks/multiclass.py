"""Beyond paper: multi-class workloads (per-class speedup + size + arrivals).

The paper's heSRPT assumes one job class; Berg et al. 2024 shows the
production regime is heterogeneous — classes differ in speedup exponent
``p_k`` and size distribution — and Berg et al. 2020 changes the objective
to mean *slowdown*.  This benchmark sweeps both through the unified engine
(``core/multiclass.py``): K = 2..4 class mixtures, >=1000 jobs x >=10 seeds
x >=2 loads x >=3 class-aware policies, each policy in ONE jit+vmap device
call, reporting per-class mean flow time and mean slowdown plus the gap
between class-aware and class-blind heSRPT on both objectives.

Sections:

- per-K sweeps: class-aware policies (heSRPT-per-class, class-weighted
  water-filling, slowdown-weighted heSRPT) vs the class-blind heSRPT
  baseline (true per-class physics, scheduler assumes one averaged p);
- slice-snapped quantized regime: the same multi-class engine with
  whole-chip allocations snapped to power-of-two ICI slices;
- cross-check: the engine's multi-class trajectory vs the per-event
  ``ClusterScheduler(class_aware=True)`` NumPy oracle — exact chips
  event-for-event for the quantized rule, <=1e-10 flow times for the
  continuous rule.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.multiclass import ClassSpec

POLICIES = ("hesrpt_pc", "waterfill", "hesrpt_sd", "hesrpt_blind")
RATES = (0.5, 2.0, 8.0)


def class_grid(K: int) -> tuple[ClassSpec, ...]:
    """K classes spanning the speedup/size heterogeneity range: exponents
    spread over [0.3, 0.85], heavier tails and larger scales for the more
    parallelizable classes (big elastic training jobs), equal arrival mix."""
    ps = np.linspace(0.3, 0.85, K)
    alphas = np.linspace(1.5, 2.5, K)
    scales = np.geomspace(1.0, 2.0 ** (K - 1), K)
    return tuple(
        ClassSpec(p=float(p), mix=1.0 / K, size_alpha=float(a), size_scale=float(s))
        for p, a, s in zip(ps, alphas, scales, strict=True)
    )


# --------------------------------------------------- per-event reference loop
def run_stream_reference_mc(
    policy: str,
    arrivals,
    sizes,
    p_jobs,
    class_ids,
    *,
    n_chips=64,
    quantize=True,
    min_chips=1,
    snap_slices=False,
    class_weights=None,
    return_events=False,
):
    """Per-event Python loop over ``ClusterScheduler(class_aware=True)`` —
    the multi-class twin of ``benchmarks.arrivals.run_stream_reference``
    (same admission epsilon / departure nudge / idle advance), with each
    job progressing at its OWN class exponent.  This is the NumPy oracle
    the multi-class engine path is cross-checked against."""
    from repro.sched import ClusterScheduler, Job

    arrivals = np.asarray(arrivals, dtype=np.float64)
    sizes = np.asarray(sizes, dtype=np.float64)
    p_jobs = np.asarray(p_jobs, dtype=np.float64)
    class_ids = np.asarray(class_ids)
    n_jobs = len(sizes)
    sched = ClusterScheduler(
        n_chips, policy=policy, quantize=quantize, min_chips=min_chips,
        snap_slices=snap_slices, class_aware=True, class_weights=class_weights,
    )
    i = 0  # next arrival index
    guard = 0
    while i < n_jobs or sched.active_jobs():
        while i < n_jobs and arrivals[i] <= sched.time + 1e-12:
            sched.add_job(
                Job(f"j{i}", size=float(sizes[i]), p=float(p_jobs[i]),
                    class_id=int(class_ids[i]))
            )
            sched.jobs[f"j{i}"].arrival_time = float(arrivals[i])
            i += 1
        act = sched.active_jobs()
        if not act:
            sched.time = float(arrivals[i])  # idle until next arrival
            continue
        sched.allocations()
        rates = sched.job_rates(act)  # per-job p: the true multi-class physics
        dts = [
            j.remaining / r for j, r in zip(act, rates, strict=True) if r > 0
        ]
        dt = min(dts)
        if i < n_jobs:
            dt = min(dt, float(arrivals[i]) - sched.time)
        sched.advance_fluid(until_departure=False, dt=dt + 1e-15)
        guard += 1
        if guard > 50 * n_jobs:
            raise RuntimeError("multi-class stream sim did not converge")
    flows = np.array(
        [sched.jobs[f"j{k}"].completion_time - sched.jobs[f"j{k}"].arrival_time
         for k in range(n_jobs)]
    )
    if return_events:
        allocs = [(e["t"], e["chips"]) for e in sched.events
                  if e["event"] == "allocate"]
        return flows, allocs
    return flows


def cross_check(
    policies=("hesrpt_pc", "waterfill", "hesrpt_sd"),
    *,
    n_jobs=12,
    rate=1.0,
    n_chips=64,
    seed=0,
    snap_slices=False,
    classes=None,
) -> dict:
    """Engine multi-class trajectory vs the class-aware ClusterScheduler.

    Quantized rule: integer chips must agree *exactly* at every decision
    epoch.  Continuous rule: per-job flow times to <=1e-10 relative (the
    reference loop advances with a +1e-15 nudge the scan does not need).
    """
    import jax
    import jax.numpy as jnp

    from benchmarks.quantized import engine_events
    from repro.core import engine as _engine
    from repro.core import make_scenario
    from repro.core.multiclass import (
        as_specs,
        class_rule,
        policy_weights,
        simulate_multiclass,
    )

    specs = as_specs(classes if classes is not None else class_grid(2))
    scn = make_scenario("multiclass_poisson", classes=specs)(
        jax.random.PRNGKey(seed), n_jobs, rate
    )
    arrivals = np.asarray(scn.arrival_times)
    sizes = np.asarray(scn.x0)
    p_jobs = np.asarray(scn.p_job)
    cls = np.asarray(scn.class_ids)

    worst_cont, worst_q, chips_ok, n_events = 0.0, 0.0, True, 0
    for name in policies:
        # --- continuous rule vs fractional-chips oracle
        flows_ref = run_stream_reference_mc(
            name, arrivals, sizes, p_jobs, cls, n_chips=n_chips, quantize=False
        )
        res = simulate_multiclass(
            scn, classes=specs, policy=name, n_servers=float(n_chips)
        )
        flows = np.asarray(res.flow_times)
        worst_cont = max(worst_cont, float(np.max(np.abs(flows - flows_ref)
                                                  / flows_ref)))
        # --- quantized rule vs whole-chips oracle, event-for-event
        flows_qref, allocs_ref = run_stream_reference_mc(
            name, arrivals, sizes, p_jobs, cls, n_chips=n_chips,
            quantize=True, snap_slices=snap_slices, return_events=True,
        )
        dtype = jnp.result_type(scn.x0.dtype, jnp.float32)
        order = jnp.argsort(scn.arrival_times)
        w = policy_weights(name, x0=scn.x0.astype(dtype))
        rule = class_rule(
            name, n_chips=n_chips, snap_slices=snap_slices, dtype=dtype,
            w=None if w is None else jnp.asarray(w, dtype)[order],
        )
        eng = _engine.run(
            scn.x0.astype(dtype), scn.arrival_times.astype(dtype),
            scn.p_job.astype(dtype), rule, record=True,
        )
        allocs_eng = engine_events(eng, arrivals)
        chips_ok &= len(allocs_eng) == len(allocs_ref)
        for (_, c_e), (_, c_r) in zip(allocs_eng, allocs_ref, strict=False):
            chips_ok &= c_e == c_r
        n_events += len(allocs_ref)
        flows_q = np.asarray(eng.completion_times) - arrivals
        worst_q = max(worst_q, float(np.max(np.abs(flows_q - flows_qref)
                                            / flows_qref)))
    return {
        "chips_exact": bool(chips_ok),
        "n_events": n_events,
        "worst_continuous_flow_rel": worst_cont,
        "worst_quantized_flow_rel": worst_q,
    }


# ----------------------------------------------------------------- the sweeps
def sweep(policies=POLICIES, rates=RATES, *, classes, n_jobs=1000, n_seeds=10,
          n_servers=256.0, seed=0, **kw):
    """Multi-class heavy-traffic sweep: delegates to ``multiclass_sweep``,
    itself a thin spec over ``core/sweeps.py`` (one compiled device call
    per policy); ``**kw`` forwards the regime knobs (scenario,
    n_chips/min_chips, snap_slices, chunking/sharding)."""
    from repro.core import multiclass_sweep

    return multiclass_sweep(
        policies, rates, classes=classes, n_jobs=n_jobs, n_seeds=n_seeds,
        n_servers=n_servers, seed=seed, **kw,
    )


def gap_rows(res: dict, rates) -> list[str]:
    """Class-aware vs class-blind heSRPT, both objectives, per load."""
    lines = []
    for metric, label in (("mean_flowtime", "flow"), ("mean_slowdown", "slowdown")):
        aware = {
            name: np.asarray(res[name][metric]).mean(axis=1)
            for name in res if name != "hesrpt_blind"
        }
        blind = np.asarray(res["hesrpt_blind"][metric]).mean(axis=1)
        best = {r: min(a[ri] for a in aware.values())
                for ri, r in enumerate(rates)}
        lines.append(
            f"  class-aware/class-blind mean {label}: " + "  ".join(
                f"{r:g}: {best[r] / blind[ri]:.3f}" for ri, r in enumerate(rates)
            )
        )
    return lines


def main(quick: bool = False, smoke: bool = False):
    if smoke:
        ks, n_jobs, n_seeds, rates = (2,), 80, 4, (0.5, 4.0)
    elif quick:
        ks, n_jobs, n_seeds, rates = (2, 3), 300, 8, (0.5, 2.0, 8.0)
    else:
        ks, n_jobs, n_seeds, rates = (2, 3, 4), 1000, 10, RATES

    lines = []
    all_res = {}
    for K in ks:
        classes = class_grid(K)
        t0 = time.perf_counter()
        res = sweep(rates=rates, classes=classes, n_jobs=n_jobs,
                    n_seeds=n_seeds)
        dt = time.perf_counter() - t0
        all_res[K] = res
        lines.append(
            f"K={K} classes (p_k = "
            + ", ".join(f"{c.p:.2f}" for c in classes)
            + f"): {n_jobs} jobs x {n_seeds} seeds x {len(rates)} loads x "
            f"{len(POLICIES)} policies, one jit+vmap call per policy "
            f"({dt:.1f}s incl. compile)"
        )
        lines.append(f"  {'rate':>8s} " + " ".join(f"{p:>14s}" for p in POLICIES)
                     + "   (mean flow time | mean slowdown)")
        for ri, r in enumerate(rates):
            cells = []
            for name in POLICIES:
                f = float(np.mean(np.asarray(res[name]["mean_flowtime"])[ri]))
                s = float(np.mean(np.asarray(res[name]["mean_slowdown"])[ri]))
                cells.append(f"{f:7.3f}|{s:6.2f}")
            lines.append(f"  {r:8.1f} " + " ".join(cells))
        lines.extend(gap_rows(res, rates))
        # per-class breakdown at the heaviest load, heSRPT-per-class
        cf = np.asarray(res["hesrpt_pc"]["class_flowtime"])[-1].mean(axis=0)
        cs = np.asarray(res["hesrpt_pc"]["class_slowdown"])[-1].mean(axis=0)
        lines.append(
            "  per-class (hesrpt_pc, heaviest load): "
            + "  ".join(
                f"k={k}: flow {cf[k]:.3f} slow {cs[k]:.2f}"
                for k in range(K)
            )
        )

    # slice-snapped quantized regime, K=2
    classes = class_grid(2)
    sq, ss = (
        sweep(("hesrpt_pc",), rates, classes=classes,
              n_jobs=min(n_jobs, 300), n_seeds=min(n_seeds, 8),
              n_chips=256, snap_slices=snap)
        for snap in (False, True)
    )
    ratio = [
        float(np.mean(np.asarray(ss["hesrpt_pc"]["mean_flowtime"])[ri])
              / np.mean(np.asarray(sq["hesrpt_pc"]["mean_flowtime"])[ri]))
        for ri in range(len(rates))
    ]
    lines.append(
        "slice-snapped / whole-chips mean flow time (hesrpt_pc, 256 chips): "
        + "  ".join(f"{r:g}: {g:.3f}" for r, g in zip(rates, ratio, strict=True))
    )

    cc = cross_check(n_jobs=12 if smoke else 14)
    lines.append(
        f"cross-check vs ClusterScheduler(class_aware=True), "
        f"{12 if smoke else 14}-job 2-class Poisson x 3 policies: chips exact "
        f"over {cc['n_events']} events: {cc['chips_exact']}, continuous flow "
        f"rel err {cc['worst_continuous_flow_rel']:.1e}, quantized flow rel "
        f"err {cc['worst_quantized_flow_rel']:.1e}"
    )
    assert cc["chips_exact"], "multi-class quantized engine diverged from oracle"
    assert cc["worst_continuous_flow_rel"] < 1e-10, cc
    assert cc["worst_quantized_flow_rel"] < 1e-9, cc
    return "\n".join(lines), {"sweeps": all_res, "cross_check": cc,
                              "snap_ratio": ratio}


if __name__ == "__main__":
    import jax

    # Same rationale as benchmarks/run.py: cross-checks against the f64
    # ClusterScheduler path need f64.
    jax.config.update("jax_enable_x64", True)
    print(main(quick=True)[0])
