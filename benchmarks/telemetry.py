"""Beyond paper: in-scan telemetry — what the probes see, at sweep scale.

Runs the canonical online sweep with the ``core/telemetry.py`` streaming
probe riding in the scan carry, so every ``BENCH_sweeps.json`` row from
this section carries time-weighted telemetry columns (``tel_*_mean`` /
``tel_*_max``) next to the flow-time metrics: system efficiency
(sum theta_i^p), utilization, queue length and allocation entropy, plus
the p-hat absolute-error probe on the estimator arm.  Also cross-checks
one trajectory's streaming aggregates against the full series read-out
reduced host-side (``analysis.time_weighted_stats``) — the O(1) stream
must agree with the O(E) series to float tolerance.

``python -m benchmarks.telemetry [--smoke]``; runs as a section of
``benchmarks/run.py`` (including ``--smoke``), logging ``kind="sweep"``
records whose specs carry the ``telemetry`` field.
"""

from __future__ import annotations

import time

import numpy as np

POLICIES = ("hesrpt", "equi")
RATES = (0.5, 2.0, 8.0)


def series_stream_crosscheck(*, n_jobs=60, rate=2.0, p=0.5, seed=0) -> float:
    """Max |stream - series| over every metric's mean/max on one run."""
    import jax
    import jax.numpy as jnp

    from repro.core import engine
    from repro.core.analysis import time_weighted_stats
    from repro.core.policies import make_policy
    from repro.core.scenarios import make_scenario
    from repro.core.telemetry import DEFAULT_METRICS, make_probe

    scn = make_scenario("poisson", p=p)(jax.random.key(seed), n_jobs, rate)
    rule = engine.continuous_rule(
        make_policy("hesrpt"), 1.0, dtype=jnp.result_type(float)
    )
    out = {}
    for mode in ("series", "stream"):
        probe = make_probe(DEFAULT_METRICS, mode=mode, n_jobs=n_jobs)
        out[mode] = engine.run(
            scn.x0, scn.arrival_times, p, rule, telemetry=probe
        ).telemetry
    series = {k: np.asarray(v) for k, v in out["series"].series.items()}
    agg = {k: np.asarray(v) for k, v in out["stream"].aggregates.items()}
    worst = 0.0
    for m in DEFAULT_METRICS:
        ref = time_weighted_stats(series[m], series["dt"])
        worst = max(
            worst,
            abs(float(agg[f"{m}_mean"]) - ref["mean"]),
            abs(float(agg[f"{m}_max"]) - ref["max"]),
        )
    return worst


def run(*, n_jobs, n_seeds, rates=RATES, p=0.5, seed=0):
    """The telemetry-instrumented sweeps this section logs: the online
    Poisson sweep with the default probe, and the estimator arm on the
    drift scenario with the p-hat error probe added."""
    from repro.core.sweeps import Sweep, run_sweep

    online = run_sweep(Sweep.create(
        list(POLICIES), list(rates), scenario="poisson", n_jobs=n_jobs,
        n_seeds=n_seeds, p=p, seed=seed, telemetry=True,
    ))
    est = run_sweep(Sweep.create(
        ["hesrpt"], [2.0], scenario="drift_poisson",
        scenario_kw={"p0": 0.7, "p1": 0.3}, n_jobs=n_jobs, n_seeds=n_seeds,
        seed=seed, arm="estimator",
        telemetry=("efficiency", "utilization", "queue", "p_hat_err"),
    ))
    return online, est


def main(quick: bool = False, smoke: bool = False):
    n_jobs, n_seeds = (60, 6) if smoke else (200, 10) if quick else (500, 20)
    t0 = time.perf_counter()
    online, est = run(n_jobs=n_jobs, n_seeds=n_seeds)
    sweep_s = time.perf_counter() - t0

    cols = ("tel_efficiency_mean", "tel_utilization_mean", "tel_queue_mean",
            "tel_queue_max", "tel_entropy_mean")
    lines = [
        f"{n_jobs} jobs x {n_seeds} seeds x {len(RATES)} loads, streaming "
        f"probe in-scan ({sweep_s:.1f}s incl. compile)",
        f"{'policy':>8s} {'rate':>6s} " + " ".join(f"{c[4:]:>16s}" for c in cols),
    ]
    for name in POLICIES:
        st = online.stats[name]
        for r, rate in enumerate(RATES):
            vals = (float(np.mean(st[c][r])) for c in cols)
            lines.append(f"{name:>8s} {rate:6.1f} "
                         + " ".join(f"{v:16.4f}" for v in vals))
    err = est.stats["hesrpt"]
    lines.append(
        "estimator arm (drift 0.7 -> 0.3): time-weighted |p_hat - p| "
        f"mean {float(np.mean(err['tel_p_hat_err_mean'])):.4f}, "
        f"max {float(np.max(err['tel_p_hat_err_max'])):.4f}"
    )

    worst = series_stream_crosscheck()
    lines.append(f"stream vs series aggregates (one 60-job run): "
                 f"max abs err {worst:.2e}")
    assert worst < 1e-9, "streaming aggregates diverged from the series"
    return "\n".join(lines), {"online": online, "estimator": est,
                              "cross_check": worst}


if __name__ == "__main__":
    import sys

    import jax

    # Same rationale as benchmarks/run.py: f64 so the stream/series
    # cross-check is limited by accumulation order, not f32 rounding.
    jax.config.update("jax_enable_x64", True)
    print(main(smoke="--smoke" in sys.argv)[0])
