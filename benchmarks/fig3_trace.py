"""Figure 3 reproduction: heSRPT trace for 3 jobs, s(k) = k^0.5, N = 500.

Emits the remaining-size and allocation trajectories (the paper plots
these); asserts the qualitative structure: SJF completion order, all jobs
held > 0 allocation while active, allocations constant between departures
and re-normalized upward at each departure.
"""

from __future__ import annotations

import numpy as np


def run(sizes=(3000.0, 2000.0, 1000.0), p: float = 0.5, n_servers: float = 500.0):
    import jax.numpy as jnp

    from repro.core import hesrpt, simulate

    x = jnp.asarray(sizes)
    res = simulate(x, p, n_servers, hesrpt)
    return {
        "completion_times": np.asarray(res.completion_times),
        "epoch_times": np.asarray(res.epoch_times),
        "theta_trace": np.asarray(res.theta_trace),
        "sizes_trace": np.asarray(res.sizes_trace),
    }


def main():
    out = run()
    lines = ["t_epoch | theta_1 theta_2 theta_3 | x_1 x_2 x_3"]
    for t, th, xs in zip(out["epoch_times"], out["theta_trace"],
                         out["sizes_trace"], strict=True):
        lines.append(
            f"{t:7.2f} | " + " ".join(f"{v:7.4f}" for v in th) + " | "
            + " ".join(f"{v:7.1f}" for v in xs)
        )
    ct = out["completion_times"]
    lines.append(f"completions: {np.round(ct, 2).tolist()} (SJF order: "
                 f"{bool(ct[2] <= ct[1] <= ct[0])})")
    # theta at epoch 0 from Thm 7 with m=3, p=.5: (1/9, 3/9, 5/9)
    expect = np.array([1 / 9, 3 / 9, 5 / 9])
    ok = np.allclose(out["theta_trace"][0], expect, rtol=1e-6)
    lines.append(f"epoch-0 allocation matches Thm 7 closed form: {ok}")
    return "\n".join(lines), out


if __name__ == "__main__":
    print(main()[0])
