"""Benchmark aggregator: one section per paper table/figure + beyond-paper
benches.  ``python -m benchmarks.run [--quick] [--smoke]
[--profile-dir DIR]``.

``--quick`` shrinks the expensive sweeps; ``--smoke`` is the CI tier-1
gate: every section that exercises the allocation engine runs at tiny
sizes (seconds, not minutes) so the sweeps cannot silently rot, and the
long-running extras (speedup timings, kernel micro-bench) are skipped.

``--profile-dir DIR`` wraps the whole run in ``jax.profiler.start_trace``:
the ``StepTraceAnnotation`` markers ``core/sweeps.py`` emits around each
compiled executor call (named by policy/scenario) then land in a
Perfetto-loadable trace under ``DIR`` — open it at https://ui.perfetto.dev
to see per-policy device time next to XLA's own slices.
"""

from __future__ import annotations

import os
import sys
import time
from pathlib import Path

import jax

# Scheduler math (closed forms vs simulation) wants f64; model/kernel code
# pins its own dtypes explicitly so this only affects the core benchmarks.
jax.config.update("jax_enable_x64", True)

# Persistent compilation cache: the benchmark sections recompile the same
# engine scans every run — cache the executables on disk so repeat runs
# (and CI, which restores the directory via actions/cache) skip straight
# to execution.  JAX_COMPILATION_CACHE_DIR overrides the repo-local
# default; threshold 0 caches even sub-second smoke-size programs.
jax.config.update(
    "jax_compilation_cache_dir",
    os.environ.get(
        "JAX_COMPILATION_CACHE_DIR",
        str(Path(__file__).resolve().parent.parent / ".jax_cache"),
    ),
)
jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)


def _section(title):
    print("\n" + "=" * 72)
    print(title)
    print("=" * 72, flush=True)


def main() -> None:
    smoke = "--smoke" in sys.argv
    quick = smoke or "--quick" in sys.argv
    profile_dir = None
    if "--profile-dir" in sys.argv:
        profile_dir = sys.argv[sys.argv.index("--profile-dir") + 1]
        jax.profiler.start_trace(profile_dir)
    t0 = time.time()

    _section("Fig 3 — heSRPT 3-job trace (s(k)=k^0.5, N=500)")
    from benchmarks import fig3_trace

    text, _ = fig3_trace.main()
    print(text)

    _section("Thm 8 — simulator vs closed-form optimal total flow time")
    from benchmarks import theorem8

    text, worst = theorem8.main()
    print(text)
    assert worst < 1e-6, "Theorem 8 closed form mismatch"

    _section("Thm 2 — heLRPT makespan closed form + tradeoff vs heSRPT")
    from benchmarks import makespan

    text, ok = makespan.main()
    print(text)
    assert ok, "Theorem 2 checks failed"

    _section("Fig 4 — heSRPT vs SRPT/EQUI/HELL/KNEE "
             + ("(quick)" if quick else "(paper scale: M=500, 10 seeds)"))
    from benchmarks import fig4_policies

    text, _ = fig4_policies.main(quick=quick)
    print(text)

    _section("Beyond paper — Poisson arrival stream at heavy traffic "
             + ("(smoke)" if smoke else
                "(quick)" if quick else "(1000 jobs x 100 seeds, lax.scan)"))
    from benchmarks import arrivals

    text, _ = arrivals.main(quick=quick, smoke=smoke)
    print(text)

    _section("Beyond paper — quantized whole-chips allocation at scale "
             + ("(smoke)" if smoke else
                "(quick)" if quick else "(1000 jobs x 20 seeds, lax.scan)"))
    from benchmarks import quantized

    text, _ = quantized.main(quick=quick, smoke=smoke)
    print(text)

    _section("Beyond paper — multi-class workloads (per-class p, slowdown) "
             + ("(smoke)" if smoke else
                "(quick)" if quick else "(1000 jobs x 10 seeds, K=2..4)"))
    from benchmarks import multiclass

    text, _ = multiclass.main(quick=quick, smoke=smoke)
    print(text)

    _section("Beyond paper — online p-hat estimation vs oracle/stale on "
             "p-drift " + ("(smoke)" if smoke else
                           "(quick)" if quick else
                           "(500 jobs x 20 seeds, 3 arms x 2 scenarios)"))
    from benchmarks import estimation

    text, _ = estimation.main(quick=quick, smoke=smoke)
    print(text)

    _section("Beyond paper — in-scan telemetry: streaming probes at sweep "
             "scale " + ("(smoke)" if smoke else
                         "(quick)" if quick else "(500 jobs x 20 seeds)"))
    from benchmarks import telemetry

    text, _ = telemetry.main(quick=quick, smoke=smoke)
    print(text)

    _section("Beyond paper — bounded-slot streaming engine: horizon scaling, "
             "load ladder, oracle " + ("(smoke)" if smoke else
                                       "(quick)" if quick else
                                       "(64k events, 1000 jobs x 10 seeds)"))
    from benchmarks import streaming

    text, _ = streaming.main(quick=quick, smoke=smoke)
    print(text)

    _section("Beyond paper — scan-body profile: sort counts + fused allocate "
             + ("(smoke)" if smoke else "(M=4096 components, M=1024 scan)"))
    from benchmarks import profile_engine

    text, _ = profile_engine.main(smoke=smoke)
    print(text)

    if not smoke:
        _section("Beyond paper — scheduler decision cost at cluster scale")
        from benchmarks import sched_scale

        text, _ = sched_scale.main()
        print(text)

        _section("Beyond paper — kernel micro-bench (CPU; TPU story = roofline)")
        from benchmarks import kernels_bench

        text, _ = kernels_bench.main()
        print(text)

    # Every run_sweep call above logged a structured record (spec, per-cell
    # stats, wall/compile time, backend); flush them so the perf trajectory
    # accumulates — CI uploads this file as a workflow artifact.
    from repro.core import sweeps

    path = sweeps.write_bench_json()
    print(f"\nwrote {len(sweeps.RUN_LOG)} sweep records to {path}")
    if profile_dir is not None:
        jax.profiler.stop_trace()
        print(f"profiler trace written under {profile_dir}")
    print(f"all benchmarks done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
