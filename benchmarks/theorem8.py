"""Theorem 8 validation: simulator vs closed-form optimal total flow time,
swept over M and p.  Reports max relative error (should be ~1e-9)."""

from __future__ import annotations

import numpy as np


def run(ms=(2, 5, 20, 100, 500), p_values=(0.05, 0.3, 0.5, 0.9, 0.99),
        n_servers: float = 1e6, seed: int = 0):
    import jax.numpy as jnp

    from repro.core import hesrpt, hesrpt_total_flowtime, simulate

    rows = []
    worst = 0.0
    rng = np.random.default_rng(seed)
    for m in ms:
        x = np.sort(rng.pareto(1.5, m) + 1.0)[::-1].copy()
        for p in p_values:
            closed = float(hesrpt_total_flowtime(jnp.asarray(x), p, n_servers))
            sim = float(simulate(jnp.asarray(x), p, n_servers, hesrpt).total_flowtime)
            rel = abs(sim - closed) / closed
            worst = max(worst, rel)
            rows.append((m, p, closed, sim, rel))
    return rows, worst


def main():
    rows, worst = run()
    lines = [f"{'M':>5s} {'p':>5s} {'closed-form':>14s} {'simulated':>14s} {'rel err':>10s}"]
    for m, p, closed, sim, rel in rows:
        lines.append(f"{m:5d} {p:5.2f} {closed:14.6g} {sim:14.6g} {rel:10.2e}")
    lines.append(f"max relative error: {worst:.2e}")
    return "\n".join(lines), worst


if __name__ == "__main__":
    print(main()[0])
