"""Theorem 8 validation: simulator vs closed-form optimal total flow time,
swept over M and p; plus the Berg-2020 slowdown analogue — the
slowdown-weighted policy (``hesrpt_sd``) vs the weighted Thm-8 closed form
(``core.flowtime.hesrpt_sd_mean_slowdown``).  Reports max relative error
(should be ~1e-9)."""

from __future__ import annotations

import numpy as np


def run(ms=(2, 5, 20, 100, 500), p_values=(0.05, 0.3, 0.5, 0.9, 0.99),
        n_servers: float = 1e6, seed: int = 0):
    import jax.numpy as jnp

    from repro.core import hesrpt, hesrpt_total_flowtime, simulate

    rows = []
    worst = 0.0
    rng = np.random.default_rng(seed)
    for m in ms:
        x = np.sort(rng.pareto(1.5, m) + 1.0)[::-1].copy()
        for p in p_values:
            closed = float(hesrpt_total_flowtime(jnp.asarray(x), p, n_servers))
            sim = float(simulate(jnp.asarray(x), p, n_servers, hesrpt).total_flowtime)
            rel = abs(sim - closed) / closed
            worst = max(worst, rel)
            rows.append((m, p, closed, sim, rel))
    return rows, worst


def run_slowdown(ms=(2, 5, 20, 100, 500),
                 p_values=(0.05, 0.3, 0.5, 0.9, 0.99),
                 n_servers: float = 1e6, seed: int = 0):
    """Berg-2020 objective: simulate the slowdown-weighted bracket policy
    (``hesrpt_sd`` = ``weighted_hesrpt`` with w = 1/x0) on the batch case
    and compare its mean slowdown against the weighted Thm-8 closed form."""
    import jax.numpy as jnp

    from repro.core import (
        hesrpt_sd_mean_slowdown,
        simulate,
        speedup,
        weighted_hesrpt,
    )

    rows = []
    worst = 0.0
    rng = np.random.default_rng(seed)
    for m in ms:
        x = np.sort(rng.pareto(1.5, m) + 1.0)[::-1].copy()
        xj = jnp.asarray(x)
        w = 1.0 / xj
        for p in p_values:
            closed = float(hesrpt_sd_mean_slowdown(xj, p, n_servers))
            res = simulate(xj, p, n_servers,
                           lambda xs, ps: weighted_hesrpt(xs, ps, w))
            sn = float(speedup(jnp.asarray(n_servers), p))
            sim = float(jnp.mean(res.completion_times * sn / xj))
            rel = abs(sim - closed) / closed
            worst = max(worst, rel)
            rows.append((m, p, closed, sim, rel))
    return rows, worst


def _table(rows, worst, value_label):
    lines = [f"{'M':>5s} {'p':>5s} {'closed-form':>14s} {'simulated':>14s} "
             f"{'rel err':>10s}"]
    for m, p, closed, sim, rel in rows:
        lines.append(f"{m:5d} {p:5.2f} {closed:14.6g} {sim:14.6g} {rel:10.2e}")
    lines.append(f"max relative error ({value_label}): {worst:.2e}")
    return lines


def main():
    rows, worst = run()
    lines = _table(rows, worst, "total flow time")
    sd_rows, sd_worst = run_slowdown()
    lines.append("")
    lines.append("Berg-2020 slowdown objective: hesrpt_sd simulation vs the "
                 "weighted Thm-8 closed form (mean slowdown)")
    lines += _table(sd_rows, sd_worst, "mean slowdown")
    return "\n".join(lines), max(worst, sd_worst)


if __name__ == "__main__":
    print(main()[0])
