"""Beyond paper: the integer-chips (quantized-allocation) regime at scale.

The paper's theta* treats the N servers as continuously divisible; a real
TPU pool hands out whole chips.  Historically that regime could only be
swept through the per-event Python ``ClusterScheduler`` loop — one JAX
dispatch per event.  The scan-based allocation engine (``core/engine.py``)
runs the same decision epoch (policy -> largest-remainder quantization with
a min-chips floor -> advance to next event) as a pure ``lax.scan`` step, so
the whole sweep — >=1000 jobs x >=20 seeds x 3 loads — is ONE jit+vmap
device call per policy (``load_sweep`` with ``n_chips=``).

Sections:

- heavy-traffic sweep of quantized heSRPT/EQUI, plus the quantization
  efficiency gap vs the continuous fluid at identical sample paths;
- scenario-registry showcase: the same quantized engine under bursty MAP
  arrivals and under size-estimation noise (``core/scenarios.py``);
- event-for-event cross-check: the engine's chips/epoch trajectory vs the
  per-event ``ClusterScheduler(quantize=True)`` loop on small instances
  (exact integer chips agreement; epoch times to float tolerance).
"""

from __future__ import annotations

import time

import numpy as np

POLICIES = ("hesrpt", "equi")
RATES = (0.5, 2.0, 8.0)


# --------------------------------------------------- per-event reference loop
def run_stream_events(policy: str, arrivals, sizes, *, p=0.5, n_chips=64,
                      min_chips=1):
    """Per-event Python loop over ``ClusterScheduler(quantize=True)`` —
    one shared implementation with the continuous cross-check
    (``benchmarks.arrivals.run_stream_reference``), so the subtle oracle
    details (admission epsilon, departure nudge, idle advance) exist once.

    Returns ``(flows, allocs)``: per-job flow times (input order) and the
    list of allocation events ``(t, {job_id: chips})`` — the ground truth
    the engine's quantized trajectory is compared against event-for-event.
    """
    from benchmarks.arrivals import run_stream_reference

    return run_stream_reference(policy, arrivals, sizes, p=p,
                                n_chips=n_chips, quantize=True,
                                min_chips=min_chips, return_events=True)


def engine_events(eng_result, arrivals):
    """Extract ``(t, {job_id: chips})`` per event from an engine trace,
    skipping idle/no-op steps (empty active set), in the reference loop's
    job naming."""
    order = np.asarray(eng_result.order)
    tr = eng_result.trace
    t_ev = np.asarray(tr.times)
    sizes_tr = np.asarray(tr.sizes)
    alloc = np.asarray(tr.alloc)
    arr_sorted = np.asarray(arrivals)[order]
    out = []
    for e in range(len(t_ev)):
        live = (arr_sorted <= t_ev[e] + 1e-12) & (sizes_tr[e] > 0)
        if not live.any():
            continue
        out.append((float(t_ev[e]),
                    {f"j{order[k]}": int(alloc[e, k])
                     for k in np.nonzero(live)[0]}))
    return out


def cross_check(policies=("hesrpt", "equi", "srpt"), *, n_jobs=12, rate=1.0,
                p=0.5, n_chips=64, seed=0) -> dict:
    """Engine quantized trajectory vs the ClusterScheduler per-event loop.

    Chips must agree *exactly* at every event; epoch times and per-job flow
    times to float tolerance (the reference loop advances with a +1e-15
    nudge the scan does not need).
    """
    import jax.numpy as jnp

    from benchmarks.arrivals import stream_trace
    from repro.core import make_policy, simulate_online_quantized

    arrivals, sizes = stream_trace(n_jobs, rate, seed)
    worst_t, worst_flow, chips_ok, n_events = 0.0, 0.0, True, 0
    for name in policies:
        flows_ref, allocs_ref = run_stream_events(
            name, arrivals, sizes, p=p, n_chips=n_chips)
        res, eng = simulate_online_quantized(
            jnp.asarray(sizes), jnp.asarray(arrivals), p, n_chips,
            make_policy(name, n_servers=float(n_chips)), record=True)
        allocs_eng = engine_events(eng, arrivals)
        chips_ok &= len(allocs_eng) == len(allocs_ref)
        for (t_e, c_e), (t_r, c_r) in zip(allocs_eng, allocs_ref, strict=False):
            chips_ok &= c_e == c_r
            worst_t = max(worst_t, abs(t_e - t_r) / max(t_r, 1e-12))
        n_events += len(allocs_ref)
        flows = np.array([float(res.flow_times[i]) for i in range(n_jobs)])
        ref = np.array([flows_ref[i] for i in range(n_jobs)])
        worst_flow = max(worst_flow, float(np.max(np.abs(flows - ref) / ref)))
    return {"chips_exact": bool(chips_ok), "n_events": n_events,
            "worst_epoch_time_rel": worst_t, "worst_flow_rel": worst_flow}


# --------------------------------------------------------------- the sweeps
def sweep(policies=POLICIES, rates=RATES, *, n_jobs=1000, n_seeds=20,
          p=0.5, n_chips=256, min_chips=1, seed=0):
    """Quantized heavy-traffic sweep: a thin spec over ``core/sweeps.py``
    (one compiled device call per policy), formatted as the historical
    ``{rate: {policy: mean}}`` table."""
    from repro.core.sweeps import Sweep, run_sweep

    spec = Sweep.create(policies, rates, n_jobs=n_jobs, n_seeds=n_seeds, p=p,
                        n_servers=float(n_chips), seed=seed, n_chips=n_chips,
                        min_chips=min_chips)
    return run_sweep(spec).cell_means()


def quantization_gap(rates=RATES, *, n_jobs=1000, n_seeds=20, p=0.5,
                     n_chips=256, seed=0, quantized=None) -> dict:
    """Mean-flow-time ratio quantized/continuous for heSRPT on identical
    sample paths — the price of whole chips.  Pass an existing quantized
    ``load_sweep`` result (with an ``"hesrpt"`` column) as ``quantized`` to
    avoid re-running the expensive whole-chips scan."""
    from repro.core import load_sweep

    q = quantized
    if q is None:
        q = load_sweep(("hesrpt",), rates, n_jobs=n_jobs, n_seeds=n_seeds,
                       p=p, n_servers=float(n_chips), seed=seed,
                       n_chips=n_chips)
    c = load_sweep(("hesrpt",), rates, n_jobs=n_jobs, n_seeds=n_seeds, p=p,
                   n_servers=float(n_chips), seed=seed)
    return {r: q[r]["hesrpt"] / c[r]["hesrpt"] for r in q}


def scenario_rows(rates=RATES, *, n_jobs=300, n_seeds=10, p=0.5,
                  n_chips=256, seed=0) -> dict:
    """The scenario registry driving the quantized engine: Poisson vs
    bursty MAP arrivals vs Poisson with size-estimation noise."""
    from repro.core import load_sweep

    out = {}
    for label, kw in (
        ("poisson", {}),
        ("bursty", {"scenario": "bursty"}),
        ("noisy-sizes", {"scenario_kw": {"sigma_size": 0.5}}),
    ):
        out[label] = load_sweep(
            ("hesrpt",), rates, n_jobs=n_jobs, n_seeds=n_seeds, p=p,
            n_servers=float(n_chips), seed=seed, n_chips=n_chips, **kw)
    return out


def main(quick: bool = False, smoke: bool = False):
    rates = RATES
    if smoke:
        n_jobs, n_seeds, s_jobs, s_seeds = 80, 4, 60, 4
    elif quick:
        n_jobs, n_seeds, s_jobs, s_seeds = 300, 10, 200, 8
    else:
        n_jobs, n_seeds, s_jobs, s_seeds = 1000, 20, 300, 10

    t0 = time.perf_counter()
    res = sweep(rates=rates, n_jobs=n_jobs, n_seeds=n_seeds)
    sweep_s = time.perf_counter() - t0
    lines = [f"{n_jobs} jobs x {n_seeds} seeds x {len(rates)} loads x "
             f"{len(POLICIES)} policies, whole-chips allocation "
             f"(one jit+vmap lax.scan call per policy, {sweep_s:.1f}s "
             f"incl. compile)"]
    lines.append(f"{'arrival rate':>12s} " + " ".join(f"{q:>10s}"
                                                      for q in POLICIES))
    ok = True
    for rate, row in res.items():
        lines.append(f"{rate:12.1f} " + " ".join(f"{row[q]:10.4f}"
                                                 for q in POLICIES))
        ok &= row["hesrpt"] <= row["equi"] * 1.02
    lines.append(f"quantized heSRPT <= quantized EQUI at every load: {ok}")

    gap = quantization_gap(rates=rates, n_jobs=n_jobs, n_seeds=n_seeds,
                           quantized=res)
    lines.append("whole-chips / continuous mean flow time (heSRPT): "
                 + "  ".join(f"{r:g}: {g:.3f}" for r, g in gap.items()))

    scn = scenario_rows(rates=rates, n_jobs=s_jobs, n_seeds=s_seeds)
    lines.append(f"scenario registry x quantized engine ({s_jobs} jobs x "
                 f"{s_seeds} seeds, heSRPT mean flow time):")
    for label, rows in scn.items():
        lines.append(f"  {label:>12s} " + " ".join(
            f"{rows[r]['hesrpt']:10.4f}" for r in rows))

    cc = cross_check()
    lines.append(
        f"event-for-event vs ClusterScheduler(quantize=True), 12-job "
        f"Poisson x 3 policies: chips exact over {cc['n_events']} events: "
        f"{cc['chips_exact']}, epoch-time rel err {cc['worst_epoch_time_rel']:.1e}, "
        f"flow rel err {cc['worst_flow_rel']:.1e}")
    assert cc["chips_exact"], "quantized engine diverged from ClusterScheduler"
    assert cc["worst_flow_rel"] < 1e-9, cc
    return "\n".join(lines), {"sweep": res, "gap": gap, "scenarios": scn,
                              "cross_check": cc}


if __name__ == "__main__":
    import jax

    # Same rationale as benchmarks/run.py: cross-checks against the f64
    # ClusterScheduler path need f64.
    jax.config.update("jax_enable_x64", True)
    print(main(quick=True)[0])
