"""Theorem 2 validation (heLRPT / makespan): ||X||_{1/p} closed form vs the
simulator, plus the makespan-vs-flowtime tradeoff against heSRPT."""

from __future__ import annotations

import numpy as np


def run(m: int = 50, p_values=(0.05, 0.3, 0.5, 0.9, 0.99),
        n_servers: float = 1e4, seed: int = 2):
    import jax.numpy as jnp

    from repro.core import helrpt, hesrpt, optimal_makespan, simulate

    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.pareto(1.5, m) + 1.0)
    rows = []
    for p in p_values:
        closed = float(optimal_makespan(x, p, n_servers))
        sim_lrpt = simulate(x, p, n_servers, helrpt)
        sim_srpt = simulate(x, p, n_servers, hesrpt)
        rows.append({
            "p": p,
            "makespan_closed": closed,
            "makespan_helrpt": float(sim_lrpt.makespan),
            "makespan_hesrpt": float(sim_srpt.makespan),
            "flow_helrpt": float(sim_lrpt.total_flowtime),
            "flow_hesrpt": float(sim_srpt.total_flowtime),
            "simultaneous": float(
                np.max(np.asarray(sim_lrpt.completion_times))
                - np.min(np.asarray(sim_lrpt.completion_times))
            ),
        })
    return rows


def main():
    rows = run()
    lines = [f"{'p':>5s} {'T*_max closed':>14s} {'heLRPT sim':>12s} "
             f"{'heSRPT mksp':>12s} {'spread':>10s}"]
    ok = True
    for r in rows:
        lines.append(
            f"{r['p']:5.2f} {r['makespan_closed']:14.6g} "
            f"{r['makespan_helrpt']:12.6g} {r['makespan_hesrpt']:12.6g} "
            f"{r['simultaneous']:10.2e}"
        )
        ok &= abs(r["makespan_helrpt"] - r["makespan_closed"]) / r["makespan_closed"] < 1e-6
        ok &= r["makespan_helrpt"] <= r["makespan_hesrpt"] * (1 + 1e-9)
        ok &= r["flow_hesrpt"] <= r["flow_helrpt"] * (1 + 1e-9)
    lines.append(f"Thm 1/2 hold (equal finishes, closed form, optimality): {ok}")
    return "\n".join(lines), ok


if __name__ == "__main__":
    print(main()[0])
