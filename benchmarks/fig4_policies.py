"""Figure 4 reproduction: heSRPT vs SRPT / EQUI / HELL / KNEE.

Paper setup: N = 1e6 servers, M = 500 jobs, sizes ~ Pareto(shape 1.5),
p in {.05, .3, .5, .9, .99}, 10 seeds, median of the mean flow times.
KNEE's alpha has no principled setting; like the paper we brute-force it
(log-spaced grid) and report its best — an optimistic KNEE.

Paper claims to validate: heSRPT wins every cell; >= ~30% over the best
competitor somewhere (KNEE at p=.3); EQUI ~2x worse at p=.99; SRPT ~10x
worse at p=.05.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def run(n_servers: float = 1e6, n_jobs: int = 500, n_seeds: int = 10,
        p_values=(0.05, 0.3, 0.5, 0.9, 0.99), pareto_shape: float = 1.5,
        n_alpha: int = 12, quick: bool = False):
    import jax
    import jax.numpy as jnp

    from repro.core import simulate

    if quick:
        n_jobs, n_seeds, n_alpha = 100, 3, 6

    from repro.core.policies import hell, knee
    from repro.core import equi, hesrpt, srpt

    # ONE compiled simulator per policy: p and alpha are traced arguments so
    # the alpha grid / p sweep never retrace (600 closures would otherwise
    # each compile their own 500-step scan).
    n_arr = jnp.asarray(n_servers)

    @jax.jit
    def flow_knee(x, p, alpha):
        def pol(xx, pp):
            return knee(xx, pp, n_servers=n_arr, alpha=alpha)

        return simulate(x, p, n_servers, pol).total_flowtime

    @jax.jit
    def flow_named(x, p, idx):
        branches = [
            lambda x, p: simulate(x, p, n_servers, hesrpt).total_flowtime,
            lambda x, p: simulate(x, p, n_servers, srpt).total_flowtime,
            lambda x, p: simulate(x, p, n_servers, equi).total_flowtime,
            lambda x, p: simulate(
                x, p, n_servers,
                lambda xx, pp: hell(xx, pp, n_servers=n_arr),
            ).total_flowtime,
        ]
        return jax.lax.switch(idx, branches, x, p)

    policies = ("hesrpt", "srpt", "equi", "hell", "knee")
    results = {}
    for p in p_values:
        meds = {}
        for pidx, name in enumerate(policies):
            flows = []
            for seed in range(n_seeds):
                rng = np.random.default_rng(seed)
                x = jnp.asarray(
                    np.sort(rng.pareto(pareto_shape, n_jobs) + 1.0)[::-1].copy()
                )
                if name == "knee":
                    best = min(
                        float(flow_knee(x, jnp.asarray(p), jnp.asarray(a)))
                        for a in np.logspace(-6, 2, n_alpha)
                    )
                    flows.append(best / n_jobs)
                else:
                    flows.append(
                        float(flow_named(x, jnp.asarray(p), pidx)) / n_jobs
                    )
            meds[name] = float(np.median(flows))
        results[p] = meds
    return results


def main(quick: bool = False):
    results = run(quick=quick)
    hdr = f"{'p':>5s} " + " ".join(f"{n:>12s}" for n in
                                   ("hesrpt", "srpt", "equi", "hell", "knee"))
    lines = [hdr]
    claims = []
    for p, meds in results.items():
        lines.append(
            f"{p:5.2f} " + " ".join(f"{meds[n]:12.4g}" for n in
                                    ("hesrpt", "srpt", "equi", "hell", "knee"))
        )
        best_comp = min(v for k, v in meds.items() if k != "hesrpt")
        claims.append((p, best_comp / meds["hesrpt"]))
    lines.append("")
    lines.append("heSRPT advantage vs best competitor per p: "
                 + ", ".join(f"p={p}: {adv:.2f}x" for p, adv in claims))
    # paper's headline: >=30% somewhere
    lines.append(f"max advantage: {max(a for _, a in claims):.2f}x "
                 f"(paper claims >= 1.3x)")
    return "\n".join(lines), results


if __name__ == "__main__":
    import sys

    text, _ = main(quick="--quick" in sys.argv)
    print(text)
