"""Beyond-paper: scheduler math at production scale.

Times the jitted theta computation + quantization at M up to 1e5 jobs —
the decision-epoch cost a cluster controller pays.  heSRPT is O(M log M)
(sort-dominated); this shows a 100k-job epoch decision is sub-second, i.e.
the policy is deployable at full-cluster scale.

Reports through :class:`repro.core.sweeps.SweepResult` (stats rows indexed
by M instead of arrival rate, per-repeat theta timings so the record
carries spread, not just a mean), so the M=1e5 epoch-decision timing lands
in the ``BENCH_sweeps.json`` trajectory alongside the simulator sweeps.
``python -m benchmarks.sched_scale --json`` prints the record.
"""

from __future__ import annotations

import time

import numpy as np


def run(ms=(100, 1_000, 10_000, 100_000), p: float = 0.5, n_chips: int = 4096,
        repeats: int = 5, log: bool = True):
    """Time theta + quantize per M; returns a ``SweepResult``.

    ``stats["hesrpt"]["theta_us"]`` is ``[len(ms), repeats]`` (one row per
    M, one column per timed repeat); ``quantize_us`` and ``chips_sum`` are
    ``[len(ms), 1]``.  ``log=True`` appends the compact record to the
    sweep run log (the ``BENCH_sweeps.json`` trajectory).
    """
    import jax
    import jax.numpy as jnp

    from repro.core import hesrpt
    from repro.core.sweeps import RUN_LOG, SweepResult
    from repro.sched.quantize import quantize_allocation

    theta_us = np.zeros((len(ms), repeats))
    quantize_us = np.zeros((len(ms), 1))
    chips_sum = np.zeros((len(ms), 1))
    f = jax.jit(hesrpt)
    t_start = time.perf_counter()
    compile_s = 0.0
    for mi, m in enumerate(ms):
        rng = np.random.default_rng(0)
        x = jnp.asarray(np.sort(rng.pareto(1.5, m) + 1.0)[::-1].copy())
        t0 = time.perf_counter()
        theta = f(x, p).block_until_ready()  # compile
        compile_s += time.perf_counter() - t0
        for r in range(repeats):
            t0 = time.perf_counter()
            theta = f(x, p).block_until_ready()
            theta_us[mi, r] = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        chips = quantize_allocation(np.asarray(theta), n_chips)
        quantize_us[mi, 0] = (time.perf_counter() - t0) * 1e6
        chips_sum[mi, 0] = int(chips.sum())
    result = SweepResult(
        spec={
            "kind": "sched_scale",
            "ms": list(ms),
            "p": p,
            "n_chips": n_chips,
            "repeats": repeats,
            "policy": "hesrpt",
        },
        stats={
            "hesrpt": {
                "theta_us": theta_us,
                "quantize_us": quantize_us,
                "chips_sum": chips_sum,
            }
        },
        wall_s=time.perf_counter() - t_start,
        compile_s=compile_s,
        backend=jax.default_backend(),
        device_count=jax.device_count(),
        chunk_seeds=None,
        sharded=False,
    )
    if log:
        RUN_LOG.append(result.record())
    return result


def main():
    res = run()
    ms = res.spec["ms"]
    stats = res.stats["hesrpt"]
    lines = [f"{'M':>8s} {'theta (us)':>12s} {'quantize (us)':>14s} "
             f"{'sum(chips)':>10s}"]
    for mi, m in enumerate(ms):
        lines.append(
            f"{m:8d} {stats['theta_us'][mi].mean():12.1f} "
            f"{stats['quantize_us'][mi, 0]:14.1f} "
            f"{int(stats['chips_sum'][mi, 0]):10d}"
        )
    return "\n".join(lines), res


if __name__ == "__main__":
    import json
    import sys

    text, res = main()
    if "--json" in sys.argv:
        print(json.dumps(res.record(), indent=1))
    else:
        print(text)
