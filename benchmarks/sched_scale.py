"""Beyond-paper: scheduler math at production scale.

Times the jitted theta computation + quantization at M up to 1e5 jobs —
the decision-epoch cost a cluster controller pays.  heSRPT is O(M log M)
(sort-dominated); this shows a 100k-job epoch decision is sub-second, i.e.
the policy is deployable at full-cluster scale.
"""

from __future__ import annotations

import time

import numpy as np


def run(ms=(100, 1_000, 10_000, 100_000), p: float = 0.5, n_chips: int = 4096,
        repeats: int = 5):
    import jax
    import jax.numpy as jnp

    from repro.core import hesrpt
    from repro.sched.quantize import quantize_allocation

    rows = []
    f = jax.jit(hesrpt)
    for m in ms:
        rng = np.random.default_rng(0)
        x = jnp.asarray(np.sort(rng.pareto(1.5, m) + 1.0)[::-1].copy())
        theta = f(x, p).block_until_ready()  # compile
        t0 = time.perf_counter()
        for _ in range(repeats):
            theta = f(x, p).block_until_ready()
        t_theta = (time.perf_counter() - t0) / repeats
        t0 = time.perf_counter()
        chips = quantize_allocation(np.asarray(theta), n_chips)
        t_quant = time.perf_counter() - t0
        rows.append({
            "M": m,
            "theta_us": t_theta * 1e6,
            "quantize_us": t_quant * 1e6,
            "chips_sum": int(chips.sum()),
        })
    return rows


def main():
    rows = run()
    lines = [f"{'M':>8s} {'theta (us)':>12s} {'quantize (us)':>14s} {'sum(chips)':>10s}"]
    for r in rows:
        lines.append(f"{r['M']:8d} {r['theta_us']:12.1f} {r['quantize_us']:14.1f} "
                     f"{r['chips_sum']:10d}")
    return "\n".join(lines), rows


if __name__ == "__main__":
    print(main()[0])
