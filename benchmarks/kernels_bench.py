"""Kernel micro-bench: CPU wall time of the jnp reference implementations
(flash/SSD/RG-LRU oracles) at smoke scale, plus interpret-mode kernel parity
timing.  On this CPU container the numbers are NOT TPU performance — the TPU
story is the dry-run roofline — but the bench keeps the kernels exercised
and regression-guarded end to end.
"""

from __future__ import annotations

import time

import numpy as np


def _time(f, *args, repeats=3):
    import jax

    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = f(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats * 1e6  # us


def run():
    import jax
    import jax.numpy as jnp

    from repro.kernels import ref
    from repro.kernels.ssd_scan import ssd_scan

    rng = np.random.default_rng(0)
    rows = []

    # attention oracle (the XLA path the dry-run lowers)
    B, Hq, Hkv, S, D = 1, 8, 2, 512, 64
    q = jnp.asarray(rng.standard_normal((B, Hq, S, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Hkv, S, D)), jnp.float32)
    att = jax.jit(lambda q, k, v: ref.attention(q, k, v, causal=True))
    rows.append(("attention_ref_512", _time(att, q, k, v)))

    # SSD: chunked kernel (interpret) vs sequential oracle
    B, S, H, P, N = 1, 256, 4, 32, 16
    x = jnp.asarray(rng.standard_normal((B, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, S, H)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    bm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    cm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    d = jnp.asarray(rng.standard_normal((H,)), jnp.float32)
    ssd_ref = jax.jit(lambda *args: ref.ssd(*args))
    rows.append(("ssd_ref_seq_256", _time(ssd_ref, x, dt, a, bm, cm, d)))
    rows.append((
        "ssd_pallas_interp_256",
        _time(lambda *args: ssd_scan(*args, block_q=64, interpret=True),
              x, dt, a, bm, cm, d),
    ))

    # RG-LRU oracle
    B, S, W = 2, 512, 64
    xr = jnp.asarray(rng.standard_normal((B, S, W)), jnp.float32)
    gx = jnp.asarray(rng.standard_normal((B, S, W)), jnp.float32)
    ga = jnp.asarray(rng.standard_normal((B, S, W)), jnp.float32)
    ap = jnp.asarray(rng.standard_normal((W,)), jnp.float32)
    rg = jax.jit(lambda *args: ref.rglru(*args))
    rows.append(("rglru_ref_512", _time(rg, xr, gx, ga, ap)))
    return rows


def main():
    rows = run()
    lines = [f"{'kernel':>24s} {'us/call':>12s}"]
    for name, us in rows:
        lines.append(f"{name:>24s} {us:12.0f}")
    return "\n".join(lines), rows


if __name__ == "__main__":
    print(main()[0])
